"""EPLB subsystem (core/placement.py): placement-table validation, replica
assignment, the heat -> greedy-rebalance policy, and — the load-bearing
contract — EP-path correctness under explicit placements:

* identity placement must be BITWISE-identical to the default contiguous
  layout across every backend (LL nccl_ep/deepep, HT flat/hierarchical,
  baseline) — outputs AND per-slot counts;
* rebalanced (permuted) and redundant (replicated) placements must still
  match the dense oracle, with replicas of one expert computing consistently;
* a redundant placement must reduce the measured max-per-rank received-token
  count on a synthetic hot-expert workload (the whole point of EPLB);
* replica-aware weight rebinding (checkpoint/store.py) round-trips across
  placements.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,
                        ep_dispatch, ep_combine)
from repro.core import placement as PL
from repro.core import plan as plan_mod
from repro.core.placement import (EpPlacement, identity_placement,
                                  redundant_placement, rebalance)

N, E, K, T, H = 8, 16, 4, 16, 32

BACKENDS = {
    "ll": dict(mode="ll"),
    "ll/deepep": dict(mode="ll", ll_layout="deepep"),
    "ht": dict(mode="ht"),
    "ht/hier": dict(mode="ht", ep_axis=("pod", "data"), ht_hierarchical=True),
    "baseline": dict(mode="baseline"),
}


# --------------------------------------------------------------------------
# table validation + derived tables
# --------------------------------------------------------------------------

def test_placement_validation():
    with pytest.raises(ValueError, match="no placement slot"):
        EpPlacement(4, ((0, 1), (2, 0)))          # expert 3 missing
    with pytest.raises(ValueError, match="out of range"):
        EpPlacement(4, ((0, 1), (2, 4)))
    with pytest.raises(ValueError, match="equal slot counts"):
        EpPlacement(4, ((0, 1, 2), (3,)))
    pl = EpPlacement(4, ((0, 1), (2, 3)))
    assert pl.is_identity() and pl.num_redundant == 0
    assert identity_placement(E, N).is_identity()
    red = EpPlacement(3, ((0, 1), (2, 0)))
    assert red.num_redundant == 1 and not red.is_identity()


def test_tables_and_assign_round_robin():
    # expert 0 replicated on ranks 0 and 1; assignment must round-robin by
    # source rank and the sentinel must map out of range
    pl = EpPlacement(3, ((0, 1), (2, 0)))
    tb = PL.tables(pl)
    np.testing.assert_array_equal(tb.replica_count[:-1], [2, 1, 1])
    r, s = PL.assign(pl, jnp.asarray([0, 0, 1, 2, 3]), jnp.asarray([0, 1, 5, 5, 0]))
    np.testing.assert_array_equal(np.asarray(r), [0, 1, 0, 1, 2])   # 3 -> sentinel rank N
    np.testing.assert_array_equal(np.asarray(s), [0, 1, 1, 0, 2])   # slot S for sentinel
    # primary replica = rank-major first occurrence
    np.testing.assert_array_equal(tb.primary_row, [0, 1, 2])


def test_fingerprint_distinguishes_table_and_version():
    a = identity_placement(E, N)
    b = dataclasses.replace(a, version=1)
    c = rebalance(np.arange(E, dtype=float), N)
    fps = {a.fingerprint(), b.fingerprint(), c.fingerprint()}
    assert len(fps) == 3 and all(f != 0 for f in fps)


# --------------------------------------------------------------------------
# heat + rebalancer policy
# --------------------------------------------------------------------------

def test_rebalance_reduces_imbalance_and_replicates_hottest():
    heat = np.ones(E)
    heat[0] = 40.0                          # one hot expert
    contiguous = PL.imbalance(PL.rank_loads(heat, None, N))
    pl = rebalance(heat, N, num_redundant=8)
    assert pl.num_redundant == 8
    # the hottest expert received the most replicas
    counts = PL.tables(pl).replica_count[:-1]
    assert counts[0] == counts.max() > 1
    assert PL.imbalance(PL.rank_loads(heat, pl)) < contiguous / 2
    # determinism
    assert rebalance(heat, N, num_redundant=8) == pl


def test_rebalance_spreads_hot_neighborhood_without_redundancy():
    # contiguous striping puts the 2 hot experts of rank 0 together; a pure
    # permutation (R=0) must split them across ranks
    heat = np.ones(E)
    heat[0] = heat[1] = 20.0                # both land on rank 0 contiguously
    contiguous = PL.imbalance(PL.rank_loads(heat, None, N))
    pl = rebalance(heat, N)
    assert pl.num_redundant == 0
    assert PL.imbalance(PL.rank_loads(heat, pl)) < contiguous


def test_heat_tracker_and_fold():
    tr = PL.HeatTracker(4, decay=0.5)
    tr.update([1.0, 0, 0, 0])
    tr.update([1.0, 2.0, 0, 0])
    np.testing.assert_allclose(tr.totals, [1.5, 2.0, 0, 0])
    with pytest.raises(ValueError):
        tr.update(np.zeros(5))
    # fold per-slot counts: replicas of expert 0 sum
    pl = EpPlacement(3, ((0, 1), (2, 0)))
    heat = PL.fold_slot_counts(pl, [[5, 1], [2, 3]])
    np.testing.assert_array_equal(heat, [8, 1, 2])
    np.testing.assert_array_equal(PL.fold_slot_counts(None, [[5, 1], [2, 3]]),
                                  [5, 1, 2, 3])
    h = PL.heat_from_topk(jnp.asarray([[0, 1], [1, 3]]), 3)  # 3 = sentinel
    np.testing.assert_array_equal(np.asarray(h), [1, 2, 0])


def test_rebalance_scheduler_dedups_unchanged_tables():
    """Steady traffic: when the rebalancer reproduces the current slot table
    the scheduler must return the SAME placement object (stable fingerprint
    -> compiled-fn caches hit, refresh fast path survives the boundary);
    shifted traffic must produce a new object with a bumped version."""
    heat = np.ones(E)
    heat[0] = 30.0
    sched = PL.RebalanceScheduler(E, N, num_redundant=8)
    sched.observe(heat)
    p1 = sched.advance()
    assert p1 is not None and p1.version == 1
    sched.observe(heat)                      # same distribution
    assert sched.advance() is p1
    shifted = np.ones(E)
    shifted[E - 1] = 500.0                   # dominant expert moves
    sched.observe(shifted)
    p2 = sched.advance()
    assert p2 is not p1 and p2.version == 2
    assert p2.fingerprint() != p1.fingerprint()


def test_group_config_validation():
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", num_redundant_experts=8)
    with pytest.raises(ValueError, match="requires an explicit placement"):
        ep_create_group(cfg, ep_size=N)
    pl = redundant_placement(E, N, 8)
    bad = dataclasses.replace(cfg, placement=pl, num_redundant_experts=4)
    with pytest.raises(ValueError, match="contradicts"):
        ep_create_group(bad, ep_size=N)
    good = dataclasses.replace(cfg, placement=pl, num_redundant_experts=0)
    g = ep_create_group(good, ep_size=N)
    assert g.local_experts == (E + 8) // N and g.physical_experts == E + 8
    assert g.placement_salt == pl.fingerprint() != 0
    with pytest.raises(ValueError, match="spans"):
        ep_create_group(dataclasses.replace(cfg, num_redundant_experts=0,
                                            placement=identity_placement(E, 4)),
                        ep_size=N)


# --------------------------------------------------------------------------
# EP-path correctness under placements, all backends
# --------------------------------------------------------------------------

def oracle(x, topk, w):
    return x * (w * (1.0 + topk)).sum(-1)[..., None]


def rand_inputs(rng):
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    return x, topk, w


def run_ep(kw, placement, x, topk, w):
    """Full dispatch -> scale-by-LOGICAL-expert -> combine cycle; returns
    (out [N, T, H], counts [N, L]). Scaling uses the placement's slot_expert
    table so replicas of one expert compute identically."""
    hier = len(kw.get("ep_axis", ("data",))) > 1
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, payload_dtype=jnp.float32,
                        placement=placement, **kw)
    group = ep_create_group(cfg, ep_size=N, inner_size=4 if hier else None)
    L = group.local_experts
    if placement is None:
        se = jnp.arange(E, dtype=jnp.int32).reshape(N, L)
    else:
        se = jnp.asarray(PL.tables(placement).slot_expert)
    if hier:
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        spec = P(("pod", "data"))
    else:
        mesh = jax.make_mesh((N,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        spec = P("data")

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ep_create_handle(group, topk, w)
        y3d, counts = ep_dispatch(group, h, x)
        me = plan_mod.my_rank(group)
        y3d = y3d * (1.0 + se[me])[:, None, None].astype(y3d.dtype)
        return ep_combine(group, h, y3d)[None], counts[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(spec,) * 3,
                              out_specs=(spec, spec)))
    out, counts = f(x, topk, w)
    return (np.asarray(out).reshape(N, T, H),
            np.asarray(counts).reshape(N, L))


@pytest.mark.parametrize("name", sorted(BACKENDS), ids=sorted(BACKENDS))
def test_identity_placement_bitwise_matches_contiguous(name):
    """The acceptance pin: an explicit identity placement routes through the
    placement tables yet must be bitwise-identical — outputs and counts — to
    the default contiguous arithmetic, for every backend."""
    rng = np.random.RandomState(0)
    x, topk, w = rand_inputs(rng)
    base, cb = run_ep(BACKENDS[name], None, x, topk, w)
    ident, ci = run_ep(BACKENDS[name], identity_placement(E, N), x, topk, w)
    np.testing.assert_array_equal(base, ident)
    np.testing.assert_array_equal(cb, ci)
    np.testing.assert_allclose(base, np.asarray(oracle(x, topk, w)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", sorted(BACKENDS), ids=sorted(BACKENDS))
@pytest.mark.parametrize("kind", ["rebalanced", "redundant"])
def test_placed_ep_matches_oracle(name, kind):
    """Permuted and replicated placements still produce oracle-exact results:
    replica selection resolves at plan time, both endpoints agree, and
    replicas of one expert compute the same logical function."""
    rng = np.random.RandomState(1)
    x, topk, w = rand_inputs(rng)
    heat = np.ones(E)
    heat[:4] += 100.0 * rng.rand(4)        # hot first-rank neighborhood
    pl = (rebalance(heat, N) if kind == "rebalanced"
          else rebalance(heat, N, num_redundant=8))
    out, counts = run_ep(BACKENDS[name], pl, x, topk, w)
    np.testing.assert_allclose(out, np.asarray(oracle(x, topk, w)),
                               rtol=2e-5, atol=2e-5)
    # conservation: every routed entry lands exactly once
    assert counts.sum() == N * T * K


def test_redundant_placement_reduces_max_rank_recv():
    """On a synthetic hot-expert workload the rebalanced+replicated placement
    must reduce the measured max-per-rank received-token count vs contiguous
    — the EPLB acceptance criterion, measured from real recv counts."""
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    # skewed routing: expert 0 in every token's top-k
    topk = np.stack([np.stack([np.concatenate(
        [[0], rng.choice(np.arange(1, E), K - 1, replace=False)])
        for _ in range(T)]) for _ in range(N)])
    topk = jnp.asarray(topk, jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)

    _, c_base = run_ep(BACKENDS["ht"], None, x, topk, w)
    heat = PL.fold_slot_counts(None, c_base)
    pl = rebalance(heat, N, num_redundant=8)
    _, c_reb = run_ep(BACKENDS["ht"], pl, x, topk, w)
    assert c_reb.sum() == c_base.sum() == N * T * K
    max_base = c_base.sum(axis=1).max()
    max_reb = c_reb.sum(axis=1).max()
    assert max_reb < max_base, (max_base, max_reb)
    # folding physical counts recovers the logical heat
    np.testing.assert_array_equal(PL.fold_slot_counts(pl, c_reb), heat)


# --------------------------------------------------------------------------
# replica-aware weight rebinding (checkpoint/store.py)
# --------------------------------------------------------------------------

def test_expand_collapse_round_trip():
    rng = np.random.RandomState(3)
    w = jnp.asarray(rng.randn(E, 5), jnp.float32)
    pl = redundant_placement(E, N, 8)
    w_phys = PL.expand_expert_params(w, pl)
    assert w_phys.shape == (E + 8, 5)
    np.testing.assert_array_equal(np.asarray(PL.collapse_expert_params(w_phys, pl)),
                                  np.asarray(w))
    # every physical slot holds its logical expert's weights
    se = PL.tables(pl).slot_expert.reshape(-1)
    np.testing.assert_array_equal(np.asarray(w_phys), np.asarray(w)[se])


def test_adopt_expert_params_spec_driven_axes():
    """Spec-driven adoption (checkpoint.adopt_expert_params): leaves whose
    ParamSpec names an "expert" axis rebind along THAT axis — scan-stacked
    [n_layers, slots, ...] weights included — and physical -> logical
    collapse after any chain of adoptions recovers the logical weights
    bitwise (replica invariant)."""
    from repro.checkpoint import adopt_expert_params
    from repro.parallel.sharding import ParamSpec
    rng = np.random.RandomState(5)
    logical = dict(stacked=jnp.asarray(rng.randn(3, E, 4), jnp.float32),
                   flat=jnp.asarray(rng.randn(E, 2), jnp.float32),
                   other=jnp.asarray(rng.randn(7), jnp.float32))
    specs = dict(stacked=ParamSpec((3, E, 4), jnp.float32,
                                   ("stack", "expert", None)),
                 flat=ParamSpec((E, 2), jnp.float32, ("expert", None)),
                 other=ParamSpec((7,), jnp.float32, (None,)))
    pl_a = redundant_placement(E, N, 8)
    pl_b = rebalance(np.arange(E, dtype=float) + 1.0, N, num_redundant=16)
    phys_a = adopt_expert_params(logical, specs, None, pl_a)
    assert phys_a["stacked"].shape == (3, E + 8, 4)
    assert phys_a["flat"].shape == (E + 8, 2)
    se_a = PL.tables(pl_a).slot_expert.reshape(-1)
    np.testing.assert_array_equal(np.asarray(phys_a["stacked"]),
                                  np.asarray(logical["stacked"])[:, se_a])
    # adopt a -> b, then collapse: logical weights recovered bitwise
    phys_b = adopt_expert_params(phys_a, specs, pl_a, pl_b)
    back = adopt_expert_params(phys_b, specs, pl_b, None)
    for k in logical:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(logical[k]))


def test_physical_checkpoint_layout_recorded_and_validated(tmp_path):
    """save_checkpoint(placement=...) records the physical layout in the
    index; restore validates the fingerprint and rebinds to whatever layout
    the restoring process requests (as-stored / other placement / logical),
    and a spec-target shape mismatch from an unrequested rebind fails
    loudly instead of restoring garbage."""
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.parallel.sharding import ParamSpec
    rng = np.random.RandomState(7)
    w = rng.randn(E, 4).astype(np.float32)
    pl_a = redundant_placement(E, N, 8)
    pl_b = rebalance(np.arange(E, dtype=float) + 1.0, N, num_redundant=16)
    w_a = PL.expand_expert_params(w, pl_a)
    tree = dict(w_gate=w_a, step=np.int64(9))
    save_checkpoint(tmp_path, 2, tree, placement=pl_a,
                    expert_keys=("w_gate",))
    # as-stored (default): physical layout untouched, fingerprint readable
    got, idx = restore_checkpoint(tmp_path, 2, tree)
    assert idx["expert_layout"]["fingerprint"] == pl_a.fingerprint()
    assert PL.placement_from_jsonable(
        idx["expert_layout"]["placement"]) == pl_a
    np.testing.assert_array_equal(np.asarray(got["w_gate"]), w_a)
    # elastic re-place: restore under a DIFFERENT placement
    got_b, _ = restore_checkpoint(tmp_path, 2, tree, placement=pl_b)
    se_b = PL.tables(pl_b).slot_expert.reshape(-1)
    np.testing.assert_array_equal(np.asarray(got_b["w_gate"]), w[se_b])
    # back to logical (placement-independent restart) — host leaf stays
    # numpy int64 (dtype hygiene)
    got_l, _ = restore_checkpoint(tmp_path, 2, tree, placement=None)
    np.testing.assert_array_equal(np.asarray(got_l["w_gate"]), w)
    assert got_l["step"].dtype == np.int64
    # a LOGICAL tree mislabeled as physical is refused at SAVE time whenever
    # the shape betrays it (redundant placements change the row count) —
    # before the filesystem is touched, so no stale .tmp dir is left
    with pytest.raises(ValueError, match="physical layout"):
        save_checkpoint(tmp_path, 3, dict(w_gate=w, step=np.int64(1)),
                        placement=pl_a, expert_keys=("w_gate",))
    assert not list(tmp_path.glob("*.tmp"))
    # a spec target whose shape doesn't match the restored layout trips the
    # validation (catches placement mismatches at restore, not at serve)
    bad_spec = dict(w_gate=ParamSpec((E, 4), jnp.float32, ("expert", None)),
                    step=np.int64(0))
    with pytest.raises(ValueError, match="placement"):
        restore_checkpoint(tmp_path, 2, bad_spec)
    # a SCAN-STACKED expert leaf saved as a plain array cannot be rebound
    # key-based (axis 0 is the layer axis): restore refuses loudly and
    # points at the spec-driven path instead of corrupting weights
    stacked = dict(w_gate=np.stack([w_a] * 3), step=np.int64(9))
    save_checkpoint(tmp_path, 4, stacked, placement=pl_a,
                    expert_keys=("w_gate",))
    with pytest.raises(ValueError, match="ParamSpec"):
        restore_checkpoint(tmp_path, 4, stacked, placement=None)
    # ...and the spec-driven target rebinds it fine
    sp = dict(w_gate=ParamSpec((3, E, 4), jnp.float32,
                               ("stack", "expert", None)),
              step=np.int64(0))
    got_s, _ = restore_checkpoint(tmp_path, 4, sp, placement=None)
    np.testing.assert_array_equal(np.asarray(got_s["w_gate"]),
                                  np.stack([w] * 3))


def test_rebalancing_decode_adopt_once_matches_expansion():
    """Driver-level adopt-once: run_rebalancing with ``params`` rebinds the
    expert leaves once per adopted placement; outputs must be bitwise-equal
    to the per-step in-graph expansion variant under the same placement
    schedule (the heat streams are identical)."""
    from jax.sharding import PartitionSpec as P2
    from repro.runtime.decode import rebalancing_decode_loop
    rng = np.random.RandomState(8)
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    router_w = jnp.asarray(rng.randn(H, E), jnp.float32)
    bump = jnp.zeros((E,)).at[:4].set(3.0)
    w_log = jnp.asarray(rng.rand(E).astype(np.float32) + 0.5)

    def router_fn(x):
        logits = x @ router_w + bump
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

    base_cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                             top_k=K, mode="ll", payload_dtype=jnp.float32)
    xs = [jnp.asarray(rng.randn(N, T, H), jnp.float32) for _ in range(6)]

    def make(group, w_phys_of):
        L = group.local_experts

        def fn(window):
            def run(x, wv):
                x = x[0]
                ti, wi = router_fn(x)
                h = ep_create_handle(group, ti, wi)
                y3d, counts = ep_dispatch(group, h, x)
                me = plan_mod.my_rank(group)
                rows = jax.lax.dynamic_slice_in_dim(w_phys_of(wv), me * L, L)
                out = ep_combine(group, h, y3d * rows[:, None, None])
                heat = jax.lax.psum(PL.heat_from_topk(ti, E), "data")
                return out[None], heat[None]
            f = jax.jit(jax.shard_map(
                run, mesh=mesh, in_specs=(P2("data"), P2(None)),
                out_specs=(P2("data"), P2("data"))))
            outs, hs = [], 0.0
            for x in window:
                o, hcur = f(x, fn.wv)
                outs.append(np.asarray(o))
                hs = hs + np.asarray(hcur)[0]
            return outs, hs
        return fn

    def make_expand(group):     # logical weights, in-graph per-step gather
        pl = group.placement
        fn = make(group, lambda wv: (PL.expand_expert_params(wv, pl)
                                     if pl is not None else wv))
        fn.wv = w_log
        return fn

    def make_adopt(group, params):   # physical rows arrive pre-bound
        fn = make(group, lambda wv: wv)
        fn.wv = params["w_gate"]
        return fn

    outs_a, pls_a = rebalancing_decode_loop(
        base_cfg, make_expand, xs, rebalance_every=2, ep_size=N,
        num_redundant=8)
    outs_b, pls_b = rebalancing_decode_loop(
        base_cfg, make_adopt, xs, rebalance_every=2, ep_size=N,
        num_redundant=8, params={"w_gate": w_log}, expert_keys=("w_gate",))
    assert [p.fingerprint() if p else 0 for p in pls_a] == \
           [p.fingerprint() if p else 0 for p in pls_b]
    assert any(p is not None for p in pls_b)      # swaps actually happened
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_rebind_across_placements(tmp_path):
    """A checkpoint persisted in one placement's physical layout restores
    under a different placement with every slot holding the right logical
    expert's weights (elastic EPLB restart)."""
    from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                                  rebind_expert_leaves)
    rng = np.random.RandomState(4)
    logical = dict(w_gate=jnp.asarray(rng.randn(E, 4), jnp.float32),
                   router=jnp.asarray(rng.randn(4, E), jnp.float32))
    pl_a = redundant_placement(E, N, 8)
    pl_b = rebalance(np.arange(E, dtype=float) + 1.0, N, num_redundant=16)
    phys_a = rebind_expert_leaves(logical, ("w_gate",), dst_placement=pl_a)
    assert phys_a["w_gate"].shape == (E + 8, 4)
    save_checkpoint(tmp_path, 1, phys_a)
    restored, _ = restore_checkpoint(tmp_path, 1, phys_a)
    phys_b = rebind_expert_leaves(restored, ("w_gate",),
                                  src_placement=pl_a, dst_placement=pl_b)
    se_b = PL.tables(pl_b).slot_expert.reshape(-1)
    np.testing.assert_array_equal(np.asarray(phys_b["w_gate"]),
                                  np.asarray(logical["w_gate"])[se_b])
    # non-expert leaves pass through untouched
    np.testing.assert_array_equal(np.asarray(phys_b["router"]),
                                  np.asarray(logical["router"]))


# --------------------------------------------------------------------------
# rebalancing prefill driver: placement swaps between batches
# --------------------------------------------------------------------------

def test_rebalancing_prefill_matches_sequential():
    """The EPLB prefill driver (placement swaps between batches, staged
    micro-batched pipeline within each) must match the unpipelined
    sequential reference under the same placement schedule."""
    from repro.runtime.prefill import (prefill_moe, sequential_prefill,
                                       rebalancing_prefill)
    rng = np.random.RandomState(6)
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    router_w = jnp.asarray(rng.randn(H, E), jnp.float32)
    bump = jnp.zeros((E,)).at[:4].set(3.0)       # keep a hot neighborhood

    def router_fn(x):
        logits = x @ router_w + bump
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

    def expert_fn_for(group, placement):
        se = (jnp.arange(E, dtype=jnp.int32).reshape(N, -1)
              if placement is None
              else jnp.asarray(PL.tables(placement).slot_expert))

        def expert_fn(y3d, counts):
            me = plan_mod.my_rank(group)
            return y3d * (1.0 + se[me])[:, None, None].astype(y3d.dtype)
        return expert_fn

    base_cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T // 2,
                             hidden=H, top_k=K, mode="ht",
                             payload_dtype=jnp.float32)
    batches_np = rng.randn(3, N, T, H).astype(np.float32)
    batches = [jnp.asarray(b) for b in batches_np]

    def make_layer(group):
        efn = expert_fn_for(group, group.placement)

        def layer(x):
            def run(x):
                out = prefill_moe(group, router_fn, efn, x[0], 2)
                heat = jax.lax.psum(
                    PL.heat_from_topk(router_fn(x[0])[0], E), "data")
                return out[None], heat[None]
            o, heat = jax.jit(jax.shard_map(
                run, mesh=mesh, in_specs=(P("data"),),
                out_specs=(P("data"), P("data"))))(x)
            return np.asarray(o), np.asarray(heat)[0]
        return layer

    outs, placements = rebalancing_prefill(
        base_cfg, make_layer, batches, rebalance_every=1, ep_size=N,
        num_redundant=8)
    assert placements[0] is None
    assert placements[1] is not None and placements[2] is not None
    assert placements[1].num_redundant == 8

    import dataclasses as dc
    for i, x in enumerate(batches):
        group = ep_create_group(dc.replace(base_cfg, placement=placements[i]),
                                ep_size=N)
        efn = expert_fn_for(group, placements[i])

        def seq(x):
            return sequential_prefill(group, router_fn, efn, x[0], 2)[None]
        want = np.asarray(jax.jit(jax.shard_map(
            seq, mesh=mesh, in_specs=(P("data"),), out_specs=P("data")))(x))
        np.testing.assert_array_equal(outs[i], want)


# --------------------------------------------------------------------------
# placement resolved at plan time, never in phase bodies (AST contract)
# --------------------------------------------------------------------------

def test_no_placement_resolution_in_phase_bodies():
    """The standing contract (docs/DESIGN.md §8): placement/replica lookup
    happens in plan construction only — phase bodies stay single-pass data
    movement, so no mode module may touch the placement tables. Shared rule:
    analysis.contracts 'phase-no-placement' (docs/DESIGN.md §12)."""
    from repro.analysis.contracts import run_rule
    assert run_rule("phase-no-placement") == []
