"""HT-mode correctness: flat and hierarchical paths vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.group import EpGroupConfig, ep_create_group
from repro.core import ht


def oracle(x, topk, w):
    scale = (w * (1.0 + topk)).sum(-1)
    return x * scale[..., None]


def rand_routing(rng, N, T, K, E):
    topk = np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                     for _ in range(N)]).astype(np.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    return jnp.asarray(topk), w


def run_flat(cfg, x, topk, w):
    N = x.shape[0]
    mesh = jax.make_mesh((N,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ht.ht_create_handle(group, topk, w)
        y3d, counts = ht.ht_dispatch(group, h, x)
        me = jax.lax.axis_index("data")
        e_glob = me * group.local_experts + jnp.arange(group.local_experts)
        y3d = y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
        out = ht.ht_combine(group, h, y3d)
        return out[None], counts[None], h.num_recv_tokens[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                              out_specs=(P("data"), P("data"), P("data"))))
    return f(x, topk, w)


def run_hier(cfg, x, topk, w, No, Ni):
    mesh = jax.make_mesh((No, Ni), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    group = ep_create_group(cfg, ep_size=No * Ni, inner_size=Ni)

    def step(x, topk, w):
        x, topk, w = x[0, 0], topk[0, 0], w[0, 0]
        h = ht.ht_create_handle(group, topk, w)
        y3d, counts = ht.ht_dispatch(group, h, x)
        me = (jax.lax.axis_index("pod") * Ni + jax.lax.axis_index("data"))
        e_glob = me * group.local_experts + jnp.arange(group.local_experts)
        y3d = y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
        out = ht.ht_combine(group, h, y3d)
        return out[None, None], counts[None, None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P("pod", "data"),) * 3,
                              out_specs=(P("pod", "data"), P("pod", "data"))))
    return f(x, topk, w)


@pytest.mark.parametrize("E,K,T,H", [(16, 4, 32, 64), (8, 8, 16, 32), (64, 4, 64, 16)])
def test_ht_flat_roundtrip(E, K, T, H):
    N = 8
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk, w = rand_routing(rng, N, T, K, E)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ht", payload_dtype=jnp.float32)  # zero-drop caps
    out, counts, nrecv = run_flat(cfg, x, topk, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle(x, topk, w)),
                               rtol=2e-5, atol=2e-5)
    assert int(counts.sum()) == N * T * K
    # the paper's GetNumRecvTokens query: exact per-rank receive totals
    np.testing.assert_array_equal(np.asarray(nrecv), np.asarray(counts.sum(1)))


def test_ht_flat_capacity_drop_is_bounded():
    """With a tight capacity factor, dropped entries zero their contribution
    but never corrupt other tokens (the static-shape overflow semantics)."""
    N, E, K, T, H = 8, 16, 4, 32, 16
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk, w = rand_routing(rng, N, T, K, E)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ht", capacity_factor=1.0, payload_dtype=jnp.float32)
    out, counts, _ = run_flat(cfg, x, topk, w)
    ref = np.asarray(oracle(x, topk, w))
    got = np.asarray(out)
    # each token's output is a partial weighted sum: |got| <= oracle's bound
    # and rows either match (no drops for that token) or are strictly smaller
    per_err = np.abs(got - ref).max(-1)
    full_match = per_err < 1e-4
    assert full_match.mean() > 0.5  # most tokens survive at cf=1.0
    # dropped contributions only *remove* terms: verify via magnitude bound
    assert np.all(np.abs(got).max(-1) <= np.abs(ref).max(-1) * (1.0 + K) + 1e-4)


@pytest.mark.parametrize("No,Ni", [(2, 4), (4, 2)])
@pytest.mark.parametrize("E,K", [(16, 4), (8, 3)])
def test_ht_hierarchical_roundtrip(No, Ni, E, K):
    T, H = 16, 32
    N = No * Ni
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(No, Ni, T, H), jnp.float32)
    topk, w = rand_routing(rng, N, T, K, E)
    topk = topk.reshape(No, Ni, T, K)
    w = w.reshape(No, Ni, T, K)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ht", ep_axis=("pod", "data"), ht_hierarchical=True,
                        payload_dtype=jnp.float32)
    out, counts = run_hier(cfg, x, topk, w, No, Ni)
    ref = oracle(x.reshape(N, T, H), topk.reshape(N, T, K), w.reshape(N, T, K))
    np.testing.assert_allclose(np.asarray(out).reshape(N, T, H), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert int(counts.sum()) == N * T * K


def test_ht_hier_matches_flat():
    """The hierarchical path must compute exactly the same function as the
    flat path (same tokens to same experts, same weighted combine)."""
    No, Ni, E, K, T, H = 2, 4, 16, 4, 8, 16
    N = No * Ni
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk, w = rand_routing(rng, N, T, K, E)
    cfg_f = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                          mode="ht", payload_dtype=jnp.float32)
    out_f, _, _ = run_flat(cfg_f, x, topk, w)
    cfg_h = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                          mode="ht", ep_axis=("pod", "data"), ht_hierarchical=True,
                          payload_dtype=jnp.float32)
    out_h, _ = run_hier(cfg_h, x.reshape(No, Ni, T, H), topk.reshape(No, Ni, T, K),
                        w.reshape(No, Ni, T, K), No, Ni)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_h).reshape(N, T, H),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("num_chunks", [1, 2])
def test_ht_hier_fp8_stage2_scales_bitwise(num_chunks):
    """quantize_dispatch=True on the hierarchical path: the payload stays
    fp8 across BOTH hops and the fp32 scales ride along the stage-2 fan
    (core/ht.py copy-mode unpack), so the destination's fused dequant must
    land bit-for-bit the same expert tensor as the flat single-hop path —
    which itself is bit-for-bit the unquantized-oracle roundtrip
    (recv_unpack's dequant of dispatch_pack's quant of x)."""
    No, Ni, E, K, T, H = 2, 4, 16, 4, 16, 32
    N = No * Ni
    rng = np.random.RandomState(11)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk, w = rand_routing(rng, N, T, K, E)

    def dispatch_only(cfg, mesh_shape, names, inner=None):
        mesh = jax.make_mesh(mesh_shape, names,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(names))
        group = ep_create_group(cfg, ep_size=N, inner_size=inner)

        def step(x, topk, w):
            h = ht.ht_create_handle(group, topk[0], w[0])
            y3d, counts = ht.ht_dispatch(group, h, x[0])
            return y3d[None], counts[None]

        spec = P(tuple(names))
        f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(spec,) * 3,
                                  out_specs=(spec, spec)))
        return f(x, topk, w)

    kw = dict(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
              mode="ht", payload_dtype=jnp.float32, quantize_dispatch=True,
              quant_block=H)
    y_f, c_f = dispatch_only(EpGroupConfig(**kw), (N,), ("data",))
    y_h, c_h = dispatch_only(
        EpGroupConfig(ep_axis=("pod", "data"), ht_hierarchical=True,
                      ht_num_chunks=num_chunks, **kw),
        (No, Ni), ("pod", "data"), inner=Ni)
    np.testing.assert_array_equal(np.asarray(c_f), np.asarray(c_h))
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_h))

    # flat reference reconstruction: every expert-region row is exactly the
    # fp8 quant->dequant roundtrip of its source token (scales bitwise)
    from repro.kernels import ops as Kops
    q, s = Kops.quantize_fp8(x.reshape(N * T, H), H)
    xq = np.asarray(Kops.dequantize_fp8(q, s)).reshape(N, T, H)
    y_np, c_np = np.asarray(y_f, np.float32), np.asarray(c_f)
    L = E // N
    for r in range(N):
        for l in range(L):
            rows = y_np[r, l, :int(c_np[r, l])]
            # each non-pad row must appear among the quantized tokens routed
            # to expert (r, l)
            src = np.asarray(topk)
            senders = [(rr, t) for rr in range(N) for t in range(T)
                       if (src[rr, t] == r * L + l).any()]
            want = np.stack([xq[rr, t] for rr, t in senders]).astype(np.float32)
            assert rows.shape == want.shape
            np.testing.assert_array_equal(np.sort(rows, axis=0),
                                          np.sort(want, axis=0))


def test_ht_hier_fp8_roundtrip_close():
    """Full hierarchical dispatch+combine with fp8 payload: lossy only by
    the quantization itself — compare against the oracle applied to the
    dequantized roundtrip of x (bf16 expert rows bound the rest)."""
    No, Ni, E, K, T, H = 2, 4, 16, 4, 16, 32
    N = No * Ni
    rng = np.random.RandomState(12)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk, w = rand_routing(rng, N, T, K, E)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ht", ep_axis=("pod", "data"),
                        ht_hierarchical=True, ht_num_chunks=2,
                        payload_dtype=jnp.float32, quantize_dispatch=True,
                        quant_block=H)
    out, _ = run_hier(cfg, x.reshape(No, Ni, T, H),
                      jnp.asarray(np.asarray(topk).reshape(No, Ni, T, K)),
                      w.reshape(No, Ni, T, K), No, Ni)
    from repro.kernels import ops as Kops
    q, s = Kops.quantize_fp8(x.reshape(N * T, H), H)
    xq = jnp.asarray(np.asarray(Kops.dequantize_fp8(q, s), np.float32)
                     ).reshape(N, T, H)
    ref = oracle(xq, topk, w)
    np.testing.assert_allclose(np.asarray(out, np.float32).reshape(N, T, H),
                               np.asarray(ref), rtol=2e-2, atol=2e-2)


def test_ht_grad_flows():
    N, E, K, T, H = 8, 8, 2, 16, 16
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk, w = rand_routing(rng, N, T, K, E)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ht", payload_dtype=jnp.float32)

    def loss(x):
        out, _, _ = run_flat(cfg, x, topk, w)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(x)
    s = (w * (1.0 + topk)).sum(-1)[..., None]
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * s * s * x),
                               rtol=2e-4, atol=2e-4)
