"""EpBackend protocol: registry routing, the mode-tagged EpPending, and the
no-silent-ignore staged contract.

The contract this file pins (ISSUE 3 / ROADMAP standing contract): every
registered backend either *executes* ``send_only=True`` staged — returning a
mode-tagged ``EpPending`` that ``ep_complete`` finishes to exactly the eager
result — or raises ``NotImplementedError``. No mode may accept the flag and
silently run eager (the seed's HT/baseline behavior). The API layer must
contain no per-mode if/elif chains and no pending-type isinstance dispatch:
``ep_complete`` routes through the registry for all modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import api as api_mod
from repro.core import (EpGroupConfig, EpPending, ep_create_group,
                        ep_create_handle, ep_dispatch, ep_combine,
                        ep_complete, get_backend, registered_modes)

N, E, K, T, H = 8, 16, 4, 16, 32

from repro.core.placement import redundant_placement

CONFIGS = {
    "ll": dict(mode="ll"),
    "ll/deepep": dict(mode="ll", ll_layout="deepep"),
    "ht": dict(mode="ht"),
    "ht/hier": dict(mode="ht", ep_axis=("pod", "data"), ht_hierarchical=True),
    "baseline": dict(mode="baseline"),
    # EPLB: a redundant placement rides the exact same staged surface — the
    # replica-aware slot maps ship in the plan like every other map
    "ll/eplb": dict(mode="ll", placement=redundant_placement(E, N, 8)),
    "ht/eplb": dict(mode="ht", placement=redundant_placement(E, N, 8)),
}


def make_group(kw):
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, payload_dtype=jnp.float32, **kw)
    hier = len(cfg.ep_axis) > 1
    return ep_create_group(cfg, ep_size=N, inner_size=4 if hier else None)


def make_mesh(group):
    if len(group.cfg.ep_axis) > 1:
        return jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def rand_inputs(rng):
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    return x, topk, w


def scale_by_expert(group, y3d):
    from repro.core import plan as PM
    L = group.local_experts
    e_glob = PM.my_rank(group) * L + jnp.arange(L)
    return y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)


# --------------------------------------------------------------------------
# registry shape
# --------------------------------------------------------------------------

def test_all_modes_registered():
    assert registered_modes() == ("baseline", "ht", "ll")


def test_api_layer_has_no_mode_chains():
    """core/api.py must route exclusively through the backend registry: no
    per-mode if/elif chains, no pending-type isinstance dispatch. Shared
    rule: analysis.contracts 'api-registry-only' (docs/DESIGN.md §12)."""
    from repro.analysis.contracts import run_rule
    assert run_rule("api-registry-only") == []


def test_backends_define_staged_halves_only():
    """No EpBackend subclass may override the derived eager surface
    (dispatch/combine/complete) — that is how send_only could silently be
    dropped. Shared rule: analysis.contracts 'backend-staged-primitive'."""
    from repro.analysis.contracts import run_rule
    assert run_rule("backend-staged-primitive") == []


# --------------------------------------------------------------------------
# no-silent-ignore: staged executes (and matches eager) or refuses loudly
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CONFIGS), ids=sorted(CONFIGS))
def test_send_only_is_never_silently_ignored(name):
    """Every registered backend must honor send_only=True: dispatch/combine
    return an EpPending (asserted at trace time — an eager tuple would mean
    the flag was dropped) and ep_complete finishes to exactly the eager
    result. A backend without a staged path must raise NotImplementedError
    instead of accepting the flag."""
    group = make_group(CONFIGS[name])
    mesh = make_mesh(group)
    rng = np.random.RandomState(0)
    x, topk, w = rand_inputs(rng)
    hier = len(group.cfg.ep_axis) > 1

    def one(xs, topk, w, staged):
        h = ep_create_handle(group, topk, w)
        if staged:
            p = ep_dispatch(group, h, xs, send_only=True)
            assert isinstance(p, EpPending), (
                f"{name}: send_only=True dispatch ran eager (returned "
                f"{type(p)}) — the no-silent-ignore contract forbids this")
            assert p.mode == group.mode and p.op == "dispatch"
            y3d, counts = ep_complete(group, h, p)
        else:
            y3d, counts = ep_dispatch(group, h, xs)
        y3d = scale_by_expert(group, y3d)
        if staged:
            pc = ep_combine(group, h, y3d, send_only=True)
            assert isinstance(pc, EpPending), (
                f"{name}: send_only=True combine ran eager")
            assert pc.mode == group.mode and pc.op == "combine"
            return ep_complete(group, h, pc)
        return ep_combine(group, h, y3d)

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        eager = one(x, topk, w, staged=False)
        staged = one(x, topk, w, staged=True)
        return eager[None], staged[None]

    spec = P(("pod", "data")) if hier else P("data")
    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(spec,) * 3,
                              out_specs=(spec, spec)))
    try:
        eager, staged = f(x, topk, w)
    except NotImplementedError:
        return        # a loud refusal is the one permitted alternative
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(staged))


# --------------------------------------------------------------------------
# EpPending tag routing
# --------------------------------------------------------------------------

def test_complete_rejects_foreign_mode_pending():
    p = EpPending(mode="ll", op="dispatch", recv=jnp.zeros((4, 8)))
    with pytest.raises(ValueError, match="not transferable across modes"):
        get_backend("ht").complete(None, None, p)


def test_complete_rejects_non_pending():
    with pytest.raises(TypeError, match="not a pending EP operation"):
        get_backend("ll").complete(None, None, (jnp.zeros((2, 2)), None))


def test_complete_rejects_unknown_op():
    p = EpPending(mode="ll", op="frobnicate", recv=jnp.zeros((4, 8)))
    with pytest.raises(ValueError, match="unknown pending op"):
        get_backend("ll").complete(None, None, p)


def test_unknown_mode_fails_loudly():
    with pytest.raises(KeyError, match="no EP backend registered"):
        get_backend("warp")


def test_pending_is_pytree_with_static_tags():
    """mode/op must be aux data (survive tracing as Python strings) and the
    payload must be the only leaf content."""
    p = EpPending(mode="ht", op="combine", recv=jnp.ones((2, 3)),
                  recv_scales=None)
    leaves, treedef = jax.tree.flatten(p)
    assert len(leaves) == 1 and leaves[0].shape == (2, 3)
    p2 = jax.tree.unflatten(treedef, leaves)
    assert p2.mode == "ht" and p2.op == "combine"
