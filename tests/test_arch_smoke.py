"""Per-architecture smoke tests: reduced same-family configs, one forward +
one gradient step + one decode step on CPU; asserts output shapes & no NaNs.
The FULL configs are exercised only via the dry-run (compile-only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import get_model
from repro.parallel.sharding import init_from_specs, abstract_from_specs

B, S = 2, 16


def make_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.randn(B, cfg.img_tokens, cfg.d_model), jnp.float32).astype(cfg.dtype)
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.randn(B, cfg.src_len, cfg.d_model) * 0.02, jnp.float32).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke(arch)
    m = get_model(cfg)
    rng = np.random.RandomState(0)
    params = init_from_specs(jax.random.PRNGKey(0), m.params_spec(cfg))
    batch = make_batch(cfg, rng)

    def loss_fn(p):
        loss, _ = m.forward(p, batch, cfg, None)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.abs(g.astype(jnp.float32))), grads))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    m = get_model(cfg)
    rng = np.random.RandomState(1)
    params = init_from_specs(jax.random.PRNGKey(0), m.params_spec(cfg))
    max_len = 32
    state_spec = m.decode_state_spec(cfg, B, max_len)
    state = init_from_specs(jax.random.PRNGKey(1), state_spec)
    state = jax.tree.map(jnp.zeros_like, state)   # caches start empty
    if cfg.family == "encdec":
        state["memory"] = jnp.asarray(
            rng.randn(B, cfg.src_len, cfg.d_model) * 0.02, cfg.dtype)

    step = jax.jit(lambda p, s, b: m.decode_step(p, s, b, cfg, None))
    tok = jnp.asarray(rng.randint(0, cfg.vocab, (B, 1)), jnp.int32)
    logits, state2 = step(params, state, {"tokens": tok})
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # a second step must advance the cache
    logits2, state3 = step(params, state2, {"tokens": tok})
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))

    def lengths(tree):
        return [int(x) for x in jax.tree.leaves(tree)
                if hasattr(x, "ndim") and x.ndim == 0 or
                (hasattr(x, "dtype") and x.dtype == jnp.int32 and x.ndim <= 1)]
    # at least one length counter advanced by 2
    flat3 = [np.asarray(l) for l in jax.tree.leaves(state3)]
    assert any(np.all(a == 2) for a in flat3 if a.dtype == np.int32 and a.size >= 1)


def test_decode_matches_forward_internlm2():
    """Greedy decode logits must match teacher-forced forward logits
    (KV-cache correctness, GQA path)."""
    cfg = get_smoke("internlm2-20b")
    m = get_model(cfg)
    rng = np.random.RandomState(2)
    params = init_from_specs(jax.random.PRNGKey(0), m.params_spec(cfg))
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 8)), jnp.int32)

    # forward logits (no loss): replicate lm_forward internals up to logits
    from repro.models import transformer as T
    from repro.models.layers import rmsnorm, logits_out, embed_lookup
    x = embed_lookup(params["embed"], toks)
    def body(x, p, c):
        return T.layer_apply(p, x, cfg, None)
    x, _, _ = T._scan_stack(body, x, params["dense_stack"],
                            T._empty_caches(cfg.num_layers), cfg, remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    full_logits = logits_out(x, params["lm_head"])

    # decode one token at a time
    state = jax.tree.map(jnp.zeros_like, init_from_specs(
        jax.random.PRNGKey(1), m.decode_state_spec(cfg, 1, 16)))
    outs = []
    for t in range(8):
        lg, state = m.decode_step(params, state, {"tokens": toks[:, t:t+1]}, cfg, None)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_mamba2():
    """SSD chunked prefill vs step-by-step recurrence must agree."""
    cfg = get_smoke("mamba2-780m")
    m = get_model(cfg)
    rng = np.random.RandomState(3)
    params = init_from_specs(jax.random.PRNGKey(0), m.params_spec(cfg))
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 8)), jnp.int32)

    from repro.models import transformer as T
    from repro.models.layers import rmsnorm, logits_out, embed_lookup
    from repro.models import mamba2 as SSM
    x = embed_lookup(params["embed"], toks)
    def body(x, p, c):
        y, _ = SSM.mamba_block(p["mamba"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg, None)
        return x + y, c, jnp.float32(0)
    x, _, _ = T._scan_stack(body, x, params["stack"],
                            T._empty_caches(cfg.num_layers), cfg, remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    full_logits = logits_out(x, params["embed"])

    state = jax.tree.map(jnp.zeros_like, init_from_specs(
        jax.random.PRNGKey(1), m.decode_state_spec(cfg, 1, 16)))
    outs = []
    for t in range(8):
        lg, state = m.decode_step(params, state, {"tokens": toks[:, t:t+1]}, cfg, None)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=3e-2, atol=3e-2)
