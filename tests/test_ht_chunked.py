"""Chunked hierarchical HT pipeline: bitwise parity vs the monolithic path.

The chunked stream (core/ht.py + the per-chunk slot-map slices in EpPlan)
must compute exactly the same function as the nc=1 monolithic hierarchical
path: dispatch lands the same rows in the same expert-region slots (the
destination positions are computed over the monolithic entry order), and
combine performs the same per-slot reductions in the same order — so at
zero-drop capacities the outputs are bitwise identical across
ht_num_chunks ∈ {1, 2, 4}, quantized and not. Also pins the steady-state
contract (chunk slices ride the plan through ep_handle_refresh without
rebuild) and the prefill driver's schedule-independence
(runtime/prefill.py pipelined == sequential).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,
                        ep_handle_refresh, ep_dispatch, ep_combine)
from repro.core import ht
from repro.runtime.prefill import prefill_moe, sequential_prefill

No, Ni, E, K, T, H = 2, 4, 16, 4, 16, 32
N = No * Ni


def make_mesh():
    return jax.make_mesh((No, Ni), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def hier_cfg(nc, quantize=False):
    return EpGroupConfig(
        num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K, mode="ht",
        ep_axis=("pod", "data"), ht_hierarchical=True, ht_num_chunks=nc,
        payload_dtype=jnp.float32, quantize_dispatch=quantize, quant_block=H)


def rand_inputs(rng):
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    return x, topk, w


def oracle(x, topk, w):
    return x * (w * (1.0 + topk)).sum(-1)[..., None]


def run_hier(nc, x, topk, w, quantize=False):
    """Full dispatch -> expert-scale -> combine roundtrip; returns the
    dispatch tensor, counts, and combined output for parity comparison."""
    group = ep_create_group(hier_cfg(nc, quantize), ep_size=N, inner_size=Ni)
    mesh = make_mesh()

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ep_create_handle(group, topk, w)
        y3d, counts = ep_dispatch(group, h, x)
        me = (jax.lax.axis_index("pod") * Ni + jax.lax.axis_index("data"))
        e_glob = me * group.local_experts + jnp.arange(group.local_experts)
        y3d_s = y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
        out = ep_combine(group, h, y3d_s)
        return y3d[None], counts[None], out[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P(("pod", "data")),) * 3,
                              out_specs=(P(("pod", "data")),) * 3))
    return f(x, topk, w)


@pytest.mark.parametrize("quantize", [False, True], ids=["f32", "fp8"])
@pytest.mark.parametrize("nc", [2, 4])
def test_chunked_bitwise_matches_monolithic(nc, quantize):
    """ht_num_chunks ∈ {2, 4} must reproduce the nc=1 path bit for bit:
    same dispatch tensor (same rows, same expert-region slots), same counts,
    same combined output (same reduction sets in the same order)."""
    rng = np.random.RandomState(0)
    x, topk, w = rand_inputs(rng)
    y_mono, c_mono, o_mono = run_hier(1, x, topk, w, quantize)
    y_chnk, c_chnk, o_chnk = run_hier(nc, x, topk, w, quantize)
    np.testing.assert_array_equal(np.asarray(y_mono), np.asarray(y_chnk))
    np.testing.assert_array_equal(np.asarray(c_mono), np.asarray(c_chnk))
    np.testing.assert_array_equal(np.asarray(o_mono), np.asarray(o_chnk))


def test_chunked_roundtrip_matches_oracle():
    """The chunked stream is still the correct function (not merely
    self-consistent): roundtrip equals the dense oracle."""
    rng = np.random.RandomState(1)
    x, topk, w = rand_inputs(rng)
    _, counts, out = run_hier(2, x, topk, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle(x, topk, w)),
                               rtol=2e-4, atol=2e-4)
    assert int(np.asarray(counts).sum()) == N * T * K


def test_chunk_maps_have_chunk_axis():
    """The plan ships per-chunk slices: leading nc axis on every stage map,
    global maps stay chunk-concatenated."""
    group = ep_create_group(hier_cfg(2), ep_size=N, inner_size=Ni)
    mesh = make_mesh()
    rng = np.random.RandomState(2)
    _, topk, w = rand_inputs(rng)

    def step(topk, w):
        h = ep_create_handle(group, topk[0], w[0])
        p = h.plan
        assert p.h_gmap1.shape[:2] == (2, Ni)
        assert p.h_gmap2.shape[:2] == (2, No)
        # h_slot_tgt is ONE [L*A] map into the chunk-concatenated stage-2
        # buffer (single scatter fills every chunk's slice)
        assert p.h_slot_tgt.shape == (group.local_experts * group.ht_expert_cap,)
        assert p.h_rail_dst_rows.shape == p.h_rail_src_rows.shape
        assert p.h_rail_dst_rows.shape[0] == 2
        assert p.h_src_rows.shape == (T, Ni)
        return h.tokens_per_expert[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P(("pod", "data")),) * 2,
                              out_specs=P(("pod", "data"))))
    f(topk, w)


def test_chunk_slices_survive_refresh():
    """ep_handle_refresh steady-state contract extends to the chunk maps: a
    weights-only refresh rebinds h_w_slot through h_entry_slot and reuses
    every chunk slice by identity — no rebuild."""
    group = ep_create_group(hier_cfg(2), ep_size=N, inner_size=Ni)
    mesh = make_mesh()
    rng = np.random.RandomState(3)
    x, topk, w = rand_inputs(rng)
    w2 = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)

    def step(x, topk, w, w2):
        x, topk, w, w2 = x[0], topk[0], w[0], w2[0]
        h = ep_create_handle(group, topk, w)
        h2 = ep_handle_refresh(group, h, w2)
        assert h2.plan.h_gmap1 is h.plan.h_gmap1
        assert h2.plan.h_gmap2 is h.plan.h_gmap2
        assert h2.plan.h_slot_tgt is h.plan.h_slot_tgt
        assert h2.plan.disp_recv_gmap is h.plan.disp_recv_gmap
        y3d, counts = ep_dispatch(group, h2, x)
        me = (jax.lax.axis_index("pod") * Ni + jax.lax.axis_index("data"))
        e_glob = me * group.local_experts + jnp.arange(group.local_experts)
        y3d = y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
        return ep_combine(group, h2, y3d)[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P(("pod", "data")),) * 4,
                              out_specs=P(("pod", "data"))))
    out = np.asarray(f(x, topk, w, w2))
    np.testing.assert_allclose(out, np.asarray(oracle(x, topk, w2)),
                               rtol=2e-5, atol=2e-5)


def test_chunks_must_divide_tokens():
    with pytest.raises(ValueError, match="must divide max_tokens_per_rank"):
        hier_group = ep_create_group(  # noqa: F841
            hier_cfg(3), ep_size=N, inner_size=Ni)


def test_staged_hier_chunked_equals_eager():
    """send_only + ep_complete on the chunked hierarchical path is the same
    computation split at the EpPending boundary."""
    group = ep_create_group(hier_cfg(2), ep_size=N, inner_size=Ni)
    mesh = make_mesh()
    rng = np.random.RandomState(4)
    x, topk, w = rand_inputs(rng)

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ht.ht_create_handle(group, topk, w)
        p = ht.ht_dispatch(group, h, x, send_only=True)
        y3d, counts = ht.ht_dispatch_complete(group, h, p)
        y3d_e, counts_e = ht.ht_dispatch(group, h, x)
        pc = ht.ht_combine(group, h, y3d, send_only=True)
        out = ht.ht_combine_complete(group, h, pc)
        out_e = ht.ht_combine(group, h, y3d_e)
        return y3d[None], y3d_e[None], out[None], out_e[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P(("pod", "data")),) * 3,
                              out_specs=(P(("pod", "data")),) * 4))
    y, ye, o, oe = map(np.asarray, f(x, topk, w))
    np.testing.assert_array_equal(y, ye)
    np.testing.assert_array_equal(o, oe)


# --------------------------------------------------------------------------
# prefill driver: pipelined == sequential, all modes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("hier", [False, True], ids=["flat", "hier"])
def test_prefill_pipeline_matches_sequential(hier):
    """runtime/prefill.py: the skewed micro-batch schedule must be a pure
    reordering — bitwise-equal to the sequential per-micro-batch loop."""
    MB = 2
    Tm = T // MB
    if hier:
        cfg = EpGroupConfig(
            num_experts=E, max_tokens_per_rank=Tm, hidden=H, top_k=K,
            mode="ht", ep_axis=("pod", "data"), ht_hierarchical=True,
            ht_num_chunks=2, payload_dtype=jnp.float32)
        group = ep_create_group(cfg, ep_size=N, inner_size=Ni)
        mesh = make_mesh()
        spec = P(("pod", "data"))
    else:
        cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=Tm, hidden=H,
                            top_k=K, mode="ht", payload_dtype=jnp.float32)
        group = ep_create_group(cfg, ep_size=N)
        mesh = jax.make_mesh((N,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        spec = P("data")
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    router_w = jnp.asarray(rng.randn(H, E), jnp.float32)

    def router_fn(xt):
        logits = xt @ router_w
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

    def expert_fn(y3d, counts):
        from repro.core import plan as PM
        L = group.local_experts
        e_glob = PM.my_rank(group) * L + jnp.arange(L)
        return y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)

    def step(x):
        x = x[0]
        pipe = prefill_moe(group, router_fn, expert_fn, x, MB)
        seq = sequential_prefill(group, router_fn, expert_fn, x, MB)
        return pipe[None], seq[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(spec,),
                              out_specs=(spec, spec)))
    pipe, seq = map(np.asarray, f(x))
    np.testing.assert_array_equal(pipe, seq)
