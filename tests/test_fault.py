"""runtime/fault.py unit coverage: PreemptionGuard install/restore (+ the
non-main-thread fallback), StragglerWatchdog EMA / persistent-slowdown
re-base, StepTimer, and the elastic-EP fault layer — FaultInjector schedule
determinism and FaultDetector heartbeat/step-timeout semantics."""
import signal
import threading
import time

import pytest

from repro.runtime.fault import (FaultDetector, FaultInjector, FaultReport,
                                 PreemptionGuard, StepTimer,
                                 StragglerWatchdog)


# --------------------------------------------------------------------------
# PreemptionGuard
# --------------------------------------------------------------------------

def test_preemption_guard_install_signal_restore():
    before = {s: signal.getsignal(s) for s in (signal.SIGTERM, signal.SIGINT)}
    g = PreemptionGuard()
    try:
        assert not g.should_stop
        for s in before:
            assert signal.getsignal(s) == g._handler
        signal.raise_signal(signal.SIGTERM)
        assert g.should_stop
    finally:
        g.restore()
    for s, h in before.items():
        assert signal.getsignal(s) == h
    g.restore()                      # idempotent: second restore is a no-op
    for s, h in before.items():
        assert signal.getsignal(s) == h


def test_preemption_guard_non_main_thread_fallback():
    """signal.signal raises ValueError off the main thread — the guard must
    degrade to an inert flag (no handlers installed, restore a no-op)
    instead of crashing the worker that built it."""
    before = {s: signal.getsignal(s) for s in (signal.SIGTERM, signal.SIGINT)}
    box = {}

    def build():
        g = PreemptionGuard()
        box["stop"] = g.should_stop
        box["orig"] = dict(g._orig)
        g.restore()

    t = threading.Thread(target=build)
    t.start()
    t.join()
    assert box["stop"] is False and box["orig"] == {}
    for s, h in before.items():      # main-thread handlers untouched
        assert signal.getsignal(s) == h


# --------------------------------------------------------------------------
# StragglerWatchdog
# --------------------------------------------------------------------------

def test_watchdog_transient_outlier_never_updates_ema():
    w = StragglerWatchdog(factor=2.0)
    for _ in range(10):
        assert not w.observe(1.0)
    assert w.observe(5.0) and w.flagged == 1
    assert abs(w.ema - 1.0) < 1e-6
    assert not w.observe(1.0)        # recovery clears the consecutive run
    assert w.consecutive == 0 and w.rebased == 0


def test_watchdog_persistent_slowdown_rebases():
    """A slowdown that persists for ``rebase_after`` consecutive steps is a
    new steady state: the EMA re-bases to the outlier run's mean and the
    flag CLEARS — without the re-base every subsequent step would be
    flagged forever."""
    w = StragglerWatchdog(factor=2.0, rebase_after=3)
    for _ in range(10):
        w.observe(1.0)
    flags = [w.observe(5.0) for _ in range(3)]
    assert flags == [True, True, True] and w.flagged == 3
    assert w.rebased == 1 and abs(w.ema - 5.0) < 1e-6
    assert not w.observe(5.0)        # the new steady state is not an outlier
    assert w.flagged == 3


def test_watchdog_interrupted_run_never_rebases():
    w = StragglerWatchdog(factor=2.0, rebase_after=3)
    for _ in range(10):
        w.observe(1.0)
    for _ in range(5):               # 2 outliers, then recovery, repeatedly
        assert w.observe(5.0) and w.observe(5.0)
        assert not w.observe(1.0)
    assert w.rebased == 0 and w.flagged == 10
    assert abs(w.ema - 1.0) < 0.2    # baseline survives the whole run


def test_step_timer():
    t = StepTimer()
    with t:
        time.sleep(0.01)
    with t:
        pass
    assert len(t.times) == 2
    assert t.times[0] >= 0.01 and t.times[1] >= 0.0


# --------------------------------------------------------------------------
# FaultInjector: deterministic kill/rejoin schedules
# --------------------------------------------------------------------------

def test_fault_injector_schedule_and_determinism():
    def run():
        inj = FaultInjector(4, kill={2: 1, 5: (0, 3)}, rejoin={7: 1})
        reports = [inj.advance(s) for s in range(10)]
        return inj, reports

    inj, reports = run()
    assert reports[2] == FaultReport((1,), ())
    assert reports[5] == FaultReport((0, 3), ())
    assert reports[7] == FaultReport((), (1,))
    assert all(not r for i, r in enumerate(reports) if i not in (2, 5, 7))
    assert inj.dead_ranks == (0, 3)
    assert inj.is_alive(1) and not inj.is_alive(0)
    # pure function of (schedule, step sequence): identical event log
    inj2, _ = run()
    assert inj.log == inj2.log
    assert [s for s, _ in inj.log] == [2, 5, 7]


def test_fault_injector_edge_cases():
    inj = FaultInjector(2, kill={0: 1, 3: 1}, rejoin={1: 0})
    assert inj.advance(0) == FaultReport((1,), ())
    assert not inj.advance(1)        # rejoin of a LIVE rank: no event
    assert not inj.advance(3)        # re-kill of a DEAD rank: no event
    with pytest.raises(ValueError, match="out of range"):
        FaultInjector(2, kill={0: 5})


# --------------------------------------------------------------------------
# FaultDetector: heartbeat / step-timeout semantics
# --------------------------------------------------------------------------

def test_fault_detector_miss_threshold_and_rejoin():
    det = FaultDetector(3, miss_threshold=2)
    for step in range(2):
        for r in range(3):
            det.heartbeat(r, step)
        assert not det.poll(step)
    # rank 1 goes silent after step 1
    for r in (0, 2):
        det.heartbeat(r, 2)
    assert not det.poll(2)           # 1 missed boundary < threshold
    for r in (0, 2):
        det.heartbeat(r, 3)
    assert det.poll(3) == FaultReport((1,), ())
    assert det.dead == (1,) and det.alive == (0, 2)
    assert not det.poll(4)           # already dead: reported exactly once
    # heartbeat resumes -> rejoined exactly once
    for r in range(3):
        det.heartbeat(r, 5)
    assert det.poll(5) == FaultReport((), (1,))
    assert det.dead == () and det.alive == (0, 1, 2)


def test_fault_detector_never_heartbeat_counts_from_start():
    det = FaultDetector(2, miss_threshold=2)
    det.heartbeat(0, 0)
    assert not det.poll(0)
    det.heartbeat(0, 1)
    assert det.poll(1) == FaultReport((1,), ())   # 1 - (-1) >= 2


def test_fault_detector_wall_clock_timeout():
    det = FaultDetector(2, miss_threshold=100, timeout_s=1.0)
    det.heartbeat(0, 0, now=0.0)
    det.heartbeat(1, 0, now=0.0)
    assert not det.poll(0, now=0.5)
    det.heartbeat(0, 1, now=2.0)     # rank 1's heartbeat is now stale
    assert det.poll(1, now=2.0) == FaultReport((1,), ())
    det.heartbeat(1, 2, now=2.5)
    assert det.poll(2, now=2.5) == FaultReport((), (1,))


def test_fault_detector_validation():
    with pytest.raises(ValueError, match="num_ranks"):
        FaultDetector(0)
    with pytest.raises(ValueError, match="miss_threshold"):
        FaultDetector(2, miss_threshold=0)
    det = FaultDetector(2)
    with pytest.raises(ValueError, match="out of range"):
        det.heartbeat(2, 0)


def test_injector_feeds_detector_deterministically():
    """The harness wiring (runtime/server.py): the injector suppresses the
    victims' heartbeats, so detection lands exactly kill_step +
    miss_threshold - 1 boundaries later — identical on every run."""
    def run():
        inj = FaultInjector(4, kill={3: 2}, rejoin={8: 2})
        det = FaultDetector(4, miss_threshold=2)
        events = []
        for step in range(12):
            inj.advance(step)
            for r in range(4):
                if inj.is_alive(r):
                    det.heartbeat(r, step)
            rep = det.poll(step)
            if rep:
                events.append((step, rep))
        return events

    a, b = run(), run()
    assert a == b
    # killed at 3 (last heartbeat step 2): missed >= 2 first at poll(4);
    # rejoin heartbeat at 8 is seen by poll(8)
    assert a == [(4, FaultReport((2,), ())), (8, FaultReport((), (2,)))]
