"""Paged KV pool + split-KV decode attention + continuous batching
(docs/DESIGN.md §10): the allocator never aliases a page across live owners
and fails LOUDLY naming its capacity; the two-stage Pallas decode kernel
matches the chunked-attention oracle in interpret mode — GQA and absorbed
MLA, every split count, ragged last pages, recycled-page garbage; and the
continuous-batching engine's per-request token streams are BITWISE identical
to running each request alone — including join/leave mid-stream and across a
heat-driven placement swap (the rank-kill transition is pinned next door in
tests/test_elastic.py)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# CI seed matrix: the interpret-parity job re-runs this file under several
# seeds (REPRO_TEST_SEED) — data/tables vary, every invariant must hold
SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))

from repro.configs import get_smoke
from repro.kernels import decode_attention as DA
from repro.kernels import ref as KREF
from repro.models.attention import _sdpa_chunked
from repro.models.kv_pages import (PageAllocator, PagePoolExhausted,
                                   pages_for_tokens, write_token)
from repro.runtime.scheduler import ContinuousScheduler, Request
from repro.runtime.server import ContinuousDecodeServer


# --------------------------------------------------------------------------
# allocator invariants
# --------------------------------------------------------------------------

def test_allocator_never_aliases_live_pages():
    a = PageAllocator(16, 4)
    r1, r2, r3 = a.alloc(5), a.alloc(4), a.alloc(7)
    ids = r1 + r2 + r3
    assert sorted(ids) == list(range(16))      # all distinct, full pool
    assert a.free_count == 0 and a.live_count == 16
    a.free(r2)
    r4 = a.alloc(4)                            # recycles r2's pages...
    assert not set(r4) & (set(r1) | set(r3))   # ...but never a LIVE page
    assert a.peak_live == 16                   # high-water survives the free


def test_allocator_exhaustion_is_loud_and_atomic():
    a = PageAllocator(4, 8)
    a.alloc(3)
    # the failure names request size, free count, capacity, and page size —
    # actionable without a debugger
    with pytest.raises(PagePoolExhausted,
                       match=r"requested 2 page\(s\) with 1 free of 4 total "
                             r"\(page_size=8\)"):
        a.alloc(2)
    assert a.free_count == 1                   # failed alloc took nothing
    assert a.alloc(1) is not None


def test_allocator_double_free_raises():
    a = PageAllocator(4, 8)
    (pid,) = a.alloc(1)
    a.free([pid])
    with pytest.raises(ValueError, match=f"page {pid}"):
        a.free([pid])


def test_pages_for_tokens_ceil():
    assert pages_for_tokens(1, 4) == 1
    assert pages_for_tokens(4, 4) == 1
    assert pages_for_tokens(5, 4) == 2
    assert pages_for_tokens(0, 4) == 0


# --------------------------------------------------------------------------
# split-KV kernel parity (interpret mode; smoke dims are below the ops.py
# TPU-alignment gates, so the kernel is exercised DIRECTLY — the ops wrapper
# would route these shapes to the jnp oracle)
# --------------------------------------------------------------------------

def _dense_softmax_ref(q, k, v, lens, scale):
    """Straight numpy softmax over the first lens[b] gathered positions —
    independent of both the kernel and the jnp oracle."""
    B, Hq, dk = q.shape
    Hkv, G = k.shape[2], Hq // k.shape[2]
    dv = v.shape[-1]
    out = np.zeros((B, Hq, dv), np.float32)
    for b in range(B):
        n = int(lens[b])
        if n == 0:
            continue
        kk = k[b, :n].astype(np.float64)                 # [n, Hkv, dk]
        vv = v[b, :n].astype(np.float64)
        for h in range(Hq):
            s = kk[:, h // G] @ q[b, h].astype(np.float64) * scale
            p = np.exp(s - s.max())
            out[b, h] = (p / p.sum()) @ vv[:, h // G]
    return out


def _paged_case(rng, *, B, Hkv, G, dk, dv, page, max_pages, lens,
                share_kv=False):
    """Random pool + SHUFFLED page tables + garbage in every unreferenced
    page (pad page included) — parity must hold regardless."""
    P = B * max_pages
    k_pool = rng.randn(P + 1, page, Hkv, dk).astype(np.float32)
    v_pool = rng.randn(P + 1, page, Hkv, dv).astype(np.float32)
    perm = rng.permutation(P)
    tbl = np.full((B, max_pages), P, np.int32)
    kd, vd = (np.zeros((B, max_pages * page, Hkv, dk), np.float32),
              np.zeros((B, max_pages * page, Hkv, dv), np.float32))
    for b in range(B):
        used = pages_for_tokens(int(lens[b]), page)
        tbl[b, :used] = perm[b * max_pages:b * max_pages + used]
        for j in range(used):
            kd[b, j * page:(j + 1) * page] = k_pool[tbl[b, j]]
            vd[b, j * page:(j + 1) * page] = (
                k_pool[tbl[b, j], :, :, :dv] if share_kv else v_pool[tbl[b, j]])
    q = rng.randn(B, Hkv * G, dk).astype(np.float32)
    return q, k_pool, v_pool, tbl, kd, vd


@pytest.mark.parametrize("splits", [1, 2, 4])
def test_kernel_matches_oracle_gqa(splits):
    """GQA, ragged last page (10 % 4 = 2), full row, and an IDLE row
    (kv_len 0, all-pad table) — kernel ≡ oracle ≡ dense softmax."""
    rng = np.random.RandomState(SEED + 11)
    B, Hkv, G, dk, dv, page, max_pages = 3, 2, 2, 16, 16, 4, 4
    lens = np.array([10, 16, 0], np.int32)
    scale = dk ** -0.5
    q, kp, vp, tbl, kd, vd = _paged_case(
        rng, B=B, Hkv=Hkv, G=G, dk=dk, dv=dv, page=page,
        max_pages=max_pages, lens=lens)
    got = DA.paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tbl),
        jnp.asarray(lens), scale=scale, num_kv_splits=splits, interpret=True)
    ref = KREF.paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tbl),
        jnp.asarray(lens), scale=scale, num_kv_splits=splits)
    dense = _dense_softmax_ref(q, kd, vd, lens, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), dense, rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(got)[2] == 0.0)   # idle row: EXACT zeros


@pytest.mark.parametrize("splits", [1, 2, 4])
def test_kernel_matches_oracle_mla_shared_pool(splits):
    """Absorbed-MLA share-kv mode: ONE pool of [ckv | k_rope] rows
    (Hkv == 1), values = leading kv_lora_rank columns, v_pages=None."""
    rng = np.random.RandomState(SEED + 13)
    B, dk, dv, page, max_pages = 3, 24, 16, 4, 4   # dk = r_kv 16 + rope 8
    Hq = 4
    lens = np.array([7, 13, 0], np.int32)
    scale = dk ** -0.5
    q, kp, _, tbl, kd, vd = _paged_case(
        rng, B=B, Hkv=1, G=Hq, dk=dk, dv=dv, page=page,
        max_pages=max_pages, lens=lens, share_kv=True)
    got = DA.paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), None, jnp.asarray(tbl),
        jnp.asarray(lens), scale=scale, num_kv_splits=splits, dv=dv,
        interpret=True)
    ref = KREF.paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), None, jnp.asarray(tbl),
        jnp.asarray(lens), scale=scale, num_kv_splits=splits, dv=dv)
    dense = _dense_softmax_ref(q, kd, vd, lens, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), dense, rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(got)[2] == 0.0)


def test_kernel_matches_chunked_prefill_last_row():
    """Cross-check against the PREFILL path: the last causal row of
    ``_sdpa_chunked`` over [B, S] must equal the paged decode of token S-1
    against the first S-1 cached tokens plus itself."""
    rng = np.random.RandomState(SEED + 17)
    B, S, Hkv, G, d, page = 2, 14, 2, 2, 16, 4     # ragged: 14 % 4 = 2
    Hq = Hkv * G
    q = rng.randn(B, S, Hq, d).astype(np.float32)
    k = rng.randn(B, S, Hkv, d).astype(np.float32)
    v = rng.randn(B, S, Hkv, d).astype(np.float32)
    scale = d ** -0.5
    pre = _sdpa_chunked(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        None, scale, None, chunk=8)   # 14 % 8 != 0 too
    # scatter ALL S tokens into pages (identity-ordered tables suffice —
    # shuffled tables are covered above), decode the last token
    max_pages = pages_for_tokens(S, page)
    P = B * max_pages
    kp = np.zeros((P + 1, page, Hkv, d), np.float32)
    vp = np.zeros((P + 1, page, Hkv, d), np.float32)
    tbl = np.full((B, max_pages), P, np.int32)
    for b in range(B):
        for j in range(max_pages):
            pid = b * max_pages + j
            tbl[b, j] = pid
            rows = k[b, j * page:(j + 1) * page]
            kp[pid, :rows.shape[0]] = rows
            rows = v[b, j * page:(j + 1) * page]
            vp[pid, :rows.shape[0]] = rows
    got = DA.paged_decode_attention(
        jnp.asarray(q[:, -1]), jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(tbl), jnp.asarray(np.full(B, S, np.int32)), scale=scale,
        num_kv_splits=2, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(pre[:, -1], np.float32),
                               rtol=2e-5, atol=2e-5)


def test_write_token_lands_at_table_slot_and_pad_for_idle():
    pool = jnp.zeros((5, 4, 1, 2), jnp.float32)     # 4 pages + pad row 4
    tbl = jnp.asarray([[2, 0], [4, 4]], jnp.int32)  # row 1 idle (all pad)
    new = jnp.asarray([[[1.0, 2.0]], [[9.0, 9.0]]], jnp.float32)
    out = write_token(pool, new, tbl, jnp.asarray([5, 0], jnp.int32))
    assert np.allclose(np.asarray(out)[0, 1, 0], [1.0, 2.0])  # page 0, off 1
    assert np.allclose(np.asarray(out)[4, 0, 0], [9.0, 9.0])  # pad page
    assert np.asarray(out)[2].sum() == 0            # nothing else written


# --------------------------------------------------------------------------
# satellite: configurable kv_chunk, ragged max_len % chunk != 0
# --------------------------------------------------------------------------

def test_kv_chunk_ragged_tail_exact():
    """S not a multiple of the chunk: the zero-padded tail must be masked
    EXACTLY — chunk widths that do and don't divide S all agree."""
    rng = np.random.RandomState(SEED + 19)
    B, S, Hkv, G, d = 2, 50, 2, 2, 16
    q = jnp.asarray(rng.randn(B, S, Hkv * G, d), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, d), jnp.float32)
    full = _sdpa_chunked(q, k, v, None, d ** -0.5, None, chunk=S)
    for chunk in (24, 32, 50, 64):                 # 50 % 24, 50 % 32 != 0
        got = _sdpa_chunked(q, k, v, None, d ** -0.5, None, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=2e-5, atol=2e-5)


def test_kv_chunk_is_config_not_module_global():
    from repro.models import attention as A
    assert not hasattr(A, "_KV_CHUNK")             # the old mutable global
    cfg = get_smoke("dbrx-132b")
    assert cfg.attn.kv_chunk == 1024
    c2 = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, kv_chunk=512))
    assert c2.attn.kv_chunk == 512 and cfg.attn.kv_chunk == 1024


# --------------------------------------------------------------------------
# model-level: paged decode step vs dense decode step (logits agreement)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dbrx-132b", "minicpm3-4b"])
def test_paged_step_matches_dense_step_logits(arch):
    """Drive the SAME token sequence through the dense decode step and the
    paged decode step (f32): logits agree to numerical tolerance at every
    position — GQA and absorbed MLA. (Bitwise token equality is asserted
    between continuous and solo runs of the SAME paged engine below; dense
    vs paged reassociates the softmax so it is allclose, not bitwise.)"""
    from repro.models import get_model
    from repro.parallel.sharding import init_from_specs
    from repro.runtime.steps import paged_serve_state_specs, serve_state_specs
    cfg = dataclasses.replace(get_smoke(arch), dtype=jnp.float32)
    model = get_model(cfg)
    B, T, page = 2, 9, 4
    max_pages = pages_for_tokens(T, page)
    params = init_from_specs(jax.random.PRNGKey(SEED), model.params_spec(cfg),
                             None)
    dense_spec, _ = serve_state_specs(cfg, B, 16)
    paged_spec, _ = paged_serve_state_specs(cfg, B, B * max_pages, page,
                                            max_pages)
    st_d = jax.tree.map(jnp.zeros_like,
                        init_from_specs(jax.random.PRNGKey(1), dense_spec, None))
    st_p = jax.tree.map(jnp.zeros_like,
                        init_from_specs(jax.random.PRNGKey(1), paged_spec, None))
    toks = np.random.RandomState(SEED + 23).randint(0, cfg.vocab, (B, T))
    tbl = np.arange(B * max_pages, dtype=np.int32).reshape(B, max_pages)
    for t in range(T):
        batch = dict(tokens=jnp.asarray(toks[:, t:t + 1], jnp.int32))
        ld, st_d = model.decode_step(params, st_d, batch, cfg, None)
        batch.update(page_tbl=jnp.asarray(tbl),
                     kv_lens=jnp.full((B,), t, jnp.int32),
                     active=jnp.ones((B,), jnp.int32))
        lp, st_p = model.paged_decode_step(params, st_p, batch, cfg, None)
        np.testing.assert_allclose(np.asarray(ld, np.float32),
                                   np.asarray(lp, np.float32),
                                   rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# continuous batching: join/leave mid-stream, bitwise solo parity
# --------------------------------------------------------------------------

def _requests():
    return [
        Request(0, np.array([3, 5, 7], np.int32), 6, arrival_step=0),
        Request(1, np.array([11, 2], np.int32), 8, arrival_step=0),
        Request(2, np.array([9, 9, 9, 9, 1], np.int32), 5, arrival_step=4),
        Request(3, np.array([4], np.int32), 7, arrival_step=6),
    ]


@pytest.mark.parametrize("arch", ["dbrx-132b", "minicpm3-4b"])
def test_continuous_bitwise_matches_solo(arch):
    """The acceptance bar: requests joining and leaving mid-stream — slots
    recycled, pages recycled LIFO under live neighbours — produce per-request
    token streams BITWISE identical to each request running alone through
    the same engine. Exact-zero masking + batch-row independence, not
    tolerance."""
    cfg = get_smoke(arch)
    reqs = _requests()
    srv = ContinuousDecodeServer(cfg, batch=3, max_len=32, page_size=4)
    m = srv.serve_requests(reqs)
    cont = {r.rid: srv.reqsched.tokens_for(r.rid) for r in reqs}
    srv.close()
    assert m.requests_completed == 4
    assert all(len(cont[r.rid]) == r.max_new_tokens for r in reqs)
    # with 3 slots and 4 requests, request 3 joined a slot recycled from a
    # completed neighbour at least once
    assert m.serve_steps > max(r.prompt.size + r.max_new_tokens for r in reqs)
    assert m.pages_peak <= m.pages_dense_equiv
    for r in reqs:
        solo = ContinuousDecodeServer(cfg, batch=3, max_len=32, page_size=4)
        solo.serve_requests([Request(r.rid, r.prompt, r.max_new_tokens)])
        st = solo.reqsched.tokens_for(r.rid)
        solo.close()
        np.testing.assert_array_equal(cont[r.rid], st)


def test_continuous_releases_all_pages_and_reservations():
    cfg = get_smoke("dbrx-132b")
    srv = ContinuousDecodeServer(cfg, batch=2, max_len=32, page_size=4,
                                 num_pages=8)      # tight pool: forces queueing
    srv.serve_requests(_requests())
    sched = srv.reqsched
    srv.close()
    assert sched.done
    assert sched.alloc.live_count == 0 and sched._reserved == 0
    assert sched.alloc.free_count == 8
    assert np.all(sched._tbl == sched.alloc.pad_page)   # every slot reset
    assert np.all(sched._active == 0)


def test_scheduler_admission_is_reservation_gated():
    """A request is admitted only when the pool can cover its WORST-CASE
    footprint on top of live reservations — lazy alloc then can never raise
    PagePoolExhausted mid-flight."""
    alloc = PageAllocator(4, 4)                    # 16 tokens of pool
    reqs = [Request(0, np.arange(6, dtype=np.int32), 5, arrival_step=0),
            Request(1, np.arange(4, dtype=np.int32), 5, arrival_step=0)]
    # each needs ceil((6+5-1)/4)=3 / ceil((4+5-1)/4)=2 pages: both at once
    # would need 5 > 4, so request 1 must wait for request 0 to finish
    sched = ContinuousScheduler(reqs, 2, 4, alloc)
    feed = sched.advance(0)
    assert list(feed["active"]) == [1, 0]          # only request 0 admitted
    assert sched._reserved + alloc.live_count <= alloc.num_pages
    step = 0
    while not sched.done and step < 64:
        if step:
            feed = sched.advance(step)
        sched.observe(np.zeros((2, 1), np.int32))
        step += 1
    assert sched.done and sorted(sched.finished) == [0, 1]
    assert alloc.live_count == 0


def test_scheduler_rejects_request_larger_than_pool():
    alloc = PageAllocator(2, 4)
    big = Request(7, np.arange(9, dtype=np.int32), 4)   # 12 tokens = 3 pages
    with pytest.raises(ValueError, match="request 7: needs 3 pages"):
        ContinuousScheduler([big], 1, 8, alloc)


def test_continuous_rejects_capacity_factor_and_bad_page_size():
    cfg = get_smoke("dbrx-132b")
    with pytest.raises(ValueError, match="kv_chunk"):
        ContinuousDecodeServer(cfg, batch=2, max_len=16, page_size=3)
    capped = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.5))
    with pytest.raises(ValueError, match="zero-drop"):
        ContinuousDecodeServer(capped, batch=2, max_len=16, page_size=4)


# --------------------------------------------------------------------------
# composition: bitwise parity ACROSS a heat-driven placement swap
# --------------------------------------------------------------------------

def test_continuous_bitwise_across_placement_swap():
    """EPLB swaps mid-serve (PR 2–5 contract) compose with continuous
    batching: placement only moves WHERE experts compute, so per-request
    streams stay bitwise equal to the no-rebalance run — and the engine
    re-jitted at least once."""
    from repro.core import placement as PL
    E = 8
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True, params_physical=True,
                              placement=PL.redundant_placement(E, 8, E))
    cfg = dataclasses.replace(cfg, moe=moe)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    reqs = _requests()

    srv_a = ContinuousDecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                                   page_size=4, num_redundant_experts=E)
    srv_a.serve_requests([dataclasses.replace(r) for r in reqs])
    base = {r.rid: srv_a.reqsched.tokens_for(r.rid) for r in reqs}
    srv_a.close()

    srv_b = ContinuousDecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                                   page_size=4, num_redundant_experts=E,
                                   rebalance_every=4)
    srv_b.serve_requests([dataclasses.replace(r) for r in reqs])
    swapped = {r.rid: srv_b.reqsched.tokens_for(r.rid) for r in reqs}
    assert len(srv_b.placements) >= 1              # at least one swap adopted
    assert len(srv_b._step_cache) >= 1
    srv_b.close()
    for r in reqs:
        np.testing.assert_array_equal(base[r.rid], swapped[r.rid])
