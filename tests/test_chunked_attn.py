"""Chunked online-softmax attention (the XLA flash-attention dataflow) must
agree exactly with the dense-score reference — GQA and MLA paths, with and
without sliding windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _sdpa, _sdpa_chunked, _scores_mask
from repro.models import mla as MLA
from repro.configs import get_smoke


@pytest.mark.parametrize("Hq,Hkv", [(8, 8), (8, 2)])
@pytest.mark.parametrize("window", [None, 48])
def test_chunked_matches_dense(Hq, Hkv, window):
    B, S, hd = 2, 128, 32
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, S, Hq, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, hd), jnp.float32)
    scale = hd ** -0.5
    got = _sdpa_chunked(q, k, v, None, scale, window, chunk=32)
    pos = jnp.arange(S)
    want = _sdpa(q, k, v, _scores_mask(pos, pos, window), None, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_softcap():
    B, S, H, hd = 1, 64, 4, 16
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    got = _sdpa_chunked(q, k, v, 20.0, 0.25, None, chunk=16)
    pos = jnp.arange(S)
    want = _sdpa(q, k, v, _scores_mask(pos, pos, None), 20.0, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_mla_chunked_matches_dense():
    cfg = get_smoke("minicpm3-4b")
    from repro.parallel.sharding import init_from_specs
    p = init_from_specs(jax.random.PRNGKey(0), MLA.mla_spec(cfg))
    rng = np.random.RandomState(2)
    B, S = 1, 64
    x = jnp.asarray(rng.randn(B, S, cfg.d_model) * 0.1, jnp.float32).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    m = cfg.mla
    kv = x @ p["wkv_a"]
    from repro.models.layers import rmsnorm, apply_rope
    ckv = rmsnorm(kv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], pos,
                        cfg.attn.rope_base, 1.0)[:, :, 0]
    q_nope, q_rope = MLA._q_proj(p, x, cfg, pos)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    got = MLA._mla_chunked(p, q_nope, q_rope, ckv, k_rope, scale, x.dtype,
                           chunk=16)
    # dense reference
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wv_b"])
    s = (jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope,
                    preferred_element_type=jnp.float32) +
         jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope,
                    preferred_element_type=jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
    s = jnp.where(mask[None, None], s, -1e30)
    prob = jax.nn.softmax(s, -1).astype(x.dtype)
    want = jnp.einsum("bhqs,bshk->bqhk", prob, v,
                      preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want.transpose(0, 2, 1, 3)
                                          .transpose(0, 2, 1, 3), np.float32),
                               rtol=3e-2, atol=3e-2)
