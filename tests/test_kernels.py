"""Per-kernel validation: Pallas (interpret=True — executes the kernel body on
CPU) against the pure-jnp oracle in kernels/ref.py, swept over shapes and
dtypes. interpret mode is slow on this 1-core host, so sweeps are compact but
cover the alignment-relevant boundaries (128-lane tiles, K extremes, dtypes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.combine_reduce import combine_reduce as cr_pallas
from repro.kernels.combine_gather_reduce import combine_gather_reduce as cgr_pallas
from repro.kernels.dispatch_pack import dispatch_pack as dp_pallas
from repro.kernels.fp8 import quantize_fp8 as qfp8_pallas
from repro.kernels.fp8 import dequantize_fp8 as dqfp8_pallas
from repro.kernels.grouped_gemm import grouped_gemm as gg_pallas
from repro.kernels.recv_unpack import recv_unpack as ru_pallas


def tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,K,H", [(8, 2, 128), (16, 8, 256), (32, 4, 512), (8, 16, 128)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_combine_reduce(T, K, H, dt):
    rng = np.random.RandomState(0)
    y = jnp.asarray(rng.randn(T, K, H), dt)
    w = jax.nn.softmax(jnp.asarray(rng.randn(T, K), jnp.float32), -1)
    got = cr_pallas(y, w, interpret=True)
    want = ref.combine_reduce(y, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dt))


@pytest.mark.parametrize("bt,bh", [(4, 128), (8, 256)])
def test_combine_reduce_tilings(bt, bh):
    rng = np.random.RandomState(1)
    y = jnp.asarray(rng.randn(16, 4, 256), jnp.float32)
    w = jnp.asarray(rng.rand(16, 4), jnp.float32)
    got = cr_pallas(y, w, bt=bt, bh=bh, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.combine_reduce(y, w)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,H,N,C", [(16, 128, 4, 8), (8, 256, 8, 4)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_dispatch_pack_copy(T, H, N, C, dt):
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(T, H), dt)
    gmap = jnp.asarray(rng.randint(0, T + 1, (N, C)), jnp.int32)  # T == sentinel
    got, _ = dp_pallas(x, gmap, out_dtype=dt, interpret=True)
    want, _ = ref.dispatch_pack(x, gmap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want.astype(dt), np.float32), **tol(dt))


@pytest.mark.parametrize("T,H,qb", [(8, 256, 128), (16, 128, 128)])
def test_dispatch_pack_quantized(T, H, qb):
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(T, H) * 3, jnp.float32)
    gmap = jnp.asarray(rng.randint(0, T + 1, (4, 8)), jnp.int32)
    q, s = dp_pallas(x, gmap, quant_block=qb, interpret=True)
    qr, sr = ref.dispatch_pack(x, gmap, quant_block=qb)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6, atol=1e-6)
    got = ref.dequantize_fp8(q.reshape(-1, H), s.reshape(-1, H // qb))
    want = ref.dequantize_fp8(qr.reshape(-1, H), sr.reshape(-1, H // qb))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("L,A,H,F", [(2, 128, 128, 128), (4, 256, 256, 128)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm(L, A, H, F, dt):
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(L, A, H) * 0.1, dt)
    w = jnp.asarray(rng.randn(L, H, F) * 0.1, dt)
    counts = jnp.asarray(rng.randint(0, A + 1, (L,)), jnp.int32)
    got = gg_pallas(x, w, counts, interpret=True)
    want = ref.grouped_gemm(x, w, counts)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dt == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dt == jnp.bfloat16 else 1e-4)


def test_grouped_gemm_count_masking():
    """Rows at/beyond counts must be exactly zero; rows below must be exact."""
    L, A, H, F = 2, 256, 128, 128
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(L, A, H), jnp.float32)
    w = jnp.asarray(rng.randn(L, H, F), jnp.float32)
    counts = jnp.asarray([100, 0], jnp.int32)
    got = np.asarray(gg_pallas(x, w, counts, interpret=True))
    assert np.all(got[0, 100:] == 0) and np.all(got[1] == 0)
    want = np.einsum("ah,hf->af", np.asarray(x[0]), np.asarray(w[0]))[:100]
    np.testing.assert_allclose(got[0, :100], want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("R,T,K,H", [(32, 8, 2, 128), (16, 8, 4, 256), (64, 4, 1, 128),
                                     (16, 4, 2, 640)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_combine_gather_reduce(R, T, K, H, dt):
    """Fused gather+reduce vs the two-pass oracle, sentinel rows included."""
    rng = np.random.RandomState(7)
    recv = jnp.asarray(rng.randn(R, H), dt)
    rows = jnp.asarray(rng.randint(0, R + 1, (T, K)), jnp.int32)  # R == sentinel
    w = jax.nn.softmax(jnp.asarray(rng.randn(T, K), jnp.float32), -1)
    got = cgr_pallas(recv, rows, w, interpret=True)
    want = ref.combine_gather_reduce(recv, rows, w)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dt))


def test_combine_gather_reduce_all_sentinel():
    recv = jnp.asarray(np.random.RandomState(8).randn(8, 128), jnp.float32)
    rows = jnp.full((4, 2), 8, jnp.int32)
    w = jnp.ones((4, 2), jnp.float32)
    got = np.asarray(cgr_pallas(recv, rows, w, interpret=True))
    assert np.all(got == 0)


@pytest.mark.parametrize("R,H,D,C", [(32, 128, 2, 8), (16, 256, 4, 4),
                                     (64, 640, 3, 8)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_recv_unpack_copy_bitwise(R, H, D, C, dt):
    """Fused recv unpack (copy mode) vs the gather reference — bitwise,
    sentinel slots included."""
    rng = np.random.RandomState(11)
    recv = jnp.asarray(rng.randn(R, H), dt)
    gmap = jnp.asarray(rng.randint(0, R + 1, (D, C)), jnp.int32)  # R == sentinel
    got = ru_pallas(recv, gmap, interpret=True)
    want = ref.recv_unpack(recv, gmap)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("R,H,D,C", [(32, 256, 2, 8), (16, 128, 4, 4)])
def test_recv_unpack_dequant_bitwise(R, H, D, C):
    """Fused recv unpack (fp8 dequant mode) vs the two-pass gather+dequant
    reference — bitwise (same f32 math elementwise)."""
    rng = np.random.RandomState(12)
    x = jnp.asarray(rng.randn(R, H) * 4, jnp.float32)
    q, s = ref.quantize_fp8(x, 128)
    gmap = jnp.asarray(rng.randint(0, R + 1, (D, C)), jnp.int32)
    got = ru_pallas(q, gmap, s, interpret=True)
    want = ref.recv_unpack(q, gmap, s)
    assert got.dtype == want.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_recv_unpack_ref_matches_two_pass():
    """The recv_unpack reference IS the seed's two-pass semantics: gather
    with zero fill, then block dequant over zero-filled scales."""
    from repro.core import slots as S
    rng = np.random.RandomState(13)
    R, H = 24, 256
    x = jnp.asarray(rng.randn(R, H) * 2, jnp.float32)
    q, s = ref.quantize_fp8(x, 128)
    gmap = jnp.asarray(rng.randint(0, R + 1, (4, 8)), jnp.int32)
    want = ref.dequantize_fp8(S.gather_rows(q, gmap),
                              S.gather_rows(s, gmap, fill=0))
    got = ref.recv_unpack(q, gmap, s)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_recv_unpack_all_sentinel_and_cast():
    recv = jnp.asarray(np.random.RandomState(14).randn(8, 128), jnp.bfloat16)
    gmap = jnp.full((2, 4), 8, jnp.int32)
    got = np.asarray(ru_pallas(recv, gmap, interpret=True), np.float32)
    assert np.all(got == 0)
    # out_dtype cast in copy mode
    got32 = ru_pallas(recv, gmap, out_dtype=jnp.float32, interpret=True)
    assert got32.dtype == jnp.float32


@pytest.mark.parametrize("M,H,block", [(8, 256, 128), (16, 512, 128), (8, 128, 128),
                                       (8, 640, 128)])
def test_fp8_quantize_pallas_matches_ref(M, H, block):
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.randn(M, H) * 4, jnp.float32)
    q, s = qfp8_pallas(x, block, interpret=True)
    qr, sr = ref.quantize_fp8(x, block)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6, atol=0)
    got = ref.dequantize_fp8(q, s, jnp.float32)
    want = ref.dequantize_fp8(qr, sr, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M,H,block", [(8, 256, 128), (16, 128, 128)])
def test_fp8_dequantize_pallas_matches_ref(M, H, block):
    rng = np.random.RandomState(10)
    x = jnp.asarray(rng.randn(M, H) * 4, jnp.float32)
    q, s = ref.quantize_fp8(x, block)
    got = dqfp8_pallas(q, s, jnp.float32, interpret=True)
    want = ref.dequantize_fp8(q, s, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_quantize_zero_rows_unit_scale():
    """Zero groups must quantize with unit scale in both implementations."""
    x = jnp.zeros((8, 256), jnp.float32)
    q, s = qfp8_pallas(x, 128, interpret=True)
    qr, sr = ref.quantize_fp8(x, 128)
    np.testing.assert_array_equal(np.asarray(s), np.ones((8, 2), np.float32))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_quantize_roundtrip_accuracy():
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(32, 512) * 5, jnp.float32)
    q, s = ref.quantize_fp8(x, 128)
    back = ref.dequantize_fp8(q, s, out_dtype=jnp.float32)
    rel = np.abs(np.asarray(back) - np.asarray(x)).mean() / np.abs(np.asarray(x)).mean()
    assert rel < 0.04, rel  # e4m3 block-quant: ~2-3% mean relative error
