"""moe_block integration: the sharded EP path (shard_map + dispatch/combine)
must compute the same function as the dense reference fallback, for both EP
layouts: EP=data (expert-TP over model) and wide EP=(data, model)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.moe import moe_block, moe_spec, _moe_dense_fallback
from repro.parallel.sharding import init_from_specs, ShardingRules, DEFAULT_RULES


def mk_mesh(shape, axes):
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def run_block(cfg, mesh, x):
    rules = dict(DEFAULT_RULES.rules)
    rules["expert"] = cfg.moe.ep_axis
    rules["expert_ffn"] = ("model",) if "model" not in cfg.moe.ep_axis else None
    p = init_from_specs(jax.random.PRNGKey(0), moe_spec(cfg), mesh,
                        ShardingRules(rules=rules))
    y, aux = jax.jit(lambda p, x: moe_block(p, x, cfg, mesh))(p, x)
    ref = _moe_dense_fallback(jax.device_get(p), x, cfg)
    return np.asarray(y, np.float32), np.asarray(ref, np.float32)


@pytest.mark.parametrize("ep_axis,mode", [
    (("data",), "ht"), (("data",), "ll"), (("data", "model"), "ht"),
])
def test_moe_block_matches_dense(ep_axis, mode):
    cfg = get_smoke("dbrx-132b")
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, moe=dataclasses.replace(
        cfg.moe, ep_axis=ep_axis, ep_mode=mode, capacity_factor=None,
        expert_capacity_factor=None))
    mesh = mk_mesh((4, 2), ("data", "model"))
    rng = np.random.RandomState(0)
    B, S = 4, 8
    x = jnp.asarray(rng.randn(B, S, cfg.d_model) * 0.1, jnp.float32)
    y, ref = run_block(cfg, mesh, x)
    np.testing.assert_allclose(y, ref, rtol=5e-3, atol=5e-3)


def test_moe_block_hierarchical_matches_dense():
    cfg = get_smoke("dbrx-132b")
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, moe=dataclasses.replace(
        cfg.moe, ep_axis=("data", "model"), ep_mode="ht",
        ht_hierarchical=True, capacity_factor=None,
        expert_capacity_factor=None))
    mesh = mk_mesh((4, 2), ("data", "model"))
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(4, 8, cfg.d_model) * 0.1, jnp.float32)
    y, ref = run_block(cfg, mesh, x)
    np.testing.assert_allclose(y, ref, rtol=5e-3, atol=5e-3)
