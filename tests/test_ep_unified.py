"""Unified-API behaviour: mode selection at group creation, baseline parity,
auto mode, tagged tensors, and the property tests (hypothesis) over the
system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,
                        ep_dispatch, ep_combine, EpTensor, EpTensorTag,
                        ep_dispatch_tensors)


def run_mode(cfg, x, topk, w):
    N = x.shape[0]
    mesh = jax.make_mesh((N,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ep_create_handle(group, topk, w)
        y3d, counts = ep_dispatch(group, h, x)
        me = jax.lax.axis_index("data")
        e_glob = me * group.local_experts + jnp.arange(group.local_experts)
        y3d = y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
        out = ep_combine(group, h, y3d)
        return out[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                              out_specs=P("data")))
    return f(x, topk, w)


def oracle(x, topk, w):
    return x * (w * (1.0 + topk)).sum(-1)[..., None]


def mk(rng, N, T, K, E, H):
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(np.stack([
        np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
        for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    return x, topk, w


@pytest.mark.parametrize("mode", ["ll", "ht", "baseline"])
def test_all_modes_same_function(mode):
    """The unified API's core promise: switching the algorithm mode at group
    creation never changes results (paper §III-A.i)."""
    N, E, K, T, H = 8, 16, 4, 16, 32
    x, topk, w = mk(np.random.RandomState(0), N, T, K, E, H)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode=mode, payload_dtype=jnp.float32)
    out = run_mode(cfg, x, topk, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle(x, topk, w)),
                               rtol=2e-5, atol=2e-5)


def test_auto_mode_selection():
    cfg = EpGroupConfig(num_experts=8, max_tokens_per_rank=64, hidden=8, top_k=2)
    assert ep_create_group(cfg, ep_size=8).mode == "ll"
    cfg = EpGroupConfig(num_experts=8, max_tokens_per_rank=4096, hidden=8, top_k=2)
    assert ep_create_group(cfg, ep_size=8).mode == "ht"


def test_tagged_tensor_surface():
    N, E, K, T, H = 8, 8, 2, 8, 16
    x, topk, w = mk(np.random.RandomState(1), N, T, K, E, H)
    mesh = jax.make_mesh((N,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w):
        h = ep_create_handle(group, topk[0], w[0])
        out_t, counts_t = ep_dispatch_tensors(
            group, h, [EpTensor(x[0], EpTensorTag.TOKENS)])
        assert out_t.tag == EpTensorTag.TOKENS
        assert counts_t.tag == EpTensorTag.TOKENS_PER_EXPERTS
        return counts_t.data[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                              out_specs=P("data")))
    counts = f(x, topk, w)
    assert int(np.asarray(counts).sum()) == N * T * K


def test_wrong_tag_rejected():
    from repro.core.tensor import validate
    t = EpTensor(jnp.zeros((4, 4)), EpTensorTag.TOPK_WEIGHTS)
    with pytest.raises(ValueError):
        validate(t, tag=EpTensorTag.TOKENS)


# ---------------------------------------------------------------------------
# property-based invariants
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    mode=st.sampled_from(["ll", "ht", "baseline"]),
    ek=st.sampled_from([(8, 2), (16, 4), (32, 8), (8, 8)]),
    t=st.sampled_from([4, 8, 24]),
)
def test_property_roundtrip_and_conservation(seed, mode, ek, t):
    """∀ routing: (1) identity experts + normalized weights reproduce the
    input exactly; (2) every (t,k) entry is delivered exactly once."""
    E, K = ek
    N, H = 8, 16
    rng = np.random.RandomState(seed)
    x, topk, w = mk(rng, N, t, K, E, H)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=t, hidden=H,
                        top_k=K, mode=mode, payload_dtype=jnp.float32)
    mesh = jax.make_mesh((N,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w):
        h = ep_create_handle(group, topk[0], w[0])
        y3d, counts = ep_dispatch(group, h, x[0])
        return ep_combine(group, h, y3d)[None], counts[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                              out_specs=(P("data"), P("data"))))
    out, counts = f(x, topk, w)
    # identity experts, weights sum to 1 -> output == input
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=2e-5, atol=2e-5)
    assert int(np.asarray(counts).sum()) == N * t * K


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_permutation_equivariance(seed):
    """Permuting tokens within a rank permutes outputs identically (LL)."""
    N, E, K, T, H = 8, 16, 4, 8, 16
    rng = np.random.RandomState(seed)
    x, topk, w = mk(rng, N, T, K, E, H)
    perm = rng.permutation(T)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    out1 = run_mode(cfg, x, topk, w)
    out2 = run_mode(cfg, x[:, perm], topk[:, perm], w[:, perm])
    np.testing.assert_allclose(np.asarray(out1[:, perm]), np.asarray(out2),
                               rtol=2e-5, atol=2e-5)
