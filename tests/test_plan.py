"""EpPlan slot-map engine: sort-based positions_by_dest vs the one-hot
oracle (bitwise), the one-pass-per-phase invariant (send AND recv side —
no slot arithmetic in phase bodies, no two-pass gather+dequant unpack),
and plan-driven dispatch/combine round-trips under padding and capacity
drops. Handle refresh / plan reuse lives in tests/test_refresh.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.group import EpGroupConfig, ep_create_group
from repro.core import ll, ht, baseline, plan as plan_mod
from repro.core import slots as S
from repro.kernels import ref


# --------------------------------------------------------------------------
# sort-based engine == one-hot oracle, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("M,D", [(1, 1), (7, 3), (64, 8), (257, 16), (1024, 64)])
def test_positions_by_dest_bitwise_matches_onehot(seed, M, D):
    rng = np.random.RandomState(seed)
    # include out-of-range destinations on both sides and invalid entries —
    # the contract covers them all, bit for bit
    dest = jnp.asarray(rng.randint(-2, D + 3, M), jnp.int32)
    valid = jnp.asarray(rng.rand(M) < 0.7)
    p_sort, c_sort = S.positions_by_dest(dest, D, valid)
    p_ref, c_ref = ref.positions_by_dest(dest, D, valid)
    np.testing.assert_array_equal(np.asarray(p_sort), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(c_sort), np.asarray(c_ref))
    assert p_sort.dtype == p_ref.dtype and c_sort.dtype == c_ref.dtype


def test_positions_by_dest_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 200), st.integers(1, 12), st.integers(0, 2**31 - 1))
    def prop(M, D, seed):
        rng = np.random.RandomState(seed)
        dest = jnp.asarray(rng.randint(-1, D + 2, M), jnp.int32)
        valid = jnp.asarray(rng.rand(M) < 0.6)
        p_s, c_s = S.positions_by_dest(dest, D, valid)
        p_r, c_r = ref.positions_by_dest(dest, D, valid)
        assert np.array_equal(np.asarray(p_s), np.asarray(p_r))
        assert np.array_equal(np.asarray(c_s), np.asarray(c_r))

    prop()


# --------------------------------------------------------------------------
# one-pass-per-phase invariant: no slot arithmetic in phase bodies
# --------------------------------------------------------------------------

def test_no_slot_arithmetic_in_phase_bodies():
    """Slot maps are computed exactly once per handle (in plan.build_plan);
    dispatch/combine bodies must be pure data movement over plan fields.
    The rule (function list + banned names) lives in analysis.contracts —
    this is its test-suite anchor."""
    from repro.analysis.contracts import run_rule
    assert run_rule("phase-one-pass") == []


def test_no_two_pass_recv_unpack():
    """Recv side of the one-pass invariant: no phase module performs a
    gather followed by a separate fp8 dequantization — every recv unpack
    goes through core.recv.unpack_recv, the single call site of the fused
    recv_unpack kernel, and every dequant through core.recv. Shared rule:
    analysis.contracts 'recv-one-pass'."""
    from repro.analysis.contracts import run_rule
    assert run_rule("recv-one-pass") == []


def test_plan_built_once_at_handle_creation():
    """Handles carry a populated EpPlan; ensure_plan returns it untouched."""
    N = 8
    cfg = EpGroupConfig(num_experts=16, max_tokens_per_rank=8, hidden=32,
                        top_k=4, mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    topk = jnp.asarray(rng.randint(0, 16, (N, 8, 4)), jnp.int32)
    w = jnp.ones((N, 8, 4), jnp.float32)

    def step(topk, w):
        h = ll.ll_create_handle(group, topk[0], w[0])
        assert h.plan is not None and h.plan.disp_send_gmap is not None
        assert plan_mod.ensure_plan(group, h) is h.plan
        return h.plan.disp_send_gmap[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 2,
                              out_specs=P("data")))
    gmap = np.asarray(f(topk, w))
    assert gmap.shape == (N, N, group.ll_disp_cap)


# --------------------------------------------------------------------------
# plan-driven round-trips: padding and capacity drops, all modes/layouts
# --------------------------------------------------------------------------

def oracle(x, topk, w):
    scale = (w * (1.0 + topk)).sum(-1)
    return x * scale[..., None]


def run_ep(cfg, x, topk, w, nt=None, module="ll"):
    N = x.shape[0]
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    group = ep_create_group(cfg, ep_size=N)
    mod = {"ll": ll, "ht": ht}[module]
    create = {"ll": ll.ll_create_handle, "ht": ht.ht_create_handle}[module]
    disp = {"ll": ll.ll_dispatch, "ht": ht.ht_dispatch}[module]
    comb = {"ll": ll.ll_combine, "ht": ht.ht_combine}[module]

    def step(x, topk, w, nt):
        x, topk, w = x[0], topk[0], w[0]
        n = nt[0] if nt is not None else None
        h = create(group, topk, w, num_tokens=n)
        y3d, counts = disp(group, h, x)
        me = jax.lax.axis_index("data")
        L = group.local_experts
        e_glob = me * L + jnp.arange(L)
        y3d = y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
        out = comb(group, h, y3d)
        return out[None], counts[None]

    if nt is None:
        f = jax.jit(jax.shard_map(lambda x, t, w: step(x, t, w, None),
                                  mesh=mesh, in_specs=(P("data"),) * 3,
                                  out_specs=(P("data"), P("data"))))
        return f(x, topk, w)
    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 4,
                              out_specs=(P("data"), P("data"))))
    return f(x, topk, w, nt)


def rand_inputs(rng, N, T, K, E, H):
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    return x, topk, w


@pytest.mark.parametrize("module,layout", [("ll", "nccl_ep"), ("ll", "deepep"),
                                           ("ht", "nccl_ep")])
def test_plan_roundtrip_with_padded_tokens(module, layout):
    """num_tokens < T: padded rows must contribute nothing and real rows must
    match the dense oracle exactly — exercises the sentinel-expert chain
    through every precomputed map."""
    N, E, K, T, H = 8, 16, 4, 16, 32
    rng = np.random.RandomState(7)
    x, topk, w = rand_inputs(rng, N, T, K, E, H)
    nt = jnp.asarray(rng.randint(1, T + 1, (N,)), jnp.int32)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode=module, ll_layout=layout, payload_dtype=jnp.float32)
    out, counts = run_ep(cfg, x, topk, w, nt=nt, module=module)
    ref_out = np.asarray(oracle(x, topk, w))
    got = np.asarray(out)
    for r in range(N):
        n = int(nt[r])
        np.testing.assert_allclose(got[r, :n], ref_out[r, :n], rtol=2e-5, atol=2e-5)
    # conservation counts only the valid entries
    assert int(counts.sum()) == int(nt.sum()) * K


def test_plan_roundtrip_capacity_drop():
    """cf < zero-drop: dropped entries zero their contribution but never
    corrupt surviving tokens (LL nccl_ep — the layout with both caps)."""
    N, E, K, T, H = 8, 16, 4, 32, 16
    rng = np.random.RandomState(8)
    x, topk, w = rand_inputs(rng, N, T, K, E, H)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ll", capacity_factor=1.0, payload_dtype=jnp.float32)
    out, _ = run_ep(cfg, x, topk, w, module="ll")
    ref_out = np.asarray(oracle(x, topk, w))
    got = np.asarray(out)
    per_err = np.abs(got - ref_out).max(-1)
    assert (per_err < 1e-4).mean() > 0.5       # most tokens survive at cf=1.0
    assert np.all(np.abs(got).max(-1) <= np.abs(ref_out).max(-1) * (1.0 + K) + 1e-4)


def test_plan_gmaps_match_oracle_construction():
    """The plan's LL nccl_ep dispatch-send map must equal the map built from
    the one-hot oracle's positions — the end-to-end bitwise check that the
    sort-based engine slots entries identically."""
    N = 8
    T, K, E = 16, 4, 16
    L = E // N
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=32,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(3)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jnp.ones((N, T, K), jnp.float32)

    def step(topk, w):
        h = ll.ll_create_handle(group, topk[0], w[0])
        return h.plan.disp_send_gmap[None], h.plan.comb_recv_rows[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 2,
                              out_specs=(P("data"), P("data"))))
    gmaps, rows = map(np.asarray, f(topk, w))

    Cd, Cc = group.ll_disp_cap, group.ll_comb_cap
    for r in range(N):
        # reconstruct with the one-hot oracle in numpy
        dst = np.asarray(topk[r]) // L                      # [T, K]
        sends = np.zeros((T, N), bool)
        for t in range(T):
            for k in range(K):
                sends[t, dst[t, k]] = True
        pos = np.cumsum(sends, 0) - 1
        want = np.full((N, Cd), T, np.int32)
        for t in range(T):
            for d in range(N):
                if sends[t, d] and pos[t, d] < Cd:
                    want[d, pos[t, d]] = t
        np.testing.assert_array_equal(gmaps[r], want)
        # combine rows: running count per destination over (t, k) order
        cnt = np.zeros(N, np.int64)
        for t in range(T):
            for k in range(K):
                d = dst[t, k]
                assert rows[r, t, k] == d * Cc + cnt[d]
                cnt[d] += 1


def test_plan_maps_identity_placement_bitwise():
    """EPLB parity at the MAP level: building the plan through an explicit
    identity placement table must produce bit-identical gather maps to the
    default contiguous `e // L` arithmetic (outputs-level parity across all
    backends lives in tests/test_placement.py)."""
    from repro.core.placement import identity_placement
    N, E, K, T = 8, 16, 4, 16
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(11)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jnp.ones((N, T, K), jnp.float32)

    def maps_for(placement):
        cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=32,
                            top_k=K, mode="ll", payload_dtype=jnp.float32,
                            placement=placement)
        group = ep_create_group(cfg, ep_size=N)

        def step(topk, w):
            h = ll.ll_create_handle(group, topk[0], w[0])
            p = h.plan
            return (p.disp_send_gmap[None], p.disp_recv_gmap[None],
                    p.comb_send_gmap[None], p.comb_recv_rows[None],
                    p.disp_counts[None])

        f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 2,
                                  out_specs=(P("data"),) * 5))
        return [np.asarray(m) for m in f(topk, w)]

    for a, b in zip(maps_for(None), maps_for(identity_placement(E, N))):
        np.testing.assert_array_equal(a, b)


def test_ht_flat_staged_counts_query():
    """disp_counts rides the plan; the paper's GetNumRecvTokens query and the
    per-expert counts must agree with the routing histogram."""
    N, E, K, T, H = 8, 16, 4, 16, 32
    rng = np.random.RandomState(5)
    x, topk, w = rand_inputs(rng, N, T, K, E, H)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ht", payload_dtype=jnp.float32)
    out, counts = run_ep(cfg, x, topk, w, module="ht")
    hist = np.zeros(E)
    for r in range(N):
        for t in range(T):
            for k in range(K):
                hist[int(topk[r, t, k])] += 1
    np.testing.assert_array_equal(np.asarray(counts).reshape(-1), hist)
