"""Elastic fault-tolerant EP (docs/DESIGN.md §9): a rank killed mid-serve by
the deterministic FaultInjector must leave the surviving ranks' greedy token
stream BITWISE-identical to an uninterrupted run whenever the dead rank's
experts have replicas elsewhere; the degraded placement must assign zero
slots to the dead rank; a rejoin must re-expand to full width with the
compiled-step/routing-hash fast path resuming; and the no-replica case must
warn ``DegradedRecovery`` loudly and restore from checkpoint or raise —
never silently corrupt. Plus the driver-level fault path
(``run_rebalancing``/``rebalancing_decode_loop``) and SIGTERM preemption
drain in ``DecodeServer.serve``."""
import dataclasses
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

# CI seed matrix: the interpret-parity job re-runs this file under several
# seeds (REPRO_TEST_SEED) — data/routing vary, every invariant must hold
SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke
from repro.core import (EpGroupConfig, ep_create_handle, ep_dispatch,
                        ep_combine)
from repro.core import placement as PL
from repro.core import plan as plan_mod
from repro.runtime.fault import DegradedRecovery, FaultInjector
from repro.runtime.server import DecodeServer


def _mesh8():
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _cfg_physical(placement):
    """dbrx smoke (E=8 experts on 8 EP ranks) in the adopt-once serving
    layout with an explicit initial placement."""
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True, params_physical=True,
                              placement=placement)
    return dataclasses.replace(cfg, moe=moe)


def _prompts(cfg):
    return jnp.asarray(np.random.RandomState(SEED).randint(
        0, cfg.vocab, (8, 4)), jnp.int32)


def test_kill_midserve_bitwise_tokens_and_rejoin():
    """The acceptance scenario: kill rank 2 mid-decode, rejoin it later.
    Every expert has 2 replicas on distinct ranks (R=E), so the shrink is
    zero-data-loss: (a) tokens bitwise-equal to the uninterrupted run,
    (b) the degraded placement gives the dead rank ZERO slots, (c) rejoin
    re-expands to full width and the fast path resumes, with the placement
    fingerprint salt forcing exactly one handle/step rebuild per
    transition."""
    E = 8
    pl0 = PL.redundant_placement(E, 8, E)      # every expert 2x replicated
    cfg = _cfg_physical(pl0)
    mesh = _mesh8()
    prompts = _prompts(cfg)

    srv_a = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                         num_redundant_experts=E)
    first_a, _ = srv_a.prefill(prompts)
    toks_a, _ = srv_a.decode(first_a, 12)

    inj = FaultInjector(8, kill={3: 2}, rejoin={8: 2})
    srv_b = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                         num_redundant_experts=E, fault_injector=inj,
                         miss_threshold=1)
    first_b, _ = srv_b.prefill(prompts)
    toks_b, _ = srv_b.decode(first_b, 12)

    # (a) surviving-rank tokens bitwise-identical across the kill + rejoin
    np.testing.assert_array_equal(toks_a, toks_b)

    # exactly one shrink + one expand, both zero-data-loss
    assert [e["kind"] for e in srv_b.recoveries] == ["shrink", "expand"]
    assert all(e["lost_experts"] == [] and e["restored_from"] is None
               for e in srv_b.recoveries)
    assert srv_b.recoveries[0]["died"] == [2]
    assert srv_b.recoveries[1]["rejoined"] == [2]

    # (b) degraded placement: zero slots on the dead rank, all experts live
    degraded, expanded = srv_b.placements[-2:]
    assert degraded.dead_ranks() == (2,)
    assert all(e == PL.EMPTY for e in degraded.slot_expert[2])
    assert degraded.num_empty == degraded.slots_per_rank
    assert PL.lost_experts(degraded, degraded.alive_ranks()) == ()

    # (c) rejoin re-expands; the current compiled step is the cached one
    # (fast path resumed) and each transition got its own fingerprint salt
    assert expanded.dead_ranks() == ()
    assert srv_b.cfg.moe.placement is expanded
    assert srv_b.step is srv_b._step_cache[expanded]
    assert len(srv_b._step_cache) <= 2
    fps = [pl0.fingerprint(), degraded.fingerprint(), expanded.fingerprint()]
    assert len(set(fps)) == 3

    # detector wound back to full health; degraded window really was served
    assert srv_b._detector.alive == tuple(range(8))
    assert srv_b._degraded_steps == 5          # boundaries 3..7 ran on N-1


def test_serve_metrics_fault_fields_json_safe():
    E = 8
    pl0 = PL.redundant_placement(E, 8, E)
    cfg = _cfg_physical(pl0)
    inj = FaultInjector(8, kill={2: 1}, rejoin={5: 1})
    srv = DecodeServer(cfg, batch=8, max_len=32, mesh=_mesh8(),
                       num_redundant_experts=E, fault_injector=inj,
                       miss_threshold=1)
    m = srv.serve(_prompts(cfg), gen_steps=8)
    assert m.recovery_count == 2 and m.degraded_steps > 0
    assert m.recovery_latency_s > 0
    assert m.alive_ranks == list(range(8))
    assert [e["kind"] for e in m.recovery_events] == ["shrink", "expand"]
    assert not m.preempted
    json.dumps(m.as_dict())                    # bench_fault emits this


def test_no_replica_death_warns_and_raises_without_checkpoint():
    """(d) the identity placement has NO replicas: killing a rank loses its
    experts' only weights. Without a checkpoint the recovery must warn
    ``DegradedRecovery`` and raise — never serve silently corrupted."""
    E = 8
    cfg = _cfg_physical(PL.identity_placement(E, 8))
    inj = FaultInjector(8, kill={2: 2})        # rank 2 dies at step 2
    srv = DecodeServer(cfg, batch=8, max_len=32, mesh=_mesh8(),
                       fault_injector=inj, miss_threshold=1)
    first, _ = srv.prefill(_prompts(cfg))
    with pytest.warns(DegradedRecovery, match="lost every replica"):
        with pytest.raises(RuntimeError, match="unrecoverable"):
            srv.decode(first, 6)
    assert srv.recoveries[-1]["lost_experts"] == [2]    # rank 2's expert


def test_no_replica_death_restores_from_checkpoint(tmp_path):
    """(d) with ``ckpt_dir`` the no-replica death recovers by restoring the
    whole tree rebound to the degraded placement — still loud (warning +
    event record), and the tokens match the uninterrupted run because the
    restored weights are the very ones that were lost."""
    E = 8
    pl_id = PL.identity_placement(E, 8)
    cfg = _cfg_physical(pl_id)
    mesh = _mesh8()
    prompts = _prompts(cfg)

    srv_a = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh)
    first_a, _ = srv_a.prefill(prompts)
    toks_a, _ = srv_a.decode(first_a, 8)

    inj = FaultInjector(8, kill={2: 2})        # rank 2 dies at step 2
    srv_b = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                         fault_injector=inj, miss_threshold=1,
                         ckpt_dir=str(tmp_path))
    save_checkpoint(tmp_path, 0, srv_b.params, placement=pl_id)
    first_b, _ = srv_b.prefill(prompts)
    with pytest.warns(DegradedRecovery, match="restoring from checkpoint"):
        toks_b, _ = srv_b.decode(first_b, 8)
    np.testing.assert_array_equal(toks_a, toks_b)
    ev = srv_b.recoveries[0]
    assert ev["kind"] == "shrink" and ev["restored_from"] == 0
    assert ev["lost_experts"] == [2]
    assert srv_b.cfg.moe.placement.dead_ranks() == (2,)


def test_preemption_drains_and_checkpoints_decode_server(tmp_path):
    """Satellite: SIGTERM mid-serve drains the pipeline, writes a
    placement-tagged checkpoint, and exits cleanly at a step boundary with
    ``preempted=True`` — the tokens that DID complete are intact."""
    E = 8
    pl0 = PL.redundant_placement(E, 8, E)
    cfg = _cfg_physical(pl0)
    srv = DecodeServer(cfg, batch=8, max_len=32, mesh=_mesh8(),
                       num_redundant_experts=E, pipeline_depth=2,
                       ckpt_dir=str(tmp_path))
    try:
        first, _ = srv.prefill(_prompts(cfg))
        signal.raise_signal(signal.SIGTERM)
        toks, _ = srv.decode(first, 16)
    finally:
        srv.close()
    assert srv.preempted
    assert toks.shape[1] < 17                  # exited at the first boundary
    step = latest_step(tmp_path)
    assert step is not None
    spec = srv.model.params_spec(srv.cfg)
    restored, idx = restore_checkpoint(tmp_path, step, spec, placement=pl0)
    assert idx["expert_layout"]["fingerprint"] == pl0.fingerprint()
    for a, b in zip(jax.tree.leaves(srv.params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_continuous_batching_survives_kill_and_rejoin():
    """PR 8 composition: rank 2 dies mid-CONTINUOUS-serve (every expert
    replicated, so the shrink is zero-data-loss) and rejoins later. The
    drain-free recovery happens at the same step boundary admission and page
    alloc/free use, so (a) every per-request token stream is bitwise equal
    to the fault-free run, and (b) the page tables come out uncorrupted —
    all pages freed, reservations zero, every slot reset to the pad page."""
    from repro.runtime.scheduler import Request
    from repro.runtime.server import ContinuousDecodeServer

    def reqs():
        return [Request(0, np.array([3, 5, 7], np.int32), 6),
                Request(1, np.array([11, 2], np.int32), 8),
                Request(2, np.array([9, 9, 9, 9, 1], np.int32), 5,
                        arrival_step=4),
                Request(3, np.array([4], np.int32), 7, arrival_step=6)]

    E = 8
    cfg = _cfg_physical(PL.redundant_placement(E, 8, E))
    mesh = _mesh8()
    srv_a = ContinuousDecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                                   page_size=4, num_redundant_experts=E)
    srv_a.serve_requests(reqs())
    base = {i: srv_a.reqsched.tokens_for(i) for i in range(4)}
    srv_a.close()

    inj = FaultInjector(8, kill={3: 2}, rejoin={8: 2})
    srv_b = ContinuousDecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                                   page_size=4, num_redundant_experts=E,
                                   fault_injector=inj, miss_threshold=1)
    m = srv_b.serve_requests(reqs())
    sched = srv_b.reqsched
    srv_b.close()

    # (a) bitwise parity across the kill + rejoin transitions
    for i in range(4):
        np.testing.assert_array_equal(base[i], sched.tokens_for(i))
    assert [e["kind"] for e in srv_b.recoveries] == ["shrink", "expand"]
    assert all(e["lost_experts"] == [] and e["restored_from"] is None
               for e in srv_b.recoveries)
    assert m.recovery_count == 2 and m.degraded_steps > 0
    assert m.requests_completed == 4

    # (b) page-table integrity through both transitions
    assert sched.done
    assert sched.alloc.live_count == 0 and sched._reserved == 0
    assert sched.alloc.free_count == sched.alloc.num_pages
    assert np.all(sched._tbl == sched.alloc.pad_page)
    assert np.all(sched._active == 0)


# --------------------------------------------------------------------------
# driver-level fault path: run_rebalancing / rebalancing_decode_loop
# --------------------------------------------------------------------------

N, E2, K, T, H = 8, 16, 4, 16, 32


def _loop_harness(mesh, rng):
    router_w = jnp.asarray(rng.randn(H, E2), jnp.float32)
    bump = jnp.zeros((E2,)).at[:4].set(3.0)

    def router_fn(x):
        logits = x @ router_w + bump
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

    def make(group, params):
        L = group.local_experts

        def fn(window):
            def run(x, wv):
                x = x[0]
                ti, wi = router_fn(x)
                h = ep_create_handle(group, ti, wi)
                y3d, counts = ep_dispatch(group, h, x)
                me = plan_mod.my_rank(group)
                rows = jax.lax.dynamic_slice_in_dim(wv, me * L, L)
                out = ep_combine(group, h, y3d * rows[:, None, None])
                heat = jax.lax.psum(PL.heat_from_topk(ti, E2), "data")
                return out[None], heat[None]
            f = jax.jit(jax.shard_map(
                run, mesh=mesh, in_specs=(P("data"), P(None)),
                out_specs=(P("data"), P("data"))))
            outs, hs = [], 0.0
            for x in window:
                o, hcur = f(x, params["w_gate"])
                outs.append(np.asarray(o))
                hs = hs + np.asarray(hcur)[0]
            return outs, hs
        return fn
    return make


def test_rebalancing_decode_loop_survives_injected_kill():
    """run_rebalancing's fault path UNDER THE MIN-REPLICA FLOOR
    (docs/DESIGN.md §9): the kill lands at a LATE window, after several
    heat-driven rebalances — the floor guarantees every intermediate
    placement still holds 2 replicas of every expert on distinct ranks
    across distinct fault domains, so the late kill shrinks with zero data
    loss (masked rebind through surviving replicas only) and the rejoin
    re-expands; outputs stay bitwise-equal to the fault-free run because
    placement only moves where experts compute. (Pre-floor, this test had
    to kill at window 0: a heat-driven rebalance could concentrate a cold
    expert's single replica, making a later kill unrecoverable.)"""
    from repro.checkpoint import rebind_expert_leaves
    from repro.runtime.decode import rebalancing_decode_loop
    rng = np.random.RandomState(SEED + 8)
    mesh = _mesh8()
    dom = PL.domains_from_geometry(N, 4)       # 2 pods of 4 ranks
    # floor-satisfying start: 2 replicas per expert, one per pod
    pl0 = PL.rebalance(np.ones(E2), N, num_redundant=E2,
                       min_replicas=2, domains=dom)
    w_log = jnp.asarray(rng.rand(E2).astype(np.float32) + 0.5)
    w_phys = rebind_expert_leaves({"w_gate": w_log}, ("w_gate",),
                                  dst_placement=pl0)
    base_cfg = EpGroupConfig(num_experts=E2, max_tokens_per_rank=T, hidden=H,
                             top_k=K, mode="ll", payload_dtype=jnp.float32,
                             placement=pl0, fault_domains=dom)
    xs = [jnp.asarray(rng.randn(N, T, H), jnp.float32) for _ in range(12)]
    make = _loop_harness(mesh, np.random.RandomState(SEED + 8))
    floor_kw = dict(min_replicas=2, fault_domains=dom)

    outs_a, pls_a = rebalancing_decode_loop(
        base_cfg, make, xs, rebalance_every=2, ep_size=N, num_redundant=E2,
        params=dict(w_phys), expert_keys=("w_gate",), donate_params=False,
        **floor_kw)
    # every adopted placement satisfies the floor (the pinned invariant)
    for pl in dict.fromkeys(pls_a):
        PL.validate_floor(pl, 2, dom)

    # kill rank 3 at window 3 — AFTER the heat-driven rebalances at the
    # window 0..2 boundaries have reshaped the table
    inj = FaultInjector(N, kill={3: 3}, rejoin={4: 3})
    outs_b, pls_b = rebalancing_decode_loop(
        base_cfg, make, xs, rebalance_every=2, ep_size=N, num_redundant=E2,
        params=dict(w_phys), expert_keys=("w_gate",), donate_params=False,
        fault_injector=inj, **floor_kw)

    for a, b in zip(outs_a, outs_b):
        np.testing.assert_array_equal(a, b)
    # placements are per WINDOW; the kill window precedes the shrink
    assert pls_b[3].dead_ranks() == ()         # heat-rebalanced, full width
    assert pls_b[4].dead_ranks() == (3,)       # degraded window
    assert pls_b[5].dead_ranks() == ()         # rejoined: full width again
    for pl in dict.fromkeys(pls_b):
        PL.validate_floor(pl, 2, dom)          # floor holds even degraded
    assert inj.log and inj.log[0][0] == 3


def test_run_rebalancing_no_replica_kill_raises():
    """Contiguous striping has no replicas: a kill must warn
    ``DegradedRecovery`` and raise (run_rebalancing has no checkpoint
    fallback — that is the DecodeServer's job)."""
    from repro.runtime.decode import rebalancing_decode_loop
    rng = np.random.RandomState(8)
    mesh = _mesh8()
    w_log = jnp.asarray(rng.rand(E2).astype(np.float32) + 0.5)
    base_cfg = EpGroupConfig(num_experts=E2, max_tokens_per_rank=T, hidden=H,
                             top_k=K, mode="ll", payload_dtype=jnp.float32)
    xs = [jnp.asarray(rng.randn(N, T, H), jnp.float32) for _ in range(4)]
    make = _loop_harness(mesh, np.random.RandomState(8))
    inj = FaultInjector(N, kill={0: 2})
    with pytest.warns(DegradedRecovery):
        with pytest.raises(ValueError, match="unrecoverable"):
            rebalancing_decode_loop(
                base_cfg, make, xs, rebalance_every=2, ep_size=N,
                params={"w_gate": w_log}, expert_keys=("w_gate",),
                donate_params=False, fault_injector=inj)
