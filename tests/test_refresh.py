"""ep_handle_refresh (plan reuse across decode steps) + the double-buffered
decode pipeline.

Covers the ROADMAP plan-reuse contract: a weights-only refresh reuses the
plan object verbatim (asserted by identity at trace time); a refresh with
identical routing values in a *different* array goes through the
routing-hash fast path and must behave exactly like the original handle; a
refresh with changed routing must behave exactly like a fresh
ep_create_handle; refreshed weights must flow into combine (including the
hierarchical h_w_slot rebind). The decode pipeline (runtime/decode.py) must
be bit-compatible with the naive unpipelined loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,
                        ep_handle_refresh, ep_dispatch, ep_combine)
from repro.core import plan as plan_mod
from repro.runtime.decode import (naive_decode_step, pipelined_decode_step,
                                  decode_loop)

N, E, K, T, H = 8, 16, 4, 16, 32


def make_mesh(shape=(N,), names=("data",)):
    return jax.make_mesh(shape, names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def rand_inputs(rng):
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    return x, topk, w


def oracle(x, topk, w):
    return x * (w * (1.0 + topk)).sum(-1)[..., None]


def scale_by_expert(group, y3d):
    L = group.local_experts
    e_glob = plan_mod.my_rank(group) * L + jnp.arange(L)
    return y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)


def ep_roundtrip(group, handle, x):
    y3d, counts = ep_dispatch(group, handle, x)
    return ep_combine(group, handle, scale_by_expert(group, y3d))


# --------------------------------------------------------------------------
# plan reuse: object identity on weights-only refresh
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode,layout", [("ll", "nccl_ep"), ("ll", "deepep"),
                                         ("ht", "nccl_ep"),
                                         ("baseline", "nccl_ep")])
def test_weights_refresh_reuses_plan_object(mode, layout):
    """topk_idx=None: every slot map is reused verbatim — for all
    weight-free plans that is the same plan object; the hash rides along."""
    rng = np.random.RandomState(0)
    x, topk, w = rand_inputs(rng)
    w2 = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode=mode, ll_layout=layout,
                        payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()

    def step(x, topk, w, w2):
        x, topk, w, w2 = x[0], topk[0], w[0], w2[0]
        h = ep_create_handle(group, topk, w)
        h2 = ep_handle_refresh(group, h, w2)
        assert h2.plan is h.plan, "weights-only refresh rebuilt the plan"
        assert h2.routing_hash is h.routing_hash
        assert h2.topk_weights is w2
        return ep_roundtrip(group, h2, x)[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 4,
                              out_specs=P("data")))
    out = np.asarray(f(x, topk, w, w2))
    np.testing.assert_allclose(out, np.asarray(oracle(x, topk, w2)),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# routing-hash fast path: same values, different array
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode,layout", [("ll", "nccl_ep"), ("ll", "deepep"),
                                         ("ht", "nccl_ep")])
def test_refresh_same_routing_matches_original(mode, layout):
    rng = np.random.RandomState(1)
    x, topk, w = rand_inputs(rng)
    topk_copy = jnp.array(np.asarray(topk))          # same values, new buffer
    w2 = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode=mode, ll_layout=layout,
                        payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()

    def step(x, topk, w, topkc, w2):
        x, topk, w, topkc, w2 = x[0], topk[0], w[0], topkc[0], w2[0]
        h = ep_create_handle(group, topk, w)
        h2 = ep_handle_refresh(group, h, w2, topkc)   # hash path, cond reuse
        return ep_roundtrip(group, h2, x)[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 5,
                              out_specs=P("data")))
    out = np.asarray(f(x, topk, w, topk_copy, w2))
    np.testing.assert_allclose(out, np.asarray(oracle(x, topk, w2)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("mode,layout", [("ll", "nccl_ep"), ("ll", "deepep"),
                                         ("ht", "nccl_ep"),
                                         ("baseline", "nccl_ep")])
def test_refresh_changed_routing_rebuilds(mode, layout):
    """A refresh with different routing must equal a fresh handle built on
    that routing — the hash mismatch takes the rebuild branch."""
    rng = np.random.RandomState(2)
    x, topk, w = rand_inputs(rng)
    _, topk2, w2 = rand_inputs(rng)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode=mode, ll_layout=layout,
                        payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()

    def step(x, topk, w, topk2, w2):
        x, topk, w, topk2, w2 = x[0], topk[0], w[0], topk2[0], w2[0]
        h = ep_create_handle(group, topk, w)
        h_ref = ep_handle_refresh(group, h, w2, topk2)
        h_new = ep_create_handle(group, topk2, w2)
        return (ep_roundtrip(group, h_ref, x)[None],
                ep_roundtrip(group, h_new, x)[None])

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 5,
                              out_specs=(P("data"), P("data"))))
    got_ref, got_new = map(np.asarray, f(x, topk, w, topk2, w2))
    want = np.asarray(oracle(x, topk2, w2))
    np.testing.assert_allclose(got_ref, want, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(got_ref, got_new)   # identical computation


def test_refresh_detects_single_rank_routing_change():
    """The hash covers the *global* routing: when only ONE rank's routing
    changes, every rank's slot maps change (recv maps encode peers'
    choices), so every rank must take the rebuild branch. A local-only hash
    would silently reuse stale maps on the unchanged ranks."""
    rng = np.random.RandomState(6)
    x, topk, w = rand_inputs(rng)
    topk2_np = np.asarray(topk).copy()
    topk2_np[1] = np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
    topk2 = jnp.asarray(topk2_np, jnp.int32)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()

    def step(x, topk, w, topk2):
        x, topk, w, topk2 = x[0], topk[0], w[0], topk2[0]
        h = ep_create_handle(group, topk, w)
        h2 = ep_handle_refresh(group, h, w, topk2)
        h_new = ep_create_handle(group, topk2, w)
        return (ep_roundtrip(group, h2, x)[None],
                ep_roundtrip(group, h_new, x)[None])

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 4,
                              out_specs=(P("data"), P("data"))))
    got_ref, got_new = map(np.asarray, f(x, topk, w, topk2))
    np.testing.assert_array_equal(got_ref, got_new)
    np.testing.assert_allclose(got_ref, np.asarray(oracle(x, topk2, w)),
                               rtol=2e-5, atol=2e-5)


def test_refresh_different_token_count_rebuilds():
    """A refresh whose topk_idx has a different (static) token count cannot
    reuse the cached maps — shapes differ — and must rebuild unconditionally
    instead of tripping over a lax.cond branch-shape mismatch."""
    rng = np.random.RandomState(9)
    x, topk, w = rand_inputs(rng)
    T2 = T // 2
    topk2 = topk[:, :T2]
    w2 = w[:, :T2]
    x2 = x[:, :T2]
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()

    def step(x2, topk, w, topk2, w2):
        x2, topk, w, topk2, w2 = x2[0], topk[0], w[0], topk2[0], w2[0]
        h = ep_create_handle(group, topk, w)
        h2 = ep_handle_refresh(group, h, w2, topk2)   # T -> T/2
        return ep_roundtrip(group, h2, x2)[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 5,
                              out_specs=P("data")))
    out = np.asarray(f(x2, topk, w, topk2, w2))
    np.testing.assert_allclose(out, np.asarray(oracle(x2, topk2, w2)),
                               rtol=2e-5, atol=2e-5)


def test_refresh_num_tokens_requires_topk_idx():
    rng = np.random.RandomState(7)
    _, topk, w = rand_inputs(rng)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()

    def step(topk, w):
        h = ep_create_handle(group, topk[0], w[0])
        with pytest.raises(ValueError):
            ep_handle_refresh(group, h, w[0], num_tokens=jnp.int32(4))
        return h.tokens_per_expert[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 2,
                              out_specs=P("data")))
    f(topk, w)


def test_refresh_hierarchical_weight_rebind():
    """HT hierarchical: h_w_slot is the one weight-carrying plan field; a
    refresh must rebind it through the stored h_entry_slot chain."""
    rng = np.random.RandomState(3)
    x, topk, w = rand_inputs(rng)
    w2 = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ht", ep_axis=("pod", "data"),
                        ht_hierarchical=True, payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N, inner_size=4)
    mesh = make_mesh((2, 4), ("pod", "data"))

    def step(x, topk, w, w2):
        x, topk, w, w2 = x[0], topk[0], w[0], w2[0]
        h = ep_create_handle(group, topk, w)
        h2 = ep_handle_refresh(group, h, w2)
        assert h2.plan is not h.plan          # h_w_slot rebound
        assert h2.plan.disp_recv_gmap is h.plan.disp_recv_gmap  # maps reused
        return ep_roundtrip(group, h2, x)[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P(("pod", "data")),) * 4,
                              out_specs=P(("pod", "data"))))
    out = np.asarray(f(x, topk, w, w2)).reshape(N, T, H)
    np.testing.assert_allclose(out, np.asarray(oracle(x, topk, w2)),
                               rtol=2e-5, atol=2e-5)


def test_refresh_changed_routing_rebuilds_hier():
    """HT hierarchical through the cond's rebuild branch: the cached
    (h_w_slot-stripped) and rebuilt plan pytrees must stay structurally
    identical, and the refreshed handle must equal a fresh one."""
    rng = np.random.RandomState(8)
    x, topk, w = rand_inputs(rng)
    _, topk2, w2 = rand_inputs(rng)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ht", ep_axis=("pod", "data"),
                        ht_hierarchical=True, payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N, inner_size=4)
    mesh = make_mesh((2, 4), ("pod", "data"))

    def step(x, topk, w, topk2, w2):
        x, topk, w, topk2, w2 = x[0], topk[0], w[0], topk2[0], w2[0]
        h = ep_create_handle(group, topk, w)
        h_ref = ep_handle_refresh(group, h, w2, topk2)
        h_new = ep_create_handle(group, topk2, w2)
        return (ep_roundtrip(group, h_ref, x)[None],
                ep_roundtrip(group, h_new, x)[None])

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P(("pod", "data")),) * 5,
                              out_specs=(P(("pod", "data")),) * 2))
    got_ref, got_new = map(np.asarray, f(x, topk, w, topk2, w2))
    np.testing.assert_array_equal(got_ref, got_new)
    np.testing.assert_allclose(got_ref.reshape(N, T, H),
                               np.asarray(oracle(x, topk2, w2)),
                               rtol=2e-5, atol=2e-5)


def test_routing_hash_sensitivity():
    """Hash must differ on any entry/order change and match on equal input."""
    rng = np.random.RandomState(4)
    a = jnp.asarray(rng.randint(0, E, (T, K)), jnp.int32)
    same = plan_mod.routing_hash(jnp.array(np.asarray(a)))
    h = plan_mod.routing_hash(a)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(same))
    b = a.at[3, 1].set((a[3, 1] + 1) % E)
    assert not np.array_equal(np.asarray(h),
                              np.asarray(plan_mod.routing_hash(b)))
    # order sensitivity: swapping two different entries must change the hash
    ij = a[0, 0], a[0, 1]
    c = a.at[0, 0].set(ij[1]).at[0, 1].set(ij[0])
    if int(ij[0]) != int(ij[1]):
        assert not np.array_equal(np.asarray(h),
                                  np.asarray(plan_mod.routing_hash(c)))


# --------------------------------------------------------------------------
# EPLB: placement swaps force rebuild; replay under one placement stays fast
# --------------------------------------------------------------------------

def _run_with_group(group, placement, x, topk, w, refresh_from=None):
    """Roundtrip under `group`, scaling y3d by LOGICAL expert id (via the
    placement's slot table) so results are placement-invariant. With
    ``refresh_from`` (another group), the handle is created there first and
    refreshed into `group` — the placement-swap path."""
    from repro.core import placement as PL
    E = group.cfg.num_experts
    L = group.local_experts
    se = (jnp.arange(E, dtype=jnp.int32).reshape(group.ep_size, L)
          if placement is None else jnp.asarray(PL.tables(placement).slot_expert))
    mesh = make_mesh()

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        if refresh_from is not None:
            h0 = ep_create_handle(refresh_from, topk, w)
            h = ep_handle_refresh(group, h0, w, jnp.array(topk))
        else:
            h = ep_create_handle(group, topk, w)
        y3d, counts = ep_dispatch(group, h, x)
        me = plan_mod.my_rank(group)
        y3d = y3d * (1.0 + se[me])[:, None, None].astype(y3d.dtype)
        return ep_combine(group, h, y3d)[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                              out_specs=P("data")))
    return np.asarray(f(x, topk, w))


@pytest.mark.parametrize("num_redundant", [0, 8],
                         ids=["same-slot-count", "changed-slot-count"])
def test_refresh_placement_swap_rebuilds(num_redundant):
    """A refresh against a group with a DIFFERENT placement must rebuild the
    plan even when the routing replays bit-for-bit: the placement-salted
    routing hash mismatches (same slot count -> cond rebuild branch) or the
    map shapes differ (changed slot count -> unconditional rebuild). The
    result must equal a fresh handle built under the new placement."""
    import dataclasses
    from repro.core.placement import rebalance
    rng = np.random.RandomState(12)
    x, topk, w = rand_inputs(rng)
    heat = np.ones(E)
    heat[:4] = 50.0
    pl = rebalance(heat, N, num_redundant=num_redundant)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    g_old = ep_create_group(cfg, ep_size=N)
    g_new = ep_create_group(dataclasses.replace(cfg, placement=pl), ep_size=N)
    got = _run_with_group(g_new, pl, x, topk, w, refresh_from=g_old)
    want = _run_with_group(g_new, pl, x, topk, w)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_allclose(got, np.asarray(oracle(x, topk, w)),
                               rtol=2e-5, atol=2e-5)


def test_refresh_same_placement_replay_keeps_fast_path():
    """Under an unchanged (non-default) placement, a routing replay must
    still take the hash fast path: the weights-only refresh reuses the plan
    object and a same-value refresh matches the original bitwise."""
    from repro.core.placement import rebalance
    rng = np.random.RandomState(13)
    x, topk, w = rand_inputs(rng)
    pl = rebalance(np.arange(E, dtype=float) + 1.0, N, num_redundant=8)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32,
                        placement=pl)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ep_create_handle(group, topk, w)
        h2 = ep_handle_refresh(group, h, w)              # weights-only
        assert h2.plan is h.plan
        h3 = ep_handle_refresh(group, h, w, jnp.array(topk))  # hash path
        return (ep_roundtrip(group, h2, x)[None],
                ep_roundtrip(group, h3, x)[None])

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                              out_specs=(P("data"), P("data"))))
    a, b = map(np.asarray, f(x, topk, w))
    np.testing.assert_array_equal(a, b)


def test_rebalancing_decode_loop_matches_naive():
    """Rebalance-mid-decode parity: the EPLB decode driver (placement swaps
    between windows through the staged pipeline) must produce exactly what
    the naive unpipelined loop produces under the same placement schedule."""
    from repro.core import placement as PL
    from repro.runtime.decode import rebalancing_decode_loop

    rng = np.random.RandomState(14)
    mesh = make_mesh()
    router_w = jnp.asarray(rng.randn(H, E), jnp.float32)
    # hot-expert routing: a logit bump keeps experts 0-3 hot so the
    # rebalancer actually moves things
    bump = jnp.zeros((E,)).at[:4].set(3.0)

    def router_fn(x):
        logits = x @ router_w + bump
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

    def expert_fn_for(group, placement):
        se = (jnp.arange(E, dtype=jnp.int32).reshape(N, -1) if placement is None
              else jnp.asarray(PL.tables(placement).slot_expert))

        def expert_fn(y3d, counts):
            me = plan_mod.my_rank(group)
            return y3d * (1.0 + se[me])[:, None, None].astype(y3d.dtype)
        return expert_fn

    base_cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                             top_k=K, mode="ll", payload_dtype=jnp.float32)
    S_steps = 4
    xs_np = rng.randn(S_steps, 2, N, T, H).astype(np.float32)
    xs = [(jnp.asarray(xs_np[s, 0]), jnp.asarray(xs_np[s, 1]))
          for s in range(S_steps)]

    def make_window(group):
        pl = group.placement
        efn = expert_fn_for(group, pl)

        def win(pairs):
            stack = jnp.stack([jnp.stack(p) for p in pairs])  # [S, 2, N, T, H]

            def run(stack):
                seq = [(stack[s, 0, 0], stack[s, 1, 0])
                       for s in range(stack.shape[0])]
                outs = decode_loop(group, router_fn, efn, seq)
                heat = sum(
                    PL.heat_from_topk(router_fn(x)[0], E)
                    for pair in seq for x in pair)
                heat = jax.lax.psum(heat, "data")
                return (jnp.stack([jnp.stack([a, b]) for a, b in outs])[None],
                        heat[None])

            o, heat = jax.jit(jax.shard_map(
                run, mesh=mesh, in_specs=(P(None, None, "data"),),
                out_specs=(P("data"), P("data"))))(stack)
            o = np.asarray(o)                       # [N, S, 2, T, H]
            return ([(o[:, s, 0], o[:, s, 1]) for s in range(len(pairs))],
                    np.asarray(heat)[0])
        return win

    outs, placements = rebalancing_decode_loop(
        base_cfg, make_window, xs, rebalance_every=2, ep_size=N,
        num_redundant=8)
    assert placements[0] is None and placements[1] is not None
    assert len(outs) == S_steps

    # naive reference under the SAME placement schedule
    import dataclasses as dc
    for s in range(S_steps):
        pl = placements[s // 2]
        group = ep_create_group(dc.replace(base_cfg, placement=pl), ep_size=N)
        efn = expert_fn_for(group, pl)

        def naive(stack):
            oa = naive_decode_step(group, router_fn, efn, stack[0, 0])
            ob = naive_decode_step(group, router_fn, efn, stack[1, 0])
            return jnp.stack([oa, ob])[None]

        want = np.asarray(jax.jit(jax.shard_map(
            naive, mesh=mesh, in_specs=(P(None, "data"),),
            out_specs=P("data")))(jnp.asarray(xs_np[s])))
        got = np.stack([outs[s][0], outs[s][1]], axis=1)   # [N, 2, T, H]
        np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# double-buffered decode pipeline == naive loop
# --------------------------------------------------------------------------

def test_decode_pipeline_matches_naive():
    rng = np.random.RandomState(5)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()
    router_w = jnp.asarray(rng.randn(H, E), jnp.float32)

    def router_fn(x):
        logits = x @ router_w
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

    def expert_fn(y3d, counts):
        return scale_by_expert(group, y3d)

    S = 3
    xs = jnp.asarray(rng.randn(S, 2, N, T, H), jnp.float32)

    def pipe(xs):
        seq = [(xs[s, 0, 0], xs[s, 1, 0]) for s in range(S)]
        outs = decode_loop(group, router_fn, expert_fn, seq)
        return jnp.stack([jnp.stack([a, b]) for a, b in outs])[None]

    def naive(xs):
        return jnp.stack([
            jnp.stack([naive_decode_step(group, router_fn, expert_fn,
                                         xs[s, m, 0]) for m in range(2)])
            for s in range(S)])[None]

    spec = (P(None, None, "data"),)
    fp = jax.jit(jax.shard_map(pipe, mesh=mesh, in_specs=spec,
                               out_specs=P("data")))
    fn = jax.jit(jax.shard_map(naive, mesh=mesh, in_specs=spec,
                               out_specs=P("data")))
    np.testing.assert_allclose(np.asarray(fp(xs)), np.asarray(fn(xs)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("mode", ["ht", "baseline"])
def test_decode_pipeline_mode_agnostic(mode):
    """The double-buffered driver is mode-agnostic (the staged surface is
    part of the EpBackend contract): the same schedule over HT or baseline
    groups must match the naive unpipelined loop."""
    rng = np.random.RandomState(11)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode=mode, payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)
    mesh = make_mesh()
    router_w = jnp.asarray(rng.randn(H, E), jnp.float32)

    def router_fn(x):
        logits = x @ router_w
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

    def expert_fn(y3d, counts):
        return scale_by_expert(group, y3d)

    xs = jnp.asarray(rng.randn(2, 2, N, T, H), jnp.float32)

    def pipe(xs):
        seq = [(xs[s, 0, 0], xs[s, 1, 0]) for s in range(2)]
        outs = decode_loop(group, router_fn, expert_fn, seq)
        return jnp.stack([jnp.stack([a, b]) for a, b in outs])[None]

    def naive(xs):
        return jnp.stack([
            jnp.stack([naive_decode_step(group, router_fn, expert_fn,
                                         xs[s, m, 0]) for m in range(2)])
            for s in range(2)])[None]

    spec = (P(None, None, "data"),)
    fp = jax.jit(jax.shard_map(pipe, mesh=mesh, in_specs=spec,
                               out_specs=P("data")))
    fn = jax.jit(jax.shard_map(naive, mesh=mesh, in_specs=spec,
                               out_specs=P("data")))
    np.testing.assert_allclose(np.asarray(fp(xs)), np.asarray(fn(xs)),
                               rtol=2e-5, atol=2e-5)
