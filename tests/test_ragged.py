"""The ragged (exact-size) path is TPU-only: XLA:CPU cannot compile
ragged-all-to-all. We verify (a) it TRACES and LOWERS correctly (the jaxpr
contains the primitive with the right shapes), (b) the gate reports
unsupported here, (c) compile on CPU raises — pinning the documented reason
the dense path is the container default."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.group import EpGroupConfig, ep_create_group
from repro.core import ll
from repro.core.ragged import ll_dispatch_ragged, ragged_supported


def test_gate_reports_cpu_unsupported():
    assert not ragged_supported()


def test_ragged_traces_and_lowers():
    if not hasattr(jax.lax, "ragged_all_to_all"):
        pytest.skip("jax.lax.ragged_all_to_all not in this JAX version")
    N, E, K, T, H = 8, 16, 4, 8, 32
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.float32)
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk):
        h = ll.ll_create_handle(group, topk[0], jnp.ones((T, K), jnp.float32))
        recv, sizes = ll_dispatch_ragged(group, h, x[0])
        return recv[None], sizes[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P("data"), P("data")),
                              out_specs=(P("data"), P("data"))))
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(np.stack([
        np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
        for _ in range(N)]), jnp.int32)
    lowered = f.lower(x, topk)
    txt = lowered.as_text()
    assert "ragged_all_to_all" in txt or "ragged-all-to-all" in txt
    with pytest.raises(Exception, match="(?i)ragged|unimplemented|not supported"):
        lowered.compile()
