"""Test fixtures. EP-collective tests need a handful of devices to exercise
shard_map all-to-alls, so we ask the host platform for 8 (NOT the production
512 — that belongs exclusively to launch/dryrun.py). Single-device smoke
tests are unaffected: they just use device 0.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
