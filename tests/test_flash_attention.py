"""Flash-attention Pallas kernel vs dense oracle: shapes x dtypes x GQA x
windows, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def dense_ref(q, k, v, scale, window, causal=True):
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    qp, kp = jnp.arange(Sq), jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp[None] <= qp[:, None]
    if window is not None:
        mask &= (qp[:, None] - kp[None]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 1)])
@pytest.mark.parametrize("S,bq,bk", [(128, 64, 64), (256, 128, 128)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_matches_dense(Hq, Hkv, S, bq, bk, dt):
    B, d = 1, 32
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, Hq, S, d), dt)
    k = jnp.asarray(rng.randn(B, Hkv, S, d), dt)
    v = jnp.asarray(rng.randn(B, Hkv, S, d), dt)
    got = flash_attention(q, k, v, scale=d ** -0.5, bq=bq, bk=bk,
                          interpret=True)
    want = dense_ref(q, k, v, d ** -0.5, None)
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 100])
def test_flash_sliding_window(window):
    B, H, S, d = 1, 2, 128, 16
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(B, H, S, d), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, S, d), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, S, d), jnp.float32)
    got = flash_attention(q, k, v, scale=0.25, window=window, bq=32, bk=32,
                          interpret=True)
    want = dense_ref(q, k, v, 0.25, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    B, H, S, d = 1, 2, 64, 16
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(B, H, S, d), jnp.float32)
    k = jnp.asarray(rng.randn(B, H, S, d), jnp.float32)
    v = jnp.asarray(rng.randn(B, H, S, d), jnp.float32)
    got = flash_attention(q, k, v, scale=0.25, causal=False, bq=32, bk=32,
                          interpret=True)
    want = dense_ref(q, k, v, 0.25, None, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
