"""Serving telemetry (docs/DESIGN.md §11): the tracer/time-series subsystem
is host-side and boundary-scoped — tracing ON must leave every token stream
BITWISE identical to tracing OFF (GQA and MLA continuous serve, including
across an EPLB placement swap and a kill/rejoin recovery), the disabled
tracer must be a true no-op (shared span singleton, zero events), exported
Chrome traces must be well-formed (spans nest, durations >= 0, every
recovery transition has a matching complete-event), and
``ServeMetrics.as_dict()`` must stay ``json.dumps``-able with the new
``timeline``/``series`` fields carrying numpy scalars."""
import dataclasses
import json
import os

import numpy as np
import pytest

# CI seed matrix: the interpret-parity job re-runs this file under several
# seeds (REPRO_TEST_SEED) — data/routing vary, every invariant must hold
SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))

from repro.configs import get_smoke
from repro.core import placement as PL
from repro.runtime.fault import FaultInjector
from repro.runtime.scheduler import Request
from repro.runtime.server import ContinuousDecodeServer, ServeMetrics
from repro.runtime.telemetry import (NULL_SERIES, NULL_TRACER, NullTracer,
                                     NullTimeSeries, TimeSeries, Tracer,
                                     json_safe, load_chrome_trace, span_names,
                                     validate_chrome_trace)


class FakeClock:
    """Injectable monotonic clock: advances only when told to."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def tick(self, dt: float) -> None:
        self.t += dt


# --------------------------------------------------------------------------
# tracer unit tests (fake clock: timings are exact, not approximate)
# --------------------------------------------------------------------------

def test_tracer_fake_clock_deterministic(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk, pid=7, tid=3)
    with tr.span("outer", step=0):
        clk.tick(0.002)
        with tr.span("inner"):
            clk.tick(0.001)
        tr.instant("mark", rid=np.int64(5))
        tr.counter("queue_depth", 4)
        clk.tick(0.0005)
    assert len(tr) == 4
    doc = tr.to_chrome_trace()
    ev = validate_chrome_trace(doc)
    by_name = {e["name"]: e for e in ev}
    # inner: opened at t=2ms for 1ms; outer: t=0 for 3.5ms — exact, in µs
    assert by_name["inner"]["ts"] == 2000.0 and by_name["inner"]["dur"] == 1000.0
    assert by_name["outer"]["ts"] == 0.0 and by_name["outer"]["dur"] == 3500.0
    assert by_name["mark"]["ph"] == "i" and by_name["mark"]["s"] == "t"
    assert by_name["mark"]["args"] == {"rid": 5}          # numpy coerced
    assert by_name["queue_depth"]["ph"] == "C"
    assert all(e["pid"] == 7 and e["tid"] == 3 for e in ev)
    # summary folds span time per name
    s = tr.summary()
    assert s["outer"]["count"] == 1 and s["outer"]["total_s"] == 0.0035
    assert s["mark"]["ph"] == "i" and s["mark"]["total_s"] == 0.0
    # round-trips through the file exporter
    p = tr.write_chrome_trace(tmp_path / "trace.json")
    assert span_names(validate_chrome_trace(load_chrome_trace(p))) == [
        "inner", "outer"]


def test_trace_validation_rejects_partial_overlap():
    """Two X-events on one track that overlap without nesting are malformed
    (a span closed after its parent) — the validator must trip."""
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0, "dur": 10.0},
        {"name": "b", "ph": "X", "pid": 0, "tid": 0, "ts": 5.0, "dur": 10.0},
    ]}
    with pytest.raises(AssertionError):
        validate_chrome_trace(bad)
    with pytest.raises(AssertionError):
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0,
             "dur": -1.0}]})


def test_span_survives_exception_and_still_validates():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with pytest.raises(RuntimeError):
        with tr.span("boundary"):
            clk.tick(0.001)
            raise RuntimeError("mid-boundary failure")
    ev = validate_chrome_trace(tr.to_chrome_trace())
    assert span_names(ev) == ["boundary"] and ev[0]["dur"] == 1000.0


def test_null_tracer_and_series_are_noops():
    tr = NullTracer()
    assert not tr.enabled and not NULL_TRACER.enabled
    # the disabled tracer hands out ONE shared span object: no per-step
    # allocation on the serve hot path
    s1, s2 = tr.span("serve_step", step=0), tr.span("rebalance")
    assert s1 is s2
    with s1:
        pass
    tr.instant("x")
    tr.counter("y", 1.0)
    assert len(tr) == 0 and tr.summary() == {}
    assert tr.to_chrome_trace()["traceEvents"] == []
    ns = NullTimeSeries()
    ns.record(kind="step", itl_s=1.0)
    assert ns.rows == () and not ns.enabled and not NULL_SERIES.enabled


def test_serve_metrics_as_dict_json_serializable():
    """timeline/series land in as_dict() with numpy leaves coerced."""
    m = ServeMetrics(
        ttft_s=np.float64(0.1), itl_mean_s=0.01, itl_p99_s=0.02,
        output_tok_s=np.float32(123.0), total_tokens=np.int64(64),
        timeline={"serve_step": {"count": np.int64(8),
                                 "total_s": np.float64(0.08), "ph": "X"}},
        series=[{"kind": "step", "itl_s": np.float32(0.01),
                 "rank_loads": np.arange(4)}])
    d = m.as_dict()
    out = json.loads(json.dumps(d))
    assert out["timeline"]["serve_step"]["count"] == 8
    assert out["series"][0]["rank_loads"] == [0, 1, 2, 3]
    assert json_safe(np.bool_(True)) in (True, 1)


# --------------------------------------------------------------------------
# bitwise parity: tracing on vs off through the continuous engine
# --------------------------------------------------------------------------

def _requests():
    return [Request(0, np.array([3, 5, 7], np.int32), 6),
            Request(1, np.array([11, 2], np.int32), 8),
            Request(2, np.array([9, 9, 9, 9, 1], np.int32), 5,
                    arrival_step=4),
            Request(3, np.array([4], np.int32), 7, arrival_step=6)]


@pytest.mark.parametrize("arch", ["dbrx-132b", "minicpm3-4b"])
def test_continuous_tracing_on_off_bitwise(arch, tmp_path):
    """GQA (dbrx) and absorbed-MLA (minicpm3) continuous serve: turning the
    tracer + time series on must not move a single token — telemetry reads
    host state the boundaries already materialize."""
    cfg = get_smoke(arch)

    off = ContinuousDecodeServer(cfg, batch=3, max_len=32, page_size=4)
    m_off = off.serve_requests(_requests())
    base = {r.rid: off.reqsched.tokens_for(r.rid) for r in _requests()}
    off.close()
    assert m_off.timeline is None and m_off.series is None

    tr, se = Tracer(), TimeSeries()
    on = ContinuousDecodeServer(cfg, batch=3, max_len=32, page_size=4,
                                tracer=tr, series=se)
    m_on = on.serve_requests(_requests())
    got = {r.rid: on.reqsched.tokens_for(r.rid) for r in _requests()}
    on.close()

    for rid, toks in base.items():
        np.testing.assert_array_equal(toks, got[rid])
    assert m_on.requests_completed == m_off.requests_completed == 4
    assert m_on.serve_steps == m_off.serve_steps

    ev = validate_chrome_trace(tr.to_chrome_trace())
    names = set(span_names(ev))
    assert {"serve_step", "admission"} <= names
    inst = [e["name"] for e in ev if e["ph"] == "i"]
    assert inst.count("admit") == 4 and inst.count("complete") == 4
    assert m_on.timeline["serve_step"]["count"] == m_on.serve_steps
    # per-step series rows carry queue/slot/page occupancy
    steps = [r for r in m_on.series if r["kind"] == "step"]
    assert len(steps) == m_on.serve_steps
    assert all(r["pages_live"] >= 0 and r["queue_depth"] >= 0 for r in steps)
    assert max(r["pages_live"] for r in steps) <= m_on.pages_peak
    json.dumps(m_on.as_dict())


# --------------------------------------------------------------------------
# parity + well-formedness across a placement swap AND a kill/rejoin
# --------------------------------------------------------------------------

def _cfg_physical(placement):
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True, params_physical=True,
                              placement=placement)
    return dataclasses.replace(cfg, moe=moe)


def _mesh8():
    import jax
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def test_traced_swap_and_kill_rejoin_bitwise_and_wellformed(tmp_path):
    """The acceptance scenario: continuous serve over the 8-rank mesh with
    EPLB swaps every 4 steps AND rank 2 killed then rejoined. Tracing on
    must stay bitwise-equal to tracing off, and the trace must contain the
    rebalance span plus BOTH recovery spans with phase timings."""
    E = 8
    cfg = _cfg_physical(PL.redundant_placement(E, 8, E))
    mesh = _mesh8()
    kw = dict(batch=8, max_len=32, page_size=4, num_redundant_experts=E,
              rebalance_every=4, miss_threshold=1)

    srv_a = ContinuousDecodeServer(cfg, mesh=mesh,
                                   fault_injector=FaultInjector(
                                       8, kill={3: 2}, rejoin={8: 2}), **kw)
    srv_a.serve_requests(_requests())
    base = {i: srv_a.reqsched.tokens_for(i) for i in range(4)}
    srv_a.close()

    tr, se = Tracer(), TimeSeries()
    srv_b = ContinuousDecodeServer(cfg, mesh=mesh,
                                   fault_injector=FaultInjector(
                                       8, kill={3: 2}, rejoin={8: 2}),
                                   tracer=tr, series=se, **kw)
    m = srv_b.serve_requests(_requests())
    sched = srv_b.reqsched
    srv_b.close()

    # (a) bitwise parity across swap + shrink + expand, telemetry on
    for i in range(4):
        np.testing.assert_array_equal(base[i], sched.tokens_for(i))
    assert [e["kind"] for e in srv_b.recoveries] == ["shrink", "expand"]
    assert m.recovery_count == 2

    # (b) trace well-formedness: spans nest, durations >= 0 (validator),
    # every recovery transition has exactly one complete-event
    ev = validate_chrome_trace(tr.to_chrome_trace())
    names = span_names(ev)
    assert names.count("recover:shrink") == 1
    assert names.count("recover:expand") == 1
    assert names.count("rebalance") >= 1
    assert {"fault_poll", "serve_step", "admission"} <= set(names)
    inst = [e["name"] for e in ev if e["ph"] == "i"]
    assert inst.count("fault_detected") == 2
    assert inst.count("placement_swap") >= 2    # shrink + expand at least
    # per-transition phase timings (detect lands as the fault_detected
    # instant; repack/adopt/restore are timed inside the recovery span)
    for e in srv_b.recoveries:
        assert e["phases"]["repack_s"] >= 0.0
        assert "adopt_s" in e["phases"] or "restore_s" in e["phases"]
    # top-level recovery spans carry the transition args (the nested
    # recover:repack / recover:adopt phase spans are unannotated timings)
    rec = [e for e in ev if e["name"] in ("recover:shrink", "recover:expand")]
    assert all("step" in e["args"] and "died" in e["args"] for e in rec)

    # (c) windowed series rows from the boundaries the engine already syncs
    kinds = {r["kind"] for r in m.series}
    assert "rebalance" in kinds and {"recover:shrink", "recover:expand"} <= kinds
    for r in m.series:
        if r["kind"] != "step":
            assert r["imbalance"] >= 1.0 and len(r["rank_loads"]) == 8
    json.dumps(m.as_dict())
    # exported file round-trips through the validator
    p = tr.write_chrome_trace(tmp_path / "serve_trace.json")
    validate_chrome_trace(load_chrome_trace(p))


# --------------------------------------------------------------------------
# driver-level: run_rebalancing with telemetry
# --------------------------------------------------------------------------

def test_run_rebalancing_traced_host_skeleton():
    """The EPLB driver skeleton with a pure-host fn: rebalance spans at
    every advance boundary, series rows showing the adopted table improving
    the skewed window's imbalance, and zero telemetry overhead on the
    placement schedule itself (same placements as the untraced run)."""
    from repro.core import EpGroupConfig
    from repro.core.placement import run_rebalancing

    E, N = 8, 4
    heat = np.zeros(E)
    heat[:2] = 100.0                      # two hot experts
    base_cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=16, hidden=8,
                             top_k=2, mode="ll")

    def make(group):
        return lambda item: (item, heat)

    items = list(range(6))
    _, pls_off = run_rebalancing(base_cfg, make, items, advance_every=2,
                                 ep_size=N, num_redundant=2)
    clk = FakeClock()
    tr, se = Tracer(clock=clk), TimeSeries()
    _, pls_on = run_rebalancing(base_cfg, make, items, advance_every=2,
                                ep_size=N, num_redundant=2,
                                tracer=tr, series=se)
    assert [p.fingerprint() if p else None for p in pls_on] == \
           [p.fingerprint() if p else None for p in pls_off]
    ev = validate_chrome_trace(tr.to_chrome_trace())
    # boundaries at items 1 and 3 (never after the last item)
    assert span_names(ev).count("rebalance") == 2
    rows = [r for r in se.rows if r["kind"] == "rebalance"]
    assert len(rows) == 2
    # the redundant rebalance spreads the two hot experts' replicas
    assert rows[0]["placement_changed"]
    assert rows[0]["imbalance_after"] <= rows[0]["imbalance"]
    assert all(r["window_tokens"] == 200.0 for r in rows)
