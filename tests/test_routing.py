"""Router unit tests: top-k selection, group-limited routing, aux-free bias,
aux losses, bias update direction."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import RouterConfig, route, update_selection_bias


def test_topk_softmax_basic():
    logits = jnp.asarray(np.random.RandomState(0).randn(32, 16), jnp.float32)
    r = route(logits, RouterConfig(num_experts=16, top_k=4))
    assert r.topk_idx.shape == (32, 4)
    # indices are the true top-4 of softmax scores
    want = np.argsort(-np.asarray(jax.nn.softmax(logits, -1)), axis=-1)[:, :4]
    np.testing.assert_array_equal(np.sort(np.asarray(r.topk_idx), -1),
                                  np.sort(want, -1))
    np.testing.assert_allclose(np.asarray(r.topk_weights.sum(-1)),
                               np.ones(32), rtol=1e-5)


def test_group_limited_routing():
    """With n_groups=4 topk_groups=1, all selected experts must come from
    one group of 4 per token."""
    rng = np.random.RandomState(1)
    logits = jnp.asarray(rng.randn(64, 16), jnp.float32)
    cfg = RouterConfig(num_experts=16, top_k=4, gating="sigmoid",
                       n_groups=4, topk_groups=1, norm_topk_prob=True)
    r = route(logits, cfg)
    groups = np.asarray(r.topk_idx) // 4
    assert (groups == groups[:, :1]).all()


def test_selection_bias_changes_selection_not_weights():
    rng = np.random.RandomState(2)
    logits = jnp.asarray(rng.randn(128, 8), jnp.float32)
    cfg = RouterConfig(num_experts=8, top_k=2, gating="sigmoid",
                       use_selection_bias=True, norm_topk_prob=False)
    bias = jnp.zeros(8).at[3].set(10.0)       # force expert 3 into every top-2
    r = route(logits, cfg, bias)
    assert (np.asarray(r.topk_idx) == 3).any(axis=-1).all()
    # weights come from the raw sigmoid scores, NOT the biased ones
    scores = np.asarray(jax.nn.sigmoid(logits))
    got_w = np.asarray(r.topk_weights)
    for t in range(8):
        for k in range(2):
            e = int(r.topk_idx[t, k])
            np.testing.assert_allclose(got_w[t, k], scores[t, e], rtol=1e-5)


def test_bias_update_direction():
    load = jnp.asarray([0.9, 0.05, 0.05])     # expert 0 overloaded
    b = update_selection_bias(jnp.zeros(3), load, update_rate=0.1)
    assert b[0] < 0 < b[1] and b[2] > 0


def test_selection_bias_with_rebalance_reduces_load_ratio():
    """EPLB satellite: on a synthetic hot-expert workload, iterated aux-free
    bias updates spread the *selection* (expert-level max/mean load drops),
    and placement rebalancing on the residual heat cuts the *per-rank*
    max/mean load further — the two mechanisms compose."""
    from repro.core.placement import (heat_from_topk, imbalance, rank_loads,
                                      rebalance)
    E, K, N, T = 16, 4, 8, 2048
    rng = np.random.RandomState(5)
    logits = jnp.asarray(rng.randn(T, E), jnp.float32)
    logits = logits.at[:, :2].add(4.0)           # experts 0-1 run hot
    cfg = RouterConfig(num_experts=E, top_k=K, gating="sigmoid",
                       use_selection_bias=True, norm_topk_prob=False)

    bias = jnp.zeros((E,))
    r0 = route(logits, cfg, bias)
    heat0 = np.asarray(heat_from_topk(r0.topk_idx, E), np.float64)
    rank_ratio0 = imbalance(rank_loads(heat0, None, N))
    expert_ratio0 = imbalance(heat0)

    for _ in range(60):                          # aux-free balancing loop
        r = route(logits, cfg, bias)
        bias = update_selection_bias(bias, r.expert_load, update_rate=0.02)
    heat1 = np.asarray(heat_from_topk(r.topk_idx, E), np.float64)
    assert imbalance(heat1) < expert_ratio0      # selection spread out

    # residual skew: heat-driven placement (permute + replicate) on top
    pl = rebalance(heat1, N, num_redundant=8)
    rank_ratio = imbalance(rank_loads(heat1, pl))
    assert rank_ratio < imbalance(rank_loads(heat1, None, N))
    # jointly: bias + rebalance beat the initial contiguous hot layout
    assert rank_ratio < rank_ratio0 / 1.5, (rank_ratio, rank_ratio0)


def test_aux_loss_penalizes_imbalance():
    T, E = 256, 8
    collapsed = jnp.zeros((T, E)).at[:, 0].set(10.0)
    uniform = jnp.zeros((T, E))
    cfg = RouterConfig(num_experts=E, top_k=2, aux_loss_weight=1.0)
    assert float(route(collapsed, cfg).aux_loss) > float(route(uniform, cfg).aux_loss)
