"""HLO collective-byte parser + roofline-correction unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import collective_bytes, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert _shape_bytes("f32[4,4,4]") == 64 * 4
    assert _shape_bytes("(f32[8], bf16[8,2]{1,0})") == 32 + 32
    assert _shape_bytes("pred[]") == 1


def test_collective_bytes_counts_real_ops():
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def f(x):
        a = jax.lax.psum(x, "model")
        b = jax.lax.all_gather(x, "data", axis=0, tiled=True)
        c = jax.lax.all_to_all(x.reshape(4, -1, x.shape[-1]), "data",
                               split_axis=0, concat_axis=0, tiled=False)
        s = a.sum() + b.sum() + c.sum()
        return jax.lax.psum(s, ("data", "model"))

    g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data", "model"),
                              out_specs=P()))
    x = jnp.ones((16, 32), jnp.float32)
    txt = g.lower(x).compile().as_text()
    cb = collective_bytes(txt)
    assert cb.get("all-to-all", 0) > 0
    assert cb.get("all-gather", 0) > 0
    assert cb["total"] >= cb.get("all-to-all", 0) + cb.get("all-gather", 0)


def test_scan_correction_math():
    from repro.launch.roofline import corrected_terms
    rec = dict(
        microbatch=2,
        program=dict(cost={"flops": 100.0, "bytes accessed": 50.0},
                     collectives={"total": 10}),
        stacks=[dict(trips=4, cost={"flops": 20.0, "bytes accessed": 8.0},
                     collectives={"total": 2})],
    )
    t = corrected_terms(rec)
    # trips*microbatch - 1 = 7 extra bodies
    assert t["flops"] == 100.0 + 7 * 20.0
    assert t["hbm_bytes"] == 50.0 + 7 * 8.0
    assert t["coll_bytes"] == 10 + 7 * 2
