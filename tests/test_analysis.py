"""HLO collective-byte parser + roofline-correction unit tests, plus the
contract-analyzer suite (docs/DESIGN.md §12): every linter rule must flag a
known-bad fixture AND pass on the real tree, suppressions must be loud, the
slot-map verifier must detect corrupted maps, and the runtime auditors must
pass on the PR 8/9 acceptance scenario (continuous serve across a placement
swap and a kill/rejoin) with the compiled-cache bound asserted."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.contracts import (RULES, check_source, run_all_contracts,
                                      run_rule)
from repro.launch.hlo_analysis import collective_bytes, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert _shape_bytes("f32[4,4,4]") == 64 * 4
    assert _shape_bytes("(f32[8], bf16[8,2]{1,0})") == 32 + 32
    assert _shape_bytes("pred[]") == 1


def test_collective_bytes_counts_real_ops():
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def f(x):
        a = jax.lax.psum(x, "model")
        b = jax.lax.all_gather(x, "data", axis=0, tiled=True)
        c = jax.lax.all_to_all(x.reshape(4, -1, x.shape[-1]), "data",
                               split_axis=0, concat_axis=0, tiled=False)
        s = a.sum() + b.sum() + c.sum()
        return jax.lax.psum(s, ("data", "model"))

    g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data", "model"),
                              out_specs=P()))
    x = jnp.ones((16, 32), jnp.float32)
    txt = g.lower(x).compile().as_text()
    cb = collective_bytes(txt)
    assert cb.get("all-to-all", 0) > 0
    assert cb.get("all-gather", 0) > 0
    assert cb["total"] >= cb.get("all-to-all", 0) + cb.get("all-gather", 0)


def test_scan_correction_math():
    from repro.launch.roofline import corrected_terms
    rec = dict(
        microbatch=2,
        program=dict(cost={"flops": 100.0, "bytes accessed": 50.0},
                     collectives={"total": 10}),
        stacks=[dict(trips=4, cost={"flops": 20.0, "bytes accessed": 8.0},
                     collectives={"total": 2})],
    )
    t = corrected_terms(rec)
    # trips*microbatch - 1 = 7 extra bodies
    assert t["flops"] == 100.0 + 7 * 20.0
    assert t["hbm_bytes"] == 50.0 + 7 * 8.0
    assert t["coll_bytes"] == 10 + 7 * 2


# ==========================================================================
# contract linter: known-bad fixtures (each rule must flag its construct)
# ==========================================================================

_BAD_FIXTURES = {
    "api-registry-only": """
        def ep_complete(group, handle, pending):
            if group.mode == "ll":
                return _ll.complete(group, handle, pending)
            if isinstance(pending, tuple):
                return pending
            return _ht.complete(group, handle, pending)
    """,
    "phase-one-pass": """
        def dispatch_send(handle, x):
            pos = positions_by_dest(handle.topk_idx, 8, None)
            order = jnp.argsort(pos.reshape(-1))
            return x, order
    """,
    "phase-no-placement": """
        SENTINEL = 0
        def resolve(group, experts, rank):
            return dest_of(group, experts, rank)
    """,
    "recv-one-pass": """
        def dispatch_recv(handle, buf):
            rows = gather_rows(buf, handle.plan.disp_recv_gmap)
            return dequantize_fp8(rows, handle.recv_scales)
    """,
    "backend-staged-primitive": """
        class SneakyBackend(BaseBackend):
            def dispatch(self, group, handle, x, send_only=False):
                return self.dispatch_send(group, handle, x)
    """,
    "step-no-host-sync": """
        def make_step(cfg):
            def step(state, tok):
                loss = state.loss.item()
                host = jax.device_get(tok)
                return float(state.metric)
            return step
    """,
}


@pytest.mark.parametrize("rule", sorted(RULES), ids=sorted(RULES))
def test_each_rule_flags_its_bad_fixture(rule):
    """A rule that cannot flag its own canonical violation is a no-op; the
    fixture violates by *construct*, not by magic function name (check_source
    scans all functions)."""
    src = textwrap.dedent(_BAD_FIXTURES[rule])
    found = check_source(rule, src)
    assert found, f"{rule}: fixture not flagged"
    assert all(f.rule == rule for f in found)
    assert all(f.path == "<fixture>" and f.line > 0 for f in found)


def test_rule_catalog_is_stable():
    """Rule names are API (tests, CI, suppression comments reference them);
    adding is fine, renames/removals must be deliberate."""
    assert set(RULES) == {
        "api-registry-only", "phase-one-pass", "phase-no-placement",
        "recv-one-pass", "backend-staged-primitive", "step-no-host-sync"}
    for r in RULES.values():
        assert r.description and r.targets


def test_clean_tree_has_no_findings():
    """The real tree satisfies every contract — the same invariant the CI
    ``analysis`` job enforces via ``python -m repro.analysis``."""
    assert run_all_contracts() == []


# -- suppressions: loud, justified, rule-scoped ----------------------------

_VIOLATION = "pos = positions_by_dest(handle.topk_idx, 8, None)"


def _fixture_with_comment(comment):
    return textwrap.dedent(f"""
        def dispatch_send(handle, x):
            {comment}
            {_VIOLATION}
            return pos
    """)


def test_suppression_with_justification_silences_finding():
    src = _fixture_with_comment(
        "# contract: allow(phase-one-pass): fixture exercises the host-side"
        " precompute path")
    assert check_source("phase-one-pass", src) == []


def test_suppression_without_justification_is_itself_a_finding():
    src = _fixture_with_comment("# contract: allow(phase-one-pass):")
    found = check_source("phase-one-pass", src)
    assert len(found) == 1
    assert "no justification" in found[0].message


def test_suppression_is_rule_scoped():
    """An allow() for a different rule never silences this one."""
    src = _fixture_with_comment(
        "# contract: allow(recv-one-pass): wrong rule on purpose")
    found = check_source("phase-one-pass", src)
    assert len(found) == 1 and "no justification" not in found[0].message


def test_run_rule_unknown_name_raises():
    with pytest.raises(KeyError):
        run_rule("no-such-rule")


# ==========================================================================
# slot-map / write-set verifier: clean on real plans, loud on corrupted ones
# ==========================================================================

def test_plan_verifier_clean_on_real_plans():
    """One matrix point end-to-end through the production jit+shard_map
    extraction; the full 15-case matrix runs in ``python -m repro.analysis``
    (CI analysis job)."""
    from repro.analysis.plan_verify import PLAN_CASES, verify_case
    assert verify_case(PLAN_CASES["ll-nccl/contig"]) == []


def test_plan_verifier_flags_corrupted_maps():
    """Corrupt extracted maps three ways — out-of-range slot, duplicated
    combine consume row (write-set no longer disjoint), dropped send entry —
    and the checker must report each."""
    from repro.analysis.plan_verify import (PLAN_CASES, check_plans,
                                            extract_plans)
    case = PLAN_CASES["ll-nccl/contig"]
    group, topk, plans = extract_plans(case)
    assert check_plans(case, group, topk, plans) == []

    def corrupted(mutate):
        bad = {k: v.copy() for k, v in plans.items()}
        mutate(bad)
        return check_plans(case, group, topk, bad)

    def oob(bad):
        bad["disp_send_gmap"][0].flat[0] = 10 ** 6

    def dup_consume(bad):
        rows = bad["comb_recv_rows"][0]
        rows.flat[1] = rows.flat[0]

    def drop_entry(bad):
        sg = bad["disp_send_gmap"]
        sg[0].flat[np.flatnonzero(sg[0].flat != sg.max())[0]] = sg.max()

    v_oob = corrupted(oob)
    assert any("out of range" in v for v in v_oob), v_oob
    v_dup = corrupted(dup_consume)
    assert any("duplicate" in v or "mismatch" in v for v in v_dup), v_dup
    v_drop = corrupted(drop_entry)
    assert v_drop, "silent token drop not detected"


# ==========================================================================
# runtime auditors on the PR 8/9 acceptance scenario: continuous serve with
# EPLB swaps + kill/rejoin, d2h-guarded steps, retrace economy asserted
# ==========================================================================

@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="CPU d2h is zero-copy — the JAX transfer guard "
                           "only arms on accelerators")
def test_transfer_guard_trips_on_d2h_but_allows_h2d():
    """The guard must be a real tripwire on accelerators: device->host
    readback inside the block is an error, while host->device feeding (how
    continuous batching ships tokens/page tables every step) stays legal."""
    from repro.analysis import transfer_guard
    x = jnp.arange(8)
    with transfer_guard():
        jnp.asarray(np.arange(4))                 # h2d: allowed
        with pytest.raises(Exception, match="[Dd]isallow"):
            np.asarray(x)                         # d2h: hard error
    np.asarray(x)                                 # boundary readback: fine

def test_auditors_on_swap_and_kill_rejoin():
    """The serving loop under all three auditors at once: every serve step
    runs inside the device->host transfer guard (a stray .item()/np.asarray
    in the step is a hard error), every adoption that can donate really
    deleted the old expert buffers, and the compiled-step cache stayed at
    the {current, previous} bound with exactly one compile + one trace per
    adopted placement."""
    import dataclasses as dc

    from repro.analysis import (DonationAuditor, RetraceAuditor,
                                guard_serve_steps)
    from repro.configs import get_smoke
    from repro.core import placement as PL
    from repro.runtime.fault import FaultInjector
    from repro.runtime.scheduler import Request
    from repro.runtime.server import ContinuousDecodeServer

    cfg = get_smoke("dbrx-132b")
    moe = dc.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                     track_expert_heat=True, params_physical=True,
                     placement=PL.redundant_placement(8, 8, 8))
    cfg = dc.replace(cfg, moe=moe)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    reqs = [Request(0, np.array([3, 5, 7], np.int32), 6),
            Request(1, np.array([11, 2], np.int32), 8),
            Request(2, np.array([9, 9, 9, 9, 1], np.int32), 5,
                    arrival_step=4),
            Request(3, np.array([4], np.int32), 7, arrival_step=6)]

    srv = ContinuousDecodeServer(
        cfg, mesh=mesh, batch=8, max_len=32, page_size=4,
        num_redundant_experts=8, rebalance_every=4, miss_threshold=1,
        fault_injector=FaultInjector(8, kill={3: 2}, rejoin={8: 2}))
    aud = RetraceAuditor(srv)        # after construction: baseline compile
                                     # excluded, counters measure swap traffic
    with DonationAuditor() as don, guard_serve_steps(srv):
        m = srv.serve_requests(reqs)
    srv.close()

    # the scenario really exercised both recovery paths
    assert [e["kind"] for e in srv.recoveries] == ["shrink", "expand"]
    assert m.requests_completed == 4

    # retrace economy: one compile + one trace per adopted placement, cache
    # never above {current, previous}
    assert aud.placements_adopted >= 2       # >= shrink + expand
    assert aud.max_cache_seen <= 2
    aud.assert_retrace_economy()

    # donation: adoptions happened and every rebind-eligible expert leaf
    # was verified deleted (assert_clean also ran at context exit)
    assert don.calls >= 2
    assert don.checked > 0 and don.donated == don.checked
    don.assert_clean()
