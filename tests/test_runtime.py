"""Runtime integration: training-loss decrease (dense + MoE-EP on a real
mesh), checkpoint save/restore + ELASTIC reshard, data-pipeline determinism,
straggler watchdog, decode server metrics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DataConfig, DataPipeline
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.runtime.fault import StragglerWatchdog
from repro.runtime.trainer import Trainer, TrainerConfig


def test_data_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    p1 = DataPipeline(cfg)
    batches = [next(p1) for _ in range(5)]
    # resume from state at step 3
    p2 = DataPipeline(cfg)
    p2.restore(dict(step=3, seed=7))
    b3 = next(p2)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(b3["tokens"]))
    # pure function of step
    np.testing.assert_array_equal(np.asarray(p1.batch_at(1)["tokens"]),
                                  np.asarray(batches[1]["tokens"]))


def _hot_opt(steps):
    from repro.optim import AdamWConfig
    return AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=steps,
                       weight_decay=0.0)


def test_train_loss_decreases_dense(tmp_path):
    cfg = get_smoke("internlm2-20b")
    t = Trainer(cfg, TrainerConfig(steps=40, global_batch=8, seq_len=32,
                                   log_every=5), opt_cfg=_hot_opt(40))
    t.run()
    losses = [m["loss"] for m in t.metrics_log]
    assert losses[-1] < losses[0] - 0.3, losses


def test_train_loss_decreases_moe_ep_on_mesh():
    """MoE arch trained THROUGH the EP dispatch/combine path on a 4x2 mesh."""
    cfg = get_smoke("dbrx-132b")
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    t = Trainer(cfg, TrainerConfig(steps=40, global_batch=8, seq_len=32,
                                   log_every=5), mesh=mesh, opt_cfg=_hot_opt(40))
    t.run()
    losses = [m["loss"] for m in t.metrics_log]
    assert losses[-1] < losses[0] - 0.3, losses


def test_checkpoint_roundtrip_and_elastic_reshard(tmp_path):
    from repro.models import get_model
    from repro.parallel.sharding import init_from_specs
    cfg = get_smoke("chatglm3-6b")
    m = get_model(cfg)
    spec = m.params_spec(cfg)
    mesh8 = jax.make_mesh((8,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    params = init_from_specs(jax.random.PRNGKey(0), spec, mesh8)
    save_checkpoint(tmp_path, 5, params)
    assert latest_step(tmp_path) == 5
    # restore onto a DIFFERENT mesh shape (elastic): values must be identical
    restored, idx = restore_checkpoint(tmp_path, 5, spec, mesh=mesh4)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and shardings must live on the new mesh
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 2, "model": 2}


def test_trainer_resume_matches_uninterrupted(tmp_path):
    cfg = get_smoke("mamba2-780m")
    base = dict(global_batch=4, seq_len=16, log_every=5, ckpt_every=10)
    # uninterrupted 20 steps
    t1 = Trainer(cfg, TrainerConfig(steps=20, **base))
    p1, _ = t1.run()
    # interrupted at 10 (ckpt), new trainer resumes to 20
    t2 = Trainer(cfg, TrainerConfig(steps=10, ckpt_dir=str(tmp_path), **base))
    t2.run()
    t3 = Trainer(cfg, TrainerConfig(steps=20, ckpt_dir=str(tmp_path), **base))
    p3, _ = t3.run()
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-2,
                                   atol=2e-2)


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=2.0)
    for _ in range(10):
        assert not w.observe(1.0)
    assert w.observe(5.0)
    assert w.flagged == 1
    assert abs(w.ema - 1.0) < 1e-6     # outliers don't poison the EMA


def test_decode_server_metrics():
    from repro.runtime.server import DecodeServer
    cfg = get_smoke("internlm2-20b")
    srv = DecodeServer(cfg, batch=2, max_len=64)
    prompts = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab, (2, 4)),
                          jnp.int32)
    m = srv.serve(prompts, gen_steps=8)
    assert m.total_tokens == 2 * 9
    assert m.output_tok_s > 0 and m.itl_p99_s >= m.itl_mean_s


def test_decode_server_heat_metrics_and_rebalance():
    """EPLB serving hook: with track_expert_heat the metrics fold per-expert
    heat + load-imbalance ratios (JSON-safe), and rebalance_every swaps
    placements mid-decode WITHOUT changing the greedy token stream."""
    import dataclasses
    import json
    from repro.runtime.server import DecodeServer
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True)
    cfg = dataclasses.replace(cfg, moe=moe)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (8, 4)), jnp.int32)

    srv = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh)
    m = srv.serve(prompts, gen_steps=6)
    assert m.expert_heat is not None and len(m.expert_heat) == moe.num_experts
    assert m.heat_max_mean >= 1.0 and m.rank_heat_max_mean >= 1.0
    assert sum(m.expert_heat) > 0
    json.dumps(m.as_dict())                 # serving benches emit this

    # rebalancing server: same greedy tokens, placements actually adopted
    srv_a = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh)
    first_a, _ = srv_a.prefill(prompts)
    toks_a, _ = srv_a.decode(first_a, 6)
    srv_b = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                         rebalance_every=2, num_redundant_experts=8)
    first_b, _ = srv_b.prefill(prompts)
    toks_b, _ = srv_b.decode(first_b, 6)
    np.testing.assert_array_equal(toks_a, toks_b)
    # at least one placement adopted (an unchanged rebalance table is
    # deduped — the scheduler reuses the object and skips the re-jit)
    assert len(srv_b.placements) >= 1
    assert srv_b.placements[0].num_redundant == 8
    assert srv_b.cfg.moe.placement is srv_b.placements[-1]

    # the hook refuses configs that can't feed it
    moe_off = dataclasses.replace(moe, track_expert_heat=False)
    with pytest.raises(ValueError, match="track_expert_heat"):
        DecodeServer(dataclasses.replace(cfg, moe=moe_off), batch=8,
                     max_len=32, mesh=mesh, rebalance_every=2)


@pytest.mark.filterwarnings("error")
def test_checkpoint_restore_dtype_hygiene(tmp_path):
    """Restore must never route pure-host numpy leaves through
    jax.numpy.asarray (x64 counters silently truncate to x32 with a
    UserWarning) and must canonicalize device-leaf target dtypes. Runs
    under filterwarnings("error"): any truncation warning fails."""
    from repro.parallel.sharding import ParamSpec
    tree = dict(
        w=jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        step=np.int64(2**40),               # > int32 range: truncation would corrupt
        heat=np.arange(4, dtype=np.float64) * 1e-9,
    )
    save_checkpoint(tmp_path, 3, tree)
    restored, _ = restore_checkpoint(tmp_path, 3, tree)
    assert isinstance(restored["step"], np.generic | np.ndarray)
    assert restored["step"].dtype == np.int64 and int(restored["step"]) == 2**40
    assert restored["heat"].dtype == np.float64
    np.testing.assert_array_equal(restored["heat"], np.asarray(tree["heat"]))
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
    # an x64 dtype in a device-leaf target spec restores canonicalized
    # (int32 on x32 runtimes) instead of warning
    spec = dict(w=ParamSpec((2, 3), jnp.bfloat16, (None, None)),
                step=ParamSpec((), np.int64, ()),
                heat=ParamSpec((4,), np.float64, (None,)))
    rs, _ = restore_checkpoint(tmp_path, 3, spec)
    assert rs["step"].dtype == jax.dtypes.canonicalize_dtype(np.int64)


def test_decode_server_adopt_once_same_tokens(tmp_path):
    """Adopt-once physical weights (MoESpec.params_physical): the server
    rebinds expert weights host-side once per placement adoption instead of
    expanding in-graph every step — the greedy token stream must be
    bitwise-identical to the per-step-expansion server across >= 2 swaps
    with redundant replicas, collapsing the final physical weights must
    recover the logical weights bitwise, and the compiled-step cache stays
    bounded to {current, previous}."""
    import dataclasses
    from repro.checkpoint import adopt_expert_params, save_checkpoint as _save
    from repro.runtime.server import DecodeServer
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True)
    cfg_l = dataclasses.replace(cfg, moe=moe)
    cfg_p = dataclasses.replace(
        cfg, moe=dataclasses.replace(moe, params_physical=True))
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (8, 4)), jnp.int32)

    srv_a = DecodeServer(cfg_l, batch=8, max_len=32, mesh=mesh,
                         rebalance_every=2, num_redundant_experts=8)
    first_a, _ = srv_a.prefill(prompts)
    toks_a, _ = srv_a.decode(first_a, 8)
    srv_b = DecodeServer(cfg_p, batch=8, max_len=32, mesh=mesh,
                         rebalance_every=2, num_redundant_experts=8)
    first_b, _ = srv_b.prefill(prompts)
    toks_b, _ = srv_b.decode(first_b, 8)
    np.testing.assert_array_equal(toks_a, toks_b)
    assert len(srv_b.placements) >= 2          # >= 2 adoption boundaries
    assert srv_b.placements[0].num_redundant == 8
    # physical layout actually adopted: expert leaves carry slot rows
    E, R = moe.num_experts, 8
    assert srv_b.params["moe_stack"]["moe"]["w_gate"].shape[1] == E + R
    assert srv_a.params["moe_stack"]["moe"]["w_gate"].shape[1] == E
    # compiled executables bounded despite multiple swaps
    assert len(srv_b._step_cache) <= 2
    # collapse after adopt-once serving == the logical weights, bitwise
    spec = srv_b.model.params_spec(srv_b._logical_cfg())
    back = adopt_expert_params(srv_b.params, spec,
                               srv_b.cfg.moe.placement, None)
    for a, b in zip(jax.tree.leaves(srv_a.params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # physical-layout checkpoint: fingerprint recorded; an elastic restore
    # against the LOGICAL spec rebinds stacked leaves along their "expert"
    # spec axis (full flat-dict roundtrip is in test_placement)
    _save(tmp_path, 1, srv_b.params, placement=srv_b.cfg.moe.placement)
    got, idx = restore_checkpoint(tmp_path, 1, spec, placement=None)
    assert (idx["expert_layout"]["fingerprint"]
            == srv_b.cfg.moe.placement.fingerprint())
    np.testing.assert_array_equal(
        np.asarray(got["moe_stack"]["moe"]["w_gate"], np.float32),
        np.asarray(srv_a.params["moe_stack"]["moe"]["w_gate"], np.float32))


def test_trainer_rejects_physical_params():
    """params_physical is a serving-only layout: training would push
    gradients into replicas independently and de-sync them."""
    import dataclasses
    cfg = get_smoke("dbrx-132b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, params_physical=True))
    with pytest.raises(ValueError, match="serving-only"):
        Trainer(cfg, TrainerConfig(steps=1, global_batch=4, seq_len=8))


def test_decode_server_pipelined_same_tokens():
    """pipeline_depth=2 (double-buffered host dispatch) must produce the
    identical greedy token stream — only the blocking schedule changes."""
    from repro.runtime.server import DecodeServer
    cfg = get_smoke("internlm2-20b")
    prompts = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab, (2, 4)),
                          jnp.int32)
    srv = DecodeServer(cfg, batch=2, max_len=64)
    first, _ = srv.prefill(prompts)
    toks, itls = srv.decode(first, 6)
    srv2 = DecodeServer(cfg, batch=2, max_len=64, pipeline_depth=2)
    first2, _ = srv2.prefill(prompts)
    toks2, itls2 = srv2.decode(first2, 6)
    np.testing.assert_array_equal(toks, toks2)
    # steady-state intervals only: the fill interval is excluded
    assert len(itls2) == 5 and np.all(itls2 >= 0)
