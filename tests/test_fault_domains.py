"""Fault-domain-aware placement (docs/DESIGN.md §9): the FaultDomains
topology (derived from the HT hierarchy's pod arithmetic or explicit), the
min-replica floor as an ENFORCED constraint in the rebalancer (distinct
ranks AND distinct fault domains when capacity permits), the shrink-
feasibility precheck that gates placement adoption, correlated (whole-pod)
kill schedules in the FaultInjector, fault-report coalescing, and the
end-to-end guarantee the floor buys: a whole pod dying at one step boundary
recovers through ONE zero-data-loss masked-rebind transition — bitwise
survivor-token parity, zero checkpoint restores."""
import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import placement as PL
from repro.core.group import EpGroupConfig, ep_create_group
from repro.core.plan import rank_pod
from repro.runtime.fault import DegradedRecovery, FaultInjector, FaultReport
from repro.runtime.server import DecodeServer

# CI seed matrix: the interpret-parity job re-runs this file under several
# seeds (REPRO_TEST_SEED) — heat/routing vary, every invariant must hold
SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


# --------------------------------------------------------------------------
# topology: derivation + validation
# --------------------------------------------------------------------------

def test_domains_from_geometry_matches_plan_rank_pod():
    """The fault-domain derivation and the hierarchical a2a must agree on
    which ranks share a pod — both route through core/plan.py rank_pod."""
    for ep, ni in [(8, 4), (8, 2), (12, 3), (16, 8)]:
        dom = PL.domains_from_geometry(ep, ni)
        assert dom.domain_of == tuple(rank_pod(r, ni) for r in range(ep))
        assert dom.num_ranks == ep and dom.num_domains == ep // ni
        for d in dom.domains():
            assert dom.ranks_in(d) == tuple(range(d * ni, (d + 1) * ni))


def test_trivial_domains_and_validation_errors():
    dom = PL.trivial_domains(4)
    assert dom.num_domains == 4 and dom.domain_of == (0, 1, 2, 3)
    assert dom.live_domains((1, 3)) == (1, 3)
    with pytest.raises(ValueError, match="non-empty"):
        PL.FaultDomains(())
    with pytest.raises(ValueError, match=">= 0"):
        PL.FaultDomains((0, -1))
    with pytest.raises(ValueError, match="must divide"):
        PL.domains_from_geometry(8, 3)
    with pytest.raises(ValueError, match=">= 1"):
        PL.trivial_domains(0)


def test_group_fault_domains_derivation_and_override():
    """EpGroup.fault_domains(): hierarchical geometry -> pod domains; flat
    -> rank-per-domain; explicit cfg override wins; a wrong-width override
    is rejected at group creation."""
    base = dict(num_experts=16, max_tokens_per_rank=16, hidden=32, top_k=2)
    hier = ep_create_group(
        EpGroupConfig(mode="ht", ht_hierarchical=True,
                      ep_axis=("pod", "data"), **base),
        ep_size=8, inner_size=4)
    assert hier.fault_domains().domain_of == (0, 0, 0, 0, 1, 1, 1, 1)
    flat = ep_create_group(EpGroupConfig(mode="ll", **base), ep_size=8)
    assert flat.fault_domains().domain_of == tuple(range(8))
    dom = PL.FaultDomains((0, 0, 1, 1, 2, 2, 3, 3))
    over = ep_create_group(EpGroupConfig(mode="ll", fault_domains=dom, **base),
                           ep_size=8)
    assert over.fault_domains() is dom
    with pytest.raises(ValueError, match="fault_domains cover"):
        ep_create_group(
            EpGroupConfig(mode="ll", fault_domains=PL.trivial_domains(4),
                          **base), ep_size=8)


# --------------------------------------------------------------------------
# the floor as a rebalancer constraint
# --------------------------------------------------------------------------

def test_rebalance_floor_holds_for_random_heats():
    """Property over random heats (seed-matrixed): every floor-mode
    placement has >= min_replicas replicas of every expert on distinct
    ranks spanning distinct domains, passes the shrink-feasibility
    precheck, and keeps legacy mode bit-identical."""
    rng = np.random.RandomState(SEED)
    dom = PL.domains_from_geometry(8, 4)
    for trial in range(6):
        h = rng.rand(16) * (10.0 ** rng.randint(0, 3, 16))
        pl = PL.rebalance(h, 8, num_redundant=16, min_replicas=2,
                          domains=dom, version=trial + 1)
        PL.validate_floor(pl, 2, dom)
        assert PL.shrink_feasibility(16, 16, 8, domains=dom, min_replicas=2,
                                     placement=pl) == []
        # any whole pod can die without losing an expert's last replica
        for d in dom.domains():
            alive = tuple(r for r in range(8) if r not in dom.ranks_in(d))
            assert PL.lost_experts(pl, alive) == ()
        # legacy path untouched: min_replicas=1, no domains — same table
        # as the pre-floor greedy (pinned indirectly by test_placement.py;
        # here: floor kwargs default off produces an unconstrained table)
        legacy = PL.rebalance(h, 8, num_redundant=16, version=trial + 1)
        assert legacy.num_experts == 16


def test_infeasible_floor_errors_name_e_r_n_domains():
    """Every floor-infeasibility raise is loud and names the geometry:
    E, R, N (alive ranks) and the domain map."""
    dom = PL.domains_from_geometry(8, 4)
    h = np.ones(8)
    with pytest.raises(ValueError, match=r"num_redundant >= E\*\(min_replicas-1\) = 8"):
        PL.rebalance(h, 8, num_redundant=4, min_replicas=2, domains=dom)
    with pytest.raises(ValueError, match="only 1 are alive"):
        PL.rebalance(h, 8, num_redundant=8, min_replicas=2,
                     alive_ranks=(0,))
    # pigeonhole: S > E forces same-expert co-hosting
    with pytest.raises(ValueError, match="exceed the 2 experts"):
        PL.rebalance(np.ones(2), 2, num_redundant=4, min_replicas=2)
    # the E/R/N/domains context tail rides on every floor error
    with pytest.raises(ValueError) as ei:
        PL.rebalance(h, 8, num_redundant=4, min_replicas=2, domains=dom)
    msg = str(ei.value)
    for part in ("E=8 experts", "R=4 redundant slots", "N=8 alive",
                 "domains={0: [0, 1, 2, 3], 1: [4, 5, 6, 7]}"):
        assert part in msg, (part, msg)


def test_legacy_cohost_warns_floor_cohost_raises():
    """Satellite: same-expert replicas on one rank — a loud
    DegradedRecovery-class warning in legacy (floor-less) mode, a hard
    error under the floor."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pl = PL.rebalance(np.ones(2), 2, num_redundant=4)   # S=3 > E=2
    assert any(isinstance(w.message, DegradedRecovery)
               and "collocate" in str(w.message) for w in rec)
    # the legacy table really does co-host (that is WHY it warned)
    rows = [[e for e in row if e != PL.EMPTY] for row in pl.slot_expert]
    assert any(len(set(r)) < len(r) for r in rows)
    with pytest.raises(ValueError, match="min_replicas=2 floor infeasible"):
        PL.rebalance(np.ones(2), 2, num_redundant=4, min_replicas=2)


def test_fit_redundant_keeps_the_floor_share():
    assert PL.fit_redundant(8, 8, 7) == 6                   # legacy: shrink R
    assert PL.fit_redundant(8, 8, 7, min_replicas=2) == 13  # floor: grow R
    assert PL.fit_redundant(8, 8, 8, min_replicas=2) == 8   # exact fit kept
    assert PL.fit_redundant(16, 16, 4, min_replicas=2) == 16


def test_required_domain_span_capacity_reduction_warns():
    """Uneven pods: when per-domain capacity cannot give every expert a
    replica in `min_replicas` distinct domains, the span lowers LOUDLY
    (never silently weakening the correlated-failure guarantee)."""
    dom = PL.FaultDomains((0, 0, 0, 0, 1, 1, 2, 2))
    caps = {0: 12, 1: 6, 2: 6}
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        span = PL.required_domain_span(8, 3, tuple(range(8)), dom, caps,
                                       warn=True)
    assert span == 2
    assert any(isinstance(w.message, DegradedRecovery)
               and "too uneven" in str(w.message) for w in rec)
    # ample capacity: full span, no warning
    assert PL.required_domain_span(8, 2, tuple(range(8)), dom,
                                   {0: 8, 1: 8, 2: 8}) == 2
    assert PL.required_domain_span(8, 2, tuple(range(8)), None) == 1


def test_shrink_feasibility_headroom_gates_adoption():
    """Spare-capacity headroom: a placement whose post-pod-kill repack
    would over-pack the survivors past max_slots_per_rank is rejected AT
    ADOPTION (rebalance raises), not discovered during recovery; the
    degraded repack itself (shrink_placement) skips the what-if so a real
    recovery is never blocked by a hypothetical second failure."""
    dom = PL.domains_from_geometry(8, 4)
    h = np.ones(16)
    # pod kill leaves 4 survivors: refit R=16 -> 32 slots / 4 = 8 per rank
    with pytest.raises(ValueError, match="shrink-feasibility precheck"):
        PL.rebalance(h, 8, num_redundant=16, min_replicas=2, domains=dom,
                     max_slots_per_rank=6)
    pl = PL.rebalance(h, 8, num_redundant=16, min_replicas=2, domains=dom,
                      max_slots_per_rank=8)     # 8 slots of headroom: fine
    PL.validate_floor(pl, 2, dom)
    # an actual pod death still shrinks (what-if for the NEXT failure off)
    sh = PL.shrink_placement(h, 8, dom.ranks_in(1), num_redundant=16,
                             min_replicas=2, domains=dom,
                             max_slots_per_rank=8)
    assert sh.dead_ranks() == (4, 5, 6, 7)
    PL.validate_floor(sh, 2, dom)
    # scenarios that kill EVERY rank are skipped, not declared infeasible
    assert PL.shrink_feasibility(
        16, 16, 4, domains=PL.FaultDomains((0, 0, 0, 0)), min_replicas=2,
        placement=None) == []


# --------------------------------------------------------------------------
# correlated-kill schedules + report coalescing
# --------------------------------------------------------------------------

def test_fault_report_merge_dedups_and_cancels():
    a = FaultReport(died=(2, 5), rejoined=())
    b = FaultReport(died=(5, 7), rejoined=(2,))
    m = a.merge(b)
    assert m.died == (5, 7) and m.rejoined == ()    # 2 died+rejoined: cancels
    assert not FaultReport((3,), ()).merge(FaultReport((), (3,)))
    assert FaultReport().merge(FaultReport()) == FaultReport()


def test_injector_kill_domains_expand_to_one_step():
    """A whole-domain kill schedule expands to every rank of the pod dying
    at the SAME step boundary — one correlated event, deterministic log."""
    dom = PL.domains_from_geometry(8, 4)
    inj = FaultInjector(8, domains=dom, kill_domains={3: 1},
                        rejoin_domains={7: 1}, kill={3: 0})
    assert inj.kill[3] == (0, 4, 5, 6, 7)     # per-rank entry merges in
    r = inj.advance(3)
    assert r.died == (0, 4, 5, 6, 7) and inj.dead_ranks == (0, 4, 5, 6, 7)
    assert inj.advance(5) == FaultReport()
    assert inj.advance(7).rejoined == (4, 5, 6, 7)
    assert inj.dead_ranks == (0,)
    # two runs over the same schedule produce identical logs
    inj2 = FaultInjector(8, domains=dom, kill_domains={3: 1},
                         rejoin_domains={7: 1}, kill={3: 0})
    for s in range(8):
        inj2.advance(s)
    assert inj2.log == inj.log
    with pytest.raises(ValueError, match="need the domains"):
        FaultInjector(8, kill_domains={0: 1})
    with pytest.raises(ValueError, match="domains cover"):
        FaultInjector(8, domains=PL.trivial_domains(4), kill_domains={0: 1})


# --------------------------------------------------------------------------
# end to end: whole-pod death under the floor
# --------------------------------------------------------------------------

def _mesh8():
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _cfg_physical(placement):
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True, params_physical=True,
                              placement=placement)
    return dataclasses.replace(cfg, moe=moe)


def _prompts(cfg):
    return jnp.asarray(np.random.RandomState(SEED).randint(
        0, cfg.vocab, (8, 4)), jnp.int32)


def test_whole_pod_kill_recovers_without_checkpoint():
    """THE acceptance scenario (ISSUE 7): a whole pod (4 of 8 ranks) dies at
    one step boundary. Under min_replicas=2 across fault domains every
    expert kept a replica in the surviving pod, so the server recovers via
    ONE masked-rebind transition — bitwise survivor-token parity with the
    uninterrupted run, zero checkpoint restores, one fingerprint bump for
    the shrink and one for the re-expand."""
    E = 8
    dom = PL.domains_from_geometry(8, 4)       # pods {0..3}, {4..7}
    pl0 = PL.rebalance(np.ones(E), 8, num_redundant=E,
                       min_replicas=2, domains=dom)
    cfg = _cfg_physical(pl0)
    mesh = _mesh8()
    prompts = _prompts(cfg)

    srv_a = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                         num_redundant_experts=E)
    first_a, _ = srv_a.prefill(prompts)
    toks_a, _ = srv_a.decode(first_a, 12)

    inj = FaultInjector(8, domains=dom, kill_domains={3: 1},
                        rejoin_domains={8: 1})
    srv_b = DecodeServer(cfg, batch=8, max_len=32, mesh=mesh,
                         num_redundant_experts=E, fault_injector=inj,
                         miss_threshold=1, min_replicas=2, fault_domains=dom)
    first_b, _ = srv_b.prefill(prompts)
    toks_b, _ = srv_b.decode(first_b, 12)

    # bitwise parity across the pod kill + rejoin; NO checkpoint involved
    np.testing.assert_array_equal(toks_a, toks_b)
    assert srv_b._ckpt_restores == 0 and srv_b.ckpt_dir is None

    # ONE coalesced shrink for all four deaths, one expand for the rejoin
    assert [e["kind"] for e in srv_b.recoveries] == ["shrink", "expand"]
    shrink, expand = srv_b.recoveries
    assert shrink["died"] == [4, 5, 6, 7]
    assert shrink["lost_experts"] == [] and shrink["restored_from"] is None
    assert expand["rejoined"] == [4, 5, 6, 7]

    # degraded table: the whole dead pod is EMPTY rows, survivors hold
    # every expert (the floor's purpose), and the floor still holds
    degraded, expanded = srv_b.placements[-2:]
    assert degraded.dead_ranks() == (4, 5, 6, 7)
    assert PL.lost_experts(degraded, (0, 1, 2, 3)) == ()
    PL.validate_floor(degraded, 2, dom)
    PL.validate_floor(expanded, 2, dom)

    # exactly one handle/step rebuild per transition: 3 distinct salts,
    # compiled-step cache stays bounded
    fps = [pl0.fingerprint(), degraded.fingerprint(), expanded.fingerprint()]
    assert len(set(fps)) == 3
    assert len(srv_b._step_cache) <= 2
    assert srv_b._detector.alive == tuple(range(8))


def test_server_floor_validation_gates_init():
    """DecodeServer floor mode: too few redundant slots and floor-violating
    initial placements are rejected at construction, not mid-recovery."""
    E = 8
    dom = PL.domains_from_geometry(8, 4)
    pl_ok = PL.rebalance(np.ones(E), 8, num_redundant=E,
                         min_replicas=2, domains=dom)
    cfg = _cfg_physical(pl_ok)
    with pytest.raises(ValueError, match=r"num_redundant_experts >= "):
        DecodeServer(cfg, batch=8, max_len=32, mesh=_mesh8(),
                     num_redundant_experts=0, min_replicas=2,
                     fault_domains=dom,
                     fault_injector=FaultInjector(8, kill={2: 1}))
    # identity placement: single replicas — violates the floor loudly
    cfg_id = _cfg_physical(PL.identity_placement(E, 8))
    with pytest.raises(ValueError, match="violates the min-replica floor"):
        DecodeServer(cfg_id, batch=8, max_len=32, mesh=_mesh8(),
                     num_redundant_experts=E, min_replicas=2,
                     fault_domains=dom,
                     fault_injector=FaultInjector(8, kill={2: 1}))
