"""LL-mode correctness: dispatch/combine vs a dense oracle, both layouts.

Oracle: with per-expert transform f_e(x) = (1 + e) * x, the MoE output for
token t is sum_k w[t,k] * (1 + topk[t,k]) * x[t]. Any slot-map bug (wrong
slot, wrong rank, wrong expert region) breaks this equality.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.group import EpGroupConfig, ep_create_group
from repro.core import ll


def make_mesh(n=8, name="data"):
    return jax.make_mesh((n,), (name,),
                         axis_types=(jax.sharding.AxisType.Auto,))


def run_ll(cfg: EpGroupConfig, x, topk, w, nt=None):
    """x: [N, T, H] global; returns (out [N, T, H], counts [N, L])."""
    N = x.shape[0]
    mesh = make_mesh(N)
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        handle = ll.ll_create_handle(group, topk, w)
        y3d, counts = ll.ll_dispatch(group, handle, x)
        # identity-per-expert transform: scale rows of expert e by (1+e_global)
        me = jax.lax.axis_index("data")
        L = group.local_experts
        e_glob = me * L + jnp.arange(L)
        y3d = y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
        out = ll.ll_combine(group, handle, y3d)
        return out[None], counts[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P("data"), P("data"), P("data")),
                              out_specs=(P("data"), P("data"))))
    return f(x, topk, w)


def oracle(x, topk, w):
    # [N, T, H], [N, T, K], [N, T, K]
    scale = (w * (1.0 + topk)).sum(-1)   # [N, T]
    return x * scale[..., None]


@pytest.mark.parametrize("layout", ["nccl_ep", "deepep"])
@pytest.mark.parametrize("E,K,T,H", [(16, 4, 16, 64), (32, 8, 8, 32), (8, 2, 32, 16)])
def test_ll_roundtrip(layout, E, K, T, H):
    N = 8
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ll", ll_layout=layout, payload_dtype=jnp.float32)
    out, counts = run_ll(cfg, x, topk, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle(x, topk, w)),
                               rtol=2e-5, atol=2e-5)
    # conservation: every (t, k) entry lands on exactly one expert
    assert int(counts.sum()) == N * T * K


@pytest.mark.parametrize("layout", ["nccl_ep", "deepep"])
def test_ll_counts_match_routing(layout):
    N, E, K, T, H = 8, 16, 4, 8, 16
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jnp.ones((N, T, K), jnp.float32) / K
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ll", ll_layout=layout, payload_dtype=jnp.float32)
    _, counts = run_ll(cfg, x, topk, w)
    # per-expert counts must equal the routing histogram
    hist = np.zeros(E)
    for r in range(N):
        for t in range(T):
            for k in range(K):
                hist[int(topk[r, t, k])] += 1
    got = np.asarray(counts).reshape(-1)  # [N*L] == [E] in block order
    np.testing.assert_array_equal(got, hist)


def test_ll_grad_flows():
    """AD through dispatch+combine == the paper's cached-dispatch backward."""
    N, E, K, T, H = 8, 8, 2, 8, 16
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ll", payload_dtype=jnp.float32)

    def loss(x):
        out, _ = run_ll(cfg, x, topk, w)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(x)
    # oracle gradient: out = s * x with s = sum_k w (1 + e)  =>  dL/dx = 2 s^2 x
    s = (w * (1.0 + topk)).sum(-1)[..., None]
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * s * s * x),
                               rtol=2e-4, atol=2e-4)


def test_ll_staged_equals_fused():
    N, E, K, T, H = 8, 16, 4, 8, 32
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ll", payload_dtype=jnp.float32)
    mesh = make_mesh(N)
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w, staged):
        x, topk, w = x[0], topk[0], w[0]
        h = ll.ll_create_handle(group, topk, w)
        if staged:
            p = ll.ll_dispatch(group, h, x, send_only=True)
            y3d, c = ll.ll_complete_dispatch(group, h, p)
            pc = ll.ll_combine(group, h, y3d, send_only=True)
            out = ll.ll_complete_combine(group, h, pc)
        else:
            y3d, c = ll.ll_dispatch(group, h, x)
            out = ll.ll_combine(group, h, y3d)
        return out[None]

    outs = []
    for staged in (False, True):
        f = jax.jit(jax.shard_map(functools.partial(step, staged=staged), mesh=mesh,
                                  in_specs=(P("data"),) * 3, out_specs=P("data")))
        outs.append(np.asarray(f(x, topk, w)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_ll_fp8_quantized_dispatch():
    """FP8 payload (paper §IV-B): lossy but close; combine stays bf16."""
    N, E, K, T, H = 8, 16, 4, 16, 256
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
    topk = jnp.asarray(
        np.stack([np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
                  for _ in range(N)]), jnp.int32)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K,
                        mode="ll", quantize_dispatch=True, quant_block=128)
    out, _ = run_ll(cfg, x, topk, w)
    ref = oracle(x, topk, w)
    rel = np.abs(np.asarray(out, np.float32) - np.asarray(ref)).mean() / np.abs(ref).mean()
    assert rel < 0.08, rel  # fp8 e4m3 block-quant error budget
