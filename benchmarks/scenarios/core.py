"""Scenario implementations. Five traffic shapes through the real serving
engines, telemetry on, acceptance asserted in-bench:

  poisson   — Poisson arrivals through the continuous-batching engine; all
              requests must complete, paged <= dense page accounting, and
              the emitted Chrome trace must validate with one admit/complete
              instant per request.
  bursty    — synchronized arrival bursts larger than the slot count; a
              queue backlog must FORM (visible in the per-step time series)
              and fully drain.
  drift     — Zipf-style routing skew that MOVES between expert pairs
              mid-serve (driven through the router's selection bias, so the
              skew flows through the real routed model, not a synthetic
              histogram); the EPLB rebalancer must cut the per-rank
              imbalance ratio after each rebalance boundary, including
              after the hot set drifts — the case where heat decay earns
              its keep.
  cliff     — context-length sweep against a deliberately small page pool;
              requests that fit must complete with monotone page high-water,
              requests past the cliff must be REJECTED loudly up front
              (reservation-gated admission), and raw pool exhaustion must
              raise PagePoolExhausted — never silent corruption.
  ramp      — the same request set at growing max concurrency; steps to
              completion must not increase, and per-request token streams
              must stay bitwise identical across concurrency levels.

Rows land in results/benchmarks/scenarios.json (folded into
BENCH_ll_kernels.json schema v7); trace/series artifacts under
results/benchmarks/scenarios/.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS, pct_ms, table, write_result
from benchmarks.scenarios.arrivals import (bursty_arrivals, poisson_arrivals,
                                           zipf_prompt_lengths)
from repro.configs import get_smoke
from repro.models.kv_pages import (PageAllocator, PagePoolExhausted,
                                   pages_for_tokens)
from repro.runtime.scheduler import Request
from repro.runtime.server import ContinuousDecodeServer, DecodeServer
from repro.runtime.telemetry import Tracer, TimeSeries, validate_chrome_trace

ARTIFACTS = RESULTS / "scenarios"


def _mesh8():
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _ll_cfg(**moe_kw):
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True, **moe_kw)
    return dataclasses.replace(cfg, moe=moe)


def _requests(arrivals, plens, max_new, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, 256, int(plens[i])).astype(np.int32),
                    max_new, arrival_step=int(arrivals[i]))
            for i in range(len(arrivals))]


# --------------------------------------------------------------------------
# poisson
# --------------------------------------------------------------------------

def scenario_poisson(n_req=12, rate=0.5, max_new=8):
    arrivals = poisson_arrivals(n_req, rate, seed=0)
    plens = zipf_prompt_lengths(n_req, 3, 8, seed=1)
    tr, ts = Tracer(), TimeSeries()
    srv = ContinuousDecodeServer(_ll_cfg(), batch=8, max_len=32, mesh=_mesh8(),
                                 page_size=4, tracer=tr, series=ts)
    m = srv.serve_requests(_requests(arrivals, plens, max_new))
    srv.close()

    # ---- acceptance ----
    assert m.requests_completed == n_req, m.requests_completed
    assert m.pages_peak <= m.pages_dense_equiv, (m.pages_peak,
                                                 m.pages_dense_equiv)
    events = validate_chrome_trace(tr.to_chrome_trace())
    names = [e["name"] for e in events]
    assert names.count("admit") == n_req, names.count("admit")
    assert names.count("complete") == n_req, names.count("complete")
    assert "serve_step" in names and "admission" in names

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    trace_path = tr.write_chrome_trace(ARTIFACTS / "poisson_trace.json")
    series_path = ts.to_jsonl(ARTIFACTS / "poisson_series.jsonl")
    ttfts = [r["ttft_s"] for r in m.per_request]
    row = dict(scenario="poisson", n_req=n_req, rate_per_step=rate,
               steps=m.serve_steps, ttft_p50_ms=pct_ms(ttfts, 50),
               ttft_p95_ms=pct_ms(ttfts, 95),
               itl_p50_ms=round(m.itl_p50_s * 1e3, 2),
               itl_p95_ms=round(m.itl_p95_s * 1e3, 2),
               pages_peak=m.pages_peak,
               pages_ratio=round(m.pages_peak / m.pages_dense_equiv, 3),
               trace_events=len(events))
    return [row], dict(trace=str(trace_path), series=str(series_path))


# --------------------------------------------------------------------------
# bursty
# --------------------------------------------------------------------------

def scenario_bursty(n_bursts=2, burst=12, gap=10, max_new=6):
    arrivals = bursty_arrivals(n_bursts, burst, gap)
    n_req = len(arrivals)
    plens = np.full(n_req, 4)
    ts = TimeSeries()
    srv = ContinuousDecodeServer(_ll_cfg(), batch=8, max_len=32, mesh=_mesh8(),
                                 page_size=4, series=ts)
    m = srv.serve_requests(_requests(arrivals, plens, max_new))
    srv.close()

    steps = [r for r in ts.rows if r["kind"] == "step"]
    depths = [r["queue_depth"] for r in steps]
    # ---- acceptance: a backlog must form (burst > slot count) and drain ----
    assert m.requests_completed == n_req, m.requests_completed
    assert max(depths) >= burst - srv.batch, (max(depths), burst, srv.batch)
    assert depths[-1] == 0, depths[-10:]        # backlog fully drained
    row = dict(scenario="bursty", n_req=n_req, bursts=n_bursts,
               burst_size=burst, steps=m.serve_steps,
               max_queue_depth=int(max(depths)),
               ttft_p95_ms=round(m.ttft_p95_s * 1e3, 2),
               itl_p95_ms=round(m.itl_p95_s * 1e3, 2))
    return [row], {}


# --------------------------------------------------------------------------
# drifting skew
# --------------------------------------------------------------------------

def _set_hot_pair(srv, pair, bias=100.0):
    """Steer the router's expert SELECTION onto ``pair`` host-side via the
    aux-free selection bias (models/moe.py ``sel_bias``): the skew then flows
    through the real routed decode — dispatch, heat counters, placement —
    rather than a synthetic histogram. Gate weights stay unbiased."""
    sb = np.asarray(srv.params["moe_stack"]["moe"]["sel_bias"])
    new = np.zeros_like(sb)
    new[..., list(pair)] = bias
    srv.params["moe_stack"]["moe"]["sel_bias"] = jnp.asarray(new)


def scenario_drift(window=8, segments=4, drop_factor=0.8, spike_factor=1.25):
    """Zipf skew that drifts: segments 0-1 route hot onto experts {0,1},
    segments 2-3 onto {4,5}. One rebalance boundary per segment. The
    acceptance bar (in-bench): the imbalance ratio measured AFTER a
    rebalance must drop vs the window before it — both for the initial skew
    and again after the drift — and the drift itself must show up as a
    spike under the stale placement."""
    cfg = _ll_cfg(use_selection_bias=True)
    E = cfg.moe.num_experts
    tr, ts = Tracer(), TimeSeries()
    srv = DecodeServer(cfg, batch=8, max_len=64, mesh=_mesh8(),
                       rebalance_every=window, num_redundant_experts=E,
                       heat_decay=0.7, tracer=tr, series=ts)
    hot = [(0, 1), (0, 1), (4, 5), (4, 5)]
    _set_hot_pair(srv, hot[0])
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (8, 6)), jnp.int32)
    tok, _ = srv.prefill(prompts)
    for seg in range(segments):
        if seg and hot[seg] != hot[seg - 1]:
            _set_hot_pair(srv, hot[seg])
        outs, _ = srv.decode(tok, window)
        tok = jnp.asarray(outs[:, -1:])
    srv.close()

    wrows = [r for r in ts.rows if r["kind"] == "rebalance"]
    assert len(wrows) == segments, [r["kind"] for r in ts.rows]
    imb = [r["imbalance"] for r in wrows]
    # ---- acceptance: rebalancing must EARN its keep under drift ----
    # window 1 ran under the post-rebalance placement for {0,1}: must drop
    assert imb[1] < imb[0] * drop_factor, (imb, "no drop after rebalance")
    # window 2 ran hot on {4,5} under the stale {0,1}-optimized table: spike
    assert imb[2] > imb[1] * spike_factor, (imb, "drift did not spike")
    # window 3 ran under the re-adapted table (heat decay forgetting {0,1})
    assert imb[3] < imb[2] * drop_factor, (imb, "no re-drop after drift")
    events = validate_chrome_trace(tr.to_chrome_trace())
    swaps = sum(1 for e in events if e["name"] == "placement_swap")
    assert swaps >= 2, swaps            # adapt + re-adapt at minimum

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    series_path = ts.to_jsonl(ARTIFACTS / "drift_series.jsonl")
    rows = [dict(scenario="drift", segment=i, hot_experts=list(hot[i]),
                 imbalance=round(imb[i], 3),
                 window_tokens=wrows[i]["window_tokens"],
                 placements_adopted=wrows[i]["placements_adopted"])
            for i in range(segments)]
    return rows, dict(series=str(series_path))


# --------------------------------------------------------------------------
# context-length cliff
# --------------------------------------------------------------------------

def scenario_cliff(num_pages=12, page_size=4, max_new=8):
    """Sweep prompt length toward the page-pool cliff. Requests whose
    worst-case footprint fits the pool complete with a monotone page
    high-water; past the cliff, reservation-gated admission REJECTS up
    front (loud ValueError naming the pool), before any device step — and
    the raw allocator raises PagePoolExhausted at the exact page."""
    srv = ContinuousDecodeServer(_ll_cfg(), batch=8, max_len=64, mesh=_mesh8(),
                                 page_size=page_size, num_pages=num_pages)
    rows, last_peak = [], 0
    for L in (8, 16, 32, 44, 56):
        need = pages_for_tokens(L + max_new - 1, page_size)
        reqs = _requests([0], [L], max_new)
        if need <= num_pages:
            m = srv.serve_requests(reqs)
            assert m.requests_completed == 1, m.requests_completed
            peak = srv.reqsched.alloc.peak_live
            assert peak == need, (peak, need)       # lazy alloc, exact
            assert peak >= last_peak, (peak, last_peak)
            last_peak = peak
            rows.append(dict(scenario="cliff", prompt_len=L,
                             pages_needed=need, pool_pages=num_pages,
                             outcome="ok", pages_peak=peak))
        else:
            # ---- acceptance: the cliff is LOUD and happens up front ----
            try:
                srv.serve_requests(reqs)
            except ValueError as e:
                assert "pool has only" in str(e), e
                rows.append(dict(scenario="cliff", prompt_len=L,
                                 pages_needed=need, pool_pages=num_pages,
                                 outcome="rejected", pages_peak=None))
            else:
                raise AssertionError(
                    f"prompt_len={L} needs {need} pages > pool {num_pages} "
                    "but admission did not reject")
    srv.close()
    assert [r["outcome"] for r in rows] == ["ok", "ok", "ok",
                                            "rejected", "rejected"], rows

    # raw allocator: exhaustion raises at the exact page, never silently
    alloc = PageAllocator(4, page_size)
    alloc.alloc(4)
    try:
        alloc.alloc(1)
    except PagePoolExhausted:
        pass
    else:
        raise AssertionError("PageAllocator over-allocated past the pool")
    return rows, {}


# --------------------------------------------------------------------------
# concurrency ramp
# --------------------------------------------------------------------------

def scenario_ramp(n_req=16, max_new=6):
    """The same 16-request set at max concurrency 8 then 16 (mesh-divisible
    slot counts): more slots must never take more steps, and every
    request's token stream must be bitwise identical across levels."""
    rows, streams, steps_seen = [], None, None
    for B in (8, 16):
        srv = ContinuousDecodeServer(_ll_cfg(), batch=B, max_len=32,
                                     mesh=_mesh8(), page_size=4)
        m = srv.serve_requests(_requests(np.zeros(n_req, int),
                                         np.full(n_req, 5), max_new))
        got = {r: srv.reqsched.tokens_for(r).tolist() for r in range(n_req)}
        srv.close()
        assert m.requests_completed == n_req, m.requests_completed
        # ---- acceptance ----
        if streams is None:
            streams = got
        else:
            assert got == streams, "token streams changed with concurrency"
        if steps_seen is not None:
            assert m.serve_steps <= steps_seen, (m.serve_steps, steps_seen)
        steps_seen = m.serve_steps
        rows.append(dict(scenario="ramp", max_concurrency=B,
                         steps=m.serve_steps,
                         ttft_p95_ms=round(m.ttft_p95_s * 1e3, 2),
                         output_tok_s=round(m.output_tok_s, 1),
                         pages_peak=m.pages_peak, bitwise_parity=True))
    return rows, {}


# --------------------------------------------------------------------------

def main():
    sections, artifacts = {}, {}
    for name, fn in [("poisson", scenario_poisson),
                     ("bursty", scenario_bursty),
                     ("drift", scenario_drift),
                     ("cliff", scenario_cliff),
                     ("ramp", scenario_ramp)]:
        print(f"\n---- scenario: {name} ----", flush=True)
        rows, arts = fn()
        sections[name] = rows
        if arts:
            artifacts[name] = arts
        cols = list(rows[0].keys())
        table(rows, cols, f"scenario: {name}")
    print("\nacceptance bars (asserted above): all requests complete; "
          "paged <= dense; backlog forms AND drains; post-rebalance "
          "imbalance drops (incl. after drift); cliff rejects loudly "
          "before any step; bitwise parity across concurrency")
    if artifacts:
        print("artifacts:", json.dumps(artifacts, indent=1))
    write_result("scenarios", dict(
        config=dict(model="dbrx-132b smoke", ranks=8, ep_mode="ll",
                    page_size=4),
        **{k: dict(rows=v) for k, v in sections.items()},
        artifacts=artifacts))
    return sections


if __name__ == "__main__":
    main()
