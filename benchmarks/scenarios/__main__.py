from benchmarks.common import ensure_devices

ensure_devices(8)

from benchmarks.scenarios.core import main   # noqa: E402

if __name__ == "__main__":
    main()
