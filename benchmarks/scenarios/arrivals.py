"""Arrival-process generators for the scenario harness. Numpy-only (no jax)
so schedules can be built — and unit-tested — before device bootstrap."""
from __future__ import annotations

import numpy as np


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """[n] int step indices of a Poisson process with ``rate`` arrivals per
    decode step, shifted so the first request lands at step 0."""
    rng = np.random.RandomState(seed)
    arr = np.floor(np.cumsum(rng.exponential(1.0 / rate, n))).astype(int)
    return arr - arr[0]


def bursty_arrivals(n_bursts: int, burst_size: int, gap: int) -> np.ndarray:
    """[n_bursts * burst_size] step indices: every ``gap`` steps, a burst of
    ``burst_size`` simultaneous arrivals — the backlog-forming antithesis of
    the Poisson stream."""
    return np.repeat(np.arange(n_bursts) * gap, burst_size)


def zipf_prompt_lengths(n: int, lo: int, hi: int, a: float = 1.3,
                        seed: int = 0) -> np.ndarray:
    """[n] prompt lengths in [lo, hi], Zipf-skewed toward ``lo`` (most
    requests short, a heavy tail of long ones — the serving-trace shape)."""
    rng = np.random.RandomState(seed)
    raw = rng.zipf(a, n)
    return np.clip(lo + (raw - 1), lo, hi).astype(int)
