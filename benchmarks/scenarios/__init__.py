"""Scenario-based serving harness (PR 9): drives the continuous-batching
engine and the EPLB serving loop through realistic traffic shapes — Poisson
and bursty arrivals, Zipf routing skew that DRIFTS over time, context-length
sweeps toward the page-pool cliff, and concurrency ramps — with telemetry on
(runtime/telemetry.py), emitting machine-readable rows plus Chrome-trace /
JSONL time-series artifacts into the BENCH schema-v7 ``scenarios`` section.

Run via ``PYTHONPATH=src python -m benchmarks.run --only scenarios`` (or
``python -m benchmarks.scenarios`` directly). Acceptance bars live INSIDE
each scenario (e.g. drifting skew: the post-rebalance imbalance ratio must
drop; cliff sweep: pool exhaustion raises loudly before any corruption), so
the CI smoke leg trips on regression.

This package's ``__init__`` stays jax-free: the entrypoint must call
``ensure_devices`` BEFORE anything imports jax.
"""
