"""Benchmark harness entrypoint: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only name]

  memory    — Eq. 3 buffer-footprint reduction (deepep vs nccl_ep layouts)
  ll        — Figs 7-8 LL dispatch/combine vs rank count
  modes     — Table III LL/HT/baseline crossover by batch size
  serving   — Table VII end-to-end serving metrics by EP backend

Each sub-benchmark needs its own fake-device count, so they run as separate
processes; results land in results/benchmarks/*.json.
"""
import argparse
import subprocess
import sys

BENCHES = ["memory", "ll", "modes", "serving"]
MODULES = {
    "memory": "benchmarks.bench_memory",
    "ll": "benchmarks.bench_ll_kernels",
    "modes": "benchmarks.bench_modes",
    "serving": "benchmarks.bench_serving",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=BENCHES)
    args = ap.parse_args()
    failed = []
    for name in ([args.only] if args.only else BENCHES):
        print(f"\n########## benchmark: {name} ##########", flush=True)
        r = subprocess.run([sys.executable, "-m", MODULES[name]])
        if r.returncode != 0:
            failed.append(name)
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        sys.exit(1)
    print("\nAll benchmarks complete. Results in results/benchmarks/.")


if __name__ == "__main__":
    main()
