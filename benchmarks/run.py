"""Benchmark harness entrypoint: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only name]

  memory    — Eq. 3 buffer-footprint reduction (deepep vs nccl_ep layouts)
  ll        — Figs 7-8 LL dispatch/combine vs rank count (per-phase timings
              + recv-unpack two-pass-vs-fused microbenchmark)
  slotmap   — one-hot vs sort-based positions_by_dest microbenchmark
  decode    — decode-pipeline steady state: naive vs double-buffered +
              handle refresh vs create (routing-hash fast path)
  modes     — Table III LL/HT/baseline crossover by batch size
  placement — EPLB imbalance sweep: skewed routing, contiguous vs
              rebalanced vs redundant expert placement (per-rank recv load)
  serving   — Table VII end-to-end serving metrics by EP backend, plus
              continuous batching vs fixed batch under Poisson arrivals
              (TTFT/ITL percentiles, paged-KV page accounting)
  fault     — elastic recovery under injected rank kill/rejoin:
              steps-to-detect, shrink/expand latency, degraded throughput
  scenarios — scenario harness (telemetry on): Poisson/bursty arrivals,
              drifting Zipf skew vs the EPLB rebalancer, context-length
              sweep to the page-pool cliff, concurrency ramp — acceptance
              asserted in-bench, Chrome-trace/JSONL artifacts emitted

Each sub-benchmark needs its own fake-device count, so they run as separate
processes; results land in results/benchmarks/*.json. After the ll and
slotmap benchmarks run, their results are folded into ``BENCH_ll_kernels.json``
at the repo root — the machine-readable perf trajectory (schema
bench_ll_kernels/v7: handle-create / dispatch / combine phase times,
recv-unpack kernel timings, slot-map engine comparison, the decode-pipeline
steady-state rows, the modes section — LL/HT/baseline crossover plus the
prefill-pipeline steady-state rows: chunked vs monolithic hierarchical HT
and hier vs flat through the staged driver — the placement section:
the EPLB skewed-routing sweep, contiguous vs rebalanced vs redundant —
the fault section: elastic kill/rejoin recovery rows, validated in-bench —
the serving section's ``continuous`` rows (v6): continuous batching vs
gang-scheduled fixed batching under Poisson arrivals with per-request
TTFT/ITL p50/p95/p99 — and, new in v7, the ``scenarios`` section: the
scenario-harness rows with their in-bench acceptance bars and pointers to
the emitted trace/time-series artifacts) tracked across PRs.
"""
import argparse
import json
import pathlib
import subprocess
import sys

BENCHES = ["memory", "ll", "slotmap", "decode", "modes", "placement",
           "serving", "fault", "scenarios"]
MODULES = {
    "memory": "benchmarks.bench_memory",
    "ll": "benchmarks.bench_ll_kernels",
    "slotmap": "benchmarks.bench_slotmap",
    "decode": "benchmarks.bench_decode_pipeline",
    "modes": "benchmarks.bench_modes",
    "placement": "benchmarks.bench_imbalance",
    "serving": "benchmarks.bench_serving",
    "fault": "benchmarks.bench_fault",
    "scenarios": "benchmarks.scenarios",
}

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "benchmarks"


def emit_bench_ll_kernels() -> bool:
    """Fold ll (per-phase + recv-unpack), slotmap, decode-pipeline, and
    modes (crossover + prefill pipeline) results into BENCH_ll_kernels.json
    at the repo root, if the ll and slotmap source files exist (decode and
    modes are folded when present). Each source's mtime is recorded so
    mixed-provenance results (e.g. `--only ll` next to a week-old slotmap
    run) are visible in the emitted file. Returns True when written."""
    import datetime

    src_ll = RESULTS / "ll_kernels.json"
    src_sm = RESULTS / "slotmap.json"
    src_dp = RESULTS / "decode_pipeline.json"
    src_md = RESULTS / "modes_crossover.json"
    src_pl = RESULTS / "imbalance.json"
    src_sv = RESULTS / "serving.json"
    src_ft = RESULTS / "fault.json"
    src_sc = RESULTS / "scenarios.json"
    if not (src_ll.exists() and src_sm.exists()):
        return False
    ll = json.loads(src_ll.read_text())
    sm = json.loads(src_sm.read_text())
    dp = json.loads(src_dp.read_text()) if src_dp.exists() else None
    md = json.loads(src_md.read_text()) if src_md.exists() else None
    pl = json.loads(src_pl.read_text()) if src_pl.exists() else None
    sv = json.loads(src_sv.read_text()) if src_sv.exists() else None
    ft = json.loads(src_ft.read_text()) if src_ft.exists() else None
    sc = json.loads(src_sc.read_text()) if src_sc.exists() else None

    def stamp(p):
        return datetime.datetime.fromtimestamp(p.stat().st_mtime).isoformat(
            timespec="seconds")

    sources = {"ll_kernels": stamp(src_ll), "slotmap": stamp(src_sm)}
    if dp is not None:
        sources["decode_pipeline"] = stamp(src_dp)
    if md is not None:
        sources["modes"] = stamp(src_md)
    if pl is not None:
        sources["placement"] = stamp(src_pl)
    if sv is not None:
        sources["serving"] = stamp(src_sv)
    if ft is not None:
        sources["fault"] = stamp(src_ft)
    if sc is not None:
        sources["scenarios"] = stamp(src_sc)
    payload = {
        "schema": "bench_ll_kernels/v7",
        "sources": sources,
        "config": ll.get("config", {}),
        "phases": ll.get("rows", []),       # handle/dispatch/combine per layout
        "recv_unpack": ll.get("recv_unpack", []),  # two-pass vs fused unpack
        "slotmap": {"config": sm.get("config", {}), "rows": sm.get("rows", [])},
    }
    if dp is not None:
        # steady-state decode: naive vs pipelined + handle create vs refresh
        payload["decode_pipeline"] = dp
    if md is not None:
        # mode crossover + prefill pipeline steady state (chunked-vs-
        # monolithic hierarchical HT, hier vs flat, staged driver)
        payload["modes"] = md
    if pl is not None:
        # EPLB imbalance sweep: per-rank recv load, contiguous vs
        # rebalanced vs redundant placement under skewed routing (plus the
        # adoption rows: per-step in-graph expansion vs adopt-once)
        payload["placement"] = pl
    if sv is not None:
        # Table VII serving metrics, incl. the placed-serving steady-state
        # rows (per-step expansion vs MoESpec.params_physical adopt-once)
        # and, v6, the continuous-batching vs fixed-batch percentile rows
        payload["serving"] = sv
    if ft is not None:
        # v5: elastic recovery under injected kill/rejoin — steps-to-detect,
        # shrink/expand latency, degraded-mode throughput (token parity and
        # the zero-slot degraded placement are ASSERTED inside the bench)
        payload["fault"] = ft
    if sc is not None:
        # v7: scenario-harness rows — Poisson/bursty/drifting-skew/cliff/
        # ramp through the real engines with telemetry on; the acceptance
        # bars (imbalance drop after rebalance, loud cliff rejection,
        # bitwise ramp parity) are ASSERTED inside the bench
        payload["scenarios"] = sc
    (ROOT / "BENCH_ll_kernels.json").write_text(json.dumps(payload, indent=1))
    print(f"wrote {ROOT / 'BENCH_ll_kernels.json'}")
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=BENCHES)
    args = ap.parse_args()
    failed = []
    for name in ([args.only] if args.only else BENCHES):
        print(f"\n########## benchmark: {name} ##########", flush=True)
        r = subprocess.run([sys.executable, "-m", MODULES[name]])
        if r.returncode != 0:
            failed.append(name)
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        sys.exit(1)
    emit_bench_ll_kernels()
    print("\nAll benchmarks complete. Results in results/benchmarks/.")


if __name__ == "__main__":
    main()
