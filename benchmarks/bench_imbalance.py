"""EPLB imbalance sweep: skewed-routing load vs expert placement.

Synthetic hot-expert workloads (Zipf-like skew over a contiguous hot
neighborhood — the worst case for the default striping, which parks every
hot expert on the same rank) are pushed through the real EP path under three
placements:

  contiguous — the default ``e // L`` striping (placement=None)
  rebalanced — heat-driven greedy permutation, no extra slots (R=0)
  redundant  — heat-driven permutation + R redundant replica slots

For each we report the measured per-rank received-token counts (max, mean,
max/mean ratio — from the handles' real ``recv_counts``, not the analytic
expectation) and the host wall time of one dispatch->scale->combine cycle.
The acceptance bar: rebalanced/redundant max-per-rank recv strictly below
contiguous on the skewed rows. Results feed the ``placement`` section of
BENCH_ll_kernels.json (schema v4) via benchmarks/run.py.
"""
from benchmarks.common import ensure_devices, interleaved_best, write_result, table

ensure_devices(8)

import dataclasses              # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,  # noqa: E402
                        ep_dispatch, ep_combine)
from repro.core import placement as PL        # noqa: E402
from repro.core import plan as plan_mod       # noqa: E402

N, E, K, H = 8, 64, 4, 256
T = 256                          # tokens per rank
R = 16                           # redundant slots for the "redundant" variant


def skewed_routing(rng, skew: float):
    """[N, T, K] top-k draws from a Zipf-ish distribution concentrated on
    the low expert ids (= rank 0's contiguous block): p(e) ∝ (1+e)^-skew.
    skew=0 is uniform."""
    p = (1.0 + np.arange(E)) ** -skew
    p /= p.sum()
    topk = np.stack([
        np.stack([rng.choice(E, K, replace=False, p=p) for _ in range(T)])
        for _ in range(N)])
    return jnp.asarray(topk, jnp.int32)


def make_cycle(placement):
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ht", payload_dtype=jnp.bfloat16,
                        placement=placement)
    group = ep_create_group(cfg, ep_size=N)
    L = group.local_experts
    se = (jnp.arange(E, dtype=jnp.int32).reshape(N, L) if placement is None
          else jnp.asarray(PL.tables(placement).slot_expert))
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ep_create_handle(group, topk, w)
        y3d, counts = ep_dispatch(group, h, x)
        me = plan_mod.my_rank(group)
        y3d = y3d * (1.0 + se[me])[:, None, None].astype(y3d.dtype)
        return ep_combine(group, h, y3d)[None], counts[None]

    return jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                                 out_specs=(P("data"), P("data")))), group


def main():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(N, T, H), jnp.bfloat16)
    rows = []
    for skew in (0.0, 0.8, 1.5):
        topk = skewed_routing(rng, skew)
        w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
        # heat measured under the contiguous layout drives the rebalancer —
        # the production loop (observe, then re-place)
        fn_c, _ = make_cycle(None)
        _, counts_c = fn_c(x, topk, w)
        heat = PL.fold_slot_counts(None, np.asarray(counts_c))
        variants = {
            "contiguous": None,
            "rebalanced": PL.rebalance(heat, N, version=1),
            "redundant": PL.rebalance(heat, N, num_redundant=R, version=1),
        }
        fns, groups = zip(*(make_cycle(pl) for pl in variants.values()))
        times = interleaved_best(list(fns), [(x, topk, w)] * len(fns), iters=4)
        for (name, pl), fn, t in zip(variants.items(), fns, times):
            _, counts = fn(x, topk, w)
            per_rank = np.asarray(counts).sum(axis=1)
            rows.append(dict(
                skew=skew, placement=name,
                redundant=0 if pl is None else pl.num_redundant,
                max_rank_tokens=int(per_rank.max()),
                mean_rank_tokens=round(float(per_rank.mean()), 1),
                max_mean_ratio=round(float(per_rank.max() / per_rank.mean()), 3),
                roundtrip_ms=round(t * 1e3, 2)))
    table(rows, ["skew", "placement", "redundant", "max_rank_tokens",
                 "mean_rank_tokens", "max_mean_ratio", "roundtrip_ms"],
          "EPLB imbalance sweep: per-rank recv tokens by placement "
          f"({N} ranks, E={E}, K={K}, T={T}/rank)")
    # the acceptance bar, enforced here so CI's smoke leg trips on regression
    for skew in (0.8, 1.5):
        by = {r["placement"]: r for r in rows if r["skew"] == skew}
        assert by["rebalanced"]["max_rank_tokens"] <= by["contiguous"]["max_rank_tokens"], by
        assert by["redundant"]["max_rank_tokens"] < by["contiguous"]["max_rank_tokens"], by
    write_result("imbalance", dict(
        config=dict(N=N, E=E, K=K, H=H, T=T, redundant=R), rows=rows))
    return rows


if __name__ == "__main__":
    main()
