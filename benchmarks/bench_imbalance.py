"""EPLB imbalance sweep: skewed-routing load vs expert placement.

Synthetic hot-expert workloads (Zipf-like skew over a contiguous hot
neighborhood — the worst case for the default striping, which parks every
hot expert on the same rank) are pushed through the real EP path under three
placements:

  contiguous — the default ``e // L`` striping (placement=None)
  rebalanced — heat-driven greedy permutation, no extra slots (R=0)
  redundant  — heat-driven permutation + R redundant replica slots

For each we report the measured per-rank received-token counts (max, mean,
max/mean ratio — from the handles' real ``recv_counts``, not the analytic
expectation) and the host wall time of one dispatch->scale->combine cycle.
The acceptance bar: rebalanced/redundant max-per-rank recv strictly below
contiguous on the skewed rows. Results feed the ``placement`` section of
BENCH_ll_kernels.json (schema v4) via benchmarks/run.py.

Adoption table (PR 5): the same placed cycle with per-expert weight
matrices, run two ways — logical weights expanded to physical slot order
IN-GRAPH every step (the training-compatible mode) vs adopt-once physical
weights bound before the step (``MoESpec.params_physical``). The delta —
the per-step reassembly (all-gather + slot gather) adopt-once eliminates —
is a real-pod quantity; on this CPU host the fake-device all-gather is a
shared-memory memcpy and the variants sit within host noise, so the rows
RECORD the trajectory but nothing asserts on wall clock (the
bitwise-parity tests are the functional guard that the expansion is
really skipped).
"""
from benchmarks.common import ensure_devices, interleaved_best, write_result, table

ensure_devices(8)

import dataclasses              # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,  # noqa: E402
                        ep_dispatch, ep_combine)
from repro.core import placement as PL        # noqa: E402
from repro.core import plan as plan_mod       # noqa: E402

N, E, K, H = 8, 64, 4, 256
T = 256                          # tokens per rank
R = 16                           # redundant slots for the "redundant" variant


def skewed_routing(rng, skew: float):
    """[N, T, K] top-k draws from a Zipf-ish distribution concentrated on
    the low expert ids (= rank 0's contiguous block): p(e) ∝ (1+e)^-skew.
    skew=0 is uniform."""
    p = (1.0 + np.arange(E)) ** -skew
    p /= p.sum()
    topk = np.stack([
        np.stack([rng.choice(E, K, replace=False, p=p) for _ in range(T)])
        for _ in range(N)])
    return jnp.asarray(topk, jnp.int32)


def make_cycle(placement):
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ht", payload_dtype=jnp.bfloat16,
                        placement=placement)
    group = ep_create_group(cfg, ep_size=N)
    L = group.local_experts
    se = (jnp.arange(E, dtype=jnp.int32).reshape(N, L) if placement is None
          else jnp.asarray(PL.tables(placement).slot_expert))
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def step(x, topk, w):
        x, topk, w = x[0], topk[0], w[0]
        h = ep_create_handle(group, topk, w)
        y3d, counts = ep_dispatch(group, h, x)
        me = plan_mod.my_rank(group)
        y3d = y3d * (1.0 + se[me])[:, None, None].astype(y3d.dtype)
        return ep_combine(group, h, y3d)[None], counts[None]

    return jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                                 out_specs=(P("data"), P("data")))), group


F = 32                           # per-expert weight columns (adoption table)


def make_weighted_cycle(placement, physical: bool):
    """dispatch -> per-expert GEMM -> combine with REAL expert weights.

    Weights enter EP-SHARDED over the leading axis, the way a model stores
    them. ``physical`` (adopt-once): each rank holds exactly its slots'
    rows ([L, H, F]) and uses them directly — zero weight movement per
    step. Logical mode mirrors ``models/moe.py``'s per-step expansion: the
    rank holds a logical shard and must assemble its PHYSICAL slot rows
    every step (all-gather + gather — the cross-rank weight traffic a
    placement's moved experts cost, which adopt-once eliminates)."""
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ht", payload_dtype=jnp.bfloat16,
                        placement=placement)
    group = ep_create_group(cfg, ep_size=N)
    L = group.local_experts
    se = (None if placement is None
          else jnp.asarray(PL.tables(placement).slot_expert))
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def step(x, topk, w, wshard):
        x, topk, w = x[0], topk[0], w[0]
        me = plan_mod.my_rank(group)
        if physical:
            rows = wshard                      # my slots' weights, resident
        else:
            # per-step expansion: reassemble my physical rows from the
            # logically-sharded weights (all-gather + slot gather)
            w_full = jax.lax.all_gather(wshard, "data", axis=0, tiled=True)
            rows = w_full[se[me]]
        h = ep_create_handle(group, topk, w)
        y3d, counts = ep_dispatch(group, h, x)
        y3d = jnp.einsum("lah,lhf->laf", y3d.astype(jnp.float32),
                         rows.astype(jnp.float32))
        y3d = jnp.concatenate([y3d] * (H // F), axis=-1).astype(x.dtype)
        return ep_combine(group, h, y3d)[None]

    fn = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"),) * 4,
                               out_specs=P("data")))
    return fn, group


def bench_adoption(rng, rows):
    """Steady-state per-step host time: placed cycle with per-step in-graph
    expansion vs adopt-once physical weights vs no placement at all."""
    skew = 1.5
    topk = skewed_routing(rng, skew)
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
    x = jnp.asarray(rng.randn(N, T, H), jnp.bfloat16)
    w_log = jnp.asarray(rng.randn(E, H, F) / np.sqrt(H), jnp.bfloat16)
    fn_c, _ = make_cycle(None)
    _, counts_c = fn_c(x, topk, w)
    heat = PL.fold_slot_counts(None, np.asarray(counts_c))
    pl = PL.rebalance(heat, N, num_redundant=R, version=1)
    w_phys = PL.expand_expert_params(w_log, pl)     # adopt-once, outside jit
    variants = [
        ("none", None, True, w_log),                # contiguous: logical==physical
        ("per-step expand", pl, False, w_log),
        ("adopt-once", pl, True, w_phys),
    ]
    fns = [make_weighted_cycle(p, phys)[0] for _, p, phys, _ in variants]
    args = [(x, topk, w, wv) for _, _, _, wv in variants]
    # more rounds than the sweep rows: this table compares timings a few
    # percent apart, so the min needs more draws to stabilize on a
    # cpu-share-throttled host
    times = interleaved_best(fns, args, iters=10)
    out = {}
    for (name, p, _, _), t in zip(variants, times):
        out[name] = t
        rows.append(dict(
            skew=skew, placement="adoption/" + name,
            redundant=0 if p is None else p.num_redundant,
            max_rank_tokens=None, mean_rank_tokens=None, max_mean_ratio=None,
            roundtrip_ms=round(t * 1e3, 2)))
    # No wall-clock assert here, deliberately: the gather being measured is
    # a few percent of the cycle and host-timer swings on a shared CPU
    # runner exceed that by an order of magnitude (observed ±35% on the
    # BASELINE between runs) — any margin wide enough not to flake the CI
    # smoke leg catches nothing. The ratio is recorded in the rows/BENCH
    # trajectory instead; the functional guard that adopt-once really
    # skips the expansion is the bitwise-parity test suite.
    print(f"  adoption steady-state ratio (adopt-once / per-step expand): "
          f"{out['adopt-once'] / out['per-step expand']:.3f}")
    return out


def main():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(N, T, H), jnp.bfloat16)
    rows = []
    for skew in (0.0, 0.8, 1.5):
        topk = skewed_routing(rng, skew)
        w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)
        # heat measured under the contiguous layout drives the rebalancer —
        # the production loop (observe, then re-place)
        fn_c, _ = make_cycle(None)
        _, counts_c = fn_c(x, topk, w)
        heat = PL.fold_slot_counts(None, np.asarray(counts_c))
        variants = {
            "contiguous": None,
            "rebalanced": PL.rebalance(heat, N, version=1),
            "redundant": PL.rebalance(heat, N, num_redundant=R, version=1),
        }
        fns, groups = zip(*(make_cycle(pl) for pl in variants.values()))
        times = interleaved_best(list(fns), [(x, topk, w)] * len(fns), iters=4)
        for (name, pl), fn, t in zip(variants.items(), fns, times):
            _, counts = fn(x, topk, w)
            per_rank = np.asarray(counts).sum(axis=1)
            rows.append(dict(
                skew=skew, placement=name,
                redundant=0 if pl is None else pl.num_redundant,
                max_rank_tokens=int(per_rank.max()),
                mean_rank_tokens=round(float(per_rank.mean()), 1),
                max_mean_ratio=round(float(per_rank.max() / per_rank.mean()), 3),
                roundtrip_ms=round(t * 1e3, 2)))
    adoption = bench_adoption(rng, rows)
    table(rows, ["skew", "placement", "redundant", "max_rank_tokens",
                 "mean_rank_tokens", "max_mean_ratio", "roundtrip_ms"],
          "EPLB imbalance sweep: per-rank recv tokens by placement "
          f"({N} ranks, E={E}, K={K}, T={T}/rank; adoption rows: "
          f"weighted cycle, W[E,{H},{F}])")
    # the acceptance bar, enforced here so CI's smoke leg trips on regression
    for skew in (0.8, 1.5):
        by = {r["placement"]: r for r in rows if r["skew"] == skew}
        assert by["rebalanced"]["max_rank_tokens"] <= by["contiguous"]["max_rank_tokens"], by
        assert by["redundant"]["max_rank_tokens"] < by["contiguous"]["max_rank_tokens"], by
    write_result("imbalance", dict(
        config=dict(N=N, E=E, K=K, H=H, T=T, redundant=R, adoption_F=F),
        rows=rows,
        adoption={k: round(v * 1e3, 3) for k, v in adoption.items()}))
    return rows


if __name__ == "__main__":
    main()
