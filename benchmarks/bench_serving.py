"""Paper Table VII: end-to-end serving metrics, EP backend vs the AllToAll
baseline (our analogue of NCCL EP vs DeepEP inside vLLM). A reduced MoE model
decodes batched requests through the full serve loop; we report output tok/s,
TTFT, ITL mean/p99, TPOT — the exact metric set of Table VII — plus the EPLB
load counters every run now tracks (per-rank max/mean heat ratio), so load
imbalance is reported alongside latency.

Placed-serving rows (PR 5): the LL backend additionally runs with a
PERMUTED EPLB placement (rebalanced, zero redundant slots — slot count
preserved, so the rows isolate the weight-layout cost rather than the
redundant-capacity cost) two ways: per-step in-graph weight expansion
(training-compatible logical mode) vs ``MoESpec.params_physical`` adopt-once
physical weights. The tracked signal is the adopt-once steady-state
per-step time (ITL mean) relative to the ``placement=None`` row — with the
per-step gather eliminated it should sit within noise of it; the ratio is
printed and recorded, but nothing asserts on wall clock (host noise on
shared runners exceeds the delta — see bench_imbalance; the bitwise-parity
tests are the functional guard). Results feed the ``serving`` section of
BENCH_ll_kernels.json via benchmarks/run.py."""
from benchmarks.common import ensure_devices, write_result, table

ensure_devices(8)

import dataclasses             # noqa: E402

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.configs import get_smoke              # noqa: E402
from repro.core import placement as PL           # noqa: E402
from repro.runtime.server import DecodeServer    # noqa: E402


def bench_backend(mode: str, ll_layout: str = "nccl_ep",
                  pipeline_depth: int = 1, placed: bool = False,
                  params_physical: bool = False):
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode=mode, ll_layout=ll_layout,
                              ep_axis=("data",), track_expert_heat=True)
    if placed:
        # a static PERMUTED placement (the serving steady state between
        # rebalance boundaries): slot count preserved, so the only delta vs
        # placement=None is the weight layout — which is where adopt-once
        # pays off. Redundant-slot capacity effects are measured separately
        # (bench_imbalance) so they don't confound this comparison.
        pl = PL.rebalance(np.arange(moe.num_experts, dtype=float) + 1.0, 8)
        moe = dataclasses.replace(moe, placement=pl,
                                  params_physical=params_physical)
    cfg = dataclasses.replace(cfg, moe=moe)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    srv = DecodeServer(cfg, batch=16, max_len=64, mesh=mesh,
                       pipeline_depth=pipeline_depth)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (16, 8)), jnp.int32)
    m = srv.serve(prompts, gen_steps=24)
    return m


def main():
    rows = []
    for name, kw in [
            ("nccl_ep (LL)", dict(mode="ll")),
            ("nccl_ep (LL, pipelined x2)", dict(mode="ll", pipeline_depth=2)),
            ("nccl_ep (LL, placed per-step)",
             dict(mode="ll", placed=True, params_physical=False)),
            ("nccl_ep (LL, placed adopt-once)",
             dict(mode="ll", placed=True, params_physical=True)),
            ("deepep-layout (LL)", dict(mode="ll", ll_layout="deepep")),
            ("alltoall baseline", dict(mode="baseline"))]:
        m = bench_backend(**kw)
        rows.append(dict(backend=name,
                         output_tok_s=round(m.output_tok_s, 1),
                         ttft_ms=round(m.ttft_s * 1e3, 1),
                         itl_mean_ms=round(m.itl_mean_s * 1e3, 2),
                         itl_p99_ms=round(m.itl_p99_s * 1e3, 2),
                         tpot_ms=round(m.itl_mean_s * 1e3, 2),
                         rank_load_imb=(None if m.rank_heat_max_mean is None
                                        else round(m.rank_heat_max_mean, 3))))
    table(rows, ["backend", "output_tok_s", "ttft_ms", "itl_mean_ms",
                 "itl_p99_ms", "tpot_ms", "rank_load_imb"],
          "Table VII analogue: serving metrics by EP backend (16 reqs, 8 ranks)")
    by = {r["backend"]: r for r in rows}
    ratio = (by["nccl_ep (LL, placed adopt-once)"]["itl_mean_ms"]
             / by["nccl_ep (LL)"]["itl_mean_ms"])
    print(f"  placed adopt-once ITL / placement=None ITL: {ratio:.3f} "
          "(tracked, not asserted — host noise exceeds the layout delta)")
    write_result("serving", dict(
        config=dict(placed_rows="rebalanced permutation, R=0"),
        adopt_once_itl_ratio=round(ratio, 3), rows=rows))
    return rows


if __name__ == "__main__":
    main()
