"""Paper Table VII: end-to-end serving metrics, EP backend vs the AllToAll
baseline (our analogue of NCCL EP vs DeepEP inside vLLM). A reduced MoE model
decodes batched requests through the full serve loop; we report output tok/s,
TTFT, ITL mean/p99, TPOT — the exact metric set of Table VII — plus the EPLB
load counters every run now tracks (per-rank max/mean heat ratio), so load
imbalance is reported alongside latency.

Placed-serving rows (PR 5): the LL backend additionally runs with a
PERMUTED EPLB placement (rebalanced, zero redundant slots — slot count
preserved, so the rows isolate the weight-layout cost rather than the
redundant-capacity cost) two ways: per-step in-graph weight expansion
(training-compatible logical mode) vs ``MoESpec.params_physical`` adopt-once
physical weights. The tracked signal is the adopt-once steady-state
per-step time (ITL mean) relative to the ``placement=None`` row — with the
per-step gather eliminated it should sit within noise of it; the ratio is
printed and recorded, but nothing asserts on wall clock (host noise on
shared runners exceeds the delta — see bench_imbalance; the bitwise-parity
tests are the functional guard). Results feed the ``serving`` section of
BENCH_ll_kernels.json via benchmarks/run.py.

Continuous-batching rows (PR 8, schema v6): the same LL backend serves a
POISSON arrival stream two ways — the paged continuous-batching engine
(requests join/leave at step boundaries, paged KV pool) vs gang-scheduled
fixed batching (every request waits for the LAST arrival, then one fixed
batch with padded prompts). Per-request TTFT/ITL p50/p95/p99 are reported
for both; the fixed engine's queueing delay is modeled in STEPS (arrival
gap x its own measured mean ITL), so the comparison is host-noise-free in
structure. The paged-vs-dense page accounting (peak pages <= dense B x
S_max equivalent) is asserted in-bench; latency ratios are tracked, not
asserted."""
from benchmarks.common import ensure_devices, pct_ms, table, write_result

ensure_devices(8)

import dataclasses             # noqa: E402

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.analysis import (RetraceAuditor,      # noqa: E402
                            guard_serve_steps)
from repro.configs import get_smoke              # noqa: E402
from repro.core import placement as PL           # noqa: E402
from repro.runtime.scheduler import Request      # noqa: E402
from repro.runtime.server import (ContinuousDecodeServer,  # noqa: E402
                                  DecodeServer)


def bench_backend(mode: str, ll_layout: str = "nccl_ep",
                  pipeline_depth: int = 1, placed: bool = False,
                  params_physical: bool = False):
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode=mode, ll_layout=ll_layout,
                              ep_axis=("data",), track_expert_heat=True)
    if placed:
        # a static PERMUTED placement (the serving steady state between
        # rebalance boundaries): slot count preserved, so the only delta vs
        # placement=None is the weight layout — which is where adopt-once
        # pays off. Redundant-slot capacity effects are measured separately
        # (bench_imbalance) so they don't confound this comparison.
        pl = PL.rebalance(np.arange(moe.num_experts, dtype=float) + 1.0, 8)
        moe = dataclasses.replace(moe, placement=pl,
                                  params_physical=params_physical)
    cfg = dataclasses.replace(cfg, moe=moe)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    srv = DecodeServer(cfg, batch=16, max_len=64, mesh=mesh,
                       pipeline_depth=pipeline_depth)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (16, 8)), jnp.int32)
    m = srv.serve(prompts, gen_steps=24)
    return m


def _ll_cfg():
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True)
    return dataclasses.replace(cfg, moe=moe)


def bench_continuous(n_req=16, rate=0.4, max_new=16, seed=0):
    """Poisson arrivals served by the paged continuous-batching engine vs
    gang-scheduled fixed batching. Returns (rows, accounting dict)."""
    rng = np.random.RandomState(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate, n_req))
                        ).astype(int)
    arrivals -= arrivals[0]                     # first request at step 0
    plens = rng.randint(3, 9, n_req)
    prompts = [rng.randint(0, 256, L).astype(np.int32) for L in plens]
    cfg = _ll_cfg()
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def reqs():
        return [Request(i, prompts[i], max_new, arrival_step=int(arrivals[i]))
                for i in range(n_req)]

    srv = ContinuousDecodeServer(cfg, batch=8, max_len=64, mesh=mesh,
                                 page_size=8)
    # audited run (docs/DESIGN.md §12): no placement changes here, so the
    # jit-stability claim is exact — request join/leave across the whole
    # stream must cause ZERO retraces/recompiles — and every step runs
    # under the d2h transfer guard (arms on accelerators)
    aud = RetraceAuditor(srv)
    with guard_serve_steps(srv):
        m = srv.serve_requests(reqs())
    srv.close()
    aud.assert_retrace_economy()
    assert m.requests_completed == n_req, m
    assert m.pages_peak <= m.pages_dense_equiv, m     # the paged-KV claim

    # fixed-batch baseline: gang scheduling — every request waits for the
    # last arrival, then one fixed batch of right-padded prompts decodes in
    # lockstep. Queueing delay is modeled in steps x the engine's OWN mean
    # ITL (host-noise-free structure; same convention as the paper's
    # fixed-batch serving baselines).
    srv2 = DecodeServer(cfg, batch=n_req, max_len=64, mesh=mesh)
    pad = np.zeros((n_req, int(plens.max())), np.int32)
    for i, p in enumerate(prompts):
        pad[i, :p.size] = p
    first, ttft_fix = srv2.prefill(jnp.asarray(pad))
    _, itls_fix = srv2.decode(first, max_new - 1)
    srv2.close()
    step_s = float(np.mean(itls_fix))
    wait_steps = arrivals.max() - arrivals
    ttfts_fix = wait_steps * step_s + ttft_fix

    ttfts_cont = [r["ttft_s"] for r in m.per_request]
    itls_cont = np.concatenate(
        [r["itl_s"] for r in m.per_request if r["itl_s"]])
    rows = [
        dict(engine="continuous (paged KV)",
             ttft_p50_ms=pct_ms(ttfts_cont, 50), ttft_p95_ms=pct_ms(ttfts_cont, 95),
             ttft_p99_ms=pct_ms(ttfts_cont, 99), itl_p50_ms=pct_ms(itls_cont, 50),
             itl_p95_ms=pct_ms(itls_cont, 95), itl_p99_ms=pct_ms(itls_cont, 99),
             output_tok_s=round(m.output_tok_s, 1), steps=m.serve_steps),
        dict(engine="fixed batch (dense KV)",
             ttft_p50_ms=pct_ms(ttfts_fix, 50), ttft_p95_ms=pct_ms(ttfts_fix, 95),
             ttft_p99_ms=pct_ms(ttfts_fix, 99), itl_p50_ms=pct_ms(itls_fix, 50),
             itl_p95_ms=pct_ms(itls_fix, 95), itl_p99_ms=pct_ms(itls_fix, 99),
             output_tok_s=round(n_req * max_new
                                / (ttft_fix + float(np.sum(itls_fix))), 1),
             steps=max_new),
    ]
    acct = dict(n_req=n_req, poisson_rate_per_step=rate, max_new=max_new,
                max_concurrency=8, page_size=8,
                pages_peak=m.pages_peak, pages_dense_equiv=m.pages_dense_equiv,
                pages_ratio=round(m.pages_peak / m.pages_dense_equiv, 3),
                retraces=aud.traces, step_cache_peak=aud.max_cache_seen)
    return rows, acct


def main():
    rows = []
    for name, kw in [
            ("nccl_ep (LL)", dict(mode="ll")),
            ("nccl_ep (LL, pipelined x2)", dict(mode="ll", pipeline_depth=2)),
            ("nccl_ep (LL, placed per-step)",
             dict(mode="ll", placed=True, params_physical=False)),
            ("nccl_ep (LL, placed adopt-once)",
             dict(mode="ll", placed=True, params_physical=True)),
            ("deepep-layout (LL)", dict(mode="ll", ll_layout="deepep")),
            ("alltoall baseline", dict(mode="baseline"))]:
        m = bench_backend(**kw)
        rows.append(dict(backend=name,
                         output_tok_s=round(m.output_tok_s, 1),
                         ttft_ms=round(m.ttft_s * 1e3, 1),
                         itl_mean_ms=round(m.itl_mean_s * 1e3, 2),
                         itl_p99_ms=round(m.itl_p99_s * 1e3, 2),
                         tpot_ms=round(m.itl_mean_s * 1e3, 2),
                         rank_load_imb=(None if m.rank_heat_max_mean is None
                                        else round(m.rank_heat_max_mean, 3))))
    table(rows, ["backend", "output_tok_s", "ttft_ms", "itl_mean_ms",
                 "itl_p99_ms", "tpot_ms", "rank_load_imb"],
          "Table VII analogue: serving metrics by EP backend (16 reqs, 8 ranks)")
    by = {r["backend"]: r for r in rows}
    ratio = (by["nccl_ep (LL, placed adopt-once)"]["itl_mean_ms"]
             / by["nccl_ep (LL)"]["itl_mean_ms"])
    print(f"  placed adopt-once ITL / placement=None ITL: {ratio:.3f} "
          "(tracked, not asserted — host noise exceeds the layout delta)")
    crows, acct = bench_continuous()
    table(crows, ["engine", "ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms",
                  "itl_p50_ms", "itl_p95_ms", "itl_p99_ms", "output_tok_s",
                  "steps"],
          "Continuous batching vs fixed batch (Poisson arrivals, 16 reqs, "
          "8 slots)")
    print(f"  paged pages peak {acct['pages_peak']} vs dense-equivalent "
          f"{acct['pages_dense_equiv']} "
          f"(ratio {acct['pages_ratio']}, asserted paged <= dense)")
    cr = (crows[0]["ttft_p50_ms"] / crows[1]["ttft_p50_ms"]
          if crows[1]["ttft_p50_ms"] else None)
    if cr is not None:
        print(f"  continuous TTFT p50 / fixed TTFT p50: {cr:.3f} "
              "(tracked, not asserted)")
    write_result("serving", dict(
        config=dict(placed_rows="rebalanced permutation, R=0"),
        adopt_once_itl_ratio=round(ratio, 3), rows=rows,
        continuous=dict(config=acct, rows=crows)))
    return rows


if __name__ == "__main__":
    main()
