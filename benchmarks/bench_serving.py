"""Paper Table VII: end-to-end serving metrics, EP backend vs the AllToAll
baseline (our analogue of NCCL EP vs DeepEP inside vLLM). A reduced MoE model
decodes batched requests through the full serve loop; we report output tok/s,
TTFT, ITL mean/p99, TPOT — the exact metric set of Table VII — plus the EPLB
load counters every run now tracks (per-rank max/mean heat ratio), so load
imbalance is reported alongside latency."""
from benchmarks.common import ensure_devices, write_result, table

ensure_devices(8)

import dataclasses             # noqa: E402

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.configs import get_smoke              # noqa: E402
from repro.runtime.server import DecodeServer    # noqa: E402


def bench_backend(mode: str, ll_layout: str = "nccl_ep",
                  pipeline_depth: int = 1):
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode=mode, ll_layout=ll_layout,
                              ep_axis=("data",), track_expert_heat=True)
    cfg = dataclasses.replace(cfg, moe=moe)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    srv = DecodeServer(cfg, batch=16, max_len=64, mesh=mesh,
                       pipeline_depth=pipeline_depth)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (16, 8)), jnp.int32)
    m = srv.serve(prompts, gen_steps=24)
    return m


def main():
    rows = []
    for name, mode, layout, depth in [
            ("nccl_ep (LL)", "ll", "nccl_ep", 1),
            ("nccl_ep (LL, pipelined x2)", "ll", "nccl_ep", 2),
            ("deepep-layout (LL)", "ll", "deepep", 1),
            ("alltoall baseline", "baseline", "nccl_ep", 1)]:
        m = bench_backend(mode, layout, depth)
        rows.append(dict(backend=name,
                         output_tok_s=round(m.output_tok_s, 1),
                         ttft_ms=round(m.ttft_s * 1e3, 1),
                         itl_mean_ms=round(m.itl_mean_s * 1e3, 2),
                         itl_p99_ms=round(m.itl_p99_s * 1e3, 2),
                         tpot_ms=round(m.itl_mean_s * 1e3, 2),
                         rank_load_imb=(None if m.rank_heat_max_mean is None
                                        else round(m.rank_heat_max_mean, 3))))
    table(rows, ["backend", "output_tok_s", "ttft_ms", "itl_mean_ms",
                 "itl_p99_ms", "tpot_ms", "rank_load_imb"],
          "Table VII analogue: serving metrics by EP backend (16 reqs, 8 ranks)")
    write_result("serving", dict(rows=rows))
    return rows


if __name__ == "__main__":
    main()
