"""Paper Figs. 7-8: LL dispatch/combine throughput vs rank count.

Paper setup: 256 experts, hidden 7168, 128 tokens/rank, top-8, BF16, ranks
8..64 (1..8 nodes). We sweep EP in {8, 16, 32} host devices (CPU memory
bounds the 64-rank full-hidden point) with the paper's layouts head-to-head:

  nccl_ep  — the paper's memory-optimized LL layout (§IV-D)
  deepep   — the DeepEP per-(expert,rank)-slot layout it is measured against
  baseline — the Megatron AllToAll dispatcher

Outputs per point: host wall-time (relative), per-rank wire bytes from the
group's buffer accounting, and the v5e ICI-bound projection bytes/(link bw).

Also tracks the recv-side unpack op latency in isolation (fp8 payloads at
LL sizes) next to the seed's two-pass formulation — a host-regression guard
for the fused ``recv_unpack`` entry point; see ``bench_recv_unpack`` for
why host parity (not a host speedup) is the expected result.
"""
from benchmarks.common import (ensure_devices, interleaved_best, timeit,
                               write_result, table, ICI_BW)

ensure_devices(32)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,  # noqa: E402
                        ep_dispatch, ep_combine)

E, K, B = 256, 8, 128
H_HOST = 896            # hidden scaled 8x down for host execution
H_PAPER = 7168


def make_fns(layout: str, N: int, H: int, mode="ll"):
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=B, hidden=H,
                        top_k=K, mode=mode if layout != "baseline" else "baseline",
                        ll_layout=layout if layout != "baseline" else "nccl_ep",
                        payload_dtype=jnp.bfloat16)
    group = ep_create_group(cfg, ep_size=N)

    def handle(x, topk, w):
        # handle creation = metadata gather + the full EpPlan slot-map chain.
        # Depend on the plan maps so XLA cannot dead-code-eliminate them.
        h = ep_create_handle(group, topk[0], w[0])
        live = (h.plan.disp_send_gmap.sum() + h.plan.comb_recv_rows.sum())
        return (h.tokens_per_expert + live)[None]

    def disp(x, topk, w):
        h = ep_create_handle(group, topk[0], w[0])
        y3d, counts = ep_dispatch(group, h, x[0])
        return y3d[None]

    def disp_comb(x, topk, w):
        h = ep_create_handle(group, topk[0], w[0])
        y3d, counts = ep_dispatch(group, h, x[0])
        return ep_combine(group, h, y3d)[None]

    sm = lambda f: jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(P("data"),) * 3, out_specs=P("data")))
    return sm(handle), sm(disp), sm(disp_comb), group


def wire_bytes(group, phase: str) -> int:
    """Per-rank bytes crossing the wire (excludes the self-block)."""
    N = group.ep_size
    frac = (N - 1) / N
    if group.cfg.mode == "baseline":
        from repro.core.baseline import _per_expert_cap
        ce = _per_expert_cap(group)
        per = N * group.local_experts * ce * group.payload_bytes_per_token()
        return int(per * frac)
    if phase == "dispatch":
        return int(group.ll_dispatch_buffer_bytes() * frac)
    return int(group.ll_combine_buffer_bytes() * frac)


def bench_recv_unpack():
    """Recv-unpack op latency at LL recv-buffer sizes (fp8 payloads), the
    tracked trajectory row for the fused kernel's entry point, next to the
    seed's two-pass formulation (gather -> separate dequant) on identical
    inputs.

    On this host both compile to the same fused XLA graph, so
    ``host_ratio`` (two_pass/fused) is EXPECTED to be ~1.0 — it guards
    against a host-path regression from routing recv through the new op,
    nothing more. The kernel's actual win is TPU-only: the scalar-prefetch
    index map DMAs each receive row exactly once with no gathered-fp8 HBM
    materialization between passes, which no CPU timing can exhibit."""
    from repro.core import slots as S
    from repro.kernels import ref

    rng = np.random.RandomState(1)
    rows = []
    for R, M in ((1024, 2048), (4096, 8192)):
        gmap = jnp.asarray(rng.randint(0, R + 1, (M,)), jnp.int32)
        x = jnp.asarray(rng.randn(R, H_HOST) * 3, jnp.float32)
        q, s = ref.quantize_fp8(x, 128)

        def two_pass(q, s, gmap):
            out = S.gather_rows(q, gmap)
            sc = S.gather_rows(s, gmap, fill=0)
            return ref.dequantize_fp8(out, sc)

        def fused(q, s, gmap):
            return ref.recv_unpack(q, gmap, s)

        t2, t1 = interleaved_best([jax.jit(two_pass), jax.jit(fused)],
                                  [(q, s, gmap)] * 2, iters=8)
        rows.append(dict(
            rows=R, slots=M, payload="fp8+scales",
            two_pass_ms=round(t2 * 1e3, 3), fused_ms=round(t1 * 1e3, 3),
            host_ratio=round(t2 / t1, 2) if t1 > 0 else float("inf"),
        ))
    return rows


def main():
    rng = np.random.RandomState(0)
    rows = []
    for N in (8, 16):
        x = jnp.asarray(rng.randn(N, B, H_HOST), jnp.bfloat16)
        topk = jnp.asarray(np.stack([
            np.stack([rng.choice(E, K, replace=False) for _ in range(B)])
            for _ in range(N)]), jnp.int32)
        w = jax.nn.softmax(jnp.asarray(rng.randn(N, B, K), jnp.float32), -1)
        for layout in ("nccl_ep", "deepep", "baseline"):
            hdl, disp, dc, group = make_fns(layout, N, H_HOST)
            t_h = timeit(hdl, x, topk, w)
            t_d = timeit(disp, x, topk, w)
            t_dc = timeit(dc, x, topk, w)
            # paper-scale projection: wire bytes at H=7168 over v5e ICI
            gp = ep_create_group(EpGroupConfig(
                num_experts=E, max_tokens_per_rank=B, hidden=H_PAPER, top_k=K,
                mode="baseline" if layout == "baseline" else "ll",
                ll_layout="nccl_ep" if layout == "baseline" else layout,
                payload_dtype=jnp.bfloat16), ep_size=N)
            db = wire_bytes(gp, "dispatch")
            cb = wire_bytes(gp, "combine")
            rows.append(dict(
                ranks=N, layout=layout,
                # per-phase host times (deltas of the nested jits): the
                # machine-readable perf trajectory across PRs
                host_handle_ms=round(t_h * 1e3, 1),
                host_dispatch_phase_ms=round(max(t_d - t_h, 0.0) * 1e3, 1),
                host_combine_phase_ms=round(max(t_dc - t_d, 0.0) * 1e3, 1),
                host_dispatch_ms=round(t_d * 1e3, 1),
                host_dispatch_combine_ms=round(t_dc * 1e3, 1),
                dispatch_MB_per_rank=round(db / 2**20, 1),
                combine_MB_per_rank=round(cb / 2**20, 1),
                v5e_dispatch_us=round(db / ICI_BW * 1e6, 1),
                v5e_combine_us=round(cb / ICI_BW * 1e6, 1),
            ))
    table(rows, ["ranks", "layout", "host_handle_ms", "host_dispatch_phase_ms",
                 "host_combine_phase_ms", "dispatch_MB_per_rank",
                 "combine_MB_per_rank", "v5e_dispatch_us", "v5e_combine_us"],
          "Figs 7-8 analogue: LL dispatch/combine vs ranks (E=256,K=8,B=128)")
    ru_rows = bench_recv_unpack()
    table(ru_rows, ["rows", "slots", "payload", "two_pass_ms", "fused_ms",
                    "host_ratio"],
          "recv unpack op latency (host_ratio ~1.0 expected: XLA fuses both;"
          " the kernel's win is TPU DMA scheduling)")
    write_result("ll_kernels", dict(config=dict(E=E, K=K, B=B, H_host=H_HOST,
                                                H_paper=H_PAPER), rows=rows,
                                    recv_unpack=ru_rows))
    return rows


if __name__ == "__main__":
    main()
