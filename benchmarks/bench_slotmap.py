"""Slot-map engine microbenchmark: one-hot-cumsum O(M·D) oracle vs the
sort-based O(M log M) production implementation, at the entry counts EP
metadata actually sees (M = tokens*top_k*ranks scales into the hundreds of
thousands on the training cells).

No devices needed — this is pure local compute; both variants are jitted and
timed on identical inputs (interleaved, min-estimated — see
``common.interleaved_best`` — so a host load burst cannot flip the tracked
comparison). Acceptance gate for PR 1: sort beats one-hot for M >= 64k (it
loses nothing at small M where both are microseconds).
"""
from benchmarks.common import interleaved_best, write_result, table

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.core import slots    # noqa: E402
from repro.kernels import ref   # noqa: E402

SIZES = (4096, 65536, 524288)
NUM_DEST = 64


def main():
    rng = np.random.RandomState(0)
    rows = []
    for M in SIZES:
        dest = jnp.asarray(rng.randint(0, NUM_DEST, M), jnp.int32)
        valid = jnp.asarray(rng.rand(M) < 0.9)
        f_sort = jax.jit(lambda d, v: slots.positions_by_dest(d, NUM_DEST, v))
        f_onehot = jax.jit(lambda d, v: ref.positions_by_dest(d, NUM_DEST, v))
        # parity first (bitwise), then timing
        ps, cs = f_sort(dest, valid)
        po, co = f_onehot(dest, valid)
        assert np.array_equal(np.asarray(ps), np.asarray(po))
        assert np.array_equal(np.asarray(cs), np.asarray(co))
        t_onehot, t_sort = interleaved_best(
            [f_onehot, f_sort], [(dest, valid)] * 2, iters=7)
        rows.append(dict(
            M=M, D=NUM_DEST,
            onehot_ms=round(t_onehot * 1e3, 3),
            sort_ms=round(t_sort * 1e3, 3),
            speedup=round(t_onehot / t_sort, 2),
        ))
    table(rows, ["M", "D", "onehot_ms", "sort_ms", "speedup"],
          "slot-map engine: one-hot O(M*D) vs sort O(M log M)")
    write_result("slotmap", dict(config=dict(num_dest=NUM_DEST), rows=rows))
    return rows


if __name__ == "__main__":
    main()
