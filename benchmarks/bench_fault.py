"""Elastic fault-tolerant EP (docs/DESIGN.md §9): recovery cost under an
injected kill/rejoin schedule in the decode serving loop.

A fully-replicated placement (R = E: every expert on 2 distinct ranks)
serves a fixed decode trace; the deterministic ``FaultInjector`` kills one
rank mid-serve and rejoins it later. Measured per ``miss_threshold``:

  * steps-to-detect — boundaries between the injected kill and the shrink
    transition (exactly ``miss_threshold - 1`` by construction: the
    detector is deterministic, and the bench ASSERTS it);
  * recovery latency — wall time inside each shrink/expand transition
    (degraded-placement build + masked weight re-adoption + re-jit);
  * degraded throughput — steady-state ITL on N-1 ranks vs healthy, the
    first post-transition step (which carries the recompile) excluded.

Correlated whole-pod kill (ISSUE 7 tentpole): a second scenario places the
experts under the fault-domain floor (``min_replicas=2`` across 2 pods of
4 ranks) and kills an ENTIRE pod at one step boundary via the injector's
``kill_domains`` schedule. The four deaths coalesce into ONE shrink
transition, recovered through the masked rebind — the bench ASSERTS
bitwise survivor-token parity with the uninterrupted run and ZERO
checkpoint restores (the floor's guarantee), and reports the coalesced
recovery latency + degraded (half-capacity) ITL rows.

In-bench acceptance (the functional contract, asserted every run): the
token stream is BITWISE-identical to an uninterrupted serve, the degraded
placement assigns zero slots to the dead rank(s), and the rejoin restores
the full-width table. Wall-clock ratios are tracked, never asserted (CPU-
host noise). Results land in results/benchmarks/fault.json and feed the
``fault`` section of BENCH_ll_kernels.json (schema v5) via
benchmarks/run.py."""
from benchmarks.common import (ensure_devices, steady_mean, table,
                               write_result)

ensure_devices(8)

import dataclasses             # noqa: E402

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.configs import get_smoke              # noqa: E402
from repro.core import placement as PL           # noqa: E402
from repro.runtime.fault import FaultInjector    # noqa: E402
from repro.runtime.server import DecodeServer    # noqa: E402

STEPS, KILL, REJOIN, DEAD_RANK = 40, 10, 30, 2
# correlated scenario: 2 pods of 4 ranks; pod 1 (ranks 4..7) dies whole
POD_DOMAINS = PL.domains_from_geometry(8, 4)
DEAD_POD = 1


def _cfg(floor=False):
    cfg = get_smoke("dbrx-132b")
    E = cfg.moe.num_experts
    if floor:
        # fault-domain floor: 2 replicas per expert, one per pod — survives
        # a whole-pod kill by construction
        pl0 = PL.rebalance(np.ones(E), 8, num_redundant=E,
                           min_replicas=2, domains=POD_DOMAINS)
    else:
        pl0 = PL.redundant_placement(E, 8, E)   # every expert 2x replicated
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True, params_physical=True,
                              placement=pl0)
    return dataclasses.replace(cfg, moe=moe), E


def _serve(fault_injector=None, miss_threshold=1, floor=False):
    cfg, E = _cfg(floor=floor)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    kw = (dict(min_replicas=2, fault_domains=POD_DOMAINS) if floor else {})
    srv = DecodeServer(cfg, batch=8, max_len=64, mesh=mesh,
                       num_redundant_experts=E,
                       fault_injector=fault_injector,
                       miss_threshold=miss_threshold, **kw)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (8, 8)), jnp.int32)
    first, _ = srv.prefill(prompts)
    toks, itls = srv.decode(first, STEPS)
    return srv, toks, np.asarray(itls)


def _pod_kill_rows():
    """Correlated whole-pod kill under the min_replicas=2 fault-domain
    floor: ranks 4..7 die at ONE boundary, coalescing into a single shrink
    transition; survivors keep serving at half capacity until the pod
    rejoins. Acceptance asserted in-bench: bitwise survivor-token parity,
    ZERO checkpoint restores, one coalesced transition, floor intact on
    every adopted table."""
    _, toks_ref, _ = _serve(floor=True)
    inj = FaultInjector(8, domains=POD_DOMAINS,
                        kill_domains={KILL: DEAD_POD},
                        rejoin_domains={REJOIN: DEAD_POD})
    srv, toks, itls = _serve(fault_injector=inj, miss_threshold=1,
                             floor=True)

    # ---- in-bench acceptance (ISSUE 7): the floor's guarantee ----
    np.testing.assert_array_equal(toks_ref, toks)   # bitwise across pod kill
    assert srv._ckpt_restores == 0, srv._ckpt_restores
    kinds = [e["kind"] for e in srv.recoveries]
    assert kinds == ["shrink", "expand"], kinds     # ONE coalesced shrink
    shrink, expand = srv.recoveries
    dead_pod_ranks = list(POD_DOMAINS.ranks_in(DEAD_POD))
    assert shrink["died"] == dead_pod_ranks, shrink
    assert shrink["lost_experts"] == [] and shrink["restored_from"] is None
    degraded, expanded = srv.placements[-2:]
    assert degraded.dead_ranks() == tuple(dead_pod_ranks)
    assert PL.lost_experts(degraded, degraded.alive_ranks()) == ()
    PL.validate_floor(degraded, 2, POD_DOMAINS)
    PL.validate_floor(expanded, 2, POD_DOMAINS)

    healthy = steady_mean(itls, 1, KILL)
    degraded_itl = steady_mean(itls, shrink["step"] + 1, expand["step"] + 1)
    post = steady_mean(itls, expand["step"] + 1, STEPS)
    return [dict(
        scenario=f"pod{DEAD_POD}_kill",
        killed_ranks=dead_pod_ranks,
        coalesced_deaths=len(dead_pod_ranks),
        transitions=len(srv.recoveries),
        checkpoint_restores=srv._ckpt_restores,
        shrink_ms=round(shrink["latency_s"] * 1e3, 1),
        expand_ms=round(expand["latency_s"] * 1e3, 1),
        healthy_itl_ms=round(healthy * 1e3, 2),
        degraded_itl_ms=round(degraded_itl * 1e3, 2),
        post_rejoin_itl_ms=round(post * 1e3, 2),
        degraded_over_healthy=round(degraded_itl / healthy, 3),
        degraded_steps=srv._degraded_steps,
        token_parity=True)]


def main():
    _, toks_ref, itls_ref = _serve()
    rows = []
    for mt in (1, 2):
        inj = FaultInjector(8, kill={KILL: DEAD_RANK},
                            rejoin={REJOIN: DEAD_RANK})
        srv, toks, itls = _serve(fault_injector=inj, miss_threshold=mt)

        # ---- in-bench acceptance: the functional contract ----
        np.testing.assert_array_equal(toks_ref, toks)   # bitwise across kill
        kinds = [e["kind"] for e in srv.recoveries]
        assert kinds == ["shrink", "expand"], kinds
        shrink, expand = srv.recoveries
        assert shrink["lost_experts"] == [] and shrink["restored_from"] is None
        degraded = srv.placements[-2]
        assert degraded.dead_ranks() == (DEAD_RANK,)
        assert degraded.num_empty == degraded.slots_per_rank  # zero slots
        assert srv.placements[-1].dead_ranks() == ()          # re-expanded
        steps_to_detect = shrink["step"] - KILL
        assert steps_to_detect == mt - 1, (shrink["step"], KILL, mt)

        healthy = steady_mean(itls, 1, KILL)
        deg_lo, deg_hi = shrink["step"] + 1, expand["step"] + 1
        degraded_itl = steady_mean(itls, deg_lo, deg_hi)
        post = steady_mean(itls, expand["step"] + 1, STEPS)
        rows.append(dict(
            miss_threshold=mt,
            steps_to_detect=steps_to_detect,
            shrink_ms=round(shrink["latency_s"] * 1e3, 1),
            expand_ms=round(expand["latency_s"] * 1e3, 1),
            healthy_itl_ms=round(healthy * 1e3, 2),
            degraded_itl_ms=round(degraded_itl * 1e3, 2),
            post_rejoin_itl_ms=round(post * 1e3, 2),
            degraded_over_healthy=round(degraded_itl / healthy, 3),
            degraded_steps=srv._degraded_steps,
            token_parity=True))
    table(rows, ["miss_threshold", "steps_to_detect", "shrink_ms",
                 "expand_ms", "healthy_itl_ms", "degraded_itl_ms",
                 "post_rejoin_itl_ms", "degraded_over_healthy",
                 "degraded_steps", "token_parity"],
          f"Elastic recovery: kill rank {DEAD_RANK} @ step {KILL}, "
          f"rejoin @ {REJOIN} (8 ranks, R=E replication, {STEPS} steps)")
    print("  degraded/healthy ITL tracked, not asserted (host noise); "
          "token parity + zero-slot degraded placement ASSERTED above")

    pod_rows = _pod_kill_rows()
    table(pod_rows, ["scenario", "coalesced_deaths", "transitions",
                     "checkpoint_restores", "shrink_ms", "expand_ms",
                     "healthy_itl_ms", "degraded_itl_ms",
                     "post_rejoin_itl_ms", "degraded_over_healthy",
                     "degraded_steps", "token_parity"],
          f"Correlated whole-pod kill: pod {DEAD_POD} "
          f"(ranks {list(POD_DOMAINS.ranks_in(DEAD_POD))}) @ step {KILL}, "
          f"rejoin @ {REJOIN} (min_replicas=2 floor, 2 pods of 4)")
    print("  4 deaths coalesce into ONE shrink; bitwise token parity + "
          "ZERO checkpoint restores ASSERTED above")

    write_result("fault", dict(
        config=dict(ranks=8, steps=STEPS, kill_step=KILL,
                    rejoin_step=REJOIN, dead_rank=DEAD_RANK,
                    replication="R=E (every expert on 2 ranks)",
                    baseline_itl_ms=round(steady_mean(itls_ref, 1, STEPS) * 1e3,
                                          2)),
        rows=rows,
        pod_kill=dict(
            config=dict(ranks=8, steps=STEPS, kill_step=KILL,
                        rejoin_step=REJOIN, dead_pod=DEAD_POD,
                        domains=POD_DOMAINS.describe(), min_replicas=2,
                        replication="floor placement, R=E, one replica "
                                    "per pod per expert"),
            rows=pod_rows)))
    return rows + pod_rows


if __name__ == "__main__":
    main()
