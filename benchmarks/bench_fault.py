"""Elastic fault-tolerant EP (docs/DESIGN.md §9): recovery cost under an
injected kill/rejoin schedule in the decode serving loop.

A fully-replicated placement (R = E: every expert on 2 distinct ranks)
serves a fixed decode trace; the deterministic ``FaultInjector`` kills one
rank mid-serve and rejoins it later. Measured per ``miss_threshold``:

  * steps-to-detect — boundaries between the injected kill and the shrink
    transition (exactly ``miss_threshold - 1`` by construction: the
    detector is deterministic, and the bench ASSERTS it);
  * recovery latency — wall time inside each shrink/expand transition
    (degraded-placement build + masked weight re-adoption + re-jit);
  * degraded throughput — steady-state ITL on N-1 ranks vs healthy, the
    first post-transition step (which carries the recompile) excluded.

In-bench acceptance (the functional contract, asserted every run): the
token stream is BITWISE-identical to an uninterrupted serve, the degraded
placement assigns zero slots to the dead rank, and the rejoin restores the
full-width table. Wall-clock ratios are tracked, never asserted (CPU-host
noise). Results land in results/benchmarks/fault.json and feed the
``fault`` section of BENCH_ll_kernels.json (schema v5) via
benchmarks/run.py."""
from benchmarks.common import ensure_devices, write_result, table

ensure_devices(8)

import dataclasses             # noqa: E402

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.configs import get_smoke              # noqa: E402
from repro.core import placement as PL           # noqa: E402
from repro.runtime.fault import FaultInjector    # noqa: E402
from repro.runtime.server import DecodeServer    # noqa: E402

STEPS, KILL, REJOIN, DEAD_RANK = 40, 10, 30, 2


def _cfg():
    cfg = get_smoke("dbrx-132b")
    E = cfg.moe.num_experts
    pl0 = PL.redundant_placement(E, 8, E)       # every expert 2x replicated
    moe = dataclasses.replace(cfg.moe, ep_mode="ll", ep_axis=("data",),
                              track_expert_heat=True, params_physical=True,
                              placement=pl0)
    return dataclasses.replace(cfg, moe=moe), E


def _serve(fault_injector=None, miss_threshold=1):
    cfg, E = _cfg()
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    srv = DecodeServer(cfg, batch=8, max_len=64, mesh=mesh,
                       num_redundant_experts=E,
                       fault_injector=fault_injector,
                       miss_threshold=miss_threshold)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (8, 8)), jnp.int32)
    first, _ = srv.prefill(prompts)
    toks, itls = srv.decode(first, STEPS)
    return srv, toks, np.asarray(itls)


def _steady(itls, lo, hi, skip_first=1):
    """Mean ITL over [lo, hi), excluding the first ``skip_first`` steps
    (they carry the post-transition recompile)."""
    window = itls[lo + skip_first:hi]
    return float(window.mean()) if window.size else float("nan")


def main():
    _, toks_ref, itls_ref = _serve()
    rows = []
    for mt in (1, 2):
        inj = FaultInjector(8, kill={KILL: DEAD_RANK},
                            rejoin={REJOIN: DEAD_RANK})
        srv, toks, itls = _serve(fault_injector=inj, miss_threshold=mt)

        # ---- in-bench acceptance: the functional contract ----
        np.testing.assert_array_equal(toks_ref, toks)   # bitwise across kill
        kinds = [e["kind"] for e in srv.recoveries]
        assert kinds == ["shrink", "expand"], kinds
        shrink, expand = srv.recoveries
        assert shrink["lost_experts"] == [] and shrink["restored_from"] is None
        degraded = srv.placements[-2]
        assert degraded.dead_ranks() == (DEAD_RANK,)
        assert degraded.num_empty == degraded.slots_per_rank  # zero slots
        assert srv.placements[-1].dead_ranks() == ()          # re-expanded
        steps_to_detect = shrink["step"] - KILL
        assert steps_to_detect == mt - 1, (shrink["step"], KILL, mt)

        healthy = _steady(itls, 1, KILL)
        deg_lo, deg_hi = shrink["step"] + 1, expand["step"] + 1
        degraded_itl = _steady(itls, deg_lo, deg_hi)
        post = _steady(itls, expand["step"] + 1, STEPS)
        rows.append(dict(
            miss_threshold=mt,
            steps_to_detect=steps_to_detect,
            shrink_ms=round(shrink["latency_s"] * 1e3, 1),
            expand_ms=round(expand["latency_s"] * 1e3, 1),
            healthy_itl_ms=round(healthy * 1e3, 2),
            degraded_itl_ms=round(degraded_itl * 1e3, 2),
            post_rejoin_itl_ms=round(post * 1e3, 2),
            degraded_over_healthy=round(degraded_itl / healthy, 3),
            degraded_steps=srv._degraded_steps,
            token_parity=True))
    table(rows, ["miss_threshold", "steps_to_detect", "shrink_ms",
                 "expand_ms", "healthy_itl_ms", "degraded_itl_ms",
                 "post_rejoin_itl_ms", "degraded_over_healthy",
                 "degraded_steps", "token_parity"],
          f"Elastic recovery: kill rank {DEAD_RANK} @ step {KILL}, "
          f"rejoin @ {REJOIN} (8 ranks, R=E replication, {STEPS} steps)")
    print("  degraded/healthy ITL tracked, not asserted (host noise); "
          "token parity + zero-slot degraded placement ASSERTED above")
    write_result("fault", dict(
        config=dict(ranks=8, steps=STEPS, kill_step=KILL,
                    rejoin_step=REJOIN, dead_rank=DEAD_RANK,
                    replication="R=E (every expert on 2 ranks)",
                    baseline_itl_ms=round(_steady(itls_ref, 1, STEPS) * 1e3,
                                          2)),
        rows=rows))
    return rows


if __name__ == "__main__":
    main()
