"""Shared benchmark utilities: timing, device-count bootstrap, reporting.

CPU-host wall times are meaningful only RELATIVELY (layout A vs layout B on
identical fake-device meshes); every benchmark therefore also reports the
analytic TPU-v5e projection (bytes / link bandwidth, flops / peak) derived
from the same buffer accounting the roofline uses.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "benchmarks"

# TPU v5e model (per task spec)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


def ensure_devices(n: int):
    """Must be called before jax import in the bench entrypoint."""
    os.environ.setdefault("XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")


def timeit(fn, *args, warmup=1, iters=2):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def interleaved_best(fns, args, iters=5):
    """Best (min) per-fn wall time over ``iters`` rounds, visiting every fn
    each round. For A-vs-B comparisons on a shared host: interleaving
    spreads load drift over all variants and the min is the noise-free
    estimate (a background burst can only inflate a timing, never deflate
    it) — back-to-back ``timeit`` calls can flip a comparison's sign when a
    burst lands on one of them. ``args``: one argument tuple per fn."""
    import numpy as np
    import jax
    for f, a in zip(fns, args):
        jax.block_until_ready(f(*a))                     # compile + warm
    times = [[] for _ in fns]
    for _ in range(iters):
        for i, (f, a) in enumerate(zip(fns, args)):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*a))
            times[i].append(time.perf_counter() - t0)
    return [float(np.min(t)) for t in times]


def pct_ms(a, q) -> float:
    """q-th percentile of a seconds array, in ms (2 decimals) — the serving
    benches' shared percentile convention."""
    import numpy as np
    return round(float(np.percentile(np.asarray(a), q)) * 1e3, 2)


def pctiles_ms(a, qs=(50, 95, 99)) -> dict:
    """{'p50_ms': ..., ...} percentile summary of a seconds array."""
    return {f"p{q}_ms": pct_ms(a, q) for q in qs}


def steady_mean(itls, lo, hi, skip_first=1) -> float:
    """Mean ITL over [lo, hi), excluding the first ``skip_first`` steps
    (they carry the post-transition recompile)."""
    import numpy as np
    window = np.asarray(itls)[lo + skip_first:hi]
    return float(window.mean()) if window.size else float("nan")


def write_result(name: str, payload: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))


def table(rows: list[dict], cols: list[str], title: str):
    print(f"\n== {title} ==")
    widths = {c: max(len(c), *(len(str(r.get(c, ''))) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
