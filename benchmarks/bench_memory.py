"""Paper Eq. 3: LL communication-buffer footprint, DeepEP layout vs the
memory-optimized NCCL EP layout.

  ratio = 2*E*B*P / (N*B*P + B*K*P) = 2E / (N + K)   (~14x at N=64,E=512,K=8)

Three accountings, all derived from the EpGroup sizing code:

  deepep        — per-(expert,src-rank) slots, double-buffered: 2*E*B*P.
  nccl_ep_slots — the paper's optimized layout with shared receive regions
                  (N*B*P dispatch + B*K*P combine). On TPU this is exactly
                  what the ragged_all_to_all path allocates (core/ragged.py);
                  it reproduces Eq. 3.
  nccl_ep_a2a   — the dense static-shape all-to-all realization this container
                  runs (capacity factor 2): per-pair combine blocks cost
                  ~2*B*K*P instead of B*K*P — the documented price of
                  synchronized dense collectives vs RDMA slot writes.

Paged-KV accounting rows (PR 8, schema v6): the continuous-batching
scheduler replayed host-side over Poisson request streams — peak pages
allocated (the paged pool's high-water mark, ``PageAllocator.peak_live``)
vs the dense ``B x S_max`` cache's page equivalent. ``paged <= dense`` is
ASSERTED in-bench for every scenario: the allocator can never hold more
than the dense reservation because admission is reservation-gated at
worst-case request footprint (runtime/scheduler.py).
"""
from benchmarks.common import write_result, table

import jax.numpy as jnp     # noqa: E402

from repro.core import EpGroupConfig, ep_create_group    # noqa: E402


def groups(N, E, K, B, H, cf):
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=B, hidden=H,
                        top_k=K, mode="ll", ll_layout="nccl_ep",
                        capacity_factor=cf, payload_dtype=jnp.bfloat16)
    return ep_create_group(cfg, ep_size=N)


def main():
    H, B = 7168, 128
    rows = []
    for (N, E, K) in [(8, 256, 8), (16, 256, 8), (32, 256, 8), (64, 256, 8),
                      (64, 512, 8), (16, 64, 4), (32, 128, 6)]:
        g = groups(N, E, K, B, H, None)
        P_ = g.payload_bytes_per_token()
        deepep = 2 * E * B * P_                       # Eq. 3 numerator
        slots = (N * B + B * K) * P_                  # Eq. 3 denominator
        g2 = groups(N, E, K, B, H, 2.0)
        a2a = g2.ll_dispatch_buffer_bytes() + g2.ll_combine_buffer_bytes()
        rows.append(dict(
            N=N, E=E, K=K,
            deepep_GiB=round(deepep / 2**30, 2),
            nccl_ep_slots_GiB=round(slots / 2**30, 3),
            nccl_ep_a2a_GiB=round(a2a / 2**30, 3),
            slots_ratio=round(deepep / slots, 1),
            eq3_ratio=round(2 * E / (N + K), 1),
            a2a_ratio=round(deepep / a2a, 1),
        ))
    table(rows, ["N", "E", "K", "deepep_GiB", "nccl_ep_slots_GiB",
                 "nccl_ep_a2a_GiB", "slots_ratio", "eq3_ratio", "a2a_ratio"],
          "Eq. 3: LL buffer footprint reduction (B=128, H=7168, bf16)")
    flagship = [r for r in rows if r["N"] == 64 and r["E"] == 512][0]
    assert abs(flagship["slots_ratio"] - flagship["eq3_ratio"]) < 0.2, flagship
    paged = paged_kv_rows()
    write_result("memory_eq3", dict(rows=rows, paged_kv=paged))
    return rows


def paged_kv_rows():
    """Replay the continuous-batching scheduler host-side (no device work)
    over Poisson request streams and account peak pages vs the dense
    B x S_max equivalent. The in-bench assert is the paged-KV memory claim:
    peak live pages never exceed what a dense cache pins up front."""
    import numpy as np
    from repro.models.kv_pages import PageAllocator, pages_for_tokens
    from repro.runtime.scheduler import ContinuousScheduler, Request

    rows = []
    # (slots B, S_max, page, requests, poisson rate/step, prompt lo..hi, gen)
    for B, S, page, n_req, rate, plo, phi, gen in [
            (8, 512, 16, 32, 0.10, 16, 128, 64),
            (8, 512, 16, 32, 0.50, 16, 128, 64),   # bursty: higher occupancy
            (16, 1024, 16, 48, 0.20, 32, 256, 128),
            (8, 256, 8, 24, 0.25, 8, 64, 32)]:
        rng = np.random.RandomState(0)
        arr = np.floor(np.cumsum(rng.exponential(1.0 / rate, n_req))).astype(int)
        reqs = [Request(i, rng.randint(0, 999, rng.randint(plo, phi + 1)),
                        gen, arrival_step=int(a - arr[0]))
                for i, a in enumerate(arr)]
        dense_pages = B * pages_for_tokens(S, page)
        alloc = PageAllocator(dense_pages, page)   # dense-equivalent pool
        sched = ContinuousScheduler(reqs, B, pages_for_tokens(S, page), alloc)
        step = 0
        while not sched.done:
            sched.advance(step, now=float(step))
            sched.observe(np.zeros((B, 1), np.int32), now=float(step))
            step += 1
        assert alloc.peak_live <= dense_pages, (alloc.peak_live, dense_pages)
        assert alloc.live_count == 0                # everything released
        rows.append(dict(
            slots=B, s_max=S, page=page, requests=n_req, rate=rate,
            steps=step, pages_peak=alloc.peak_live, pages_dense=dense_pages,
            paged_over_dense=round(alloc.peak_live / dense_pages, 3)))
    table(rows, ["slots", "s_max", "page", "requests", "rate", "steps",
                 "pages_peak", "pages_dense", "paged_over_dense"],
          "Paged-KV accounting: peak pages vs dense B x S_max equivalent "
          "(asserted paged <= dense)")
    return dict(rows=rows)


if __name__ == "__main__":
    main()
