"""Paper Table III / §III-D: LL vs HT vs baseline across batch sizes — the
crossover that motivates the unified mode-selected API. Host wall time for
one dispatch->expert-FFN->combine cycle on 8 fake devices, plus the wire-byte
accounting that determines the TPU-side crossover."""
from benchmarks.common import ensure_devices, timeit, write_result, table, ICI_BW

ensure_devices(8)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,  # noqa: E402
                        ep_dispatch, ep_combine)
from repro.kernels import ops as K           # noqa: E402

E, Kk, H, F = 64, 4, 512, 1024
N = 8


def make_step(mode: str, B: int):
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=B, hidden=H,
                        top_k=Kk, mode=mode, payload_dtype=jnp.bfloat16,
                        capacity_factor=(None if mode == "ll" else 1.5),
                        expert_capacity_factor=(None if mode == "ll" else 1.5))
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w, w1, w2):
        h = ep_create_handle(group, topk[0], w[0])
        y3d, counts = ep_dispatch(group, h, x[0])
        if group.mode == "baseline":
            counts = jnp.full_like(counts, y3d.shape[1])
        y3d = K.grouped_gemm(y3d, w1[0], counts)
        y3d = K.grouped_gemm(jax.nn.silu(y3d.astype(jnp.float32)).astype(y3d.dtype),
                             w2[0], counts)
        return ep_combine(group, h, y3d)[None]

    return jax.jit(jax.shard_map(
        step, mesh=mesh,
        in_specs=(P("data"),) * 3 + (P("data"), P("data")),
        out_specs=P("data"))), group


def main():
    rng = np.random.RandomState(0)
    w1 = jnp.asarray(rng.randn(N, E // N, H, F) * 0.05, jnp.bfloat16)
    w2 = jnp.asarray(rng.randn(N, E // N, F, H) * 0.05, jnp.bfloat16)
    rows = []
    for B in (8, 64, 512):
        x = jnp.asarray(rng.randn(N, B, H), jnp.bfloat16)
        topk = jnp.asarray(np.stack([
            np.stack([rng.choice(E, Kk, replace=False) for _ in range(B)])
            for _ in range(N)]), jnp.int32)
        w = jax.nn.softmax(jnp.asarray(rng.randn(N, B, Kk), jnp.float32), -1)
        row = dict(tokens_per_rank=B)
        for mode in ("ll", "ht", "baseline"):
            step, group = make_step(mode, B)
            row[f"{mode}_ms"] = round(timeit(step, x, topk, w, w1, w2) * 1e3, 1)
        rows.append(row)
    table(rows, ["tokens_per_rank", "ll_ms", "ht_ms", "baseline_ms"],
          "Table III analogue: mode crossover by batch (host wall, 8 ranks)")
    write_result("modes_crossover", dict(config=dict(E=E, K=Kk, H=H, N=N),
                                         rows=rows))
    return rows


if __name__ == "__main__":
    main()
