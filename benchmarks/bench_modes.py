"""Paper Table III / §III-D: LL vs HT vs baseline across batch sizes — the
crossover that motivates the unified mode-selected API. Host wall time for
one dispatch->expert-FFN->combine cycle on 8 fake devices, plus the wire-byte
accounting that determines the TPU-side crossover.

Also measures the **prefill pipeline steady state** (BENCH schema v3): one
staged MoE layer through runtime/prefill.py over the HT presets — flat vs
hierarchical, and chunked (ht_num_chunks ∈ {2, 4}) vs monolithic (nc=1)
hierarchical — with the shared interleaved-min timer, so host load bursts
cannot flip the chunked-vs-monolithic comparison. Host wall time serializes
collectives, so the pipeline's overlap itself is invisible here; what the
rows track is the *schedule overhead* of chunking (the chunked stream must
hold parity with the monolithic path on host time — its win is TPU-side
async scheduling freedom, like the decode pipeline's)."""
from benchmarks.common import (ensure_devices, timeit, interleaved_best,
                               write_result, table, ICI_BW)

ensure_devices(8)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,  # noqa: E402
                        ep_dispatch, ep_combine)
from repro.kernels import ops as K           # noqa: E402

E, Kk, H, F = 64, 4, 512, 1024
N = 8


def make_step(mode: str, B: int):
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=B, hidden=H,
                        top_k=Kk, mode=mode, payload_dtype=jnp.bfloat16,
                        capacity_factor=(None if mode == "ll" else 1.5),
                        expert_capacity_factor=(None if mode == "ll" else 1.5))
    group = ep_create_group(cfg, ep_size=N)

    def step(x, topk, w, w1, w2):
        h = ep_create_handle(group, topk[0], w[0])
        y3d, counts = ep_dispatch(group, h, x[0])
        if group.mode == "baseline":
            counts = jnp.full_like(counts, y3d.shape[1])
        y3d = K.grouped_gemm(y3d, w1[0], counts)
        y3d = K.grouped_gemm(jax.nn.silu(y3d.astype(jnp.float32)).astype(y3d.dtype),
                             w2[0], counts)
        return ep_combine(group, h, y3d)[None]

    return jax.jit(jax.shard_map(
        step, mesh=mesh,
        in_specs=(P("data"),) * 3 + (P("data"), P("data")),
        out_specs=P("data"))), group


# ---------------------------------------------------------------------------
# prefill pipeline steady state (schema v3 rows)
# ---------------------------------------------------------------------------

PF_B, PF_MB = 512, 2                 # tokens/rank per layer, micro-batches
PF_No, PF_Ni = 2, 4


def make_prefill_step(variant: str):
    """One staged prefill MoE layer (runtime/prefill.py) per host call.
    variant: "flat" | "hier-nc1" | "hier-nc2" | "hier-nc4"."""
    from repro.runtime.prefill import prefill_moe

    Tm = PF_B // PF_MB
    hier = variant != "flat"
    nc = int(variant.rsplit("nc", 1)[1]) if hier else 1
    kw = dict(num_experts=E, max_tokens_per_rank=Tm, hidden=H, top_k=Kk,
              mode="ht", payload_dtype=jnp.bfloat16,
              capacity_factor=1.5, expert_capacity_factor=1.5)
    if hier:
        cfg = EpGroupConfig(ep_axis=("pod", "data"), ht_hierarchical=True,
                            ht_num_chunks=nc, **kw)
        group = ep_create_group(cfg, ep_size=N, inner_size=PF_Ni)
        mesh = jax.make_mesh((PF_No, PF_Ni), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        spec = P(("pod", "data"))
    else:
        cfg = EpGroupConfig(**kw)
        group = ep_create_group(cfg, ep_size=N)
        mesh = jax.make_mesh((N,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        spec = P("data")

    def step(x, router_w, w1, w2):
        def router_fn(xt):
            logits = xt.astype(jnp.float32) @ router_w
            w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), Kk)
            return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)

        def expert_fn(y3d, counts):
            g = K.grouped_gemm(y3d, w1[0], counts)
            return K.grouped_gemm(
                jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype),
                w2[0], counts)

        return prefill_moe(group, router_fn, expert_fn, x[0], PF_MB)[None]

    return jax.jit(jax.shard_map(
        step, mesh=mesh,
        in_specs=(spec, P(None, None), spec, spec), out_specs=spec))


def prefill_rows(rng):
    router_w = jnp.asarray(rng.randn(H, E) * 0.1, jnp.float32)
    w1 = jnp.asarray(rng.randn(N, E // N, H, F) * 0.05, jnp.bfloat16)
    w2 = jnp.asarray(rng.randn(N, E // N, F, H) * 0.05, jnp.bfloat16)
    x = jnp.asarray(rng.randn(N, PF_B, H), jnp.bfloat16)
    variants = ["flat", "hier-nc1", "hier-nc2", "hier-nc4"]
    fns = [make_prefill_step(v) for v in variants]
    times = interleaved_best(fns, [(x, router_w, w1, w2)] * len(fns), iters=4)
    base = times[variants.index("hier-nc1")]     # monolithic hier = reference
    rows = [dict(variant=v, tokens_per_rank=PF_B, microbatches=PF_MB,
                 per_layer_ms=round(t * 1e3, 1),
                 vs_monolithic_hier=round(base / t, 2))
            for v, t in zip(variants, times)]
    return rows


def main():
    rng = np.random.RandomState(0)
    w1 = jnp.asarray(rng.randn(N, E // N, H, F) * 0.05, jnp.bfloat16)
    w2 = jnp.asarray(rng.randn(N, E // N, F, H) * 0.05, jnp.bfloat16)
    rows = []
    for B in (8, 64, 512):
        x = jnp.asarray(rng.randn(N, B, H), jnp.bfloat16)
        topk = jnp.asarray(np.stack([
            np.stack([rng.choice(E, Kk, replace=False) for _ in range(B)])
            for _ in range(N)]), jnp.int32)
        w = jax.nn.softmax(jnp.asarray(rng.randn(N, B, Kk), jnp.float32), -1)
        row = dict(tokens_per_rank=B)
        for mode in ("ll", "ht", "baseline"):
            step, group = make_step(mode, B)
            row[f"{mode}_ms"] = round(timeit(step, x, topk, w, w1, w2) * 1e3, 1)
        rows.append(row)
    table(rows, ["tokens_per_rank", "ll_ms", "ht_ms", "baseline_ms"],
          "Table III analogue: mode crossover by batch (host wall, 8 ranks)")
    p_rows = prefill_rows(rng)
    table(p_rows, ["variant", "tokens_per_rank", "per_layer_ms",
                   "vs_monolithic_hier"],
          f"prefill pipeline steady state (staged driver, {PF_MB} "
          "micro-batches, min-of-interleaved)")
    write_result("modes_crossover", dict(
        config=dict(E=E, K=Kk, H=H, N=N),
        rows=rows,
        prefill=dict(config=dict(B=PF_B, microbatches=PF_MB, No=PF_No,
                                 Ni=PF_Ni, E=E, K=Kk, H=H, F=F),
                     rows=p_rows)))
    return rows


if __name__ == "__main__":
    main()
