"""Decode-pipeline steady state (paper §IV overlap + ROADMAP plan reuse).

Measures the two claims the runtime/decode.py driver makes against the naive
per-step loop (rebuild handle, unstaged dispatch/combine — what every decode
step cost before this PR):

  * steady-state per-step time of the double-buffered pipeline, with the
    routing replayed every step (speculative-decode replay: the
    ``ep_handle_refresh`` routing-hash fast path reuses all slot maps) and
    with the routing changed every step (refresh still staged, but the hash
    mismatch rebuilds the plan);
  * handle refresh vs handle creation, isolated: the incremental host cost
    of ``ep_handle_refresh`` on unchanged routing vs a full
    ``ep_create_handle``.

Host wall times on fake devices are meaningful relatively (same mesh, same
data movement); the per-step delta is the plan-construction work the fast
path removes. The CPU host serializes collectives, so the comm/compute
overlap itself is invisible here — it is measured as scheduling freedom in
the staged HLO (examples/staged_overlap.py); what IS host-measurable is the
steady-state driver cost (see the note at ``HS`` for the operating point —
the plan share of a step shrinks as the payload grows). Naive and
pipelined runs are
interleaved and min-estimated so host load bursts cannot flip the
comparison. Expected shape of the result: the replay rows beat naive (plan
construction skipped); the changed-every-step rows are a wash or slightly
negative — the hash mismatch rebuilds the plan AND pays the cond's map
copy-through, which is exactly why the fast path targets replay (the
speculative-decode / cached-dispatch case), not routing churn.
"""
from benchmarks.common import ensure_devices, interleaved_best, write_result, table

ensure_devices(8)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,  # noqa: E402
                        ep_handle_refresh)
from repro.runtime.decode import naive_decode_step, decode_loop  # noqa: E402

N, E, K, T = 8, 64, 8, 128            # paper's LL decode point: B=128/rank
# Hidden size for the steady-state rows. At the bench_ll_kernels host scale
# (H=896) a step costs ~2s and the ~10% plan-reuse delta sits inside this
# box's load-burst noise band, flipping sign run to run; H=256 keeps the
# same routing/plan work against a 3.5x smaller payload, so the effect
# (~1.4x) is resolvable and stable — the right property for a tracked
# trajectory metric. On real TPU decode the plan share is larger still
# (steps are launch-latency-bound, collectives are async).
HS = (256,)
STEPS = 4                             # decode window per timed call
MB = 2                                # micro-batch buffers (double buffer)


def make_group(H):
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, mode="ll", payload_dtype=jnp.bfloat16)
    return ep_create_group(cfg, ep_size=N)


def make_router(group, router_w):
    def router_fn(x):
        logits = (x.astype(jnp.float32) @ router_w)
        w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        return idx.astype(jnp.int32), w / w.sum(-1, keepdims=True)
    return router_fn


def expert_fn_for(group):
    from repro.core import plan as PM

    def expert_fn(y3d, counts):
        L = group.local_experts
        e_glob = PM.my_rank(group) * L + jnp.arange(L)
        return y3d * (1.0 + e_glob)[:, None, None].astype(y3d.dtype)
    return expert_fn


def steady_state_rows(rng, mesh):
    rows = []
    for H in HS:
        group = make_group(H)
        router_w = jnp.asarray(rng.randn(H, E), jnp.float32)
        router_fn = make_router(group, router_w)
        expert_fn = expert_fn_for(group)
        sm = lambda f: jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=(P(None, None, "data"),),
            out_specs=P("data")))

        # xs_replay: one pair replayed STEPS times (unchanged routing);
        # xs_fresh: a new pair every step (routing changes step to step)
        pair = jnp.asarray(rng.randn(1, MB, N, T, H), jnp.bfloat16)
        xs_replay = jnp.broadcast_to(pair, (STEPS, MB, N, T, H))
        xs_fresh = jnp.asarray(rng.randn(STEPS, MB, N, T, H), jnp.bfloat16)

        def pipe(xs):
            seq = [(xs[s, 0, 0], xs[s, 1, 0]) for s in range(STEPS)]
            outs = decode_loop(group, router_fn, expert_fn, seq)
            return sum(a.sum() + b.sum() for a, b in outs)[None]

        def naive(xs):
            tot = jnp.float32(0)
            for s in range(STEPS):
                for m in range(MB):
                    tot += naive_decode_step(group, router_fn, expert_fn,
                                             xs[s, m, 0]).sum()
            return tot[None]

        per = STEPS * MB
        pipe_jit = sm(pipe)              # one trace serves both arg sets
        t_naive, t_replay, t_fresh = interleaved_best(
            [sm(naive), pipe_jit, pipe_jit],
            [(xs_fresh,), (xs_replay,), (xs_fresh,)], iters=5)
        rows += [
            dict(variant="naive (rebuild plan, unstaged)", hidden=H,
                 per_step_ms=round(t_naive / per * 1e3, 2), speedup=1.0),
            dict(variant="pipeline, routing replay (hash fast path)",
                 hidden=H, per_step_ms=round(t_replay / per * 1e3, 2),
                 speedup=round(t_naive / t_replay, 2)),
            dict(variant="pipeline, routing changed each step", hidden=H,
                 per_step_ms=round(t_fresh / per * 1e3, 2),
                 speedup=round(t_naive / t_fresh, 2)),
        ]
    return rows


def main():
    rng = np.random.RandomState(0)
    mesh = jax.make_mesh((N,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rows = steady_state_rows(rng, mesh)
    group = make_group(HS[-1])

    # ---- handle refresh vs create, isolated. Per-call fixed overhead (jit
    # dispatch, 8-shard orchestration) swamps a single ms-scale op, so each
    # timed fn chains REPS ops over *distinct* input buffers (identical
    # values — XLA cannot CSE distinct parameters) and the per-op cost is
    # the (chained - baseline)/REPS delta.
    REPS = 8
    topk1 = np.stack([np.stack([rng.choice(E, K, replace=False)
                                for _ in range(T)]) for _ in range(N)])
    topks = jnp.asarray(np.broadcast_to(topk1, (REPS,) + topk1.shape).copy(),
                        jnp.int32)                    # [REPS, N, T, K]
    w = jax.nn.softmax(jnp.asarray(rng.randn(N, T, K), jnp.float32), -1)

    def live(h):
        return (h.plan.disp_send_gmap.sum() + h.plan.comb_recv_rows.sum()
                + h.tokens_per_expert.sum())

    def f_base(topks, w):
        return live(ep_create_handle(group, topks[0, 0], w[0]))[None]

    def f_creates(topks, w):
        h = ep_create_handle(group, topks[0, 0], w[0])
        tot = live(h)
        for i in range(REPS):
            tot += live(ep_create_handle(group, topks[i, 0], w[0]))
        return tot[None]

    def f_refreshes(topks, w):
        h = ep_create_handle(group, topks[0, 0], w[0])
        tot = live(h)
        for i in range(REPS):
            tot += live(ep_handle_refresh(group, h, w[0], topks[i, 0]))
        return tot[None]

    smh = lambda f: jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(P(None, "data"), P("data")),
        out_specs=P("data")))
    fns = [smh(f_base), smh(f_creates), smh(f_refreshes)]
    # deltas must stay positive (chained > baseline by construction); a load
    # burst on the baseline can still violate that, so retry rather than
    # fold a negative/degenerate metric into the tracked trajectory file
    for attempt in range(3):
        t_base, t_creates, t_refreshes = interleaved_best(
            fns, [(topks, w)] * 3, iters=12)
        t_create = (t_creates - t_base) / REPS
        t_refresh = (t_refreshes - t_base) / REPS
        if t_create > 0 and t_refresh > 0:
            break
    else:
        raise RuntimeError(
            f"handle timing degenerate after 3 attempts: base={t_base:.4f}s "
            f"creates={t_creates:.4f}s refreshes={t_refreshes:.4f}s")
    handle_rows = [dict(
        op="ep_create_handle", ms=round(t_create * 1e3, 2), speedup=1.0,
    ), dict(
        op="ep_handle_refresh (unchanged routing)",
        ms=round(t_refresh * 1e3, 2),
        speedup=round(t_create / t_refresh, 2),
    )]

    table(rows, ["variant", "hidden", "per_step_ms", "speedup"],
          f"decode pipeline steady state (N={N}, E={E}, K={K}, T={T}, "
          f"{STEPS} steps x {MB} micro-batches)")
    table(handle_rows, ["op", "ms", "speedup"],
          "handle: full create vs routing-hash refresh")
    write_result("decode_pipeline", dict(
        config=dict(N=N, E=E, K=K, T=T, hiddens=list(HS), steps=STEPS,
                    microbatches=MB),
        rows=rows, handle=handle_rows))
    return rows


if __name__ == "__main__":
    main()
