"""Per-stack layer probes for the scan-trip roofline correction.

XLA's cost_analysis counts a scan body ONCE (verified on this container), so
the dry-run lowers each homogeneous stack's body separately — forward+backward
for train (with rematerialization replayed via jax.checkpoint), plain forward
for decode — and the roofline computes

    total_term = program_term + sum_s (trips_s - 1) * body_term_s.

Each probe returns (name, trips, lowered) with shardings identical to the
in-model activations, so the probe HLO's collectives match the scan body's.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.models import transformer as T
from repro.models import hybrid as HY
from repro.models import encdec as ED
from repro.models import attention as ATT
from repro.models import mamba2 as SSM
from repro.models.config import ArchConfig
from repro.models.layers import rmsnorm
from repro.parallel.sharding import (ParamSpec, abstract_from_specs,
                                     arch_rules, DEFAULT_RULES)

_RULES = DEFAULT_RULES  # set per-arch by train_probes/serve_probes


def _x_spec(cfg, b, s):
    return ParamSpec((b, s, cfg.d_model), cfg.dtype, ("batch", None, None))


def _train_lower(fn, mesh, *specs):
    def probe(*args):
        def loss(*a):
            return jnp.sum(fn(*a).astype(jnp.float32))
        return jax.grad(jax.checkpoint(loss), argnums=tuple(range(len(args))))(*args)
    return jax.jit(probe).lower(*abstract_from_specs(list(specs), mesh, _RULES))


def _serve_lower(fn, mesh, *specs):
    return jax.jit(fn).lower(*abstract_from_specs(list(specs), mesh, _RULES))


def train_probes(cfg: ArchConfig, mesh, global_batch: int, seq: int):
    global _RULES
    _RULES = arch_rules(cfg)
    b = global_batch // max(cfg.microbatch, 1)
    xs = _x_spec(cfg, b, seq)
    out = []

    if cfg.family in ("lm", "vlm"):
        n_dense = cfg.moe.first_k_dense if cfg.moe else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.moe else 0
        if n_dense:
            ps = T.layer_spec(cfg, moe_layer=False)
            fn = lambda x, p: T.layer_apply(p, x, cfg, mesh)[0]
            out.append(("dense_layer", n_dense, _train_lower(fn, mesh, xs, ps)))
        if n_moe:
            ps = T.layer_spec(cfg, moe_layer=True)
            fn = lambda x, p: T.layer_apply(p, x, cfg, mesh)[0]
            out.append(("moe_layer", n_moe, _train_lower(fn, mesh, xs, ps)))
    elif cfg.family == "gemma3":
        loc, glob, n_super, tail = T._g3_counts(cfg)
        per = loc + glob
        ps = T._stack(T.layer_spec(cfg, moe_layer=False), per)

        def fn(x, p):
            for j in range(per):
                pj = jax.tree.map(lambda a: a[j], p)
                w = cfg.local_window if j < loc else None
                x, _, _ = T.layer_apply(pj, x, cfg, mesh, window=w)
            return x
        out.append(("super_block", n_super, _train_lower(fn, mesh, xs, ps)))
        if tail:
            pt = T.layer_spec(cfg, moe_layer=False)
            fnt = lambda x, p: T.layer_apply(p, x, cfg, mesh,
                                             window=cfg.local_window)[0]
            out.append(("tail_layer", tail, _train_lower(fnt, mesh, xs, pt)))
    elif cfg.family == "ssm":
        ps = dict(ln=T.rmsnorm_spec(cfg.d_model, cfg.dtype),
                  mamba=SSM.mamba_spec(cfg))
        fn = lambda x, p: x + SSM.mamba_block(
            p["mamba"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg, mesh)[0]
        out.append(("mamba_layer", cfg.num_layers, _train_lower(fn, mesh, xs, ps)))
    elif cfg.family == "hybrid":
        per, n_super, tail = HY._counts(cfg)
        ps = T._stack(HY._mamba_layer_spec(cfg), per)
        sh = HY._shared_block_spec(cfg)

        def fn(x, p, s):
            for j in range(per):
                pj = jax.tree.map(lambda a: a[j], p)
                x, _ = HY._mamba_apply(pj, x, cfg, mesh, None)
            x, _ = HY._shared_apply(s, x, cfg, mesh, None)
            return x
        out.append(("super_block", n_super, _train_lower(fn, mesh, xs, ps, sh)))
        if tail:
            pt = HY._mamba_layer_spec(cfg)
            fnt = lambda x, p: HY._mamba_apply(p, x, cfg, mesh, None)[0]
            out.append(("tail_mamba", tail, _train_lower(fnt, mesh, xs, pt)))
    elif cfg.family == "encdec":
        src = ParamSpec((b, cfg.src_len, cfg.d_model), cfg.dtype,
                        ("batch", None, None))
        pe = ED._enc_layer_spec(cfg)

        def fe(x, p):
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            a, _ = ATT.attention(p["attn"], h, cfg, mesh, window=None, causal=False)
            x = x + a
            h = rmsnorm(x, p["ln2"], cfg.norm_eps)
            from repro.models.layers import ffn_apply
            return x + ffn_apply(p["ffn"], h, cfg.act)
        out.append(("enc_layer", cfg.enc_layers, _train_lower(fe, mesh, src, pe)))
        pd = ED._dec_layer_spec(cfg)
        fd = lambda x, p, mem: ED._dec_layer(p, x, cfg, mesh, mem, None)[0]
        out.append(("dec_layer", cfg.dec_layers, _train_lower(fd, mesh, xs, pd, src)))
    return out


def serve_probes(cfg: ArchConfig, mesh, batch: int, kv_len: int, *, long=False):
    global _RULES
    _RULES = arch_rules(cfg)
    xs = _x_spec(cfg, batch, 1)
    out = []

    if cfg.family in ("lm", "vlm"):
        n_dense = cfg.moe.first_k_dense if cfg.moe else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.moe else 0
        from repro.models import mla as MLAM
        mk = (MLAM.mla_cache_spec if (cfg.attn and cfg.attn.kind == "mla")
              else ATT.kv_cache_spec)
        cs = mk(cfg, batch, kv_len, long=long)
        fn = lambda x, p, c: T.layer_apply(p, x, cfg, mesh, cache=c)[:2]
        if n_dense:
            ps = T.layer_spec(cfg, moe_layer=False)
            out.append(("dense_layer", n_dense, _serve_lower(fn, mesh, xs, ps, cs)))
        if n_moe:
            ps = T.layer_spec(cfg, moe_layer=True)
            out.append(("moe_layer", n_moe, _serve_lower(fn, mesh, xs, ps, cs)))
    elif cfg.family == "gemma3":
        loc, glob, n_super, tail = T._g3_counts(cfg)
        wlen = min(cfg.local_window, kv_len)
        ps = T.layer_spec(cfg, moe_layer=False)
        cl = ATT.kv_cache_spec(cfg, batch, wlen)
        fn_l = lambda x, p, c: T._ring_local_decode(p, x, cfg, mesh, c, wlen)[:2]
        out.append(("local_layer", loc * n_super + tail,
                    _serve_lower(fn_l, mesh, xs, ps, cl)))
        cg = ATT.kv_cache_spec(cfg, batch, kv_len, long=long)
        fn_g = lambda x, p, c: T.layer_apply(p, x, cfg, mesh, cache=c,
                                             window=None)[:2]
        out.append(("global_layer", glob * n_super,
                    _serve_lower(fn_g, mesh, xs, ps, cg)))
    elif cfg.family == "ssm":
        ps = dict(ln=T.rmsnorm_spec(cfg.d_model, cfg.dtype),
                  mamba=SSM.mamba_spec(cfg))
        cs = SSM.ssm_cache_spec(cfg, batch)

        def fn(x, p, c):
            y, c2 = SSM.mamba_block(p["mamba"], rmsnorm(x, p["ln"], cfg.norm_eps),
                                    cfg, mesh, cache=c)
            return x + y, c2
        out.append(("mamba_layer", cfg.num_layers, _serve_lower(fn, mesh, xs, ps, cs)))
    elif cfg.family == "hybrid":
        per, n_super, tail = HY._counts(cfg)
        pm = HY._mamba_layer_spec(cfg)
        cm = SSM.ssm_cache_spec(cfg, batch)
        fm = lambda x, p, c: HY._mamba_apply(p, x, cfg, mesh, c)
        out.append(("mamba_layer", per * n_super + tail,
                    _serve_lower(fm, mesh, xs, pm, cm)))
        sh = HY._shared_block_spec(cfg)
        ca = ATT.kv_cache_spec(cfg, batch, kv_len, long=long)
        fs = lambda x, p, c: HY._shared_apply(p, x, cfg, mesh, c)
        out.append(("shared_attn", n_super, _serve_lower(fs, mesh, xs, sh, ca)))
    elif cfg.family == "encdec":
        pd = ED._dec_layer_spec(cfg)
        cs = ATT.kv_cache_spec(cfg, batch, kv_len, long=long)
        mem = ParamSpec((batch, cfg.src_len, cfg.d_model), cfg.dtype,
                        ("batch", None, None))
        fd = lambda x, p, m, c: ED._dec_layer(p, x, cfg, mesh, m, c)
        out.append(("dec_layer", cfg.dec_layers,
                    _serve_lower(fd, mesh, xs, pd, mem, cs)))
    return out
