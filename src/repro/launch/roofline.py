"""Roofline analysis from the dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs / (chips * 197e12)        [bf16 peak]
  memory     = HLO_bytes / (chips * 819e9)         [HBM]
  collective = collective_bytes / (chips * 50e9)   [single ICI link, per spec]

HLO terms are scan-trip corrected: total = program + sum_s (trips_s-1)*body_s
(cost_analysis counts a while-loop body once; see docs/DESIGN.md §6). cost_analysis
FLOPs/bytes are PER-DEVICE on this backend (verified numerically), collective
bytes are parsed per-module (whole-program scope) — so the collective term
divides by 1, not by chips: the parse already yields per-device traffic
because every rank executes the same SPMD module.

MODEL_FLOPS = 6*N_params*D_tokens (dense) or 6*N_active*D (MoE); the ratio to
(3x for train: fwd+bwd) HLO FLOPs flags remat/redundancy waste.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16] [--csv]
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK = 197e12
HBM = 819e9
LINK = 50e9

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# approximate parameter counts (embedding included once) and active-param
# counts for the MoE archs, used for the MODEL_FLOPS sanity ratio
PARAMS = {  # total, active (B)
    "minicpm3-4b": (4.0e9, 4.0e9),
    "internlm2-20b": (20e9, 20e9),
    "gemma3-27b": (27e9, 27e9),
    "chatglm3-6b": (6.2e9, 6.2e9),
    "deepseek-v3-671b": (671e9, 37e9),
    "dbrx-132b": (132e9, 36e9),
    "phi-3-vision-4.2b": (4.2e9, 4.2e9),
    "zamba2-7b": (7.4e9, 7.4e9),
    "seamless-m4t-large-v2": (2.3e9, 2.3e9),
    "mamba2-780m": (0.78e9, 0.78e9),
}


def corrected_terms(rec: dict) -> dict:
    """Scan-trip-corrected per-device flops/bytes/collective-bytes."""
    p = rec["program"]
    flops = p["cost"].get("flops", 0.0)
    mem_b = p["cost"].get("bytes accessed", 0.0)
    coll = p["collectives"].get("total", 0)
    # microbatch scan: the grad-accumulation loop body is ALSO counted once;
    # multiply whole-program layer terms by microbatch trips first.
    g = max(rec.get("microbatch", 1), 1)
    for st in rec.get("stacks", []):
        t = (st["trips"] * g) - 1
        flops += t * st["cost"].get("flops", 0.0)
        mem_b += t * st["cost"].get("bytes accessed", 0.0)
        coll += t * st["collectives"].get("total", 0)
    return dict(flops=flops, hbm_bytes=mem_b, coll_bytes=coll)


def analyze(rec: dict) -> dict:
    chips = 1
    for s in rec["mesh"]:
        chips *= s
    t = corrected_terms(rec)
    terms = dict(
        compute_s=t["flops"] / PEAK,
        memory_s=t["hbm_bytes"] / HBM,
        collective_s=t["coll_bytes"] / LINK,
    )
    dom = max(terms, key=terms.get)
    total, active = PARAMS[rec["arch"]]
    if rec["kind"] == "train":
        # 6*N*D counts fwd (2ND) + bwd (4ND); do NOT multiply again.
        tokens = rec["global_batch"] * rec["seq"]
        model_flops = 6 * active * tokens / chips
    else:
        tokens = rec["global_batch"] * 1
        model_flops = 2 * active * tokens / chips
    ratio = model_flops / max(t["flops"], 1.0)
    bound = max(terms.values())
    # Roofline fraction = (irreducible time) / (modeled time):
    #  train  -> MFU-like: model-FLOPs time vs the dominating term;
    #  decode -> BW utilization: the ideal read set is exactly the step's
    #            arguments (params + caches, each read once per token) over
    #            the modeled HBM traffic.
    if rec["kind"] == "train":
        ideal = model_flops / PEAK
    else:
        arg_bytes = rec["program"]["memory"].get("argument_size_in_bytes", 0)
        ideal = max(arg_bytes / HBM, model_flops / PEAK)
    return dict(
        arch=rec["arch"], shape=rec["shape"], chips=chips, kind=rec["kind"],
        flops_per_dev=t["flops"], hbm_bytes_per_dev=t["hbm_bytes"],
        coll_bytes_per_dev=t["coll_bytes"], **{k: round(v, 6) for k, v in terms.items()},
        dominant=dom.replace("_s", ""),
        model_flops_per_dev=model_flops,
        useful_flops_ratio=round(ratio, 3),
        roofline_fraction=round(ideal / bound, 4) if bound > 0 else None,
        hbm_gib_per_dev=round(
            (rec["program"]["memory"].get("argument_size_in_bytes", 0) +
             rec["program"]["memory"].get("temp_size_in_bytes", 0)) / 2**30, 2),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--dir", default=None, help="explicit results directory")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = []
    src = pathlib.Path(args.dir) if args.dir else (RESULTS / args.mesh)
    for f in sorted(src.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                             dominant="SKIP", note=rec["reason"][:60]))
            continue
        if "program" not in rec:
            continue
        rows.append(analyze(rec))
    cols = ["arch", "shape", "dominant", "compute_s", "memory_s",
            "collective_s", "roofline_fraction", "useful_flops_ratio",
            "hbm_gib_per_dev"]
    if args.csv:
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))
    else:
        w = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
        print("  ".join(c.ljust(w[c]) for c in cols))
        for r in rows:
            print("  ".join(str(r.get(c, "")).ljust(w[c]) for c in cols))
    if args.json_out:
        pathlib.Path(args.json_out).write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
