"""Perf-iteration harness (§Perf hillclimbing): rerun one dry-run cell with a
named config variant and record the roofline deltas.

  PYTHONPATH=src python -m repro.launch.perf --arch deepseek-v3-671b \
      --shape train_4k --variant flat_ht

Results land in results/perf/<arch>__<shape>__<variant>.json; compare with
`python -m repro.launch.perf --report --arch ... --shape ...`.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse    # noqa: E402
import dataclasses  # noqa: E402
import json        # noqa: E402
import pathlib     # noqa: E402
import time        # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "perf"


def _moe(cfg, **kw):
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **kw))


def _no_chunked_attn(cfg):
    from repro.models import attention as A
    A.CHUNKED_ATTN_THRESHOLD = 10 ** 9      # module-level switch
    return cfg


def _chunk_size(n):
    def t(cfg):
        return dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn, kv_chunk=n))
    return t


TRANSFORMS = {
    "current": lambda cfg: cfg,                       # whatever HEAD does now
    "no_chunked_attn": _no_chunked_attn,              # dense-score attention
    "kv_chunk_512": _chunk_size(512),
    "kv_chunk_2048": _chunk_size(2048),
    "flat_ht": lambda cfg: _moe(cfg, ht_hierarchical=False),
    "hier_ht": lambda cfg: _moe(cfg, ht_hierarchical=True),
    "fp8_dispatch": lambda cfg: _moe(cfg, quantize_dispatch=True),
    "bf16_dispatch": lambda cfg: _moe(cfg, quantize_dispatch=False),
    "cf_100": lambda cfg: _moe(cfg, capacity_factor=1.0,
                               expert_capacity_factor=1.0),
    "cf_200": lambda cfg: _moe(cfg, capacity_factor=2.0,
                               expert_capacity_factor=2.0),
    "ll_deepep": lambda cfg: _moe(cfg, ll_layout="deepep"),
    "ep_baseline": lambda cfg: _moe(cfg, ep_mode="baseline"),
    "mtp_off": lambda cfg: dataclasses.replace(cfg, mtp=False),
    "remat_off": lambda cfg: dataclasses.replace(cfg, remat=False),
    "micro_x2": lambda cfg: dataclasses.replace(cfg, microbatch=cfg.microbatch * 2),
    "micro_half": lambda cfg: dataclasses.replace(
        cfg, microbatch=max(cfg.microbatch // 2, 1)),
}


def main():
    from repro.launch.dryrun import run_cell
    from repro.launch.roofline import analyze

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="current")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    if args.report:
        rows = []
        for f in sorted(RESULTS.glob(f"{args.arch}__{args.shape}__*.json")):
            rec = json.loads(f.read_text())
            a = analyze(rec)
            a["variant"] = f.stem.split("__")[-1]
            rows.append(a)
        cols = ["variant", "dominant", "compute_s", "memory_s",
                "collective_s", "roofline_fraction", "hbm_gib_per_dev"]
        w = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
        print("  ".join(c.ljust(w[c]) for c in cols))
        for r in rows:
            print("  ".join(str(r.get(c, "")).ljust(w[c]) for c in cols))
        return

    t0 = time.time()
    rec = run_cell(args.arch, args.shape, args.multi_pod,
                   transform=TRANSFORMS[args.variant])
    rec["variant"] = args.variant
    rec["wall_s"] = round(time.time() - t0, 1)
    out = RESULTS / f"{args.arch}__{args.shape}__{args.variant}.json"
    out.write_text(json.dumps(rec, indent=1))
    a = analyze(rec)
    print(f"[perf] {args.arch} {args.shape} {args.variant}: "
          f"dominant={a['dominant']} compute={a['compute_s']} "
          f"memory={a['memory_s']} collective={a['collective_s']} "
          f"fraction={a['roofline_fraction']} hbm={a['hbm_gib_per_dev']}GiB")


if __name__ == "__main__":
    main()
