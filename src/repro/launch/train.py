"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b --smoke \
      --steps 100 --global-batch 8 --seq 128 --mesh 4x2 --ckpt /tmp/ckpt

--smoke uses the reduced config (CPU-runnable); without it the full published
config is used (needs real accelerators). --resume auto restarts from the
latest checkpoint — the preemption/restart path."""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def parse_mesh(s: str | None):
    if not s:
        return None
    dims = [int(x) for x in s.split("x")]
    axes = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
        ("pod", "data", "model")
    return jax.make_mesh(tuple(dims), axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(dims))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 -> (data,model)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch, "train_4k")
    mesh = parse_mesh(args.mesh)
    t = Trainer(cfg, TrainerConfig(
        steps=args.steps, global_batch=args.global_batch, seq_len=args.seq,
        ckpt_dir=args.ckpt),
        mesh=mesh,
        opt_cfg=AdamWConfig(lr=args.lr, total_steps=args.steps,
                            warmup_steps=max(args.steps // 20, 1)))
    t.run()


if __name__ == "__main__":
    main()
