"""Serving launcher: batched greedy decode through the LL EP path.

  PYTHONPATH=src python -m repro.launch.serve --arch dbrx-132b --smoke \
      --batch 8 --prompt-len 16 --gen 32 --mesh 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.launch.train import parse_mesh
from repro.runtime.server import DecodeServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch, "decode_32k")
    mesh = parse_mesh(args.mesh)
    srv = DecodeServer(cfg, batch=args.batch,
                       max_len=args.prompt_len + args.gen + 8, mesh=mesh)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    m = srv.serve(prompts, gen_steps=args.gen)
    print(f"output_tok_s={m.output_tok_s:.1f} ttft_ms={m.ttft_s*1e3:.1f} "
          f"itl_mean_ms={m.itl_mean_s*1e3:.2f} itl_p99_ms={m.itl_p99_s*1e3:.2f}")


if __name__ == "__main__":
    main()
