"""Kernel-corrected memory term for attention-heavy cells.

Measures the attention region's fusion-blind byte charge by differencing two
single-layer lowerings (full layer vs layer with the attention sublayer
replaced by identity), then replaces it with the flash kernel's definitional
Q+K+V+O traffic. Reported alongside the measured term in docs/EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.attn_correction --arch minicpm3-4b \
      --shape prefill_32k
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse   # noqa: E402
import json       # noqa: E402
import pathlib    # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, SHAPES                 # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.hlo_analysis import cost_dict              # noqa: E402
from repro.launch.probes import _x_spec, _train_lower        # noqa: E402
from repro.models import transformer as T                    # noqa: E402
from repro.models.layers import rmsnorm, ffn_apply           # noqa: E402

HBM = 819e9


def measure(arch: str, shape: str):
    cfg = get_config(arch, shape)
    mesh = make_production_mesh()
    seq, gbatch, kind = SHAPES[shape]
    b = gbatch // max(cfg.microbatch, 1)
    xs = _x_spec(cfg, b, seq)
    ps = T.layer_spec(cfg, moe_layer=False)

    full = lambda x, p: T.layer_apply(p, x, cfg, mesh)[0]

    def no_attn(x, p):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + h                                   # attention -> identity
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + ffn_apply(p["ffn"], h, cfg.act)

    cost_full = cost_dict(_train_lower(full, mesh, xs, ps).compile())
    cost_na = cost_dict(_train_lower(no_attn, mesh, xs, ps).compile())
    attn_bytes = cost_full["bytes accessed"] - cost_na["bytes accessed"]

    # flash-kernel traffic for the attention region (per device, fwd+bwd~3x):
    # Q,O: [b_loc, S, H_loc, hd]; K,V (MLA: ckv+rope, read twice for dQ/dKV)
    chips_b = 16                         # batch over data
    chips_h = 16                         # heads over model
    hq = cfg.padded_heads() // chips_h
    if cfg.mla:
        per_tok_kv = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
        per_tok_q = hq * (cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim)
        per_tok_o = hq * cfg.mla.v_head_dim
    else:
        per_tok_kv = 2 * cfg.attn.n_kv * cfg.attn.head_dim
        per_tok_q = hq * cfg.attn.head_dim
        per_tok_o = per_tok_q
    b_loc = max(b // chips_b, 1)
    flash_bytes = 3 * 2 * b_loc * seq * (per_tok_q + per_tok_kv + per_tok_o)

    return dict(
        arch=arch, shape=shape,
        layer_bytes=cost_full["bytes accessed"],
        layer_bytes_no_attn=cost_na["bytes accessed"],
        attn_region_bytes=attn_bytes,
        flash_kernel_bytes=flash_bytes,
        per_layer_saving_bytes=attn_bytes - flash_bytes,
        layers=cfg.num_layers,
        memory_term_saving_s=round(
            cfg.num_layers * max(cfg.microbatch, 1) *
            (attn_bytes - flash_bytes) / HBM, 2),
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm3-4b")
    ap.add_argument("--shape", default="prefill_32k")
    a = ap.parse_args()
    out = measure(a.arch, a.shape)
    print(json.dumps(out, indent=1))
    p = pathlib.Path(__file__).resolve().parents[3] / "results" / "perf"
    p.mkdir(parents=True, exist_ok=True)
    (p / f"attn_correction__{a.arch}__{a.shape}.json").write_text(
        json.dumps(out, indent=1))
