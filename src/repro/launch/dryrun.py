"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k

Results land incrementally in results/dryrun/<mesh>/<arch>__<shape>.json.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse    # noqa: E402
import json        # noqa: E402
import pathlib     # noqa: E402
import time        # noqa: E402
import traceback   # noqa: E402

import jax         # noqa: E402

from repro.configs import SHAPES, ARCH_IDS, LONG_OK, get_config   # noqa: E402
from repro.launch.mesh import make_production_mesh                # noqa: E402
from repro.launch.hlo_analysis import (collective_bytes, cost_dict,  # noqa: E402
                                       memory_dict)
from repro.launch import probes as PR                             # noqa: E402
from repro.models import get_model                                # noqa: E402
from repro.optim import AdamWConfig, adamw_init_specs             # noqa: E402
from repro.parallel.sharding import abstract_from_specs, arch_rules  # noqa: E402
from repro.runtime.steps import (make_train_step, make_serve_step,  # noqa: E402
                                 train_batch_specs, serve_state_specs)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def input_specs(arch: str, shape: str, mesh, transform=None):
    """ShapeDtypeStruct stand-ins for every step input (no allocation).
    ``transform`` (perf iterations) may rewrite the ArchConfig."""
    cfg = get_config(arch, shape)
    if transform is not None:
        cfg = transform(cfg)
    model = get_model(cfg)
    rules = arch_rules(cfg)
    seq, gbatch, kind = SHAPES[shape]
    pspecs = model.params_spec(cfg)
    params = abstract_from_specs(pspecs, mesh, rules)
    if kind == "train":
        opt = abstract_from_specs(
            adamw_init_specs(pspecs, _opt_cfg(arch)), mesh, rules)
        batch = abstract_from_specs(train_batch_specs(cfg, gbatch, seq), mesh,
                                    rules)
        return cfg, dict(params=params, opt_state=opt, batch=batch)
    long = shape == "long_500k"
    st_spec, tok_spec = serve_state_specs(cfg, gbatch, seq, long=long)
    state = abstract_from_specs(st_spec, mesh, rules)
    batch = abstract_from_specs(tok_spec, mesh, rules)
    return cfg, dict(params=params, state=state, batch=batch)


def _opt_cfg(arch: str) -> AdamWConfig:
    import jax.numpy as jnp
    # 671B-class: bf16 moments so single-pod HBM holds the state (DESIGN §7)
    dt = jnp.bfloat16 if arch == "deepseek-v3-671b" else jnp.float32
    return AdamWConfig(state_dtype=dt)


def _analyze(lowered, t_lower):
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = memory_dict(compiled)
    cost = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return compiled, dict(memory=mem, cost=cost, collectives=coll,
                          lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))


def run_cell(arch: str, shape: str, multi_pod: bool, with_probes: bool = True,
             transform=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq, gbatch, kind = SHAPES[shape]
    cfg, specs = input_specs(arch, shape, mesh, transform)
    rec = dict(arch=arch, shape=shape, mesh=list(mesh.shape.values()),
               axes=list(mesh.shape.keys()), kind=kind,
               seq=seq, global_batch=gbatch, num_layers=cfg.num_layers,
               microbatch=cfg.microbatch)

    t0 = time.time()
    if kind == "train":
        step = make_train_step(cfg, mesh, _opt_cfg(arch))
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            specs["params"], specs["opt_state"], specs["batch"])
    else:
        step = make_serve_step(cfg, mesh)
        lowered = jax.jit(step, donate_argnums=(1,)).lower(
            specs["params"], specs["state"], specs["batch"])
    _, rec["program"] = _analyze(lowered, time.time() - t0)

    if with_probes:
        rec["stacks"] = []
        t0 = time.time()
        if kind == "train":
            b = gbatch
            pr = PR.train_probes(cfg, mesh, b, seq)
        else:
            pr = PR.serve_probes(cfg, mesh, gbatch, seq,
                                 long=(shape == "long_500k"))
        for name, trips, plow in pr:
            _, a = _analyze(plow, 0.0)
            a["name"], a["trips"] = name, trips
            rec["stacks"].append(a)
        rec["probe_s"] = round(time.time() - t0, 1)
    return rec


def cell_path(arch, shape, multi_pod) -> pathlib.Path:
    mdir = "pod2x16x16" if multi_pod else "pod16x16"
    d = RESULTS / mdir
    d.mkdir(parents=True, exist_ok=True)
    return d / f"{arch}__{shape}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    cells = []
    for a in (ARCH_IDS if args.all or not args.arch else [args.arch]):
        for s in (SHAPES if args.all or not args.shape else [args.shape]):
            cells.append((a, s))

    for arch, shape in cells:
        out = cell_path(arch, shape, args.multi_pod)
        if out.exists() and not args.force:
            print(f"[skip] {out.name} exists")
            continue
        if shape == "long_500k" and arch not in LONG_OK:
            rec = dict(arch=arch, shape=shape, skipped=True,
                       reason="pure full-attention arch: long_500k skipped "
                              "per assignment (see docs/DESIGN.md §5)")
            out.write_text(json.dumps(rec, indent=1))
            print(f"[SKIP-noted] {arch} {shape}")
            continue
        t0 = time.time()
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           with_probes=not args.no_probes)
            rec["wall_s"] = round(time.time() - t0, 1)
            out.write_text(json.dumps(rec, indent=1))
            m = rec["program"]["memory"]
            per_dev = (m.get("argument_size_in_bytes", 0) +
                       m.get("temp_size_in_bytes", 0)) / 2**30
            print(f"[ok] {arch} {shape} mesh={'2x16x16' if args.multi_pod else '16x16'} "
                  f"args+temp/dev={per_dev:.2f}GiB flops/dev={rec['program']['cost'].get('flops', 0):.3e} "
                  f"coll={rec['program']['collectives'].get('total', 0):.3e}B "
                  f"wall={rec['wall_s']}s")
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            rec = dict(arch=arch, shape=shape, error=str(e)[:2000],
                       traceback=traceback.format_exc()[-4000:])
            out.with_suffix(".err.json").write_text(json.dumps(rec, indent=1))
            print(f"[FAIL] {arch} {shape}: {str(e)[:200]}")


if __name__ == "__main__":
    main()
