"""HLO-text analysis: collective-byte accounting for the roofline.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective traffic, so
we parse the compiled module text and sum the result sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute op
(result size ~= operand size for these ops, within (N-1)/N). While-loop
(scan) bodies appear once in the text — the caller multiplies per-stack terms
by trip counts, mirroring the cost_analysis correction (docs/DESIGN.md §6).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: bytes, ..., "total": bytes, "count": n_ops}."""
    out = defaultdict(int)
    count = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # avoid double counting async pairs
        b = _shape_bytes(shape_str)
        out[kind] += b
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["count"] = count
    return dict(out)


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "optimal_seconds", "utilization")}


def memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = ["generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes"]
    return {k: int(getattr(ma, k, 0)) for k in keys}
