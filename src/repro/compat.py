"""JAX version compatibility shims.

The codebase is written against the modern JAX surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``). Older
runtimes (<= 0.4.x) ship the same functionality under experimental or
reduced signatures. ``install()`` — called once from ``repro.__init__`` —
back-fills the missing attributes in place so every call site (library,
tests, benchmarks, examples) runs unchanged on either version. On a modern
JAX it is a no-op.
"""
from __future__ import annotations

import functools

import jax


def _axis_type_stub():
    class AxisType:  # minimal stand-in for jax.sharding.AxisType
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    return AxisType


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _axis_type_stub()

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, *args, **kw):
            # Old check_rep chokes on nested-jit ops over replicated values
            # (e.g. jnp.argsort of a broadcast iota); modern JAX removed the
            # flag. Default it off for parity with current semantics.
            kw.setdefault("check_rep", False)
            return _shard_map(f, *args, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            # pre-0.4.38 spelling; constant-folds to the mapped axis extent
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size

    # Old jax.make_mesh lacks the axis_types kwarg; accept and drop it.
    # (Feature-test via the signature — building a probe mesh would force
    # backend initialization as a side effect of `import repro`.)
    import inspect

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
            del axis_types
            return _orig_make_mesh(axis_shapes, axis_names, *args, **kw)

        jax.make_mesh = make_mesh
