"""Sharded checkpointing with elastic restore.

Format: one .npy per pytree leaf (written from the addressable host view) +
a JSON index carrying the tree structure, dtypes, mesh metadata, and step.
Restore re-shards onto WHATEVER mesh the restoring process provides — the
elastic path for scale-up/scale-down and failed-node replacement: leaves are
loaded host-side and device_put with the new sharding.

(On a real multi-host pod each host writes its addressable shards and the
index records the global shape; this container is single-host so the "shard"
is the whole array — the reshard logic is identical either way.)

EPLB interplay (`core/placement.py`): expert-stacked weights are stored in
LOGICAL [E, ...] order — placements rebind them to physical slot order
in-graph — so checkpoints are placement-independent by default and a restart
may adopt any placement. For engines that persist the *physical* layout
(replicated hot experts on their serving ranks), ``rebind_expert_leaves``
converts expert leaves between placements at restore time: collapse the
source placement's replicas to logical weights (primary replica), then
expand for the destination placement — the elastic-EPLB analogue of the
mesh reshard this module already does.
"""
from __future__ import annotations

import json
import pathlib
import re
import time

import jax
import ml_dtypes
import numpy as np

from repro.core import placement as PL
from repro.parallel.sharding import ParamSpec, spec_to_named_sharding

# numpy can't serialize ml_dtypes natively: store raw integer views + the
# logical dtype name in the index, re-view on restore.
_ML_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _ML_DTYPES:
        return arr.view(_ML_DTYPES[name][1]), name
    return arr, name


def _from_savable(arr: np.ndarray, name: str):
    if name in _ML_DTYPES:
        return arr.view(_ML_DTYPES[name][0])
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def rebind_expert_leaves(tree, expert_keys, src_placement=None,
                         dst_placement=None):
    """Replica-aware expert-weight rebinding between placements.

    Leaves whose dict key is in ``expert_keys`` (e.g. ``w_gate``/``w_up``/
    ``w_down``) carry a leading expert axis laid out by ``src_placement``
    (None = logical [E, ...] order) and are re-gathered for
    ``dst_placement`` (None = back to logical). Replicas of one expert hold
    identical weights by construction, so collapsing reads the primary
    replica and expanding duplicates — a rebalance that moves or replicates
    an expert never loses weight state. All other leaves pass through
    untouched."""
    keys = set(expert_keys)

    def rebind(path, leaf):
        name = next((p.key for p in reversed(path)
                     if isinstance(p, jax.tree_util.DictKey)), None)
        if name not in keys:
            return leaf
        w = leaf
        if src_placement is not None:
            w = PL.collapse_expert_params(w, src_placement)
        if dst_placement is not None:
            w = PL.expand_expert_params(w, dst_placement)
        return w

    return jax.tree_util.tree_map_with_path(rebind, tree)


def save_checkpoint(ckpt_dir, step: int, tree, *, extra: dict | None = None):
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    tmp.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    index = dict(step=step, n_leaves=len(leaves),
                 treedef=str(treedef), time=time.time(), extra=extra or {})
    shapes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        sav, name = _to_savable(arr)
        np.save(tmp / f"leaf_{i:05d}.npy", sav)
        shapes.append([list(arr.shape), name])
    index["shapes"] = shapes
    (tmp / "index.json").write_text(json.dumps(index))
    # atomic publish: rename tmp -> final (crash-safe)
    if d.exists():
        import shutil
        shutil.rmtree(d)
    tmp.rename(d)
    return d


def latest_step(ckpt_dir) -> int | None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := re.match(r"step_(\d+)$", p.name))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, step: int, target_tree, *, mesh=None,
                       rules=None):
    """target_tree: pytree of arrays OR ParamSpec (for sharding metadata).
    Elastic: the mesh may differ from the one that wrote the checkpoint."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    index = json.loads((d / "index.json").read_text())
    is_leaf = lambda x: isinstance(x, ParamSpec)
    leaves, treedef = jax.tree.flatten(target_tree, is_leaf=is_leaf)
    assert len(leaves) == index["n_leaves"], \
        f"leaf count mismatch: {len(leaves)} vs {index['n_leaves']}"
    out = []
    for i, tgt in enumerate(leaves):
        arr = _from_savable(np.load(d / f"leaf_{i:05d}.npy"),
                            index["shapes"][i][1])
        if isinstance(tgt, ParamSpec):
            if mesh is not None:
                from repro.parallel.sharding import DEFAULT_RULES
                sh = spec_to_named_sharding(tgt, mesh, rules or DEFAULT_RULES)
                out.append(jax.device_put(arr.astype(tgt.dtype), sh))
            else:
                out.append(jax.numpy.asarray(arr, tgt.dtype))
        else:
            x = jax.numpy.asarray(arr, tgt.dtype)
            if hasattr(tgt, "sharding") and mesh is not None:
                x = jax.device_put(x, tgt.sharding)
            out.append(x)
    return jax.tree.unflatten(treedef, out), index
