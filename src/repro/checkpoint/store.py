"""Sharded checkpointing with elastic restore.

Format: one .npy per pytree leaf (written from the addressable host view) +
a JSON index carrying the tree structure, dtypes, mesh metadata, and step.
Restore re-shards onto WHATEVER mesh the restoring process provides — the
elastic path for scale-up/scale-down and failed-node replacement: leaves are
loaded host-side and device_put with the new sharding.

(On a real multi-host pod each host writes its addressable shards and the
index records the global shape; this container is single-host so the "shard"
is the whole array — the reshard logic is identical either way.)

EPLB interplay (`core/placement.py`): expert-stacked weights are stored in
LOGICAL [E, ...] order by default — training rebinds them to physical slot
order in-graph — so checkpoints are placement-independent and a restart may
adopt any placement. Serving engines that adopt placements once
(``MoESpec.params_physical``) persist the *physical* layout instead:
``save_checkpoint(..., placement=...)`` records the placement table +
fingerprint in the index, and ``restore_checkpoint(..., placement=...)``
validates the fingerprint against the requested layout and rebinds on
mismatch (collapse the stored placement's replicas to logical via the
primary replica, then expand for the requested placement — the elastic-EPLB
analogue of the mesh reshard this module already does). ``rebind_expert_
leaves`` / ``adopt_expert_params`` are the standalone rebinds the runtime
drivers use at adoption boundaries (old physical -> new physical, device
buffers donated so peak memory stays ~one set of expert weights).

Dtype hygiene: restore never routes a pure-host numpy leaf through
``jax.numpy.asarray`` (x64 counters would be silently truncated on x32
runtimes — the trainer's step/seed leaves and drained float64 heat totals
stay numpy), and device-leaf target dtypes are canonicalized with
``jax.dtypes.canonicalize_dtype`` so an x64 host dtype in a target spec
restores cleanly instead of emitting a truncation warning.
"""
from __future__ import annotations

import functools
import json
import pathlib
import re
import time

import jax
import ml_dtypes
import numpy as np

from repro.core import placement as PL
from repro.parallel.sharding import ParamSpec, spec_to_named_sharding

# numpy can't serialize ml_dtypes natively: store raw integer views + the
# logical dtype name in the index, re-view on restore.
_ML_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _ML_DTYPES:
        return arr.view(_ML_DTYPES[name][1]), name
    return arr, name


def _from_savable(arr: np.ndarray, name: str):
    if name in _ML_DTYPES:
        return arr.view(_ML_DTYPES[name][0])
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


# canonical in core/placement.py; re-exported here because the checkpoint
# surface is where callers meet the rebinding helpers
EXPERT_PARAM_KEYS = PL.EXPERT_PARAM_KEYS


def _leaf_name(path):
    """Innermost dict key on a tree path — the single definition the
    save-time layout check, restore-time rebind, and rebind_expert_leaves
    all share, so they can never disagree on which leaves are expert
    weights."""
    return next((p.key for p in reversed(path)
                 if isinstance(p, jax.tree_util.DictKey)), None)


def _same_layout(src_placement, dst_placement) -> bool:
    if src_placement is dst_placement:
        return True
    if src_placement is None or dst_placement is None:
        # None = logical order; only an identity table matches it exactly
        other = src_placement if dst_placement is None else dst_placement
        return other.is_identity()
    return src_placement.slot_expert == dst_placement.slot_expert


@functools.lru_cache(maxsize=8)
def _donating_rebind(src_placement, dst_placement, axis: int):
    """Jitted physical->physical rebind with input-buffer donation: the old
    layout's buffer is reused for the new one, so an adoption boundary holds
    ~one set of expert weights plus one leaf in flight, never two full sets.
    Donation requires shape preservation — when the slot count changes
    (e.g. a different redundant-slot budget) XLA cannot alias the buffers,
    so we skip the donation flag rather than warn; the old buffer still
    frees at its last use. Cached per (src, dst, axis) — placements are
    hashable — and bounded, so a long-lived rebalancing server cannot
    accumulate compiled rebinds."""
    any_pl = src_placement or dst_placement
    in_rows = (src_placement.num_slots if src_placement
               else any_pl.num_experts if any_pl else None)
    out_rows = (dst_placement.num_slots if dst_placement
                else any_pl.num_experts if any_pl else None)
    same_rows = in_rows is not None and in_rows == out_rows

    def f(w):
        if src_placement is not None:
            w = PL.collapse_expert_params(w, src_placement, axis)
        if dst_placement is not None:
            w = PL.expand_expert_params(w, dst_placement, axis)
        return w
    return jax.jit(f, donate_argnums=(0,) if same_rows else ())


def _structural(placement):
    """Placement canonicalized to its table content (version stripped): the
    rebind computation reads only the table, and the scheduler bumps the
    version on every changed table — keying compiled rebinds on the full
    object would therefore never cache-hit across adoption boundaries."""
    import dataclasses
    if placement is None or placement.version == 0:
        return placement
    return dataclasses.replace(placement, version=0)


def _rebind_leaf(w, src_placement, dst_placement, axis: int, donate: bool):
    if _same_layout(src_placement, dst_placement):
        return w
    if donate and not isinstance(w, (np.ndarray, np.generic)):
        return _donating_rebind(_structural(src_placement),
                                _structural(dst_placement), axis)(w)
    if src_placement is not None:
        w = PL.collapse_expert_params(w, src_placement, axis)
    if dst_placement is not None:
        w = PL.expand_expert_params(w, dst_placement, axis)
    return w


def rebind_expert_leaves(tree, expert_keys=EXPERT_PARAM_KEYS,
                         src_placement=None, dst_placement=None, *,
                         axis: int = 0, donate: bool = False):
    """Replica-aware expert-weight rebinding between placements.

    Leaves whose dict key is in ``expert_keys`` (e.g. ``w_gate``/``w_up``/
    ``w_down``) carry an expert axis (``axis``) laid out by ``src_placement``
    (None = logical [E, ...] order) and are re-gathered for
    ``dst_placement`` (None = back to logical). Replicas of one expert hold
    identical weights by construction, so collapsing reads the primary
    replica and expanding duplicates — a rebalance that moves or replicates
    an expert never loses weight state. All other leaves pass through
    untouched. ``donate=True`` routes device leaves through a jitted rebind
    that donates the source buffer (the adopt-once serving path); numpy
    leaves always rebind host-side."""
    keys = set(expert_keys)

    def rebind(path, leaf):
        name = _leaf_name(path)
        if name not in keys:
            return leaf
        return _rebind_leaf(leaf, src_placement, dst_placement, axis, donate)

    return jax.tree_util.tree_map_with_path(rebind, tree)


def adopt_expert_params(params, specs, src_placement=None, dst_placement=None,
                        *, donate: bool = True):
    """Adopt-once rebinding over a FULL model parameter tree: every leaf
    whose ``ParamSpec`` names an ``"expert"`` logical axis is rebound from
    ``src_placement``'s physical slot order to ``dst_placement``'s along
    that axis (handles scan-stacked ``[n_layers, slots, ...]`` leaves, where
    the expert axis sits behind the stack axis). Non-expert leaves pass
    through untouched. This is the ``MoESpec.params_physical`` serving path:
    the runtime rebinds once at a placement-adoption boundary instead of
    paying the in-graph gather every step (docs/DESIGN.md §8).

    OWNERSHIP: ``donate=True`` (the default — adoption means taking
    ownership, matching the runtime drivers' ``donate_params=True``)
    DELETES the input tree's expert device buffers whenever the slot count
    is preserved (e.g. logical -> pure-permutation placement); pass
    ``donate=False`` to keep using the source tree afterwards (e.g. to
    also save a logical checkpoint from it)."""
    def go(spec, leaf):
        axes = spec.axes or ()
        if "expert" not in axes:
            return leaf
        return _rebind_leaf(leaf, src_placement, dst_placement,
                            axes.index("expert"), donate)

    return jax.tree.map(go, specs, params,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def save_checkpoint(ckpt_dir, step: int, tree, *, extra: dict | None = None,
                    placement=None, expert_keys=EXPERT_PARAM_KEYS):
    """Write one checkpoint. With ``placement`` the tree's expert leaves are
    declared to be in that placement's PHYSICAL slot order (the adopt-once
    serving layout): the placement table + fingerprint are recorded in the
    index so ``restore_checkpoint`` can validate the layout or rebind to
    whatever placement the restoring process wants — an elastic restart is
    never locked to the placement that wrote the checkpoint."""
    if placement is not None:
        # sanity-check the declaration where a shape signal exists — and do
        # it BEFORE touching the filesystem, so a rejected save leaves no
        # stale .tmp directory behind: every expert leaf must carry
        # num_slots rows on its expert axis (axis 0, or axis 1 for
        # scan-stacked leaves). A mislabeled LOGICAL tree under a redundant
        # placement is caught here at save time instead of restoring
        # corrupted weights later; a pure-permutation placement
        # (num_slots == E) is shape-indistinguishable from logical order,
        # so THAT mislabel is the caller's to avoid.
        keys, S = set(expert_keys), placement.num_slots

        def check(path, leaf):
            name = _leaf_name(path)
            if name in keys and S not in leaf.shape[:2]:
                raise ValueError(
                    f"save_checkpoint(placement=...): expert leaf "
                    f"{jax.tree_util.keystr(path)} has shape "
                    f"{tuple(leaf.shape)} but the placement defines {S} "
                    "physical slots — the tree is not in this placement's "
                    "physical layout (adopt_expert_params first)")
            return leaf
        jax.tree_util.tree_map_with_path(check, tree)
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    tmp.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    index = dict(step=step, n_leaves=len(leaves),
                 treedef=str(treedef), time=time.time(), extra=extra or {})
    if placement is not None:
        index["expert_layout"] = dict(
            keys=list(expert_keys),
            fingerprint=placement.fingerprint(),
            placement=PL.placement_to_jsonable(placement))
    shapes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        sav, name = _to_savable(arr)
        np.save(tmp / f"leaf_{i:05d}.npy", sav)
        shapes.append([list(arr.shape), name])
    index["shapes"] = shapes
    (tmp / "index.json").write_text(json.dumps(index))
    # atomic publish: rename tmp -> final (crash-safe)
    if d.exists():
        import shutil
        shutil.rmtree(d)
    tmp.rename(d)
    return d


def latest_step(ckpt_dir) -> int | None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := re.match(r"step_(\d+)$", p.name))]
    return max(steps) if steps else None


# sentinel: restore the expert leaves exactly as stored (no layout change)
_AS_STORED = object()


def restore_checkpoint(ckpt_dir, step: int, target_tree, *, mesh=None,
                       rules=None, placement=_AS_STORED, expert_keys=None):
    """target_tree: pytree of arrays OR ParamSpec (for sharding metadata).
    Elastic: the mesh may differ from the one that wrote the checkpoint.

    ``placement`` requests the expert-leaf layout the restoring process
    wants: an ``EpPlacement`` (physical slot order for that table), ``None``
    (logical ``[E, ...]`` order), or omitted (as stored). When the request
    differs from the layout recorded in the index — fingerprints compared,
    absent record = logical — the expert leaves are rebound host-side
    (collapse the stored placement via primary replicas, expand for the
    requested one), so an elastic restart may adopt any placement
    regardless of which one wrote the checkpoint. ``expert_keys`` defaults
    to the keys recorded at save time (or the standard MoE weight keys).

    Dtype policy: numpy targets restore as numpy at full host precision
    (x64-safe); device targets canonicalize the requested dtype first, so an
    x32 runtime restores an int64-specced counter as int32 cleanly instead
    of emitting a truncation warning."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    index = json.loads((d / "index.json").read_text())
    is_leaf = lambda x: isinstance(x, ParamSpec)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target_tree,
                                                             is_leaf=is_leaf)
    assert len(leaves_p) == index["n_leaves"], \
        f"leaf count mismatch: {len(leaves_p)} vs {index['n_leaves']}"

    layout = index.get("expert_layout")
    src_pl = (PL.placement_from_jsonable(layout["placement"])
              if layout else None)
    dst_pl = src_pl if placement is _AS_STORED else placement
    need_rebind = not _same_layout(src_pl, dst_pl)
    keys = set(expert_keys if expert_keys is not None
               else (layout["keys"] if layout else EXPERT_PARAM_KEYS))
    # rows the stored layout puts at the expert axis — used to sanity-check
    # key-matched plain-array targets, whose expert axis we must assume is 0
    src_rows = (src_pl.num_slots if src_pl
                else dst_pl.num_experts if dst_pl else None)

    def _canon(dt):
        return jax.dtypes.canonicalize_dtype(dt)

    out = []
    for i, (path, tgt) in enumerate(leaves_p):
        arr = _from_savable(np.load(d / f"leaf_{i:05d}.npy"),
                            index["shapes"][i][1])
        if need_rebind:
            name = _leaf_name(path)
            spec_axes = tgt.axes if isinstance(tgt, ParamSpec) else ()
            if "expert" in (spec_axes or ()):
                arr = _rebind_leaf(arr, src_pl, dst_pl,
                                   spec_axes.index("expert"), False)
            elif name in keys:
                # plain-array target: no spec to name the expert axis, so it
                # must be the leading one. A scan-stacked leaf ([n_layers,
                # slots, ...]) would be silently rebound along the LAYER
                # axis — refuse when detectable (n_layers != slot count; a
                # coincidental match is indistinguishable, which is why
                # ParamSpec targets are the authoritative path for stacked
                # trees) and point at the spec-driven path.
                if arr.shape[0] != src_rows:
                    raise ValueError(
                        f"cannot rebind leaf {jax.tree_util.keystr(path)}: "
                        f"axis 0 has {arr.shape[0]} rows but the stored "
                        f"layout defines {src_rows} expert slots — for "
                        "stacked expert leaves restore against a ParamSpec "
                        "target (the spec's \"expert\" axis names the "
                        "rebind axis)")
                arr = _rebind_leaf(arr, src_pl, dst_pl, 0, False)
        if isinstance(tgt, ParamSpec):
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"restored leaf {jax.tree_util.keystr(path)} has shape "
                    f"{tuple(arr.shape)} but the target spec says "
                    f"{tuple(tgt.shape)} — for expert-stacked weights this "
                    "usually means the checkpoint's placement layout doesn't "
                    "match the requested one (pass placement=... to rebind)")
            if mesh is not None:
                from repro.parallel.sharding import DEFAULT_RULES
                sh = spec_to_named_sharding(tgt, mesh, rules or DEFAULT_RULES)
                out.append(jax.device_put(
                    np.asarray(arr).astype(_canon(tgt.dtype), copy=False), sh))
            else:
                out.append(jax.numpy.asarray(arr, _canon(tgt.dtype)))
        elif isinstance(tgt, (np.ndarray, np.generic)):
            # pure-host leaf (trainer step/seed counters, drained float64
            # heat totals): stays numpy — never routed through
            # jax.numpy.asarray, where x64 dtypes truncate on x32 runtimes
            out.append(np.asarray(arr, dtype=tgt.dtype))
        else:
            x = jax.numpy.asarray(arr, _canon(tgt.dtype))
            if hasattr(tgt, "sharding") and mesh is not None:
                x = jax.device_put(x, tgt.sharding)
            out.append(x)
    return jax.tree.unflatten(treedef, out), index
