from repro.checkpoint.store import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, rebind_expert_leaves,
    adopt_expert_params, EXPERT_PARAM_KEYS,
)
