"""EpGroup / EpHandle: the paper's two-tier resource hierarchy (§III-C).

``EpGroup`` is the long-lived tier: algorithm mode, expert count, capacities
(= buffer sizing), EP axis names, payload dtype. Created once per model via
``ep_create_group`` — the analogue of ``ncclEpCreateGroup`` (a collective call;
here, a pure-config construction validated against the mesh).

``EpHandle`` is the per-forward-pass tier: the routing state (globally
gathered ``topk_idx``), derived slot maps and counts. Created inside the
sharded computation via ``ep_create_handle`` (≈ ``ncclEpCreateHandle``); shared
between matching dispatch and combine of forward *and* backward passes — in
JAX, the backward pass reuses the very same traced routing constants, which is
the paper's "cached dispatch" for free.

All shapes are static: capacities are part of the group config, mirroring the
paper's own worst-case buffer sizing at init (§V-C). ``capacity_factor=None``
means zero-drop sizing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

import jax
import jax.numpy as jnp

AxisNames = tuple[str, ...]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class EpGroupConfig:
    """User-facing configuration — analogue of ``ncclEpGroupConfig_t``."""

    num_experts: int
    max_tokens_per_rank: int                  # B_cap — per-EP-rank token budget
    hidden: int
    top_k: int
    mode: Literal["ll", "ht", "baseline", "auto"] = "auto"
    # LL layout selection: "nccl_ep" = the paper's memory-optimized layout
    # (per-rank dedup, packed combine); "deepep" = per-(expert,rank) slots.
    ll_layout: Literal["nccl_ep", "deepep"] = "nccl_ep"
    # None = zero-drop capacities (faithful); float = GShard-style factor.
    capacity_factor: float | None = None
    # Per-expert output-region capacity factor (LL 3D layout compaction).
    # None = paper layout: num_ranks * max_tokens_per_rank slots per expert.
    expert_capacity_factor: float | None = None
    payload_dtype: jnp.dtype = jnp.bfloat16   # dispatch payload (bf16 | fp8)
    quantize_dispatch: bool = False           # fp8 payload + fp32 scales
    quant_block: int = 128                    # scale granularity along hidden
    # HT hierarchy: inter-axis (slow, e.g. "pod") set when EP spans pods.
    ep_axis: AxisNames = ("data",)
    ht_hierarchical: bool = False             # 2-stage a2a when EP = (outer, inner)
    ht_pod_dedup: bool = False                # stage-3 dedup (perf option)
    # Chunked hierarchical pipeline: the token dim is split into this many
    # static chunks and the two a2a stages stream — chunk i's intra-pod hop
    # overlaps chunk i-1's inter-pod hop (HybridEP-style pipelining). 1 =
    # monolithic (bitwise-identical output for any value at zero-drop caps).
    ht_num_chunks: int = 1
    # EPLB (core/placement.py): explicit expert placement table with optional
    # redundant replicas. None = the contiguous striping (expert e at rank
    # e // L — the exact pre-placement arithmetic, untouched). A placement
    # with redundant slots implies num_redundant_experts; setting the count
    # without a table is an error (the table defines where replicas live).
    placement: "object | None" = None         # EpPlacement | None
    num_redundant_experts: int = 0
    # Fault domains (core/placement.py FaultDomains, docs/DESIGN.md §9):
    # rank -> correlated-failure unit for the replica-placement floor. None
    # derives from the HT hierarchy when one exists (pod = rank //
    # inner_size) and falls back to the flat rank-per-domain map — see
    # EpGroup.fault_domains().
    fault_domains: "object | None" = None     # FaultDomains | None
    slot_align: int = 8                       # capacity rounding (TPU lane-friendly)

    LL_BATCH_THRESHOLD = 128  # paper: LL targets 1–128 tokens/rank

    def resolved_mode(self) -> str:
        if self.mode != "auto":
            return self.mode
        # Paper §III: auto mode detection from workload characteristics.
        return "ll" if self.max_tokens_per_rank <= self.LL_BATCH_THRESHOLD else "ht"


@dataclasses.dataclass(frozen=True)
class EpGroup:
    """Resolved, validated group — static (hashable) so it can close over jits."""

    cfg: EpGroupConfig
    ep_size: int                 # N — total EP ranks
    # L — physical expert slots per rank: E / N contiguous, (E + R) / N under
    # a redundant placement (every buffer/capacity shape keys off this)
    local_experts: int
    # --- LL capacities ---
    ll_disp_cap: int             # C_d: slots per (src,dst) rank pair, dispatch
    ll_comb_cap: int             # C_c: slots per (src,dst) rank pair, combine
    ll_expert_cap: int           # A: rows per local expert in 3D output
    # --- HT capacities ---
    ht_pair_cap: int             # C_h: entry slots per rank pair (flat a2a)
    ht_expert_cap: int           # A_h: rows per local expert in output
    ht_stage1_cap: int           # C1: hierarchical intra-pod stage
    ht_stage2_cap: int           # C2: hierarchical inter-pod stage
    inner_size: int              # N_i (hierarchical); == ep_size when flat
    outer_size: int              # N_o

    @property
    def mode(self) -> str:
        return self.cfg.resolved_mode()

    @property
    def placement(self):
        """The group's EpPlacement, or None for the contiguous default."""
        return self.cfg.placement

    @property
    def placement_salt(self) -> int:
        """Placement fingerprint mixed into the routing hash (0 for the
        contiguous default, so pre-placement hash values are unchanged). A
        placement swap changes the salt, which forces ``ep_handle_refresh``
        to rebuild stale handles while routing replays under an unchanged
        placement keep the fast path."""
        pl = self.cfg.placement
        return 0 if pl is None else pl.fingerprint()

    @property
    def physical_experts(self) -> int:
        """Total physical expert slots (= num_experts + redundant replicas)."""
        return self.ep_size * self.local_experts

    def fault_domains(self):
        """The group's correlated-failure topology (docs/DESIGN.md §9):
        the explicit ``cfg.fault_domains`` override when set; else derived
        from the HT hierarchy — ranks sharing an NVLink pod fail together,
        and the pod is ``rank // inner_size`` (the same arithmetic the
        hierarchical plan uses, `core/plan.py rank_pod`); else the flat
        rank-per-domain map (every rank its own failure unit)."""
        from repro.core.placement import domains_from_geometry, trivial_domains
        if self.cfg.fault_domains is not None:
            return self.cfg.fault_domains
        if self.outer_size > 1:
            return domains_from_geometry(self.ep_size, self.inner_size)
        return trivial_domains(self.ep_size)

    def ht_chunks(self, num_tokens: int) -> int:
        """Static chunk count for a ``num_tokens``-token hierarchical handle
        (the handle may carry fewer tokens than ``max_tokens_per_rank``, but
        the chunk grid must still tile it exactly)."""
        nc = self.cfg.ht_num_chunks
        if num_tokens % nc != 0:
            raise ValueError(
                f"ht_num_chunks={nc} must divide the handle's token count "
                f"{num_tokens}")
        return nc

    # ---- buffer byte accounting (for Eq. 3 benchmark + roofline) ----
    def payload_bytes_per_token(self) -> int:
        h = self.cfg.hidden
        if self.cfg.quantize_dispatch:
            return h + 4 * math.ceil(h / self.cfg.quant_block)  # fp8 + fp32 scales
        return h * jnp.dtype(self.cfg.payload_dtype).itemsize

    def ll_dispatch_buffer_bytes(self) -> int:
        return self.ep_size * self.ll_disp_cap * self.payload_bytes_per_token()

    def ll_combine_buffer_bytes(self) -> int:
        h = self.cfg.hidden * jnp.dtype(self.cfg.payload_dtype).itemsize
        return self.ep_size * self.ll_comb_cap * h


def ep_create_group(
    cfg: EpGroupConfig,
    *,
    ep_size: int | None = None,
    mesh: jax.sharding.Mesh | None = None,
    inner_size: int | None = None,
) -> EpGroup:
    """Create the long-lived group. Pass either a mesh (sizes are read from
    ``cfg.ep_axis``) or explicit ``ep_size``. Mirrors ``ncclEpCreateGroup``."""
    if mesh is not None:
        sizes = [mesh.shape[a] for a in cfg.ep_axis]
        ep_size = math.prod(sizes)
        inner_size = sizes[-1]
    assert ep_size is not None
    if inner_size is None:
        inner_size = ep_size
    outer_size = ep_size // inner_size

    E, K, B = cfg.num_experts, cfg.top_k, cfg.max_tokens_per_rank
    N = ep_size
    # EPLB: a placement table defines the physical slot grid (logical experts
    # + redundant replicas); the contiguous default keeps L = E / N.
    R = cfg.num_redundant_experts
    if cfg.placement is not None:
        pl = cfg.placement
        if pl.num_experts != E:
            raise ValueError(f"placement covers {pl.num_experts} experts, "
                             f"group has num_experts={E}")
        if pl.num_ranks != N:
            raise ValueError(f"placement spans {pl.num_ranks} ranks, "
                             f"group has ep_size={N}")
        if R not in (0, pl.num_redundant):
            raise ValueError(
                f"num_redundant_experts={R} contradicts the placement's "
                f"{pl.num_redundant} redundant slots")
        # physical slot grid straight from the table: L = slots per rank.
        # For healthy tables this is (E + R) / N exactly as before; a
        # DEGRADED table (dead ranks' rows all EMPTY — elastic EP,
        # docs/DESIGN.md §9) packs all experts onto the survivors, so
        # slots_per_rank grows while empty slots host (and receive) nothing.
        L = pl.slots_per_rank
        R = pl.num_redundant
    elif R:
        raise ValueError(
            f"num_redundant_experts={R} requires an explicit placement "
            "(the table defines where replicas live — build one with "
            "repro.core.placement.rebalance or redundant_placement)")
    else:
        if E % N != 0:
            raise ValueError(f"num_experts={E} (+{R} redundant) must divide "
                             f"by ep_size={N}")
        L = E // N
    if cfg.fault_domains is not None and cfg.fault_domains.num_ranks != N:
        raise ValueError(
            f"fault_domains cover {cfg.fault_domains.num_ranks} ranks, "
            f"group has ep_size={N}")
    cf = cfg.capacity_factor
    al = cfg.slot_align

    def cap(expected: float, zero_drop: int) -> int:
        if cf is None:
            return _round_up(zero_drop, al)
        return min(_round_up(max(int(math.ceil(cf * expected)), al), al), _round_up(zero_drop, al))

    # LL (paper §IV-D): dispatch dedups to one send per destination *rank*;
    # zero-drop bound is B (every token can need every rank at most once).
    ll_disp_cap = cap(B * min(K, N) / N, B)
    # combine: one entry per (t,k) owned; zero-drop bound B*min(K,L).
    ll_comb_cap = cap(B * K / N, B * min(K, L))
    # LL 3D expert-major region: paper layout = num_ranks * B rows per expert.
    ecf = cfg.expert_capacity_factor
    if ecf is None:
        ll_expert_cap = N * B
    else:
        ll_expert_cap = min(_round_up(int(math.ceil(ecf * N * B * K / E)), 128), N * B)

    # HT flat: one entry per (t,k); pair capacity around B*K/N.
    ht_pair_cap = cap(B * K / N, B * min(K, L))
    if ecf is None:
        ht_expert_cap = _round_up(min(N * B, int(N * ht_pair_cap // max(L, 1)) or 1), 128)
        ht_expert_cap = max(ht_expert_cap, 128)
    else:
        ht_expert_cap = _round_up(int(math.ceil(ecf * N * B * K / E)), 128)
    # Hierarchical stages: stage1 dedup over distinct destination-inner index,
    # stage2 dedup over distinct destination chip. Capacities are PER CHUNK:
    # the chunked pipeline (cfg.ht_num_chunks) streams B/nc-token slices
    # through each stage, so each stage buffer sizes to the slice.
    nc = cfg.ht_num_chunks
    if nc < 1:
        raise ValueError(f"ht_num_chunks={nc} must be >= 1")
    if B % nc != 0:
        raise ValueError(
            f"ht_num_chunks={nc} must divide max_tokens_per_rank={B}")
    Bc = B // nc
    ki = min(K, inner_size)
    ht_stage1_cap = cap(Bc * ki / inner_size, Bc)
    # a rail chip holds <= inner_size * C1 entries, fanned over outer axis
    ko = min(K, outer_size) if outer_size > 1 else 1
    ht_stage2_cap = cap(inner_size * ht_stage1_cap * ko / max(outer_size, 1),
                        inner_size * ht_stage1_cap)

    return EpGroup(
        cfg=cfg, ep_size=N, local_experts=L,
        ll_disp_cap=ll_disp_cap, ll_comb_cap=ll_comb_cap, ll_expert_cap=ll_expert_cap,
        ht_pair_cap=ht_pair_cap, ht_expert_cap=ht_expert_cap,
        ht_stage1_cap=ht_stage1_cap, ht_stage2_cap=ht_stage2_cap,
        inner_size=inner_size, outer_size=outer_size,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EpHandle:
    """Per-forward-pass routing state (analogue of ``ncclEpHandle_t``).

    Everything here is derived from ``topk_idx`` gathered across the EP axis —
    the paper's metadata exchange (explicit at handle creation in HT mode;
    folded into dispatch headers in LL mode; here always at handle creation,
    which is strictly cheaper than headers since the slot maps are then
    computed redundantly-but-locally on every rank instead of being shipped).

    ``plan`` carries the precomputed slot-map engine (``repro.core.plan``):
    the full chain of gather maps and counts for every dispatch/combine phase,
    derived exactly once at handle creation so the phases themselves are pure
    gather/scatter passes (the one-pass-per-phase invariant).

    ``routing_hash`` is the [2]-uint32 checksum of ``topk_global`` (the
    gathered routing — every slot map depends on every rank's choices) that
    powers ``ep_handle_refresh``'s fast path: an unchanged-routing refresh
    compares hashes at runtime and reuses every precomputed map verbatim
    instead of rebuilding the plan (speculative-decode replay, cached
    dispatch).
    """

    topk_idx: jax.Array          # [T, K] local routing (this rank's tokens)
    topk_weights: jax.Array      # [T, K] combine weights
    topk_global: jax.Array       # [N, T, K] all-gathered routing
    tokens_per_expert: jax.Array  # [L] int32 — received tokens per local expert
    num_recv_tokens: jax.Array   # [] int32 — total received (HT query, §III-B)
    # number of *valid* tokens on this rank (<= T); slots beyond are padding
    num_tokens: jax.Array        # [] int32
    # precomputed slot maps for all phases (None only for hand-built handles)
    plan: "object | None" = None
    # [2]-uint32 checksum of topk_global for the refresh fast path
    # (None: hand-built handle)
    routing_hash: "jax.Array | None" = None


def ep_handle_get_num_recv_tokens(handle: EpHandle) -> jax.Array:
    """``ncclEpHandleGetNumRecvTokens`` — exact receive count (HT mode)."""
    return handle.num_recv_tokens


def ep_handle_destroy(handle: EpHandle) -> None:
    """No-op in JAX (buffers are managed by XLA); kept for API parity."""
    del handle
