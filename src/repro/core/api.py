"""Unified EP API — the paper's headline contribution (§III).

One dispatch/combine pair for every workload; the algorithm (LL / HT /
baseline) is chosen **once, at group creation** (`EpGroupConfig.mode`,
"auto" selects by `max_tokens_per_rank` like the paper's planned
auto-detection). Call sites never change across modes:

    group  = ep_create_group(cfg, mesh=mesh)
    handle = ep_create_handle(group, topk_idx, topk_weights)
    xs, counts = ep_dispatch(group, handle, tokens)
    ...expert FFN...
    out = ep_combine(group, handle, expert_out)

All functions must be called *inside* the sharded region (shard_map over the
group's EP axes) — they are collectives, exactly like `jax.lax.psum`. The
handle is shared between forward and backward (the Megatron "cached dispatch"
integration, §VI-B): JAX AD transposes dispatch into combine and vice versa
through the same traced slot maps, so handle reuse is automatic.

`ep_create_handle` also derives the complete slot-map chain for every phase
(the `EpPlan` engine, core/plan.py) — dispatch and combine are then pure
single-pass data movement over precomputed maps; no slot arithmetic runs
inside them (the one-pass-per-phase invariant).

The tagged-tensor entry points (`ep_dispatch_tensors`) mirror the C API's
``ncclNDTensor_t`` signature for framework integrations that want role
validation.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.group import (EpGroup, EpGroupConfig, EpHandle, ep_create_group,
                              ep_handle_get_num_recv_tokens, ep_handle_destroy)
from repro.core import ll as _ll
from repro.core import ht as _ht
from repro.core import baseline as _bl
from repro.core import plan as _plan
from repro.core.tensor import EpTensor, EpTensorTag, validate

__all__ = [
    "EpGroup", "EpGroupConfig", "EpHandle", "ep_create_group",
    "ep_create_handle", "ep_handle_refresh", "ep_dispatch", "ep_combine",
    "ep_complete", "ep_handle_get_num_recv_tokens", "ep_handle_destroy",
    "ep_dispatch_tensors", "ep_combine_tensors",
]


def ep_create_handle(group: EpGroup, topk_idx: jax.Array,
                     topk_weights: jax.Array, num_tokens=None) -> EpHandle:
    """``ncclEpCreateHandle``: capture per-forward-pass routing state.

    HT/baseline run their metadata exchange here (paper §III-C2); LL's
    exchange is folded in too (strictly earlier than the paper's in-dispatch
    headers, see DESIGN.md §2)."""
    mode = group.mode
    if mode == "ll":
        return _ll.ll_create_handle(group, topk_idx, topk_weights, num_tokens)
    if mode == "ht":
        return _ht.ht_create_handle(group, topk_idx, topk_weights, num_tokens)
    return _bl.baseline_create_handle(group, topk_idx, topk_weights, num_tokens)


def ep_handle_refresh(group: EpGroup, handle: EpHandle,
                      topk_weights: jax.Array,
                      topk_idx: jax.Array | None = None,
                      num_tokens=None) -> EpHandle:
    """``ncclEpHandleRefresh``-style steady-state path: rebind per-step
    routing state into an existing handle without rebuilding slot maps.

    ``topk_idx=None`` (or passing the handle's own array) rebinds weights
    only — every precomputed map is reused verbatim. With a new ``topk_idx``
    the routing-hash fast path decides at runtime: unchanged routing
    (speculative-decode replay, cached dispatch in backward) skips plan
    construction entirely; changed routing rebuilds like ``ep_create_handle``.
    Mode-agnostic — works for LL, HT, and baseline handles alike."""
    return _plan.refresh_handle(group, handle, topk_weights, topk_idx,
                                num_tokens)


def ep_dispatch(group: EpGroup, handle: EpHandle, tokens: jax.Array, *,
                send_only: bool = False):
    """``ncclEpDispatch``: route tokens to their experts.

    Returns (expert_major [L, A, H], tokens_per_expert [L]) — or, with
    send_only=True in LL mode, a PendingDispatch for staged overlap."""
    mode = group.mode
    if mode == "ll":
        return _ll.ll_dispatch(group, handle, tokens, send_only=send_only)
    if mode == "ht":
        return _ht.ht_dispatch(group, handle, tokens, send_only=send_only)
    return _bl.baseline_dispatch(group, handle, tokens, send_only=send_only)


def ep_combine(group: EpGroup, handle: EpHandle, expert_out: jax.Array, *,
               send_only: bool = False):
    """``ncclEpCombine``: gather expert outputs, weighted-reduce to original
    token order. Input layout must match the group's dispatch output."""
    mode = group.mode
    if mode == "ll":
        return _ll.ll_combine(group, handle, expert_out, send_only=send_only)
    if mode == "ht":
        return _ht.ht_combine(group, handle, expert_out, send_only=send_only)
    return _bl.baseline_combine(group, handle, expert_out, send_only=send_only)


def ep_complete(group: EpGroup, handle: EpHandle, pending):
    """``ncclEpComplete``: finalize a staged (send_only) operation."""
    if isinstance(pending, _ll.PendingDispatch):
        return _ll.ll_complete_dispatch(group, handle, pending)
    if isinstance(pending, _ll.PendingCombine):
        return _ll.ll_complete_combine(group, handle, pending)
    raise TypeError(f"not a pending EP operation: {type(pending)}")


# ---------------------------------------------------------------------------
# tagged-tensor surface (C-API parity)
# ---------------------------------------------------------------------------

def ep_dispatch_tensors(group: EpGroup, handle: EpHandle,
                        inputs: Sequence[EpTensor], *, send_only=False):
    toks = next(t for t in inputs if t.tag == EpTensorTag.TOKENS)
    tokens = validate(toks, tag=EpTensorTag.TOKENS, ndim=2)
    out, counts = ep_dispatch(group, handle, tokens, send_only=send_only)
    return (EpTensor(out, EpTensorTag.TOKENS),
            EpTensor(counts, EpTensorTag.TOKENS_PER_EXPERTS))


def ep_combine_tensors(group: EpGroup, handle: EpHandle,
                       inputs: Sequence[EpTensor], *, send_only=False):
    toks = next(t for t in inputs if t.tag == EpTensorTag.TOKENS)
    y = validate(toks, tag=EpTensorTag.TOKENS, ndim=3)
    out = ep_combine(group, handle, y, send_only=send_only)
    return EpTensor(out, EpTensorTag.TOKENS)
