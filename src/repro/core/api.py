"""Unified EP API — the paper's headline contribution (§III).

One dispatch/combine pair for every workload; the algorithm (LL / HT /
baseline) is chosen **once, at group creation** (`EpGroupConfig.mode`,
"auto" selects by `max_tokens_per_rank` like the paper's planned
auto-detection). Call sites never change across modes:

    group  = ep_create_group(cfg, mesh=mesh)
    handle = ep_create_handle(group, topk_idx, topk_weights)
    xs, counts = ep_dispatch(group, handle, tokens)
    ...expert FFN...
    out = ep_combine(group, handle, expert_out)

All functions must be called *inside* the sharded region (shard_map over the
group's EP axes) — they are collectives, exactly like `jax.lax.psum`. The
handle is shared between forward and backward (the Megatron "cached dispatch"
integration, §VI-B): JAX AD transposes dispatch into combine and vice versa
through the same traced slot maps, so handle reuse is automatic.

Every entry point routes through the ``EpBackend`` registry
(core/backend.py) keyed by ``group.mode`` — the API layer contains no
per-mode branching and no pending-type ``isinstance`` chains. The staged
surface is part of the backend contract: ``send_only=True`` returns a
mode-tagged ``EpPending`` and ``ep_complete`` finishes it, for **every**
registered mode (LL decode overlap, HT prefill pipelining, baseline
apples-to-apples) — a backend may refuse with ``NotImplementedError`` but
may never accept the flag and silently run eager.

`ep_create_handle` also derives the complete slot-map chain for every phase
(the `EpPlan` engine, core/plan.py) — dispatch and combine are then pure
single-pass data movement over precomputed maps; no slot arithmetic runs
inside them (the one-pass-per-phase invariant).

The tagged-tensor entry points (`ep_dispatch_tensors`) mirror the C API's
``ncclNDTensor_t`` signature for framework integrations that want role
validation.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.core.group import (EpGroup, EpGroupConfig, EpHandle, ep_create_group,
                              ep_handle_get_num_recv_tokens, ep_handle_destroy)
from repro.core.backend import EpPending, get_backend, registered_modes
# importing the mode modules registers their backends with the registry
from repro.core import ll as _ll        # noqa: F401
from repro.core import ht as _ht        # noqa: F401
from repro.core import baseline as _bl  # noqa: F401
from repro.core import plan as _plan
from repro.core.tensor import EpTensor, EpTensorTag, validate

__all__ = [
    "EpGroup", "EpGroupConfig", "EpHandle", "EpPending", "ep_create_group",
    "ep_create_handle", "ep_handle_refresh", "ep_dispatch", "ep_combine",
    "ep_complete", "ep_handle_get_num_recv_tokens", "ep_handle_destroy",
    "ep_dispatch_tensors", "ep_combine_tensors", "registered_modes",
]


def ep_create_handle(group: EpGroup, topk_idx: jax.Array,
                     topk_weights: jax.Array, num_tokens=None) -> EpHandle:
    """``ncclEpCreateHandle``: capture per-forward-pass routing state.

    HT/baseline run their metadata exchange here (paper §III-C2); LL's
    exchange is folded in too (strictly earlier than the paper's in-dispatch
    headers, see docs/DESIGN.md §2)."""
    return get_backend(group.mode).create_handle(group, topk_idx,
                                                 topk_weights, num_tokens)


def ep_handle_refresh(group: EpGroup, handle: EpHandle,
                      topk_weights: jax.Array,
                      topk_idx: jax.Array | None = None,
                      num_tokens=None) -> EpHandle:
    """``ncclEpHandleRefresh``-style steady-state path: rebind per-step
    routing state into an existing handle without rebuilding slot maps.

    ``topk_idx=None`` (or passing the handle's own array) rebinds weights
    only — every precomputed map is reused verbatim. With a new ``topk_idx``
    the routing-hash fast path decides at runtime: unchanged routing
    (speculative-decode replay, cached dispatch in backward) skips plan
    construction entirely; changed routing rebuilds like ``ep_create_handle``.
    Mode-agnostic — works for LL, HT, and baseline handles alike."""
    return _plan.refresh_handle(group, handle, topk_weights, topk_idx,
                                num_tokens)


def ep_dispatch(group: EpGroup, handle: EpHandle, tokens: jax.Array, *,
                send_only: bool = False):
    """``ncclEpDispatch``: route tokens to their experts.

    Returns (expert_major [L, A, H], tokens_per_expert [L]) — or, with
    send_only=True, a mode-tagged EpPending for staged overlap (honored by
    every registered backend)."""
    return get_backend(group.mode).dispatch(group, handle, tokens,
                                            send_only=send_only)


def ep_combine(group: EpGroup, handle: EpHandle, expert_out: jax.Array, *,
               send_only: bool = False):
    """``ncclEpCombine``: gather expert outputs, weighted-reduce to original
    token order. Input layout must match the group's dispatch output."""
    return get_backend(group.mode).combine(group, handle, expert_out,
                                           send_only=send_only)


def ep_complete(group: EpGroup, handle: EpHandle, pending: EpPending):
    """``ncclEpComplete``: finalize a staged (send_only) operation.

    Routes by the pending's mode/op tags through the backend registry; a
    pending created under a different mode than the group's fails loudly."""
    return get_backend(group.mode).complete(group, handle, pending)


# ---------------------------------------------------------------------------
# tagged-tensor surface (C-API parity)
# ---------------------------------------------------------------------------

def ep_dispatch_tensors(group: EpGroup, handle: EpHandle,
                        inputs: Sequence[EpTensor], *, send_only=False):
    toks = next(t for t in inputs if t.tag == EpTensorTag.TOKENS)
    tokens = validate(toks, tag=EpTensorTag.TOKENS, ndim=2)
    out, counts = ep_dispatch(group, handle, tokens, send_only=send_only)
    return (EpTensor(out, EpTensorTag.TOKENS),
            EpTensor(counts, EpTensorTag.TOKENS_PER_EXPERTS))


def ep_combine_tensors(group: EpGroup, handle: EpHandle,
                       inputs: Sequence[EpTensor], *, send_only=False):
    toks = next(t for t in inputs if t.tag == EpTensorTag.TOKENS)
    y = validate(toks, tag=EpTensorTag.TOKENS, ndim=3)
    out = ep_combine(group, handle, y, send_only=send_only)
    return EpTensor(out, EpTensorTag.TOKENS)
