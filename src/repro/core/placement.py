"""EPLB: expert placement & load balancing (the production layer the paper's
striped-expert assumption leaves out).

Every mode so far assumed experts are striped contiguously across EP ranks —
``e // L`` was baked into every plan builder and into the capacity math. Under
real serving traffic routing is skewed: one hot expert saturates its rank's
dispatch slots while neighbors idle (the imbalance UBEP's production superpod
re-architecture and HybridEP's skew-aware transmission both address, see
PAPERS.md). This module makes placement an explicit, swappable table:

* ``EpPlacement`` — logical expert -> [(rank, local_slot)] with optional
  redundant replicas. Stored as nested tuples so it is hashable and can live
  inside the (static) ``EpGroupConfig``; derived numpy tables are cached.
  The contiguous layout is ``placement=None`` on the group config — that
  default path keeps the exact ``e // L`` arithmetic, untouched.

* replica selection — ``assign`` resolves (expert, source rank) to ONE
  physical (rank, slot) as ``src_rank % num_replicas``: a pure function of
  replicated routing metadata, so sender and receiver derive identical slot
  coordinates with zero extra communication (the same determinism argument
  as core/slots.py), and a hot expert's load round-robins across its
  replicas by source rank. Resolution happens **at plan time only** — phase
  bodies stay single-pass over precomputed maps (docs/DESIGN.md §8).

* heat — per-logical-expert token counts folded from routing histograms or
  from the per-slot ``recv_counts`` (``fold_slot_counts``), accumulated by
  ``HeatTracker`` (optional exponential decay for drifting traffic).

* ``rebalance`` — the greedy policy: give each of the R redundant slots to
  the expert with the highest per-replica load, then LPT-pack all replicas
  onto ranks minimizing the max per-rank load (replicas of one expert prefer
  distinct ranks, where the round-robin selection actually splits load).

Placement swaps are host-level events between steps: a new placement means a
new (static) group, and ``ep_handle_refresh`` force-rebuilds stale handles
because the routing hash is salted with the placement fingerprint — while a
routing replay under an unchanged placement still takes the fast path. The
runtime drivers (`runtime/decode.py::rebalancing_decode_loop`,
`runtime/prefill.py::rebalancing_prefill`, `runtime/server.py` serving hook)
wire heat -> policy -> live re-plan on top of this module.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import zlib
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# dict keys of the expert-stacked MoE weights (models/moe.py moe_spec) —
# the leaves the placement/checkpoint rebinding helpers act on by default
# (canonical here; checkpoint.store re-exports it)
EXPERT_PARAM_KEYS = ("w_gate", "w_up", "w_down")

# Sentinel for a slot that hosts NOTHING: degraded placements (a dead rank's
# row is all EMPTY) and the masked view of a placement restricted to its
# survivors. An empty slot never appears in any expert's replica list, so
# plan-time assignment (``assign``/``plan.dest_of``) can never route a token
# to it — zero traffic to a dead rank by construction (docs/DESIGN.md §9).
EMPTY = -1


# --------------------------------------------------------------------------
# fault domains: the correlated-failure topology (docs/DESIGN.md §9)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultDomains:
    """Rank -> failure-domain map: ranks in one domain fail TOGETHER (a whole
    NVLink pod losing power, a switch taking its rail down — UBEP's
    correlated-failure model, PAPERS.md). Replica-placement constraints
    (`rebalance(min_replicas=..., domains=...)`) and the shrink-feasibility
    precheck (`shrink_feasibility`) treat the domain, not the rank, as the
    unit of failure. Hashable (tuple) so it can ride in the static
    ``EpGroupConfig``; the default derivation from the HT hierarchy is
    ``EpGroup.fault_domains()`` (pod = rank // inner_size — the same
    arithmetic the hierarchical plan uses, `core/plan.py rank_pod`)."""

    domain_of: tuple[int, ...]      # [num_ranks] rank -> domain id

    def __post_init__(self):
        if not self.domain_of:
            raise ValueError("fault-domain map must be non-empty")
        if any(d < 0 for d in self.domain_of):
            raise ValueError(f"domain ids must be >= 0, got {self.domain_of}")

    @property
    def num_ranks(self) -> int:
        return len(self.domain_of)

    @property
    def num_domains(self) -> int:
        return len(set(self.domain_of))

    def domains(self) -> tuple[int, ...]:
        return tuple(sorted(set(self.domain_of)))

    def ranks_in(self, domain: int) -> tuple[int, ...]:
        return tuple(r for r, d in enumerate(self.domain_of) if d == domain)

    def live_domains(self, alive_ranks) -> tuple[int, ...]:
        """Domains with at least one alive rank."""
        alive = set(alive_ranks)
        return tuple(sorted({d for r, d in enumerate(self.domain_of)
                             if r in alive}))

    def describe(self) -> str:
        """Compact rendering for error messages: ``{domain: [ranks]}``."""
        return "{" + ", ".join(f"{d}: {list(self.ranks_in(d))}"
                               for d in self.domains()) + "}"


def trivial_domains(num_ranks: int) -> FaultDomains:
    """Every rank its own domain — the flat (non-hierarchical) topology,
    where the only correlated-failure unit is the single rank. Under this
    map "distinct domains" and "distinct ranks" coincide, so the floor
    degenerates to exactly the rank-level constraint."""
    if num_ranks < 1:
        raise ValueError(f"num_ranks={num_ranks} must be >= 1")
    return FaultDomains(tuple(range(num_ranks)))


def domains_from_geometry(ep_size: int, inner_size: int) -> FaultDomains:
    """The HT hierarchy's natural fault boundary: pod = rank // inner_size
    (must agree with `core/plan.py rank_pod`, pinned by
    tests/test_fault_domains.py)."""
    if inner_size < 1 or ep_size % inner_size:
        raise ValueError(f"inner_size={inner_size} must divide "
                         f"ep_size={ep_size}")
    from repro.core.plan import rank_pod
    return FaultDomains(tuple(rank_pod(r, inner_size)
                              for r in range(ep_size)))


@dataclasses.dataclass(frozen=True)
class EpPlacement:
    """Physical expert layout: ``slot_expert[r][s]`` is the logical expert
    hosted in rank *r*'s local slot *s*. Hashable (nested tuples) so it can
    ride in the static ``EpGroupConfig``; every logical expert must appear in
    at least one slot, and slots beyond the first are redundant replicas.
    ``version`` distinguishes successive rebalances that happen to emit the
    same table (it feeds the placement fingerprint that salts the routing
    hash, so a swap always forces handle rebuild)."""

    num_experts: int
    slot_expert: tuple[tuple[int, ...], ...]    # [num_ranks][slots_per_rank]
    version: int = 0

    def __post_init__(self):
        E, tbl = self.num_experts, self.slot_expert
        if not tbl or not tbl[0]:
            raise ValueError("placement table must be non-empty")
        S = len(tbl[0])
        if any(len(r) != S for r in tbl):
            raise ValueError("placement rows must have equal slot counts")
        seen = np.zeros(E, bool)
        for row in tbl:
            for e in row:
                if e == EMPTY:
                    continue            # degraded: slot hosts nothing
                if not (0 <= e < E):
                    raise ValueError(f"slot expert {e} out of range [0, {E})")
                seen[e] = True
        if not seen.all():
            missing = np.nonzero(~seen)[0][:8].tolist()
            raise ValueError(f"experts {missing} have no placement slot")

    @property
    def num_ranks(self) -> int:
        return len(self.slot_expert)

    @property
    def slots_per_rank(self) -> int:
        return len(self.slot_expert[0])

    @property
    def num_slots(self) -> int:
        return self.num_ranks * self.slots_per_rank

    @property
    def num_empty(self) -> int:
        """Empty (EMPTY-sentinel) slots — nonzero only on degraded tables."""
        return sum(1 for row in self.slot_expert for e in row if e == EMPTY)

    @property
    def num_redundant(self) -> int:
        """Replica surplus over one-slot-per-expert, counting LIVE slots
        only (empty slots host nothing, so they are capacity, not
        redundancy)."""
        return self.num_slots - self.num_empty - self.num_experts

    def dead_ranks(self) -> tuple[int, ...]:
        """Ranks whose every slot is empty — the degraded-placement marker
        (a rank with zero slots assigned receives zero traffic)."""
        return tuple(r for r, row in enumerate(self.slot_expert)
                     if all(e == EMPTY for e in row))

    def alive_ranks(self) -> tuple[int, ...]:
        dead = set(self.dead_ranks())
        return tuple(r for r in range(self.num_ranks) if r not in dead)

    def is_identity(self) -> bool:
        """True iff this is exactly the contiguous striping (no replicas)."""
        if self.num_slots != self.num_experts:
            return False
        S = self.slots_per_rank
        return all(e == r * S + s
                   for r, row in enumerate(self.slot_expert)
                   for s, e in enumerate(row))

    def fingerprint(self) -> int:
        """Nonzero uint32 identifying (table, version) — the salt that the
        routing hash mixes in so a placement swap always forces handle
        rebuild. Deterministic across processes (crc32, not Python hash)."""
        flat = np.asarray([e for row in self.slot_expert for e in row],
                          np.int64)
        fp = zlib.crc32(flat.tobytes())
        fp ^= (self.version * 0x9E3779B1) & 0xFFFFFFFF
        return fp or 1


def placement_to_jsonable(placement: EpPlacement) -> dict:
    """JSON-safe rendering of a placement table (checkpoint indexes, bench
    result files). Round-trips exactly through ``placement_from_jsonable``."""
    return dict(num_experts=placement.num_experts,
                slot_expert=[list(row) for row in placement.slot_expert],
                version=placement.version)


def placement_from_jsonable(d: dict) -> EpPlacement:
    return EpPlacement(int(d["num_experts"]),
                       tuple(tuple(int(e) for e in row)
                             for row in d["slot_expert"]),
                       version=int(d.get("version", 0)))


def identity_placement(num_experts: int, num_ranks: int) -> EpPlacement:
    """The explicit rendering of the default contiguous striping: expert e at
    (e // L, e % L). Bitwise-identical behavior to ``placement=None`` is
    pinned by tests/test_placement.py."""
    if num_experts % num_ranks:
        raise ValueError(f"num_experts={num_experts} must divide by "
                         f"num_ranks={num_ranks}")
    L = num_experts // num_ranks
    return EpPlacement(num_experts, tuple(
        tuple(range(r * L, (r + 1) * L)) for r in range(num_ranks)))


# --------------------------------------------------------------------------
# derived tables + plan-time assignment
# --------------------------------------------------------------------------

class PlacementTables(NamedTuple):
    """Numpy renderings of the placement, cached per EpPlacement. Row E of
    each replica table is the padding-sentinel expert: rank=num_ranks,
    slot=slots_per_rank — out of range everywhere, exactly like ``E // L``
    under the contiguous layout."""

    replica_rank: np.ndarray    # [E+1, Rmax] int32
    replica_slot: np.ndarray    # [E+1, Rmax] int32
    replica_count: np.ndarray   # [E+1] int32 (>= 1)
    slot_expert: np.ndarray     # [N, S] int32
    primary_row: np.ndarray     # [E] int32 — flat (rank*S + slot) of replica 0


@functools.lru_cache(maxsize=128)
def tables(placement: EpPlacement) -> PlacementTables:
    E, N, S = placement.num_experts, placement.num_ranks, placement.slots_per_rank
    reps: list[list[tuple[int, int]]] = [[] for _ in range(E)]
    for r, row in enumerate(placement.slot_expert):
        for s, e in enumerate(row):
            if e == EMPTY:
                continue                     # degraded slot: hosts nothing
            reps[e].append((r, s))           # rank-major replica order
    rmax = max(len(x) for x in reps)
    rank_t = np.full((E + 1, rmax), N, np.int32)
    slot_t = np.full((E + 1, rmax), S, np.int32)
    count_t = np.ones((E + 1,), np.int32)
    for e, rs in enumerate(reps):
        count_t[e] = len(rs)
        for j, (r, s) in enumerate(rs):
            rank_t[e, j], slot_t[e, j] = r, s
        for j in range(len(rs), rmax):       # pad with the primary replica
            rank_t[e, j], slot_t[e, j] = rs[0]
    se = np.asarray(placement.slot_expert, np.int32)
    primary = np.asarray([rs[0][0] * S + rs[0][1] for rs in reps], np.int32)
    return PlacementTables(rank_t, slot_t, count_t, se, primary)


def assign(placement: EpPlacement, experts, src_rank):
    """Resolve global expert ids to physical (rank, slot) at plan time.

    ``experts`` may include the padding sentinel ``num_experts`` (-> rank N,
    slot S, out of range everywhere). ``src_rank`` (broadcastable to
    ``experts``) picks the replica as ``src_rank % replica_count`` — a pure
    function of replicated metadata, so every rank derives the same answer
    and a hot expert's senders round-robin over its replicas."""
    tb = tables(placement)
    e = jnp.clip(jnp.asarray(experts), 0, placement.num_experts)
    j = jnp.asarray(src_rank) % jnp.asarray(tb.replica_count)[e]
    return (jnp.asarray(tb.replica_rank)[e, j],
            jnp.asarray(tb.replica_slot)[e, j])


# --------------------------------------------------------------------------
# heat: per-logical-expert load statistics
# --------------------------------------------------------------------------

def heat_from_topk(topk_idx, num_experts: int):
    """[E] routed-token histogram from a routing tensor (any leading shape);
    out-of-range ids (the padding sentinel) are dropped."""
    flat = jnp.asarray(topk_idx).reshape(-1)
    ok = (flat >= 0) & (flat < num_experts)
    return jnp.zeros((num_experts,), jnp.float32).at[
        jnp.where(ok, flat, num_experts)].add(
            ok.astype(jnp.float32), mode="drop")


def fold_slot_counts(placement: EpPlacement | None, counts_by_rank):
    """Fold per-physical-slot receive counts [N, S] (each rank's
    ``recv_counts`` / ``tokens_per_expert``) into logical per-expert heat
    [E]: replicas of one expert sum. ``placement=None`` = contiguous."""
    c = np.asarray(counts_by_rank, np.float64)
    if placement is None:
        return c.reshape(-1)
    heat = np.zeros(placement.num_experts, np.float64)
    se = tables(placement).slot_expert.reshape(-1)
    live = se != EMPTY      # empty slots receive nothing; don't let the
    #                         sentinel alias an expert id under np.add.at
    np.add.at(heat, se[live], c.reshape(-1)[live])
    return heat


class HeatTracker:
    """Host-side heat accumulator: fold per-step heat vectors, optionally
    with exponential decay so stale traffic ages out of the rebalancer's
    view. ``totals`` is the current [E] float64 heat."""

    def __init__(self, num_experts: int, decay: float = 0.0):
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay={decay} must be in [0, 1)")
        self.totals = np.zeros(num_experts, np.float64)
        self.decay = decay

    def update(self, heat) -> np.ndarray:
        h = np.asarray(heat, np.float64)
        if h.shape != self.totals.shape:
            raise ValueError(f"heat shape {h.shape} != {self.totals.shape}")
        if self.decay:
            self.totals *= 1.0 - self.decay
        self.totals += h
        return self.totals


def rank_loads(heat, placement: EpPlacement | None, num_ranks: int | None = None):
    """Expected per-rank load [N] under a placement: each expert's heat
    splits evenly over its replicas (the round-robin selection's steady
    state). ``placement=None`` (contiguous) needs ``num_ranks``."""
    h = np.asarray(heat, np.float64)
    if placement is None:
        assert num_ranks is not None
        return h.reshape(num_ranks, -1).sum(axis=1)
    tb = tables(placement)
    share = h / np.maximum(tb.replica_count[:-1], 1)
    live = tb.slot_expert != EMPTY
    return (share[np.where(live, tb.slot_expert, 0)] * live).sum(axis=1)


def imbalance(loads) -> float:
    """max/mean load ratio (1.0 = perfectly balanced)."""
    loads = np.asarray(loads, np.float64)
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0


# --------------------------------------------------------------------------
# rebalancer: heat -> placement (optionally fault-domain constrained)
# --------------------------------------------------------------------------

def _floor_ctx(E: int, num_redundant: int, num_ranks: int, alive,
               domains: FaultDomains | None, min_replicas: int) -> str:
    """The E/R/N/domains tail every floor error message carries."""
    return (f"[E={E} experts, R={num_redundant} redundant slots, "
            f"N={len(alive)} alive of {num_ranks} ranks, "
            f"min_replicas={min_replicas}, domains="
            f"{domains.describe() if domains is not None else None}]")


def _warn_degraded(msg: str):
    """Loud DegradedRecovery-class warning without a core->runtime import
    cycle (runtime/fault.py imports nothing from here either way, but the
    category is defined there — the serving layer owns the recovery
    vocabulary)."""
    import warnings

    from repro.runtime.fault import DegradedRecovery
    warnings.warn(DegradedRecovery(msg), stacklevel=3)


def required_domain_span(E: int, min_replicas: int, alive,
                         domains: FaultDomains | None,
                         domain_caps: dict | None = None, *,
                         warn: bool = False) -> int:
    """How many DISTINCT fault domains each expert's replicas must span.

    The target is ``min(min_replicas, live domain count)`` — "distinct
    domains when domains permit" (ISSUE/DESIGN §9). Domains stop permitting
    when capacity does: each expert claims one slot in each of ``span``
    domains and a domain can serve at most ``min(cap_D, E)`` such claims, so
    the span is lowered (never below 1) until
    ``sum_D min(cap_D, E) >= E * span`` holds. ``domain_caps`` maps live
    domain -> slot capacity; with ``warn=True`` a capacity-forced lowering
    emits a loud DegradedRecovery-class warning (uneven pods weaken the
    correlated-failure guarantee and that must never be silent)."""
    if domains is None or min_replicas <= 1:
        return 1
    live = domains.live_domains(alive)
    target = min(min_replicas, len(live))
    if target <= 1:
        return 1
    caps = [domain_caps[d] for d in live] if domain_caps is not None else None
    span = target
    if caps is not None:
        while span > 1 and sum(min(c, E) for c in caps) < E * span:
            span -= 1
    if span < target and warn:
        _warn_degraded(
            f"fault domains too uneven to give every expert {target} "
            f"distinct domains (per-domain slot capacities "
            f"{dict(zip(live, caps))}, E={E}) — enforcing span {span}; "
            "a whole-domain failure may lose some experts' last replica")
    return span


def rebalance(heat, num_ranks: int, *, num_redundant: int = 0,
              version: int = 1,
              alive_ranks: tuple[int, ...] | None = None,
              min_replicas: int = 1,
              domains: FaultDomains | None = None,
              max_slots_per_rank: int | None = None,
              check_shrink: bool | None = None) -> EpPlacement:
    """Greedy placement minimizing the max per-rank load.

    1. Replica counts: every expert gets ``min_replicas`` slots (the
       min-replica floor); each remaining redundant slot goes to the expert
       with the current highest per-replica load (heat / replicas) —
       DeepSeek-EPLB-style redundancy for the hottest experts. Under the
       floor, replica counts are capped at the alive-rank count (a replica
       beyond that could only co-host).
    2. Packing: replicas sorted by descending per-replica load are LPT-packed
       onto ranks (least-loaded rank with a free slot wins). Replicas of one
       expert land on distinct ranks — a hard constraint under the floor
       (``min_replicas > 1``; an impossible table raises loudly), a
       preference in legacy floor-less mode where a FORCED co-hosting now
       emits a loud DegradedRecovery-class warning (co-hosted replicas are
       dead weight for both load-splitting and fault tolerance). Fully
       deterministic: ties break by expert id then rank id.

    ``alive_ranks`` (elastic EP, docs/DESIGN.md §9): pack onto that subset
    only — the table still spans ``num_ranks`` rows (the group's static
    geometry is unchanged) but every dead rank's row is all ``EMPTY``, so
    plan-time assignment routes it zero traffic. ``num_experts +
    num_redundant`` must then divide by the survivor count
    (``shrink_placement`` auto-fits the redundancy budget).

    Fault domains (docs/DESIGN.md §9): with ``domains`` and a floor, each
    expert's first ``required_domain_span(...)`` replicas are forced into
    DISTINCT fault domains — a whole-domain (pod) failure then leaves every
    expert a surviving replica, so recovery is the zero-data-loss masked
    rebind, never a checkpoint restore. Extra replicas prefer fresh domains.
    Infeasible floors (too few redundant slots / alive ranks / domain
    capacity) raise loudly, naming E/R/N/domains.

    Shrink-feasibility precheck: under a floor (default) the produced table
    is validated with ``assert_shrink_feasible`` BEFORE being returned — a
    subsequent whole-domain failure must leave a survivor set onto which the
    shrink can re-pack without violating the floor or over-packing past
    ``max_slots_per_rank``. Adoption-time is where infeasibility surfaces,
    never mid-recovery. ``check_shrink=False`` opts out (the degraded
    re-pack after an actual death keeps the floor checks but skips the
    what-if)."""
    h = np.asarray(heat, np.float64)
    E = h.size
    P = E + num_redundant
    if num_redundant < 0:
        raise ValueError(f"num_redundant={num_redundant} must be >= 0")
    if min_replicas < 1:
        raise ValueError(f"min_replicas={min_replicas} must be >= 1")
    alive = (tuple(range(num_ranks)) if alive_ranks is None
             else tuple(sorted(set(alive_ranks))))
    if not alive or any(not 0 <= r < num_ranks for r in alive):
        raise ValueError(f"alive_ranks={alive_ranks} must be a non-empty "
                         f"subset of range({num_ranks})")
    if domains is not None and domains.num_ranks != num_ranks:
        raise ValueError(f"domains cover {domains.num_ranks} ranks, "
                         f"rebalance spans num_ranks={num_ranks}")
    m = min_replicas
    ctx = _floor_ctx(E, num_redundant, num_ranks, alive, domains, m)
    if m > 1:
        if len(alive) < m:
            raise ValueError(
                f"min_replicas={m} floor infeasible: needs {m} distinct "
                f"ranks per expert but only {len(alive)} are alive {ctx}")
        if num_redundant < E * (m - 1):
            raise ValueError(
                f"min_replicas={m} floor infeasible: needs num_redundant >= "
                f"E*(min_replicas-1) = {E * (m - 1)}, got {num_redundant} "
                f"{ctx}")
    if P % len(alive):
        raise ValueError(
            f"num_experts+num_redundant={P} must divide by the "
            f"{'alive rank count' if alive_ranks is not None else 'rank count'}"
            f"={len(alive)}")
    S = P // len(alive)
    if m > 1:
        if S > E:
            raise ValueError(
                f"min_replicas={m} floor infeasible: {S} slots per alive "
                f"rank exceed the {E} experts — some rank would have to "
                f"co-host replicas of one expert {ctx}")

    # ---- replica counts: floor first, extras to the hottest ----
    rc = np.full(E, m, np.int64)
    for _ in range(num_redundant - E * (m - 1)):
        per = h / rc
        if m > 1:                            # hard floor: no co-hosting ever
            per = np.where(rc >= len(alive), -np.inf, per)
        e = int(np.argmax(per))              # argmax: first index on ties
        rc[e] += 1

    # ---- domain spread target + per-domain capacities ----
    dom_caps = None
    if domains is not None:
        dom_caps = {d: S * len([r for r in alive
                                if domains.domain_of[r] == d])
                    for d in domains.live_domains(alive)}
    span_req = required_domain_span(E, m, alive, domains, dom_caps, warn=True)

    # ---- LPT packing under the constraints ----
    items = sorted(
        ((h[e] / rc[e], e) for e in range(E) for _ in range(rc[e])),
        key=lambda t: (-t[0], t[1]))
    loads = np.zeros(num_ranks, np.float64)
    rows: dict[int, list[int]] = {r: [] for r in alive}
    hosted: dict[int, set[int]] = {r: set() for r in alive}
    placed = np.zeros(E, np.int64)
    doms_used: dict[int, set[int]] = {e: set() for e in range(E)}

    def _place(e, r, load):
        rows[r].append(e)
        hosted[r].add(e)
        loads[r] += load
        placed[e] += 1
        if domains is not None:
            doms_used[e].add(domains.domain_of[r])

    def _repair(e, want_fresh_domain: bool):
        """Free a slot on a constraint-satisfying rank by relocating one
        already-placed replica (deterministic search; returns the freed
        rank or None). Only reached under the floor when greedy order
        painted itself into a corner — the relocated replica keeps its own
        rank-distinctness and domain span."""
        targets = [r for r in alive if e not in hosted[r]]
        if want_fresh_domain:
            targets = [r for r in targets
                       if domains.domain_of[r] not in doms_used[e]]
        for r_t in sorted(targets, key=lambda r: (loads[r], r)):
            for e2 in list(rows[r_t]):
                for r_o in sorted(alive, key=lambda r: (loads[r], r)):
                    if (r_o == r_t or len(rows[r_o]) >= S
                            or e2 in hosted[r_o]):
                        continue
                    if domains is not None:
                        new_doms = {domains.domain_of[r] for r in alive
                                    if e2 in hosted[r] and r != r_t}
                        new_doms.add(domains.domain_of[r_o])
                        need2 = min(span_req, int(placed[e2]))
                        if len(new_doms) < need2:
                            continue
                    # move e2: r_t -> r_o (its load share moves with it)
                    l2 = h[e2] / rc[e2]
                    rows[r_t].remove(e2)
                    hosted[r_t].discard(e2)
                    loads[r_t] -= l2
                    rows[r_o].append(e2)
                    hosted[r_o].add(e2)
                    loads[r_o] += l2
                    if domains is not None:
                        doms_used[e2] = {domains.domain_of[r] for r in alive
                                         if e2 in hosted[r]}
                    return r_t
        return None

    for load, e in items:
        cand = [r for r in alive
                if len(rows[r]) < S and e not in hosted[r]]
        want_fresh = (domains is not None and m > 1
                      and placed[e] < span_req
                      and len(doms_used[e]) < span_req)
        if want_fresh:
            fresh = [r for r in cand
                     if domains.domain_of[r] not in doms_used[e]]
            if not fresh:
                freed = _repair(e, want_fresh_domain=True)
                if freed is None:
                    raise ValueError(
                        f"min_replicas={m} floor infeasible: expert {e} "
                        f"cannot reach {span_req} distinct fault domains "
                        f"{ctx}")
                fresh = [freed]
            cand = fresh
        elif domains is not None and cand:
            pref = [r for r in cand
                    if domains.domain_of[r] not in doms_used[e]]
            if pref:                         # soft: spread extras too
                cand = pref
        if not cand:
            if m > 1:                        # hard error under the floor
                freed = _repair(e, want_fresh_domain=False)
                if freed is None:
                    raise ValueError(
                        f"min_replicas={m} floor infeasible: no rank can "
                        f"host a distinct replica of expert {e} {ctx}")
                cand = [freed]
            else:                            # legacy: forced co-host, LOUD
                cand = [r for r in alive if len(rows[r]) < S]
                _warn_degraded(
                    f"rebalance forced to collocate replicas of expert {e} "
                    f"on one rank (every alive rank with free slots already "
                    f"hosts it) — the co-hosted replica splits no load and "
                    f"survives no rank death {ctx}")
        r = min(cand, key=lambda r: (loads[r], r))
        _place(e, r, load)
    pl = EpPlacement(E, tuple(
        tuple(rows[r]) if r in rows else (EMPTY,) * S
        for r in range(num_ranks)), version=version)
    if m > 1:
        validate_floor(pl, m, domains)       # bug guard: never emit a
        #                                      floor-violating table
        if check_shrink is None:
            check_shrink = True
        if check_shrink:
            assert_shrink_feasible(
                E, num_redundant, num_ranks, alive_ranks=alive,
                domains=domains, min_replicas=m,
                max_slots_per_rank=max_slots_per_rank, placement=pl)
    return pl


def redundant_placement(num_experts: int, num_ranks: int, num_redundant: int,
                        version: int = 0) -> EpPlacement:
    """Uniform-heat convenience: replicate ``num_redundant`` experts (ties
    resolve to the lowest ids) and pack — the zero-knowledge starting point
    before any heat has been observed."""
    return rebalance(np.ones(num_experts), num_ranks,
                     num_redundant=num_redundant, version=version)


# --------------------------------------------------------------------------
# elastic EP: degraded placements around dead ranks (docs/DESIGN.md §9)
# --------------------------------------------------------------------------

def fit_redundant(num_experts: int, num_redundant: int, n_alive: int, *,
                  min_replicas: int = 1) -> int:
    """Largest redundancy budget <= ``num_redundant`` whose total slot count
    divides by the survivor count — or, when none exists (e.g. E=8 on 7
    survivors with R=0), the smallest larger one. Keeps shrink/expand from
    failing on divisibility when the rank count changes under a fixed R.

    ``min_replicas`` imposes the replica floor on the budget itself: the
    result never drops below ``E * (min_replicas - 1)`` (each expert's floor
    replicas beyond the first consume one redundant slot), so a refit after
    rank death cannot silently fit a budget the floor can't live in —
    e.g. ``fit_redundant(8, 8, 7, min_replicas=2)`` is 13, not 6."""
    floor = num_experts * (max(min_replicas, 1) - 1)
    for r in range(num_redundant, floor - 1, -1):
        if (num_experts + r) % n_alive == 0:
            return r
    r = max(num_redundant + 1, floor)
    while (num_experts + r) % n_alive:
        r += 1
    return r


def validate_floor(placement: EpPlacement, min_replicas: int,
                   domains: FaultDomains | None = None, *,
                   where: str = "placement") -> None:
    """Assert the min-replica floor on a CONCRETE table: every expert has
    >= ``min_replicas`` replicas, each on a distinct alive rank, spanning
    >= ``required_domain_span(...)`` distinct fault domains. Raises
    ``ValueError`` naming the first offending expert — the safety net behind
    ``rebalance``'s constructive guarantees and the adoption-time check in
    the serving layer."""
    if min_replicas <= 1 and domains is None:
        return
    E = placement.num_experts
    alive = placement.alive_ranks()
    span_req = 1
    if domains is not None:
        if domains.num_ranks != placement.num_ranks:
            raise ValueError(
                f"domains cover {domains.num_ranks} ranks, {where} spans "
                f"{placement.num_ranks}")
        S = placement.slots_per_rank
        caps = {d: S * len([r for r in alive
                            if domains.domain_of[r] == d])
                for d in domains.live_domains(alive)}
        span_req = required_domain_span(E, min_replicas, alive, domains, caps)
    hosts: dict[int, list[int]] = {e: [] for e in range(E)}
    for r, row in enumerate(placement.slot_expert):
        for e in row:
            if e != EMPTY:
                hosts[e].append(r)
    for e in range(E):
        rs = hosts[e]
        if len(set(rs)) < len(rs):
            dup = sorted({r for r in rs if rs.count(r) > 1})
            raise ValueError(
                f"{where} violates the min-replica floor: expert {e} has "
                f"co-hosted replicas on rank(s) {dup} — collocated replicas "
                "split no load and survive no rank death")
        if len(rs) < min_replicas:
            raise ValueError(
                f"{where} violates the min-replica floor: expert {e} has "
                f"{len(rs)} replica(s) on ranks {sorted(rs)}, needs "
                f">= {min_replicas}")
        if domains is not None:
            span = len({domains.domain_of[r] for r in rs})
            if span < span_req:
                raise ValueError(
                    f"{where} violates the fault-domain floor: expert {e}'s "
                    f"replicas on ranks {sorted(rs)} span {span} domain(s) "
                    f"of required {span_req} (domains {domains.describe()})")


def shrink_feasibility(num_experts: int, num_redundant: int, num_ranks: int,
                       *, alive_ranks=None,
                       domains: FaultDomains | None = None,
                       min_replicas: int = 1,
                       max_slots_per_rank: int | None = None,
                       placement: EpPlacement | None = None) -> list[str]:
    """What-if every single correlated failure, BEFORE adopting a placement:
    for each failure unit (a live fault domain, or each alive rank when
    ``domains`` is None), would the shrink onto the survivors still work?
    Returns a list of human-readable infeasibility reasons (empty = all
    scenarios recoverable). A scenario is feasible when

    - the concrete ``placement`` (if given) keeps a surviving replica of
      every expert (``lost_experts`` empty) — zero-data-loss masked rebind;
    - the refitted budget ``fit_redundant(E, R, n_surv,
      min_replicas=min(m, n_surv))`` packs at <= ``num_experts`` slots per
      survivor (pigeonhole: no forced co-hosting) and at
      <= ``max_slots_per_rank`` when a headroom cap is set.

    Scenarios that kill EVERY alive rank are skipped — nothing recovers
    from losing the whole deployment, and requiring it would make every
    single-domain topology infeasible by definition."""
    alive = (tuple(range(num_ranks)) if alive_ranks is None
             else tuple(sorted(set(alive_ranks))))
    units: list[tuple[str, tuple[int, ...]]] = (
        [(f"domain {d}", tuple(r for r in domains.ranks_in(d) if r in alive))
         for d in domains.live_domains(alive)]
        if domains is not None else
        [(f"rank {r}", (r,)) for r in alive])
    problems: list[str] = []
    ctx = _floor_ctx(num_experts, num_redundant, num_ranks, alive, domains,
                     min_replicas)
    for name, killed in units:
        survivors = tuple(r for r in alive if r not in set(killed))
        if not survivors:
            continue                         # total loss: out of scope
        if placement is not None:
            lost = lost_experts(placement, survivors)
            if lost:
                problems.append(
                    f"killing {name} (ranks {list(killed)}) loses every "
                    f"replica of experts {list(lost)[:8]} — shrink would "
                    f"need a checkpoint restore {ctx}")
                continue
        m_eff = min(min_replicas, len(survivors))
        R2 = fit_redundant(num_experts, num_redundant, len(survivors),
                           min_replicas=m_eff)
        S2 = (num_experts + R2) // len(survivors)
        if S2 > num_experts:
            problems.append(
                f"killing {name} (ranks {list(killed)}) over-packs the "
                f"{len(survivors)} survivor(s): {S2} slots per rank exceed "
                f"the {num_experts} experts {ctx}")
        elif max_slots_per_rank is not None and S2 > max_slots_per_rank:
            problems.append(
                f"killing {name} (ranks {list(killed)}) over-packs the "
                f"{len(survivors)} survivor(s): {S2} slots per rank exceed "
                f"the max_slots_per_rank={max_slots_per_rank} headroom cap "
                f"{ctx}")
    return problems


def assert_shrink_feasible(num_experts: int, num_redundant: int,
                           num_ranks: int, *, alive_ranks=None,
                           domains: FaultDomains | None = None,
                           min_replicas: int = 1,
                           max_slots_per_rank: int | None = None,
                           placement: EpPlacement | None = None) -> None:
    """Raise ``ValueError`` listing every infeasible correlated-failure
    scenario found by ``shrink_feasibility`` — the adoption-time gate:
    infeasibility surfaces when a placement is BUILT, never mid-recovery."""
    problems = shrink_feasibility(
        num_experts, num_redundant, num_ranks, alive_ranks=alive_ranks,
        domains=domains, min_replicas=min_replicas,
        max_slots_per_rank=max_slots_per_rank, placement=placement)
    if problems:
        raise ValueError(
            "placement fails the shrink-feasibility precheck:\n  - "
            + "\n  - ".join(problems))


def lost_experts(placement: EpPlacement | None,
                 alive_ranks) -> tuple[int, ...]:
    """Experts whose EVERY replica sits on a non-alive rank — the weights a
    shrink cannot recover from survivors (zero-data-loss fails; the driver
    must fall back to checkpoint restore). ``placement=None`` = contiguous
    striping, where no expert has a second replica."""
    alive = set(alive_ranks)
    if placement is None:
        return ()               # resolved by the caller via identity_placement
    lost = []
    tb = tables(placement)
    for e in range(placement.num_experts):
        n = int(tb.replica_count[e])
        if not any(int(tb.replica_rank[e, j]) in alive for j in range(n)):
            lost.append(e)
    return tuple(lost)


def mask_placement(placement: EpPlacement,
                   alive_ranks) -> EpPlacement:
    """The placement restricted to its survivors: non-alive rows become all
    ``EMPTY``. This is the SOURCE layout for a zero-data-loss shrink rebind
    — collapsing through it reads only live replicas, never a dead rank's
    slots. Raises when any expert would lose its last replica
    (``lost_experts`` names them); callers check first and take the
    checkpoint-restore fallback instead."""
    alive = set(alive_ranks)
    lost = lost_experts(placement, alive)
    if lost:
        raise ValueError(
            f"experts {list(lost)[:8]} have no replica on alive ranks "
            f"{sorted(alive)} — weights unrecoverable from survivors "
            "(restore from checkpoint)")
    S = placement.slots_per_rank
    tbl = tuple(row if r in alive else (EMPTY,) * S
                for r, row in enumerate(placement.slot_expert))
    if tbl == placement.slot_expert:
        return placement
    return dataclasses.replace(placement, slot_expert=tbl)


def _floor_kwargs(min_replicas: int, domains: FaultDomains | None,
                  max_slots_per_rank: int | None, *,
                  check_shrink: bool | None = None) -> dict:
    """The kwargs the elastic paths forward to ``rebalance`` — EMPTY unless
    floor mode is active (``min_replicas > 1`` or explicit ``domains``), so
    a legacy custom ``rebalance_fn`` that predates the floor keeps working
    and legacy placements stay bit-identical."""
    if min_replicas <= 1 and domains is None:
        return {}
    kw: dict = dict(min_replicas=min_replicas, domains=domains,
                    max_slots_per_rank=max_slots_per_rank)
    if check_shrink is not None:
        kw["check_shrink"] = check_shrink
    return kw


def shrink_placement(heat, num_ranks: int, dead_ranks, *,
                     num_redundant: int = 0, version: int = 1,
                     rebalance_fn=None, min_replicas: int = 1,
                     domains: FaultDomains | None = None,
                     max_slots_per_rank: int | None = None) -> EpPlacement:
    """Degraded placement after rank death: every expert packed onto the
    survivors (dead rows all ``EMPTY`` — zero slots, zero traffic), the
    redundancy budget auto-fitted to the survivor count. Heat-driven like
    any rebalance, so the degraded table is still load-balanced.

    Under the min-replica floor the budget refit keeps the floor's share
    (``fit_redundant(..., min_replicas=...)``, the floor itself relaxing to
    the survivor count when fewer ranks than ``min_replicas`` remain) and
    the repack enforces distinct ranks/domains — but the degraded table
    skips the what-if shrink precheck: the HEALTHY placement's
    adoption-time precheck already guaranteed this shrink works, and
    demanding the degraded table survive a FURTHER correlated failure
    would turn every recovery into a double-failure requirement."""
    dead = set(dead_ranks)
    alive = tuple(r for r in range(num_ranks) if r not in dead)
    if not alive:
        raise ValueError(f"all {num_ranks} ranks dead — nothing to shrink onto")
    E = np.asarray(heat).size
    m_eff = min(min_replicas, len(alive))
    R = fit_redundant(E, num_redundant, len(alive), min_replicas=m_eff)
    fn = rebalance_fn or rebalance
    return fn(heat, num_ranks, num_redundant=R, version=version,
              alive_ranks=alive,
              **_floor_kwargs(m_eff, domains, max_slots_per_rank,
                              check_shrink=False))


def expand_placement(heat, num_ranks: int, *, num_redundant: int = 0,
                     version: int = 1, rebalance_fn=None,
                     min_replicas: int = 1,
                     domains: FaultDomains | None = None,
                     max_slots_per_rank: int | None = None) -> EpPlacement:
    """The symmetric rejoin path: a full-width rebalance over all ranks
    again (redundancy budget refitted in case the caller's R only fit the
    degraded geometry). The rejoined rank's slots are filled by replica
    expansion at adoption — replicas duplicate live weights, so re-expand
    is always zero-data-loss. Floor mode re-runs the full shrink-
    feasibility precheck: a full-width table must again survive any single
    correlated failure."""
    E = np.asarray(heat).size
    R = fit_redundant(E, num_redundant, num_ranks, min_replicas=min_replicas)
    fn = rebalance_fn or rebalance
    return fn(heat, num_ranks, num_redundant=R, version=version,
              **_floor_kwargs(min_replicas, domains, max_slots_per_rank))


class RebalanceScheduler:
    """Host-side EPLB schedule shared by the runtime drivers
    (`runtime/decode.py`, `runtime/prefill.py`, `runtime/server.py`):
    ``observe`` folds heat, ``advance`` emits the placement for the next
    window. When the rebalancer reproduces the current slot table verbatim
    (steady traffic), the SAME placement object is returned — version and
    fingerprint unchanged — so per-placement compiled-function caches keep
    hitting and the refresh fast path survives the boundary.

    Elastic EP: ``set_alive`` narrows the scheduler to the surviving ranks —
    every subsequent ``advance`` emits a DEGRADED placement (dead rows all
    ``EMPTY``, redundancy refitted to the survivor count); restoring the
    full set flips it back to full-width tables (the rejoin/expand path).
    A custom ``rebalance_fn`` must accept ``alive_ranks=`` to be used with
    a narrowed alive set (and the floor kwargs when ``min_replicas``/
    ``domains`` are set — floor kwargs are only forwarded in floor mode,
    so legacy custom fns keep working floor-less).

    Fault-domain floor (docs/DESIGN.md §9): with ``min_replicas > 1``
    and/or ``domains``, every emitted FULL-WIDTH placement enforces the
    floor and passes the shrink-feasibility precheck before it leaves the
    scheduler; degraded placements enforce the (survivor-relaxed) floor
    but skip the what-if precheck."""

    def __init__(self, num_experts: int, num_ranks: int, *,
                 num_redundant: int = 0, decay: float = 0.0,
                 rebalance_fn=None, initial: EpPlacement | None = None,
                 min_replicas: int = 1,
                 domains: FaultDomains | None = None,
                 max_slots_per_rank: int | None = None):
        if min_replicas < 1:
            raise ValueError(f"min_replicas={min_replicas} must be >= 1")
        if domains is not None and domains.num_ranks != num_ranks:
            raise ValueError(f"domains cover {domains.num_ranks} ranks, "
                             f"scheduler spans num_ranks={num_ranks}")
        self.tracker = HeatTracker(num_experts, decay=decay)
        self.num_ranks = num_ranks
        self.num_redundant = num_redundant
        self.rebalance_fn = rebalance_fn or rebalance
        self.placement = initial
        self.alive: tuple[int, ...] = tuple(range(num_ranks))
        self._version = 0
        self.min_replicas = min_replicas
        self.domains = domains
        self.max_slots_per_rank = max_slots_per_rank

    def observe(self, heat):
        self.tracker.update(np.asarray(heat, np.float64))

    def set_alive(self, alive_ranks):
        alive = tuple(sorted(set(alive_ranks)))
        if not alive or any(not 0 <= r < self.num_ranks for r in alive):
            raise ValueError(f"alive_ranks={alive_ranks} must be a non-empty "
                             f"subset of range({self.num_ranks})")
        self.alive = alive

    def advance(self) -> EpPlacement:
        v = self._version + 1
        if len(self.alive) < self.num_ranks:
            dead = [r for r in range(self.num_ranks) if r not in self.alive]
            new = shrink_placement(self.tracker.totals, self.num_ranks, dead,
                                   num_redundant=self.num_redundant,
                                   version=v, rebalance_fn=self.rebalance_fn,
                                   min_replicas=self.min_replicas,
                                   domains=self.domains,
                                   max_slots_per_rank=self.max_slots_per_rank)
        else:
            R = fit_redundant(self.tracker.totals.size, self.num_redundant,
                              self.num_ranks,
                              min_replicas=self.min_replicas)
            new = self.rebalance_fn(self.tracker.totals, self.num_ranks,
                                    num_redundant=R, version=v,
                                    **_floor_kwargs(self.min_replicas,
                                                    self.domains,
                                                    self.max_slots_per_rank))
        if (self.placement is not None
                and new.slot_expert == self.placement.slot_expert):
            return self.placement            # unchanged table: reuse object
        self._version += 1
        self.placement = (new if new.version == self._version
                          else dataclasses.replace(new, version=self._version))
        return self.placement


def run_rebalancing(base_cfg, make_fn, items, *, advance_every: int,
                    ep_size: int, num_redundant: int = 0,
                    inner_size: int | None = None, decay: float = 0.0,
                    rebalance_fn=None, params=None,
                    expert_keys: tuple = EXPERT_PARAM_KEYS,
                    donate_params: bool = True, fault_injector=None,
                    min_replicas: int = 1,
                    fault_domains: FaultDomains | None = None,
                    max_slots_per_rank: int | None = None,
                    tracer=None, series=None):
    """Shared skeleton of the host-level EPLB drivers (`runtime/decode.py`,
    `runtime/prefill.py`): run each item through a per-placement compiled
    fn, fold its heat, and advance the placement at every ``advance_every``
    item boundary (never after the last item). ``make_fn(group)`` builds the
    caller's jit/shard_map-wrapped unit returning ``(out, heat)``; fns are
    cached per placement object, so an unchanged rebalance table (the
    scheduler's dedup) re-traces nothing. The cache is BOUNDED to the
    current and previous placement: a long-lived server swapping hundreds
    of times must not accumulate compiled executables (each holds device
    buffers for its traced constants). Returns ``(outs, placements)``,
    one entry per item.

    Adopt-once physical weights: with ``params`` (a pytree whose
    ``expert_keys`` dict leaves carry a leading expert axis), ``make_fn`` is
    called as ``make_fn(group, params)`` where the expert leaves have been
    rebound ONCE per adopted placement into that placement's physical slot
    order (old physical -> new physical) — the per-step in-graph expansion
    is skipped entirely, which is the serving fast path (docs/DESIGN.md
    §8). ``params`` must arrive laid out for ``base_cfg.placement``
    (logical when that is None). With ``donate_params=True`` (default) the
    driver takes OWNERSHIP: old expert buffers are donated at each
    boundary (peak memory ~one weight set), which deletes the caller's
    arrays when the slot count is preserved — pass ``donate_params=False``
    to keep using the original tree afterwards.

    Elastic EP (``fault_injector``, docs/DESIGN.md §9): the injector's
    kill/rejoin schedule is polled at every item boundary. A fault forces an
    immediate placement advance — shrink to a DEGRADED table (dead rows all
    ``EMPTY``) on a kill, full-width re-expand on a rejoin — instead of
    waiting for the next ``advance_every`` boundary. Across a shrink the
    ``params`` rebind collapses through the MASKED old placement (reads only
    surviving replicas); an expert whose every replica died makes
    zero-data-loss impossible, so the driver warns ``DegradedRecovery`` and
    raises — the serving layer (`runtime/server.py`) owns the
    checkpoint-restore fallback.

    Fault-domain floor (``min_replicas`` / ``fault_domains`` /
    ``max_slots_per_rank``, docs/DESIGN.md §9): forwarded to the scheduler —
    every adopted full-width placement then satisfies the floor and the
    shrink-feasibility precheck, which is what makes the injector path
    recover from ANY single correlated failure without hitting the
    lost-experts raise above.

    Telemetry (``tracer`` / ``series``, runtime/telemetry.py): each advance
    boundary lands as a ``rebalance`` span (params rebind nested as
    ``adopt``), injected faults as instants, and — with ``series`` — a
    per-window row carrying the imbalance ratio under the placement the
    window RAN under vs under the newly adopted one. Host-side only: the
    heat is already on the host at every boundary."""
    import dataclasses as _dc

    from repro.core.group import ep_create_group

    if advance_every < 1:
        raise ValueError(f"rebalance_every={advance_every} must be >= 1")
    sched = RebalanceScheduler(
        base_cfg.num_experts, ep_size, num_redundant=num_redundant,
        decay=decay, rebalance_fn=rebalance_fn, initial=base_cfg.placement,
        min_replicas=min_replicas, domains=fault_domains,
        max_slots_per_rank=max_slots_per_rank)
    pl = base_cfg.placement
    fns: dict = {}
    outs, placements = [], []
    for i, item in enumerate(items):
        cfg = _dc.replace(base_cfg, placement=pl, num_redundant_experts=0)
        group = ep_create_group(cfg, ep_size=ep_size, inner_size=inner_size)
        if pl not in fns:
            fns[pl] = (make_fn(group) if params is None
                       else make_fn(group, params))
            if len(fns) > 2:     # keep current + previous placement only
                for k in [k for k in fns if k is not pl][:-1]:
                    del fns[k]
        out, heat = fns[pl](item)
        outs.append(out)
        placements.append(pl)
        window = np.asarray(heat, np.float64)
        sched.observe(heat)
        fault = (fault_injector.advance(i) if fault_injector is not None
                 else None)
        if fault:
            if tracer is not None:
                tracer.instant("fault_detected", step=i,
                               died=list(fault.died),
                               rejoined=list(fault.rejoined))
            sched.set_alive(tuple(r for r in range(ep_size)
                                  if fault_injector.is_alive(r)))
        if (fault or (i + 1) % advance_every == 0) and i + 1 < len(items):
            with (tracer.span("rebalance", step=i) if tracer is not None
                  else contextlib.nullcontext()):
                new_pl = sched.advance()
                if series is not None:
                    # the window's imbalance as experienced (old placement)
                    # vs what the freshly adopted table would have given it
                    series.record(
                        kind="rebalance", step=i,
                        window_tokens=float(window.sum()),
                        imbalance=imbalance(rank_loads(window, pl, ep_size)),
                        imbalance_after=imbalance(
                            rank_loads(window, new_pl, ep_size)),
                        placement_changed=new_pl is not pl)
                if new_pl is not pl and params is not None:
                    from repro.checkpoint.store import rebind_expert_leaves
                    src = pl
                    if fault and fault.died:
                        # shrink: collapse only through surviving replicas —
                        # a dead rank's slot rows are gone on a real pod
                        src_live = (pl if pl is not None else
                                    identity_placement(base_cfg.num_experts,
                                                       ep_size))
                        lost = lost_experts(src_live, sched.alive)
                        if lost:
                            import warnings

                            from repro.runtime.fault import DegradedRecovery
                            warnings.warn(DegradedRecovery(
                                f"rank death {list(fault.died)} lost every "
                                f"replica of experts {list(lost)[:8]} — "
                                "zero-data-loss shrink impossible; restore "
                                "from checkpoint"))
                            raise ValueError(
                                f"experts {list(lost)[:8]} unrecoverable "
                                "from surviving ranks and run_rebalancing "
                                "has no checkpoint fallback — use "
                                "DecodeServer (ckpt_dir=...) or re-init the "
                                "lost weights")
                        src = mask_placement(src_live, sched.alive)
                    with (tracer.span("adopt", step=i) if tracer is not None
                          else contextlib.nullcontext()):
                        params = rebind_expert_leaves(
                            params, expert_keys, src_placement=src,
                            dst_placement=new_pl, donate=donate_params)
                pl = new_pl
    return outs, placements


# --------------------------------------------------------------------------
# replica-aware expert-parameter rebinding
# --------------------------------------------------------------------------

def expand_expert_params(w, placement: EpPlacement, axis: int = 0):
    """Logical expert-stacked weights [..., E, ...] -> physical slot order
    [..., N*S, ...] along ``axis``: each physical slot gets its logical
    expert's weights (replicas duplicate). numpy stays numpy (host-side
    checkpoint rebinds never round-trip through the device), jnp stays jnp
    — ``axis`` covers scan-stacked parameter trees whose expert dim sits
    behind the leading stack axis. Empty (degraded) slots host nothing but
    the physical buffer still needs rows, so they carry expert 0's weights —
    plan-time assignment never routes a token to them."""
    perm = tables(placement).slot_expert.reshape(-1)
    perm = np.where(perm == EMPTY, 0, perm)
    if isinstance(w, np.ndarray):
        return np.take(w, perm, axis=axis)
    return jnp.take(jnp.asarray(w), jnp.asarray(perm), axis=axis)


def collapse_expert_params(w_phys, placement: EpPlacement, axis: int = 0):
    """Physical slot-ordered weights [..., N*S, ...] -> logical [..., E, ...]
    along ``axis`` via each expert's primary replica (replicas hold identical
    weights by construction, so any replica would do — the primary is
    deterministic). numpy in, numpy out (see ``expand_expert_params``)."""
    rows = tables(placement).primary_row
    if isinstance(w_phys, np.ndarray):
        return np.take(w_phys, rows, axis=axis)
    return jnp.take(jnp.asarray(w_phys), jnp.asarray(rows), axis=axis)
