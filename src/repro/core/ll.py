"""Low-Latency (LL) mode — paper §IV.

Targets inference decode (1–128 tokens/rank). Direct all-to-all mesh over the
EP axis; 3D expert-major output ``[L, A, H]`` feeding grouped GEMM.

Two buffer layouts, selected by ``EpGroupConfig.ll_layout``:

* ``"deepep"`` — the original DeepEP layout the paper starts from: one slot
  per (expert, source-rank) pair, ``O(E·B·P)`` buffers. A token routed to k
  experts is sent k times. Dispatch/combine become pure reshape/transpose
  around the all-to-all (no metadata needed).

* ``"nccl_ep"`` — the paper's memory-optimized layout (§IV-D): a token is sent
  **once per destination rank** (routing dedup) into a per-rank block of
  ``C_d ≤ B`` slots → ``O(N·B·P)``; combine responses are packed compactly at
  per-(t,k) slots → ``O(B·K·P)``. The paper ships routing info in message
  headers; here both sides compute identical slot maps from the handle's
  replicated ``topk_idx``, so the header is zero bytes (see slots.py).

Every slot map is precomputed once at handle creation by the ``EpPlan``
engine (core/plan.py); the four phase bodies below are single-pass data
movement over those maps — dispatch-send runs the fused ``dispatch_pack``
kernel (gather + optional fp8 quantization in one pass, §IV-C(a)),
dispatch-recv runs its mirror ``recv_unpack`` via the shared
``core.recv.unpack_recv`` helper (gather through the expert-region map +
in-kernel fp8 dequantization, §IV-C(b)), and combine-recv runs the fused
``combine_gather_reduce`` kernel (gather through the slot rows + top-k
weighted reduction with no [T, K, H] materialization, §IV-C(c)). This is the
one-pass-per-phase invariant tests/test_plan.py enforces — on the recv side
it additionally greps that no phase performs a gather followed by a separate
dequantize pass.

Across decode steps, handles are steady-state-cheap: ``ep_handle_refresh``
(core/plan.py) rebinds per-step weights without rebuilding these maps, and
its routing-hash fast path skips plan construction entirely when the routing
replays (speculative decode, cached dispatch in backward).

Both layouts support staged execution (``send_only=True`` + ``ep_complete``),
the JAX rendering of the paper's double-buffered overlap: the returned
mode-tagged ``EpPending`` (core/backend.py — the one pending pytree shared by
every mode) lets XLA schedule the expert GEMM of one micro-batch against the
all-to-all of the next.

Quantized dispatch (fp8 payload + fp32 scales, §IV-B) rides the same slot maps
with a parallel scales buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import BaseBackend, EpPending, register_backend
from repro.core.group import EpGroup, EpHandle
from repro.core import slots as S
from repro.core import plan as P
from repro.core.recv import unpack_recv, dequant_rows
from repro.kernels import ops as K


def _axis(group: EpGroup):
    a = group.cfg.ep_axis
    return a if len(a) > 1 else a[0]


def _a2a(x, group):
    return jax.lax.all_to_all(x, _axis(group), split_axis=0, concat_axis=0, tiled=False)


# --------------------------------------------------------------------------
# handle
# --------------------------------------------------------------------------

def ll_create_handle(group: EpGroup, topk_idx, topk_weights, num_tokens=None) -> EpHandle:
    """All-gather routing metadata; derive the full slot-map plan.

    In the paper LL metadata travels in dispatch headers; gathering it at
    handle creation is the synchronized-collective equivalent (§IV-D a).
    The EpPlan computed here is the only place slot arithmetic happens."""
    topk_idx, nt = P.mask_padding(group, topk_idx, num_tokens)
    topk_g = P.gather_routing(group, topk_idx)
    counts = P.recv_counts(group, topk_g)
    plan = P.build_plan(group, topk_idx, topk_g, nt, topk_weights)
    return EpHandle(
        topk_idx=topk_idx, topk_weights=topk_weights, topk_global=topk_g,
        tokens_per_expert=counts, num_recv_tokens=counts.sum(), num_tokens=nt,
        plan=plan, routing_hash=P.routing_hash(topk_g, group.placement_salt),
    )


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def ll_dispatch_send(group: EpGroup, handle: EpHandle, x: jax.Array) -> EpPending:
    if group.cfg.ll_layout == "deepep":
        return _deepep_dispatch_send(group, handle, x)
    return _ncclep_dispatch_send(group, handle, x)


def ll_dispatch(group: EpGroup, handle: EpHandle, x: jax.Array, *, send_only=False):
    """x: [T, H] local tokens -> (out3d [L, A, H], tokens_per_expert [L]).

    With send_only=True returns a mode-tagged EpPending (staged mode)."""
    pending = ll_dispatch_send(group, handle, x)
    if send_only:
        return pending
    return ll_complete_dispatch(group, handle, pending)


def ll_complete_dispatch(group: EpGroup, handle: EpHandle, pending: EpPending):
    if group.cfg.ll_layout == "deepep":
        return _deepep_dispatch_recv(group, handle, pending)
    return _ncclep_dispatch_recv(group, handle, pending)


def _pack_send(group: EpGroup, x, gmap):
    """One fused pass over the send path: gather rows through the plan's slot
    map and (when configured) quantize to fp8 in the same kernel."""
    if group.cfg.quantize_dispatch:
        return K.dispatch_pack(x, gmap, quant_block=group.cfg.quant_block)
    return K.dispatch_pack(x, gmap, out_dtype=group.cfg.payload_dtype)


# ---- nccl_ep (memory-optimized) layout ----

def _ncclep_dispatch_send(group, handle, x):
    plan = P.ensure_plan(group, handle)
    send, scales = _pack_send(group, x, plan.disp_send_gmap)   # [N, Cd, ...]
    recv = _a2a(send, group)
    recv_s = _a2a(scales, group) if scales is not None else None
    return EpPending(mode="ll", op="dispatch", recv=recv, recv_scales=recv_s)


def _ncclep_dispatch_recv(group, handle, pending):
    """Unpack [N, C_d, H] into the 3D expert-major tensor [L, A, H]: one
    fused pass over the plan's precomputed expert-region map (gather +
    in-kernel fp8 dequantization when the payload is quantized)."""
    plan = P.ensure_plan(group, handle)
    out = unpack_recv(pending.recv, plan.disp_recv_gmap, pending.recv_scales)
    return out, plan.disp_counts


# ---- deepep (per-(expert,rank)-slot) layout ----

def _deepep_dispatch_send(group, handle, x):
    """One send per (t, k) entry into slot (dst_rank, e_local*B + t)."""
    plan = P.ensure_plan(group, handle)
    send, scales = _pack_send(group, x, plan.disp_send_gmap)   # [N, L*B, ...]
    recv = _a2a(send, group)
    recv_s = _a2a(scales, group) if scales is not None else None
    return EpPending(mode="ll", op="dispatch", recv=recv, recv_scales=recv_s)


def _deepep_dispatch_recv(group, handle, pending):
    """[N, L*B, H] -> [L, N*B, H] is a pure transpose (the layout's virtue)."""
    plan = P.ensure_plan(group, handle)
    N, L = group.ep_size, group.local_experts
    B = group.cfg.max_tokens_per_rank
    H = pending.recv.shape[-1]
    out = pending.recv.reshape(N, L, B, H).transpose(1, 0, 2, 3).reshape(L, N * B, H)
    if pending.recv_scales is not None:
        q = pending.recv_scales.shape[-1]
        sc = pending.recv_scales.reshape(N, L, B, q).transpose(1, 0, 2, 3).reshape(L, N * B, q)
        out = dequant_rows(out, sc)
    return out, plan.disp_counts


# --------------------------------------------------------------------------
# combine
# --------------------------------------------------------------------------

def ll_combine_send(group: EpGroup, handle: EpHandle, y3d: jax.Array) -> EpPending:
    if group.cfg.ll_layout == "deepep":
        return _deepep_combine_send(group, handle, y3d)
    return _ncclep_combine_send(group, handle, y3d)


def ll_combine(group: EpGroup, handle: EpHandle, y3d: jax.Array, *, send_only=False):
    """y3d: [L, A, H] expert outputs -> [T, H] weighted-combined tokens."""
    pending = ll_combine_send(group, handle, y3d)
    if send_only:
        return pending
    return ll_complete_combine(group, handle, pending)


def ll_complete_combine(group: EpGroup, handle: EpHandle, pending: EpPending):
    if group.cfg.ll_layout == "deepep":
        return _deepep_combine_recv(group, handle, pending)
    return _ncclep_combine_recv(group, handle, pending)


def _ncclep_combine_send(group, handle, y3d):
    """Expert side: pack owned responses compactly per source rank — one
    fused gather over the plan's combine map."""
    plan = P.ensure_plan(group, handle)
    send, _ = K.dispatch_pack(S.flat_rows(y3d), plan.comb_send_gmap,
                              out_dtype=group.cfg.payload_dtype)
    return EpPending(mode="ll", op="combine", recv=_a2a(send, group))


def _ncclep_combine_recv(group, handle, pending):
    """DP side: gather each (t, k) response through the plan's slot rows and
    apply the weighted reduction in one fused pass (no [T, K, H] buffer)."""
    plan = P.ensure_plan(group, handle)
    return K.combine_gather_reduce(S.flat_rows(pending.recv),
                                   plan.comb_recv_rows, handle.topk_weights)


def _deepep_combine_send(group, handle, y3d):
    N, L = group.ep_size, group.local_experts
    B = group.cfg.max_tokens_per_rank
    H = y3d.shape[-1]
    send = (y3d.reshape(L, N, B, H).transpose(1, 0, 2, 3)
            .reshape(N, L * B, H).astype(group.cfg.payload_dtype))
    return EpPending(mode="ll", op="combine", recv=_a2a(send, group))


def _deepep_combine_recv(group, handle, pending):
    plan = P.ensure_plan(group, handle)
    return K.combine_gather_reduce(S.flat_rows(pending.recv),
                                   plan.comb_recv_rows, handle.topk_weights)


# --------------------------------------------------------------------------
# backend registration
# --------------------------------------------------------------------------

class LLBackend(BaseBackend):
    """LL mode behind the EpBackend protocol (nccl_ep + deepep layouts)."""

    mode = "ll"

    def create_handle(self, group, topk_idx, topk_weights, num_tokens=None):
        return ll_create_handle(group, topk_idx, topk_weights, num_tokens)

    def dispatch_send(self, group, handle, tokens):
        return ll_dispatch_send(group, handle, tokens)

    def dispatch_complete(self, group, handle, pending):
        return ll_complete_dispatch(group, handle, pending)

    def combine_send(self, group, handle, expert_out):
        return ll_combine_send(group, handle, expert_out)

    def combine_complete(self, group, handle, pending):
        return ll_complete_combine(group, handle, pending)


register_backend(LLBackend())
