"""Low-Latency (LL) mode — paper §IV.

Targets inference decode (1–128 tokens/rank). Direct all-to-all mesh over the
EP axis; 3D expert-major output ``[L, A, H]`` feeding grouped GEMM.

Two buffer layouts, selected by ``EpGroupConfig.ll_layout``:

* ``"deepep"`` — the original DeepEP layout the paper starts from: one slot
  per (expert, source-rank) pair, ``O(E·B·P)`` buffers. A token routed to k
  experts is sent k times. Dispatch/combine become pure reshape/transpose
  around the all-to-all (no metadata needed).

* ``"nccl_ep"`` — the paper's memory-optimized layout (§IV-D): a token is sent
  **once per destination rank** (routing dedup) into a per-rank block of
  ``C_d ≤ B`` slots → ``O(N·B·P)``; combine responses are packed compactly at
  per-(t,k) slots → ``O(B·K·P)``. The paper ships routing info in message
  headers; here both sides compute identical slot maps from the handle's
  replicated ``topk_idx``, so the header is zero bytes (see slots.py).

Both layouts support staged execution (``send_only=True`` + ``ll_complete``),
the JAX rendering of the paper's double-buffered overlap: the returned pending
buffers let XLA schedule the expert GEMM of one micro-batch against the
all-to-all of the next.

Quantized dispatch (fp8 payload + fp32 scales, §IV-B) rides the same slot maps
with a parallel scales buffer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.group import EpGroup, EpHandle
from repro.core import slots as S
from repro.kernels import ops as K


def _axis(group: EpGroup):
    a = group.cfg.ep_axis
    return a if len(a) > 1 else a[0]


def _my_rank(group: EpGroup) -> jax.Array:
    a = group.cfg.ep_axis
    if len(a) == 1:
        return jax.lax.axis_index(a[0])
    # row-major over (outer, inner) — must match expert block distribution
    r = jax.lax.axis_index(a[0])
    for name in a[1:]:
        r = r * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return r


def _a2a(x, group):
    return jax.lax.all_to_all(x, _axis(group), split_axis=0, concat_axis=0, tiled=False)


# --------------------------------------------------------------------------
# handle
# --------------------------------------------------------------------------

def ll_create_handle(group: EpGroup, topk_idx, topk_weights, num_tokens=None) -> EpHandle:
    """All-gather routing metadata; compute per-local-expert counts.

    In the paper LL metadata travels in dispatch headers; gathering it at
    handle creation is the synchronized-collective equivalent (§IV-D a)."""
    N, L = group.ep_size, group.local_experts
    T, Kk = topk_idx.shape
    me = _my_rank(group)
    if num_tokens is not None:
        # padded tokens route to sentinel expert E (rank N, OOB everywhere):
        # every rank's slot accounting then agrees without gathering counts.
        pad = jnp.arange(T)[:, None] >= num_tokens
        topk_idx = jnp.where(pad, group.cfg.num_experts, topk_idx)
    topk_g = jax.lax.all_gather(topk_idx, _axis(group), axis=0, tiled=False)
    topk_g = topk_g.reshape(N, T, Kk)
    mine = (topk_g // L) == me                          # [N, T, K]
    e_l = (topk_g - me * L).clip(0, L - 1)
    counts = jnp.zeros((L,), jnp.int32).at[e_l.reshape(-1)].add(
        mine.reshape(-1).astype(jnp.int32))
    nt = jnp.asarray(T, jnp.int32) if num_tokens is None else num_tokens
    return EpHandle(
        topk_idx=topk_idx, topk_weights=topk_weights, topk_global=topk_g,
        tokens_per_expert=counts, num_recv_tokens=counts.sum(), num_tokens=nt,
    )


# --------------------------------------------------------------------------
# staged-execution containers
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PendingDispatch:
    recv: jax.Array                    # [N, C, H'] raw received payload
    recv_scales: jax.Array | None      # [N, C, H/Q] when quantized


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PendingCombine:
    recv: jax.Array                    # [N, C_c, H]


# --------------------------------------------------------------------------
# shared entry geometry
# --------------------------------------------------------------------------

def _entry_geometry(group: EpGroup, topk_g: jax.Array, me):
    """Per-entry coordinates used by unpack/combine, derived identically on
    every rank. Entries are flattened (src-rank-major, then token, then k)."""
    N, L = group.ep_size, group.local_experts
    _, T, Kk = topk_g.shape
    dst_g = topk_g // L                                  # [N, T, K] dest rank
    mine = dst_g == me
    e_l = (topk_g - me * L).clip(0, L - 1)
    return dst_g, mine, e_l


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def ll_dispatch(group: EpGroup, handle: EpHandle, x: jax.Array, *, send_only=False):
    """x: [T, H] local tokens -> (out3d [L, A, H], tokens_per_expert [L]).

    With send_only=True returns a PendingDispatch (paper's staged mode)."""
    if group.cfg.ll_layout == "deepep":
        pending = _deepep_dispatch_send(group, handle, x)
    else:
        pending = _ncclep_dispatch_send(group, handle, x)
    if send_only:
        return pending
    return ll_complete_dispatch(group, handle, pending)


def ll_complete_dispatch(group: EpGroup, handle: EpHandle, pending: PendingDispatch):
    if group.cfg.ll_layout == "deepep":
        return _deepep_dispatch_recv(group, handle, pending)
    return _ncclep_dispatch_recv(group, handle, pending)


def _quantize(group: EpGroup, x):
    if not group.cfg.quantize_dispatch:
        return x.astype(group.cfg.payload_dtype), None
    return K.quantize_fp8(x, block=group.cfg.quant_block)


def _dequant_rows(group: EpGroup, rows, scales):
    if scales is None:
        return rows
    return K.dequantize_fp8(rows, scales)


# ---- nccl_ep (memory-optimized) layout ----

def _ncclep_dispatch_send(group, handle, x):
    N = group.ep_size
    T, Kk = handle.topk_idx.shape
    C = group.ll_disp_cap
    dst = handle.topk_idx // group.local_experts            # [T, K]
    token_valid = jnp.arange(T) < handle.num_tokens
    sends = jnp.zeros((T, N), bool).at[
        jnp.arange(T)[:, None], dst].set(True, mode="drop")
    sends = sends & token_valid[:, None]                    # [T, N] dedup per rank
    # slot of token t in the r->d block: running count over t (the "counter")
    pos = jnp.cumsum(sends.astype(jnp.int32), axis=0) - 1   # [T, N]
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, N)).reshape(-1)
    d_idx = jnp.broadcast_to(jnp.arange(N)[None, :], (T, N)).reshape(-1)
    gmap = S.build_gather_map(d_idx, pos.reshape(-1), t_idx, sends.reshape(-1),
                              N, C, sentinel=T)
    xq, scales = _quantize(group, x)
    send = S.gather_rows(xq, gmap)                          # [N, C, H]
    recv = _a2a(send, group)
    recv_s = None
    if scales is not None:
        recv_s = _a2a(S.gather_rows(scales, gmap), group)
    return PendingDispatch(recv=recv, recv_scales=recv_s)


def _ncclep_dispatch_recv(group, handle, pending):
    """Unpack [N, C_d, H] into the 3D expert-major tensor [L, A, H]."""
    N, L, A, C = group.ep_size, group.local_experts, group.ll_expert_cap, group.ll_disp_cap
    me = _my_rank(group)
    topk_g = handle.topk_global
    _, T, Kk = topk_g.shape
    dst_g, mine, e_l = _entry_geometry(group, topk_g, me)
    # slot of token (r,t) in the r->me block (same counter as the sender's)
    sends_to_me = mine.any(-1)                              # [N, T]
    pos_to_me = jnp.cumsum(sends_to_me.astype(jnp.int32), axis=1) - 1   # [N, T]
    slot_valid = sends_to_me & (pos_to_me < C)
    # recv flat row index of token (r, t)
    recv_row = jnp.arange(N)[:, None] * C + pos_to_me       # [N, T]
    # expert-region position of entry (r,t,k): running count per local expert
    ent_valid = (mine & slot_valid[:, :, None]).reshape(-1)
    a_pos, counts = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    rows_src = jnp.broadcast_to(recv_row[:, :, None], (N, T, Kk)).reshape(-1)
    gmap = S.build_gather_map(e_l.reshape(-1), a_pos, rows_src, ent_valid,
                              L, A, sentinel=N * C)
    out = S.gather_rows(S.flat_rows(pending.recv), gmap)    # [L, A, H]
    if pending.recv_scales is not None:
        sc = S.gather_rows(S.flat_rows(pending.recv_scales), gmap, fill=0)
        out = _dequant_rows(group, out, sc)
    return out, counts


# ---- deepep (per-(expert,rank)-slot) layout ----

def _deepep_dispatch_send(group, handle, x):
    """One send per (t, k) entry into slot (dst_rank, e_local*B + t)."""
    N, L = group.ep_size, group.local_experts
    T, Kk = handle.topk_idx.shape
    B = group.cfg.max_tokens_per_rank
    assert T <= B
    dst = handle.topk_idx // L
    e_l = handle.topk_idx % L
    token_valid = (jnp.arange(T) < handle.num_tokens)
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, Kk))
    slot = e_l * B + t_idx                                   # [T, K]
    gmap = S.build_gather_map(dst.reshape(-1), slot.reshape(-1), t_idx.reshape(-1),
                              jnp.broadcast_to(token_valid[:, None], (T, Kk)).reshape(-1),
                              N, L * B, sentinel=T)
    xq, scales = _quantize(group, x)
    send = S.gather_rows(xq, gmap)                           # [N, L*B, H]
    recv = _a2a(send, group)
    recv_s = _a2a(S.gather_rows(scales, gmap), group) if scales is not None else None
    return PendingDispatch(recv=recv, recv_scales=recv_s)


def _deepep_dispatch_recv(group, handle, pending):
    """[N, L*B, H] -> [L, N*B, H] is a pure transpose (the layout's virtue)."""
    N, L = group.ep_size, group.local_experts
    B = group.cfg.max_tokens_per_rank
    H = pending.recv.shape[-1]
    out = pending.recv.reshape(N, L, B, H).transpose(1, 0, 2, 3).reshape(L, N * B, H)
    if pending.recv_scales is not None:
        q = pending.recv_scales.shape[-1]
        sc = pending.recv_scales.reshape(N, L, B, q).transpose(1, 0, 2, 3).reshape(L, N * B, q)
        out = _dequant_rows(group, out, sc)
    me = _my_rank(group)
    _, mine, e_l = _entry_geometry(group, handle.topk_global, me)
    counts = jnp.zeros((L,), jnp.int32).at[e_l.reshape(-1)].add(
        mine.reshape(-1).astype(jnp.int32))
    return out, counts


# --------------------------------------------------------------------------
# combine
# --------------------------------------------------------------------------

def ll_combine(group: EpGroup, handle: EpHandle, y3d: jax.Array, *, send_only=False):
    """y3d: [L, A, H] expert outputs -> [T, H] weighted-combined tokens."""
    if group.cfg.ll_layout == "deepep":
        pending = _deepep_combine_send(group, handle, y3d)
    else:
        pending = _ncclep_combine_send(group, handle, y3d)
    if send_only:
        return pending
    return ll_complete_combine(group, handle, pending)


def ll_complete_combine(group: EpGroup, handle: EpHandle, pending: PendingCombine):
    if group.cfg.ll_layout == "deepep":
        return _deepep_combine_recv(group, handle, pending)
    return _ncclep_combine_recv(group, handle, pending)


def _ncclep_combine_send(group, handle, y3d):
    """Expert side: pack owned responses compactly per source rank."""
    N, L, A, Cd = group.ep_size, group.local_experts, group.ll_expert_cap, group.ll_disp_cap
    Cc = group.ll_comb_cap
    me = _my_rank(group)
    topk_g = handle.topk_global
    _, T, Kk = topk_g.shape
    dst_g, mine, e_l = _entry_geometry(group, topk_g, me)
    # recompute the dispatch-side expert-region slot of each owned entry
    sends_to_me = mine.any(-1)
    pos_to_me = jnp.cumsum(sends_to_me.astype(jnp.int32), axis=1) - 1
    slot_valid = sends_to_me & (pos_to_me < Cd)
    ent_valid = (mine & slot_valid[:, :, None]).reshape(-1)
    a_pos, _ = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    y_row = e_l.reshape(-1) * A + a_pos                      # flat index into y3d
    # combine slot of entry (r,t,k) within the me->r block: running count
    # over (t,k) of entries of r owned by me — identical on both sides.
    r_of = jnp.broadcast_to(jnp.arange(N)[:, None, None], (N, T, Kk)).reshape(-1)
    c_pos, _ = S.positions_by_dest(r_of, N, ent_valid)
    gmap = S.build_gather_map(r_of, c_pos, y_row, ent_valid & (a_pos < A),
                              N, Cc, sentinel=L * A)
    send = S.gather_rows(S.flat_rows(y3d.astype(group.cfg.payload_dtype)), gmap)
    return PendingCombine(recv=_a2a(send, group))


def _ncclep_combine_recv(group, handle, pending):
    """DP side: slot of MY entry (t,k) in block from owner d equals the same
    running count the owner used; gather [T,K,H] then weighted-reduce."""
    N, L, Cc = group.ep_size, group.local_experts, group.ll_comb_cap
    me = _my_rank(group)
    topk = handle.topk_idx
    T, Kk = topk.shape
    dst = topk // L                                          # [T, K] owner rank
    # my tokens' dispatch-slot validity (drops propagate to combine)
    token_valid = jnp.arange(T) < handle.num_tokens
    sends = jnp.zeros((T, N), bool).at[
        jnp.arange(T)[:, None], dst].set(True, mode="drop")
    sends = sends & token_valid[:, None]
    pos = jnp.cumsum(sends.astype(jnp.int32), axis=0) - 1
    tok_slot_ok = jnp.take_along_axis(pos, dst, axis=1) < group.ll_disp_cap  # [T, K]
    ent_valid = (tok_slot_ok & token_valid[:, None]).reshape(-1)
    c_pos, _ = S.positions_by_dest(dst.reshape(-1), N, ent_valid)
    row = dst.reshape(-1) * Cc + c_pos
    row = jnp.where(ent_valid & (c_pos < Cc), row, N * Cc)
    y_tk = S.gather_rows(S.flat_rows(pending.recv), row.reshape(T, Kk))  # [T,K,H]
    return K.combine_reduce(y_tk, handle.topk_weights)


def _deepep_combine_send(group, handle, y3d):
    N, L = group.ep_size, group.local_experts
    B = group.cfg.max_tokens_per_rank
    H = y3d.shape[-1]
    send = (y3d.reshape(L, N, B, H).transpose(1, 0, 2, 3)
            .reshape(N, L * B, H).astype(group.cfg.payload_dtype))
    return PendingCombine(recv=_a2a(send, group))


def _deepep_combine_recv(group, handle, pending):
    N, L = group.ep_size, group.local_experts
    B = group.cfg.max_tokens_per_rank
    topk = handle.topk_idx
    T, Kk = topk.shape
    dst, e_l = topk // L, topk % L
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, Kk))
    row = dst * (L * B) + e_l * B + t_idx                    # [T, K]
    token_valid = jnp.arange(T)[:, None] < handle.num_tokens
    row = jnp.where(token_valid, row, N * L * B)
    y_tk = S.gather_rows(S.flat_rows(pending.recv), row)     # [T, K, H]
    return K.combine_reduce(y_tk, handle.topk_weights)
