"""Slot-map arithmetic shared by LL and HT modes.

The paper's kernels address communication buffers by (expert-rank pair,
slot) — slots are reserved by atomically incrementing per-pair counters
(§IV-B/C). Under XLA's synchronized-collective model the same reservation is
computed *deterministically on every rank* from the replicated routing
metadata: an exclusive cumulative count over a fixed entry order plays the
role of the atomic counter. Both endpoints of every transfer derive identical
(pair, slot) coordinates, so messages need no headers at all.

``positions_by_dest`` is the core of that counter arithmetic. It is
O(M log M) via a stable sort by destination plus segment-relative ranks —
the one-hot-cumsum O(M·D) formulation it replaced survives as the oracle in
``repro.kernels.ref.positions_by_dest`` and the two are bitwise identical
(tests/test_plan.py asserts so, including invalid and out-of-range entries).
All functions remain static-shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def positions_by_dest(dest: jax.Array, num_dest: int, valid: jax.Array):
    """For flat entries with destination ids ``dest`` [M] and validity mask
    ``valid`` [M], compute for each entry its slot index within its
    destination's block (exclusive running count over the fixed entry order),
    plus per-destination totals.

    Returns (pos [M] int32, counts [num_dest] int32). For every entry m,
    ``pos[m]`` equals the number of valid in-range entries j < m with
    ``dest[j] == clip(dest[m])`` — which for a valid entry is its reserved
    slot, and for an invalid/out-of-range entry is an arbitrary-but-
    deterministic value the caller must mask (same contract as the one-hot
    oracle, bit for bit).

    Sort-based O(M log M): stable-argsort by clipped destination groups
    entries per destination while preserving entry order; an exclusive
    cumsum of validity minus each segment's base count yields the
    within-destination rank; a scatter restores entry order.
    """
    dest = jnp.asarray(dest)
    M = dest.shape[0]
    if M == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((num_dest,), jnp.int32)
    d_clip = dest.clip(0, num_dest - 1).astype(jnp.int32)
    eff = (valid & (dest >= 0) & (dest < num_dest)).astype(jnp.int32)
    order = jnp.argsort(d_clip, stable=True)
    d_s = d_clip[order]
    v_s = eff[order]
    excl = jnp.cumsum(v_s) - v_s                  # valid-before count, sorted order
    is_start = jnp.concatenate([jnp.ones((1,), bool), d_s[1:] != d_s[:-1]])
    # segment base = excl at the segment's first element; excl is monotone so a
    # running max of (start ? excl : 0) carries each segment's base forward.
    base = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, excl, 0))
    pos = jnp.zeros((M,), jnp.int32).at[order].set((excl - base).astype(jnp.int32))
    counts = jnp.zeros((num_dest,), jnp.int32).at[d_clip].add(eff)
    return pos, counts


def build_gather_map(
    dest: jax.Array, pos: jax.Array, src: jax.Array, valid: jax.Array,
    num_dest: int, capacity: int, sentinel: int,
):
    """Build map [num_dest, capacity] such that map[d, c] = src index of the
    entry occupying slot (d, c), or ``sentinel`` for empty slots. Entries with
    pos >= capacity are dropped (the static-shape analogue of buffer overflow
    — only possible when a capacity factor < zero-drop is configured)."""
    m = jnp.full((num_dest, capacity), sentinel, dtype=jnp.int32)
    pos_c = jnp.where(valid, pos, capacity)  # invalid -> OOB -> dropped
    return m.at[dest.clip(0, num_dest - 1), pos_c].set(src, mode="drop")


def gather_rows(x: jax.Array, gmap: jax.Array, *, fill=0):
    """x: [M, ...] rows; gmap: any-shape int32 with sentinel == M meaning
    "empty" -> returns x[gmap] with empty slots filled with ``fill``."""
    pad = jnp.full((1,) + x.shape[1:], fill, dtype=x.dtype)
    xp = jnp.concatenate([x, pad], axis=0)
    return xp[gmap]


def flat_rows(x: jax.Array) -> jax.Array:
    """Collapse leading dims so gather maps can address [M, H] rows."""
    return x.reshape((-1,) + x.shape[-1:])
