"""Slot-map arithmetic shared by LL and HT modes.

The paper's kernels address communication buffers by (expert-rank pair,
slot) — slots are reserved by atomically incrementing per-pair counters
(§IV-B/C). Under XLA's synchronized-collective model the same reservation is
computed *deterministically on every rank* from the replicated routing
metadata: an exclusive cumulative count over a fixed entry order plays the
role of the atomic counter. Both endpoints of every transfer derive identical
(pair, slot) coordinates, so messages need no headers at all.

All functions are static-shape and O(M·D) via one-hot cumsum (M = entries,
D = destinations) — fine for the M ≤ ~1e6 sizes EP metadata has.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def positions_by_dest(dest: jax.Array, num_dest: int, valid: jax.Array):
    """For flat entries with destination ids ``dest`` [M] and validity mask
    ``valid`` [M], compute for each entry its slot index within its
    destination's block (exclusive running count over the fixed entry order),
    plus per-destination totals.

    Returns (pos [M] int32, counts [num_dest] int32). Invalid entries get an
    arbitrary position but must be masked by the caller.
    """
    oh = jax.nn.one_hot(dest, num_dest, dtype=jnp.int32) * valid[:, None].astype(jnp.int32)
    incl = jnp.cumsum(oh, axis=0)
    pos = jnp.take_along_axis(incl - oh, dest[:, None].clip(0, num_dest - 1), axis=1)[:, 0]
    counts = incl[-1] if dest.shape[0] > 0 else jnp.zeros((num_dest,), jnp.int32)
    return pos.astype(jnp.int32), counts.astype(jnp.int32)


def build_gather_map(
    dest: jax.Array, pos: jax.Array, src: jax.Array, valid: jax.Array,
    num_dest: int, capacity: int, sentinel: int,
):
    """Build map [num_dest, capacity] such that map[d, c] = src index of the
    entry occupying slot (d, c), or ``sentinel`` for empty slots. Entries with
    pos >= capacity are dropped (the static-shape analogue of buffer overflow
    — only possible when a capacity factor < zero-drop is configured)."""
    m = jnp.full((num_dest, capacity), sentinel, dtype=jnp.int32)
    pos_c = jnp.where(valid, pos, capacity)  # invalid -> OOB -> dropped
    return m.at[dest.clip(0, num_dest - 1), pos_c].set(src, mode="drop")


def gather_rows(x: jax.Array, gmap: jax.Array, *, fill=0):
    """x: [M, ...] rows; gmap: any-shape int32 with sentinel == M meaning
    "empty" -> returns x[gmap] with empty slots filled with ``fill``."""
    pad = jnp.full((1,) + x.shape[1:], fill, dtype=x.dtype)
    xp = jnp.concatenate([x, pad], axis=0)
    return xp[gmap]


def flat_rows(x: jax.Array) -> jax.Array:
    """Collapse leading dims so gather maps can address [M, H] rows."""
    return x.reshape((-1,) + x.shape[-1:])
