"""EpPlan: the precomputed slot-map engine (paper §IV-B/D rendered statically).

The paper's LL mode wins by making slot reservation and packing essentially
free on the device: GPU-initiated transfers address buffers by (pair, slot)
with no headers, and both endpoints agree on slots via atomic counters. The
JAX rendering of that counter arithmetic (``slots.positions_by_dest`` et al.)
is deterministic, so there is no reason to recompute it inside every
dispatch/combine call — it depends only on the handle's routing metadata.

``EpPlan`` therefore derives the complete chain of gather maps, slot
positions, validity masks, and per-expert counts **once, at handle-creation
time**, for whichever algorithm the group selected (LL ``nccl_ep``/``deepep``
layouts, HT flat/hierarchical, baseline). Every dispatch/combine phase then
reduces to a single gather/scatter pass over precomputed int32 maps — the
**one-pass-per-phase invariant**: no ``positions_by_dest`` (or any other
slot arithmetic) appears inside a dispatch/combine body, and each payload row
is touched exactly once per phase. tests/test_plan.py enforces the invariant
by inspecting the phase implementations.

Map conventions (shared with slots.py): a gather map value equal to the
source row count is the "empty" sentinel — gathers route it to an appended
zero pad row; scatters route it to an appended trash row that is sliced off.

Across decode steps the plan is also **steady-state-cheap**:
``refresh_handle`` (exported as ``ep_handle_refresh``) rebinds per-step
combine weights into an existing handle without rebuilding any map — the
only weight-dependent plan field (the hierarchical ``h_w_slot``) is a single
scatter through the stored ``h_entry_slot`` chain. When a new ``topk_idx``
is supplied, a routing-hash fast path compares checksums at runtime and a
``lax.cond`` selects the cached maps verbatim on a match (speculative-decode
replay, cached dispatch in backward), so unchanged routing skips plan
construction entirely; changed routing rebuilds exactly like handle creation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import placement as PL
from repro.core import slots as S
from repro.core.group import EpGroup, EpHandle


def my_rank(group: EpGroup) -> jax.Array:
    """Linear EP rank of the calling shard — row-major over cfg.ep_axis,
    matching the expert block distribution. Must run inside shard_map."""
    axes = group.cfg.ep_axis
    r = jax.lax.axis_index(axes[0])
    for name in axes[1:]:
        r = r * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return r


def dest_of(group: EpGroup, experts: jax.Array, src_rank):
    """Physical (dest_rank, dest_slot) for global expert ids — the ONE place
    plan construction resolves logical experts to hardware (docs/DESIGN.md
    §8). With the default contiguous layout this is exactly the historic
    ``(e // L, e % L)`` arithmetic; with an ``EpPlacement`` it is the table
    lookup with replica selection by ``src_rank % replica_count`` (a pure
    function of replicated metadata, so both endpoints of every transfer
    agree — same determinism as the slot counters). The padding sentinel
    ``E`` maps to (N, L), out of range everywhere. Entries not owned by the
    caller return their slot at *their* rank — callers must mask by
    ``dest_rank == me`` before using slots locally, exactly like the
    ``(e - me*L).clip`` chain this generalizes."""
    if group.placement is None:
        L = group.local_experts
        r = experts // L
        return r, experts - r * L
    return PL.assign(group.placement, experts, src_rank)


def _src_rank_grid(group: EpGroup, topk_g: jax.Array):
    """Source-rank coordinates for a gathered routing tensor [N, T, K] —
    the replica-selection key for receiver-side dest_of."""
    N = group.ep_size
    return jnp.arange(N, dtype=jnp.int32)[:, None, None]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EpPlan:
    """Precomputed gather/scatter maps for every phase of the group's mode.

    Fields unused by the active mode/layout are None. All maps are int32
    except ``h_w_slot`` (f32 combine weights in the y3d slot domain).
    """

    # -- shared across LL / HT-flat / baseline --
    disp_send_gmap: jax.Array | None = None   # [N, C] slot -> local token row
    disp_recv_gmap: jax.Array | None = None   # [L, A] expert slot -> recv row
    #   (hierarchical: row values address the nc-chunk concatenation of
    #   stage-2 recv buffers, sentinel nc*No*C2)
    disp_counts: jax.Array | None = None      # [L] capacity-aware recv counts
    comb_send_gmap: jax.Array | None = None   # [N, Cc] slot -> y3d flat row
    comb_recv_rows: jax.Array | None = None   # [T, K] entry -> recv flat row
    # -- HT hierarchical extras (leading nc axis = ht_num_chunks slices of
    #    the token dim; nc=1 is the monolithic path, maps unchanged) --
    h_gmap1: jax.Array | None = None          # [nc, Ni, C1] stage-1 slot -> token
    h_gmap2: jax.Array | None = None          # [nc, No, C2] stage-2 slot -> recv1 row
    h_slot_tgt: jax.Array | None = None       # [L*A] y3d slot -> stage-2 row
    #   (row values address the nc-chunk concatenation of stage-2 combine
    #   buffers, sentinel nc*No*C2 — one scatter fills every chunk's slice)
    h_w_slot: jax.Array | None = None         # [L*A] f32 combine weight / slot
    h_rail_dst_rows: jax.Array | None = None  # [nc, No, Ni*Tc] rail accum dst
    h_rail_src_rows: jax.Array | None = None  # [nc, No, Ni*Tc] rail accum src
    h_src_rows: jax.Array | None = None       # [T, Ni] source-chip final gather
    #   (row values address the nc-chunk concatenation of stage-1 combine
    #   buffers, sentinel nc*Ni*C1)
    h_entry_slot: jax.Array | None = None     # [N*T*K] global entry -> y3d slot
    #   (sentinel L*A) — the weight-rebind chain: lets refresh_handle rebuild
    #   h_w_slot with one scatter, no slot arithmetic


def build_plan(group: EpGroup, topk_idx: jax.Array, topk_global: jax.Array,
               num_tokens: jax.Array, topk_weights: jax.Array | None = None) -> EpPlan:
    """Derive the full slot-map chain for the group's resolved mode. Runs
    inside the sharded region (uses axis_index); called from handle creation
    so the maps are computed exactly once per handle."""
    mode = group.mode
    if mode == "ll":
        if group.cfg.ll_layout == "deepep":
            return _ll_deepep_plan(group, topk_idx, topk_global, num_tokens)
        return _ll_ncclep_plan(group, topk_idx, topk_global, num_tokens)
    if mode == "ht":
        if (group.cfg.ht_hierarchical and len(group.cfg.ep_axis) > 1
                and group.outer_size > 1):
            plan = _ht_hier_plan(group, topk_idx, topk_global, num_tokens)
            # weights enter through the same single-scatter rebind path that
            # refresh_handle uses — maps never depend on them
            if topk_weights is not None:
                plan = rebind_weights(group, plan, topk_weights)
            return plan
        return _ht_flat_plan(group, topk_idx, topk_global, num_tokens)
    return _baseline_plan(group, topk_idx, topk_global, num_tokens)


def ensure_plan(group: EpGroup, handle) -> EpPlan:
    """Return the handle's plan, deriving it on the fly for handles built
    without one (compat path for hand-constructed EpHandles)."""
    if handle.plan is not None:
        return handle.plan
    return build_plan(group, handle.topk_idx, handle.topk_global,
                      handle.num_tokens, handle.topk_weights)


# --------------------------------------------------------------------------
# steady-state handle refresh (plan reuse across decode steps)
# --------------------------------------------------------------------------

def _mix(x: jax.Array) -> jax.Array:
    """murmur3-style avalanche over uint32 lanes."""
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)


def routing_hash(topk_idx: jax.Array, salt: int = 0) -> jax.Array:
    """Order-sensitive [2]-lane uint32 checksum of a routing tensor.

    Two independently-mixed position-salted sums; computed once per handle
    and compared by ``refresh_handle`` to detect a routing replay at
    runtime. Handles hash the **globally gathered** ``topk_global`` — every
    slot map depends on every rank's routing, so a local-only hash would let
    a rank whose own routing replayed reuse stale maps while a peer's
    routing changed (and, being replicated, the global hash makes the
    reuse/rebuild decision uniform across ranks). A collision would
    silently reuse stale maps — with two independent 32-bit lanes the odds
    are ~2^-64 per refresh, far below any hardware soft-error rate.

    ``salt`` is the group's placement fingerprint (``group.placement_salt``):
    slot maps depend on the placement table exactly as they depend on the
    routing, so a placement swap must read as "routing changed" and force
    the rebuild branch. 0 (the contiguous default) leaves the hash
    bit-identical to the unsalted form."""
    flat = topk_idx.reshape(-1).astype(jnp.uint32)
    i = jnp.arange(flat.shape[0], dtype=jnp.uint32)
    h1 = _mix(flat + i * np.uint32(0x9E3779B9)).sum()
    h2 = _mix(flat ^ ((i + np.uint32(1)) * np.uint32(0x85EBCA6B))).sum()
    h = jnp.stack([h1, h2])
    if salt:
        h = h ^ jnp.stack([_mix(jnp.uint32(salt)),
                           _mix(jnp.uint32(salt) ^ np.uint32(0x9E3779B9))])
    return h


def mask_padding(group: EpGroup, topk_idx: jax.Array, num_tokens):
    """Shared create/refresh prologue: route padded token rows to the
    sentinel expert E (rank N, out of range everywhere — every rank's slot
    accounting then agrees without gathering counts) and coerce the
    valid-token count. Returns (topk_idx, num_tokens[int32 scalar])."""
    T = topk_idx.shape[0]
    if num_tokens is None:
        return topk_idx, jnp.asarray(T, jnp.int32)
    pad = jnp.arange(T)[:, None] >= num_tokens
    return jnp.where(pad, group.cfg.num_experts, topk_idx), num_tokens


def gather_routing(group: EpGroup, topk_idx: jax.Array) -> jax.Array:
    """All-gather local routing across the EP axes into [N, T, K] — row-major
    over cfg.ep_axis, matching ``my_rank``'s linearization. The single
    metadata exchange every handle create/refresh performs."""
    g = topk_idx
    for ax in reversed(group.cfg.ep_axis):
        g = jax.lax.all_gather(g, ax, axis=0, tiled=False)
    return g.reshape((group.ep_size,) + topk_idx.shape)


def recv_counts(group: EpGroup, topk_g: jax.Array) -> jax.Array:
    """[L] tokens received per local expert slot, from the gathered routing —
    the one derivation handle create and refresh must agree on (sentinel
    expert E lands out of every rank's range and is never counted).
    Placement-aware: under a redundant placement each entry counts at the
    replica its source rank selects."""
    L = group.local_experts
    me = my_rank(group)
    r_dst, s_dst = dest_of(group, topk_g, _src_rank_grid(group, topk_g))
    mine = r_dst == me
    e_l = s_dst.clip(0, L - 1)
    return jnp.zeros((L,), jnp.int32).at[e_l.reshape(-1)].add(
        mine.reshape(-1).astype(jnp.int32))


def _plan_shape_compatible(group: EpGroup, plan: EpPlan) -> bool:
    """True when the cached plan's maps have the shapes this group would
    rebuild — required for the lax.cond fast path (both branches must carry
    an identical pytree). A placement swap that adds/removes redundant slots
    changes the per-rank slot count and every expert-region map with it."""
    c = plan.disp_counts
    return c is None or c.shape[0] == group.local_experts


def rebind_weights(group: EpGroup, plan: EpPlan | None,
                   topk_weights: jax.Array) -> EpPlan | None:
    """Rebind combine weights into a plan without touching any slot map.

    Only the hierarchical ``h_w_slot`` embeds weights — rebuilt here with a
    single scatter through the stored ``h_entry_slot`` chain. Every other
    plan is weight-independent and returned unchanged (same object, so
    callers can assert map reuse by identity)."""
    if plan is None or plan.h_entry_slot is None:
        return plan
    w_g = topk_weights
    for ax in reversed(group.cfg.ep_axis):
        w_g = jax.lax.all_gather(w_g, ax, axis=0, tiled=False)
    L, A = group.local_experts, group.ht_expert_cap
    h_w_slot = jnp.zeros((L * A + 1,), jnp.float32).at[
        plan.h_entry_slot].set(w_g.reshape(-1), mode="drop")[:L * A]
    return dataclasses.replace(plan, h_w_slot=h_w_slot)


def refresh_handle(group: EpGroup, handle: EpHandle, topk_weights: jax.Array,
                   topk_idx: jax.Array | None = None,
                   num_tokens=None) -> EpHandle:
    """Rebind per-step routing state into an existing handle — the ROADMAP's
    plan-reuse-across-decode-steps path (public name ``ep_handle_refresh``).

    With ``topk_idx`` None (or the very same traced array) the routing is
    unchanged by construction: every slot map is reused verbatim and only the
    combine weights are rebound. With a (possibly different) ``topk_idx``,
    the routing-hash fast path compares checksums at runtime: a ``lax.cond``
    returns the cached maps on a match — plan construction is skipped
    entirely, which is what makes speculative-decode replay and cached
    dispatch steady-state-cheap — and rebuilds exactly like handle creation
    on a mismatch. Must run inside the sharded region, like every EP call."""
    if topk_idx is None or topk_idx is handle.topk_idx:
        if num_tokens is not None:
            # the padding sentinel is baked into topk_idx; a new valid-token
            # count without new routing is ill-defined — refuse loudly
            raise ValueError("num_tokens requires topk_idx on refresh")
        # weights-only refresh trusts the caller that `group` is the
        # handle's own group (the plan-object-reuse contract pinned by
        # tests/test_refresh.py rules out a runtime hash check here); a
        # placement swap must go through the topk_idx path, where the
        # salted hash forces the rebuild. Slot-count changes are at least
        # statically detectable — refuse them loudly.
        if handle.plan is not None and not _plan_shape_compatible(group,
                                                                  handle.plan):
            raise ValueError(
                "weights-only refresh got a handle built under a different "
                "physical slot layout — after a placement swap, refresh "
                "with topk_idx so the placement-salted routing hash can "
                "force the rebuild (docs/DESIGN.md §8)")
        plan = rebind_weights(group, handle.plan, topk_weights)
        return dataclasses.replace(handle, topk_weights=topk_weights, plan=plan)

    topk_idx, nt = mask_padding(group, topk_idx, num_tokens)
    topk_g = gather_routing(group, topk_idx)
    # global (all maps depend on all ranks) and placement-salted (a swapped
    # placement must read as changed routing and take the rebuild branch)
    rhash = routing_hash(topk_g, group.placement_salt)
    counts = recv_counts(group, topk_g)

    if (handle.plan is None or handle.routing_hash is None
            or topk_idx.shape != handle.topk_idx.shape
            or not _plan_shape_compatible(group, handle.plan)):
        # hand-built handle, a different token count, or a placement swap
        # that changed the physical slot count: the cached maps have
        # different (static) shapes than the rebuild — no cond possible,
        # rebuild unconditionally, exactly like handle creation
        plan = build_plan(group, topk_idx, topk_g, nt)
    else:
        # weight-free cached plan so both cond branches carry an identical
        # pytree structure (h_w_slot is rebound below, outside the cond —
        # keeping collectives out of the branches)
        cached = (handle.plan if handle.plan.h_entry_slot is None
                  else dataclasses.replace(handle.plan, h_w_slot=None))
        same = jnp.all(rhash == handle.routing_hash)
        plan = jax.lax.cond(same, lambda: cached,
                            lambda: build_plan(group, topk_idx, topk_g, nt))
    plan = rebind_weights(group, plan, topk_weights)
    return EpHandle(
        topk_idx=topk_idx, topk_weights=topk_weights, topk_global=topk_g,
        tokens_per_expert=counts, num_recv_tokens=counts.sum(), num_tokens=nt,
        plan=plan, routing_hash=rhash)


# --------------------------------------------------------------------------
# LL layouts (paper §IV)
# --------------------------------------------------------------------------

def _ll_ncclep_plan(group, topk_idx, topk_g, num_tokens) -> EpPlan:
    """Memory-optimized layout (§IV-D): dispatch dedups per destination rank,
    combine packs responses compactly per (t, k). Four maps, one per phase."""
    N, L = group.ep_size, group.local_experts
    Cd, Cc, A = group.ll_disp_cap, group.ll_comb_cap, group.ll_expert_cap
    me = my_rank(group)
    T, Kk = topk_idx.shape

    # ---- sender side (local tokens): slot of token t in the me->d block is
    # the running count of senders to d over t — the "atomic counter".
    dst, _ = dest_of(group, topk_idx, me)                   # [T, K]
    token_valid = jnp.arange(T) < num_tokens
    sends = jnp.zeros((T, N), bool).at[
        jnp.arange(T)[:, None], dst].set(True, mode="drop")
    sends = sends & token_valid[:, None]                    # [T, N] rank dedup
    pos = jnp.cumsum(sends.astype(jnp.int32), axis=0) - 1   # [T, N]
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, N)).reshape(-1)
    d_idx = jnp.broadcast_to(jnp.arange(N)[None, :], (T, N)).reshape(-1)
    disp_send_gmap = S.build_gather_map(d_idx, pos.reshape(-1), t_idx,
                                        sends.reshape(-1), N, Cd, sentinel=T)

    # ---- receiver side (global entries): mirror the senders' counters.
    dst_g, slot_g = dest_of(group, topk_g,
                            _src_rank_grid(group, topk_g))  # [N, T, K]
    mine = dst_g == me
    e_l = slot_g.clip(0, L - 1)
    sends_to_me = mine.any(-1)                              # [N, T]
    pos_to_me = jnp.cumsum(sends_to_me.astype(jnp.int32), axis=1) - 1
    slot_valid = sends_to_me & (pos_to_me < Cd)
    recv_row = jnp.arange(N)[:, None] * Cd + pos_to_me      # [N, T]
    ent_valid = (mine & slot_valid[:, :, None]).reshape(-1)
    a_pos, counts = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    rows_src = jnp.broadcast_to(recv_row[:, :, None], (N, T, Kk)).reshape(-1)
    disp_recv_gmap = S.build_gather_map(e_l.reshape(-1), a_pos, rows_src,
                                        ent_valid, L, A, sentinel=N * Cd)

    # ---- combine send (expert side): same a_pos chain, packed per src rank.
    y_row = e_l.reshape(-1) * A + a_pos                     # flat row into y3d
    r_of = np.broadcast_to(np.arange(N, dtype=np.int32)[:, None, None],
                           (N, T, Kk)).reshape(-1)
    c_pos, _ = S.positions_by_dest(r_of, N, ent_valid)
    comb_send_gmap = S.build_gather_map(r_of, c_pos, y_row,
                                        ent_valid & (a_pos < A), N, Cc,
                                        sentinel=L * A)

    # ---- combine recv (source side): my entry (t, k) sits at the same
    # running count its owner used; dispatch drops propagate.
    tok_slot_ok = jnp.take_along_axis(pos, dst.clip(0, N - 1), axis=1) < Cd
    ent_valid2 = (tok_slot_ok & token_valid[:, None]).reshape(-1)
    c_pos2, _ = S.positions_by_dest(dst.reshape(-1), N, ent_valid2)
    row = jnp.where(ent_valid2 & (c_pos2 < Cc),
                    dst.reshape(-1).clip(0, N - 1) * Cc + c_pos2, N * Cc)
    return EpPlan(
        disp_send_gmap=disp_send_gmap, disp_recv_gmap=disp_recv_gmap,
        disp_counts=counts, comb_send_gmap=comb_send_gmap,
        comb_recv_rows=row.reshape(T, Kk).astype(jnp.int32),
    )


def _ll_deepep_plan(group, topk_idx, topk_g, num_tokens) -> EpPlan:
    """Per-(expert, src-rank)-slot layout: slot ids are positional (e_l*B + t)
    so recv/combine-send are pure transposes — only the send gather map and
    the combine source rows need precomputing."""
    N, L = group.ep_size, group.local_experts
    B = group.cfg.max_tokens_per_rank
    T, Kk = topk_idx.shape
    assert T <= B
    src = my_rank(group) if group.placement is not None else 0
    dst, e_l = dest_of(group, topk_idx, src)
    token_valid = jnp.arange(T) < num_tokens
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, Kk))
    slot = e_l * B + t_idx                                   # [T, K]
    disp_send_gmap = S.build_gather_map(
        dst.reshape(-1), slot.reshape(-1), t_idx.reshape(-1),
        jnp.broadcast_to(token_valid[:, None], (T, Kk)).reshape(-1),
        N, L * B, sentinel=T)
    row = dst * (L * B) + e_l * B + t_idx                    # [T, K]
    row = jnp.where(token_valid[:, None], row, N * L * B)
    return EpPlan(disp_send_gmap=disp_send_gmap,
                  disp_counts=recv_counts(group, topk_g),
                  comb_recv_rows=row.astype(jnp.int32))


# --------------------------------------------------------------------------
# HT flat path (paper §V, single EP axis)
# --------------------------------------------------------------------------

def _ht_flat_plan(group, topk_idx, topk_g, num_tokens) -> EpPlan:
    """Entry-level all-to-all: every (t, k) is its own slot; combine mirrors
    dispatch slots exactly (the deterministic Fig. 4 layout)."""
    N, L = group.ep_size, group.local_experts
    C, A = group.ht_pair_cap, group.ht_expert_cap
    me = my_rank(group)
    T, Kk = topk_idx.shape

    # ---- sender side
    dst = dest_of(group, topk_idx, me)[0].reshape(-1)       # [T*K]
    valid = jnp.broadcast_to((jnp.arange(T) < num_tokens)[:, None],
                             (T, Kk)).reshape(-1)
    c_pos, _ = S.positions_by_dest(dst, N, valid)
    t_of = jnp.broadcast_to(jnp.arange(T)[:, None], (T, Kk)).reshape(-1)
    disp_send_gmap = S.build_gather_map(dst, c_pos, t_of, valid, N, C, sentinel=T)

    # ---- receiver side: reconstruct every sender's counter restricted to me
    dst_g, slot_g = dest_of(group, topk_g,
                            _src_rank_grid(group, topk_g))  # [N, T, K]
    mine = dst_g == me
    e_l = slot_g.clip(0, L - 1)
    flat_mine = mine.reshape(N, T * Kk)
    pos_r = jnp.cumsum(flat_mine.astype(jnp.int32), axis=1) - 1
    slot_ok = flat_mine & (pos_r < C)
    rows = jnp.arange(N)[:, None] * C + pos_r               # recv flat row
    ent_valid = slot_ok.reshape(-1)
    a_pos, counts = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    disp_recv_gmap = S.build_gather_map(e_l.reshape(-1), a_pos, rows.reshape(-1),
                                        ent_valid, L, A, sentinel=N * C)

    # ---- combine send: y3d rows back into the mirrored [N, C] blocks
    y_row = e_l.reshape(-1) * A + a_pos
    r_of = np.broadcast_to(np.arange(N, dtype=np.int32)[:, None, None],
                           (N, T, Kk)).reshape(-1)
    comb_send_gmap = S.build_gather_map(r_of, pos_r.reshape(-1), y_row,
                                        ent_valid & (a_pos < A), N, C,
                                        sentinel=L * A)

    # ---- combine recv: my own dispatch slots
    row = jnp.where(valid & (c_pos < C), dst.clip(0, N - 1) * C + c_pos, N * C)
    return EpPlan(
        disp_send_gmap=disp_send_gmap, disp_recv_gmap=disp_recv_gmap,
        disp_counts=counts, comb_send_gmap=comb_send_gmap,
        comb_recv_rows=row.reshape(T, Kk).astype(jnp.int32),
    )


# --------------------------------------------------------------------------
# HT hierarchical path (paper §V / Hybrid-EP two-tier scheme)
# --------------------------------------------------------------------------

def rank_pod(rank, inner_size: int):
    """Pod (outer) coordinate of an EP rank in the HT hierarchy. THE
    definition of which ranks share an NVLink pod — the hierarchical a2a
    stages below and the fault-domain derivation
    (`core/placement.domains_from_geometry`, docs/DESIGN.md §9) must agree
    on it, so both route through this helper (pinned by
    tests/test_fault_domains.py). Works elementwise on arrays."""
    return rank // inner_size


def _hier_geometry(group: EpGroup, topk_g: jax.Array):
    """Global stage-1 maps, computed identically on every chip."""
    L, Ni, No = group.local_experts, group.inner_size, group.outer_size
    C1 = group.ht_stage1_cap
    N, T, Kk = topk_g.shape
    g = topk_g.reshape(No, Ni, T, Kk)
    src = (jnp.arange(No, dtype=jnp.int32)[:, None] * Ni +
           jnp.arange(Ni, dtype=jnp.int32)[None, :])[:, :, None, None]
    r_dst, s_dst = dest_of(group, g, src)                   # placement-aware
    o_dst, i_dst = rank_pod(r_dst, Ni), r_dst % Ni          # [No, Ni, T, K]
    # stage 1 (per source chip): dedup over destination inner coordinate.
    # Invalid entries (sentinel expert) have r_dst == N -> i_dst computed from
    # it could alias a real coordinate, so mask by dst validity explicitly.
    ent_ok = r_dst < (No * Ni)
    i_dst_s = jnp.where(ent_ok, i_dst, Ni)                  # sentinel -> dropped
    sends1 = jnp.zeros((No, Ni, T, Ni), bool).at[
        jnp.arange(No)[:, None, None, None],
        jnp.arange(Ni)[None, :, None, None],
        jnp.arange(T)[None, None, :, None],
        i_dst_s].set(True, mode="drop")
    pos1 = jnp.cumsum(sends1.astype(jnp.int32), axis=2) - 1  # over tokens
    ok1 = sends1 & (pos1 < C1)
    o_dst = jnp.where(ent_ok, o_dst, No)
    i_dst = jnp.where(ent_ok, i_dst, Ni)
    return dict(g=g, r_dst=r_dst, s_dst=s_dst, o_dst=o_dst, i_dst=i_dst,
                sends1=sends1, pos1=pos1, ok1=ok1)


def _hier_recv_chain(group, geo, me_o, me_i):
    """For every (o_s, r_i, t): the stage-2 slot c2 (at source pod o_s's rail
    with inner coord me_i, sending to pod me_o) and validity."""
    Ni, No = group.inner_size, group.outer_size
    C2 = group.ht_stage2_cap
    No_, Ni_, T, Kk = geo["g"].shape
    held = geo["ok1"][:, :, :, me_i]                        # [No, Ni, T]
    needs_me = ((geo["i_dst"] == me_i) & (geo["o_dst"] == me_o)).any(-1)
    fanned = held & needs_me
    # c2 = running count in (r_i, t) order per source pod (matches the rail's
    # flat (r_i*C1+pos1) order because pos1 is monotone in t)
    c2 = jnp.cumsum(fanned.reshape(No, Ni * T).astype(jnp.int32), axis=1) - 1
    c2 = c2.reshape(No, Ni, T)
    ok2 = fanned & (c2 < C2)
    return c2, ok2


def _ht_hier_plan(group, topk_idx, topk_g, num_tokens) -> EpPlan:
    """Two-stage scheme, chunked: the token dim splits into ``ht_num_chunks``
    static slices and every map of the dispatch chain (stage-1 dedup, stage-2
    fan-out) plus the mirror combine chain (slot-domain weighting, rail
    partial sums) is derived **per chunk**, so ht.py can stream the slices —
    chunk *i*'s intra-pod a2a overlapping chunk *i-1*'s inter-pod a2a. The
    destination-side maps (``disp_recv_gmap``, ``h_entry_slot``,
    ``h_src_rows``) stay global: expert-region positions are computed over
    the monolithic entry order, with row values offset into the chunk-
    concatenated stage buffers — which is what makes the chunked pipeline
    bitwise-identical to the nc=1 monolithic path at zero-drop capacities.
    Weight-free: combine weights are bound afterwards via ``rebind_weights``
    through the stored ``h_entry_slot`` chain, so a weight refresh never
    re-runs this."""
    ax_o, ax_i = group.cfg.ep_axis[0], group.cfg.ep_axis[-1]
    L, Ni, No = group.local_experts, group.inner_size, group.outer_size
    C1, C2, A = group.ht_stage1_cap, group.ht_stage2_cap, group.ht_expert_cap
    me_o, me_i = jax.lax.axis_index(ax_o), jax.lax.axis_index(ax_i)
    me = me_o * Ni + me_i
    T, Kk = topk_idx.shape
    nc = group.ht_chunks(T)
    Tc = T // nc

    g1_c, g2_c = [], []
    el_c, entv_c, rows_c = [], [], []
    rail_dst_c, rail_src_c, src_rows_c = [], [], []
    for c in range(nc):
        geo = _hier_geometry(group, topk_g[:, c * Tc:(c + 1) * Tc])

        # ---- stage-1 send map (local chip's view; src rows are GLOBAL
        # token indices so dispatch_pack runs over the full [T, H] tokens)
        s1 = geo["sends1"][me_o, me_i]                      # [Tc, Ni]
        p1 = geo["pos1"][me_o, me_i]
        t_of = jnp.broadcast_to(c * Tc + jnp.arange(Tc)[:, None],
                                (Tc, Ni)).reshape(-1)
        i_of = jnp.broadcast_to(jnp.arange(Ni)[None, :], (Tc, Ni)).reshape(-1)
        g1_c.append(S.build_gather_map(i_of, p1.reshape(-1), t_of,
                                       s1.reshape(-1), Ni, C1, sentinel=T))

        # ---- stage-2 fan map: rail (me_o, me_i) fans chunk-held tokens
        # over destination pods (rows address this chunk's recv1 buffer)
        need = (geo["i_dst"][me_o] == me_i)                 # [Ni, Tc, K]
        fan = jnp.zeros((Ni, Tc, No), bool).at[
            jnp.arange(Ni)[:, None, None], jnp.arange(Tc)[None, :, None],
            jnp.where(need, geo["o_dst"][me_o], No)].set(True, mode="drop")
        ok1_me = geo["ok1"][me_o, :, :, me_i]               # [Ni, Tc] held?
        fan = fan & ok1_me[..., None]
        o_bcast = np.broadcast_to(np.arange(No, dtype=np.int32)[None, None, :],
                                  (Ni, Tc, No)).reshape(-1)
        pos2, _ = S.positions_by_dest(o_bcast, No, fan.reshape(-1))
        row1 = jnp.arange(Ni)[:, None] * C1 + geo["pos1"][me_o, :, :, me_i]
        g2_c.append(S.build_gather_map(
            o_bcast, pos2,
            jnp.broadcast_to(row1[..., None], (Ni, Tc, No)).reshape(-1),
            fan.reshape(-1), No, C2, sentinel=Ni * C1))

        # ---- destination chain (chunk-local stage-2 rows + concat offset)
        c2, ok2 = _hier_recv_chain(group, geo, me_o, me_i)
        mine = geo["r_dst"] == me                           # [No, Ni, Tc, K]
        e_l = geo["s_dst"].clip(0, L - 1)
        entv = mine & ok2[..., None]
        r2 = (jnp.arange(No)[:, None, None] * C2 + c2)[..., None]
        r2 = jnp.broadcast_to(r2, (No, Ni, Tc, Kk))
        el_c.append(e_l)
        entv_c.append(entv)
        rows_c.append(c * (No * C2) + r2)       # into the chunk concatenation

        # ---- combine, rail side: accumulate partials from every pod into
        # the chunk's held-slot buffer (same c2 chain per destination pod,
        # vectorized over o_p)
        held = geo["ok1"][me_o, :, :, me_i]                 # [Ni, Tc] my rail
        p1i = geo["pos1"][me_o, :, :, me_i]
        flat1_rows = jnp.arange(Ni)[:, None] * C1 + p1i
        needs = ((geo["i_dst"][me_o] == me_i)[None] &
                 (geo["o_dst"][me_o][None] ==
                  jnp.arange(No)[:, None, None, None])).any(-1)  # [No, Ni, Tc]
        fanned = held[None] & needs
        c2p = jnp.cumsum(fanned.reshape(No, Ni * Tc).astype(jnp.int32),
                         axis=1) - 1
        okp = fanned.reshape(No, Ni * Tc) & (c2p < C2)
        rail_dst_c.append(jnp.where(
            okp & (p1i.reshape(-1)[None] < C1),
            jnp.broadcast_to(flat1_rows.reshape(-1)[None], (No, Ni * Tc)),
            Ni * C1))
        rail_src_c.append(jnp.where(
            okp, jnp.arange(No)[:, None] * C2 + c2p, No * C2))

        # ---- combine, source side: rows into the chunk-concatenated
        # stage-1 combine buffers, in token order
        src_rows_c.append(jnp.where(
            s1 & (p1 < C1),
            c * (Ni * C1) + jnp.arange(Ni)[None, :] * C1 + p1,
            nc * Ni * C1))

    def glob(parts):
        """[nc] x [No, Ni, Tc, K] -> flat [No*Ni*T*K] in MONOLITHIC entry
        order (o, i, t, k) — chunk slices interleave back into the token dim,
        so expert-region positions match the nc=1 plan exactly."""
        st = jnp.stack(parts)                               # [nc, No, Ni, Tc, K]
        return st.transpose(1, 2, 0, 3, 4).reshape(-1)

    ent_valid = glob(entv_c)
    e_l_all = glob(el_c)
    rows_all = glob(rows_c)
    a_pos, counts = S.positions_by_dest(e_l_all, L, ent_valid)
    disp_recv_gmap = S.build_gather_map(e_l_all, a_pos, rows_all, ent_valid,
                                        L, A, sentinel=nc * No * C2)

    # ---- combine, expert side: per-y3d-slot stage-2 target — ONE [L*A]
    # map whose rows address the chunk-concatenated [nc*No*C2] stage-2
    # buffer (a y3d slot belongs to exactly one chunk — its source token's
    # — so a single scatter fills every chunk's slice at once and the
    # H-wide combine work stays <= L*A rows regardless of nc; ht.py slices
    # the buffer per chunk for the a2a stream).
    slot_of_entry = jnp.where(ent_valid & (a_pos < A), e_l_all * A + a_pos,
                              L * A)
    idx2g = jnp.where(ent_valid, rows_all, nc * No * C2)
    h_slot_tgt = jnp.full((L * A + 1,), nc * No * C2, jnp.int32).at[
        slot_of_entry].set(idx2g.astype(jnp.int32), mode="drop")[:L * A]

    return EpPlan(
        disp_recv_gmap=disp_recv_gmap, disp_counts=counts,
        h_gmap1=jnp.stack(g1_c), h_gmap2=jnp.stack(g2_c),
        h_slot_tgt=h_slot_tgt,
        h_rail_dst_rows=jnp.stack(rail_dst_c).astype(jnp.int32),
        h_rail_src_rows=jnp.stack(rail_src_c).astype(jnp.int32),
        h_src_rows=jnp.concatenate(src_rows_c, axis=0).astype(jnp.int32),
        h_entry_slot=slot_of_entry.astype(jnp.int32),
    )


# --------------------------------------------------------------------------
# baseline (Megatron AllToAll dispatcher, paper §I)
# --------------------------------------------------------------------------

def _baseline_plan(group, topk_idx, topk_g, num_tokens) -> EpPlan:
    """Per-(expert, src) capacity blocks; dispatch permute and combine
    unpermute share the same position chain."""
    from repro.core.baseline import _per_expert_cap
    N, L = group.ep_size, group.local_experts
    T, Kk = topk_idx.shape
    Ce = _per_expert_cap(group)
    src = my_rank(group) if group.placement is not None else 0
    dst, e_l = dest_of(group, topk_idx, src)                # [T, K]
    valid = topk_idx < group.cfg.num_experts
    block = jnp.where(valid, dst * L + e_l, N * L).reshape(-1)
    pos, _ = S.positions_by_dest(block, N * L, valid.reshape(-1))
    t_of = jnp.broadcast_to(jnp.arange(T)[:, None], (T, Kk)).reshape(-1)
    gmap = S.build_gather_map(block, pos, t_of, valid.reshape(-1),
                              N * L, Ce, sentinel=T)
    row = jnp.where(valid.reshape(-1) & (pos < Ce),
                    block.clip(0, N * L - 1) * Ce + pos, N * L * Ce)
    return EpPlan(disp_send_gmap=gmap.reshape(N, L * Ce),
                  disp_counts=recv_counts(group, topk_g),
                  comb_recv_rows=row.reshape(T, Kk).astype(jnp.int32))
