"""Shared recv-side unpack: the single call site of the fused ``recv_unpack``
kernel.

Every dispatch-recv phase (LL nccl_ep, HT flat, both HT hierarchical stages)
unpacks received payload blocks through a plan-precomputed slot map; quantized
payloads additionally need block-wise FP8 dequantization. The seed did this
in two passes — an XLA gather followed by a separate ``dequantize_fp8`` over
the gathered copy — per site, with LL and HT each carrying their own fp8
plumbing. ``unpack_recv`` below is now the only place recv-side unpack
happens: one fused pass (kernels/recv_unpack.py — gather through the slot map
+ in-kernel dequant), so the one-pass-per-phase invariant holds on the recv
side too. tests/test_plan.py greps the phase modules to keep it that way.

``dequant_rows`` covers the one recv path with no gather at all (the LL
deepep layout, where unpack is a pure transpose): plain block dequantization,
shared by any layout that lands rows positionally.
"""
from __future__ import annotations

import jax

from repro.core import slots as S
from repro.kernels import ops as K


def unpack_recv(recv: jax.Array, gmap: jax.Array,
                scales: jax.Array | None = None, out_dtype=None) -> jax.Array:
    """Unpack received payload through a slot map in one fused pass.

    recv: [..., H] received blocks (leading dims collapse to the flat rows
    the map addresses; sentinel == total rows); gmap: int32 slot map of any
    shape; scales: matching [..., H/block] f32 when the payload is fp8.
    Returns ``gmap.shape + (H,)`` — dequantized when scales are given."""
    flat = S.flat_rows(recv)
    s_flat = S.flat_rows(scales) if scales is not None else None
    return K.recv_unpack(flat, gmap, s_flat, out_dtype)


def dequant_rows(rows: jax.Array, scales: jax.Array | None) -> jax.Array:
    """Block-dequantize positionally-landed rows (no slot map). scales None
    means an unquantized payload — returned unchanged."""
    if scales is None:
        return rows
    return K.dequantize_fp8(rows, scales)
