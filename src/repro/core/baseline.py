"""Baseline: the CPU-orchestrated *AllToAll dispatcher* (paper §I).

"Tokens are permuted according to routing decisions, communicated via
AllToAll, and unpermuted after expert computation" — the Megatron-Core / Tutel
pattern the paper (and DeepEP) position against. Differences from the fused
NCCL-EP paths that show up directly in the roofline terms:

  * no routing dedup — every (token, k) entry crosses the wire, so dispatch
    moves K copies of every token (vs ≤ min(K, N) per-rank-deduped copies);
  * per-(expert, source) capacity blocks — padding is allocated and *moved*
    per expert pair rather than per rank pair, inflating collective bytes by
    ~L/E·cf relative to need;
  * no quantization — payloads travel at model dtype.

Interface-compatible with LL/HT: returns the [L, A, H] expert-major tensor +
counts so the same expert FFN consumes it, and — through the ``EpBackend``
protocol (core/backend.py) — honors the same staged ``send_only=True`` +
``ep_complete`` surface (the a2a is the send half; the unpermute/reduce is
the complete half), so drivers built on the staged contract run unchanged on
the baseline for apples-to-apples comparisons. Like LL/HT, the permutation
maps are precomputed once per handle by the EpPlan engine; dispatch/combine
are single gather passes.
"""
from __future__ import annotations

import jax

from repro.core.backend import BaseBackend, EpPending, register_backend
from repro.core.group import EpGroup, EpHandle
from repro.core import slots as S
from repro.core import plan as P
from repro.kernels import ops as K


def _axis(group):
    a = group.cfg.ep_axis
    return a if len(a) > 1 else a[0]


def _a2a(x, group):
    return jax.lax.all_to_all(x, _axis(group), split_axis=0, concat_axis=0, tiled=False)


def _per_expert_cap(group: EpGroup) -> int:
    """Per-(expert, src-rank) slot count: cf * expected, zero-drop = T."""
    T, Kk, E = (group.cfg.max_tokens_per_rank, group.cfg.top_k,
                group.cfg.num_experts)
    cf = group.cfg.capacity_factor
    if cf is None:
        return T
    import math
    return min(max(8, int(math.ceil(cf * T * Kk / E / 8.0) * 8)), T)


def baseline_create_handle(group, topk_idx, topk_weights, num_tokens=None) -> EpHandle:
    from repro.core.ht import ht_create_handle
    return ht_create_handle(group, topk_idx, topk_weights, num_tokens)


def baseline_dispatch_send(group: EpGroup, handle: EpHandle, x: jax.Array) -> EpPending:
    plan = P.ensure_plan(group, handle)
    send, _ = K.dispatch_pack(x, plan.disp_send_gmap,
                              out_dtype=group.cfg.payload_dtype)  # [N, L*Ce, H]
    return EpPending(mode="baseline", op="dispatch", recv=_a2a(send, group))


def baseline_dispatch_complete(group: EpGroup, handle: EpHandle, pending: EpPending):
    N, L = group.ep_size, group.local_experts
    Ce = _per_expert_cap(group)
    plan = P.ensure_plan(group, handle)
    H = pending.recv.shape[-1]
    out = pending.recv.reshape(N, L, Ce, H).transpose(1, 0, 2, 3).reshape(L, N * Ce, H)
    return out, plan.disp_counts


def baseline_combine_send(group: EpGroup, handle: EpHandle, y3d: jax.Array) -> EpPending:
    N, L = group.ep_size, group.local_experts
    Ce = _per_expert_cap(group)
    H = y3d.shape[-1]
    send = (y3d.reshape(L, N, Ce, H).transpose(1, 0, 2, 3)
            .reshape(N, L * Ce, H).astype(group.cfg.payload_dtype))
    return EpPending(mode="baseline", op="combine",
                     recv=_a2a(send, group))     # [N, L*Ce, H] back at src


def baseline_combine_complete(group: EpGroup, handle: EpHandle, pending: EpPending):
    plan = P.ensure_plan(group, handle)
    return K.combine_gather_reduce(S.flat_rows(pending.recv),
                                   plan.comb_recv_rows, handle.topk_weights)


def baseline_dispatch(group: EpGroup, handle: EpHandle, x: jax.Array, *, send_only=False):
    pending = baseline_dispatch_send(group, handle, x)
    if send_only:
        return pending
    return baseline_dispatch_complete(group, handle, pending)


def baseline_combine(group: EpGroup, handle: EpHandle, y3d: jax.Array, *, send_only=False):
    pending = baseline_combine_send(group, handle, y3d)
    if send_only:
        return pending
    return baseline_combine_complete(group, handle, pending)


class BaselineBackend(BaseBackend):
    """Megatron-style a2a dispatcher behind the EpBackend protocol."""

    mode = "baseline"

    def create_handle(self, group, topk_idx, topk_weights, num_tokens=None):
        return baseline_create_handle(group, topk_idx, topk_weights, num_tokens)

    def dispatch_send(self, group, handle, tokens):
        return baseline_dispatch_send(group, handle, tokens)

    def dispatch_complete(self, group, handle, pending):
        return baseline_dispatch_complete(group, handle, pending)

    def combine_send(self, group, handle, expert_out):
        return baseline_combine_send(group, handle, expert_out)

    def combine_complete(self, group, handle, pending):
        return baseline_combine_complete(group, handle, pending)


register_backend(BaselineBackend())
