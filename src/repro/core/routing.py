"""MoE routers: top-k gating producing the (topk_idx, topk_weights) pair that
drives dispatch/combine.

Supports the gating variants used by the assigned MoE architectures:
  * softmax top-k (DBRX: 16 experts, top-4)
  * sigmoid + group-limited + aux-loss-free bias (DeepSeek-V3: 256 experts,
    top-8, 1 shared expert, node-limited routing, bias-corrected selection)
plus the standard load-balancing auxiliary loss (GShard/Switch style) and the
router-z loss.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    num_experts: int
    top_k: int
    gating: Literal["softmax", "sigmoid"] = "softmax"
    # DeepSeek-V3 group-limited ("node-limited") routing: experts are divided
    # into n_groups; only experts inside the topk_groups best groups are
    # eligible. Disabled when n_groups == 1.
    n_groups: int = 1
    topk_groups: int = 1
    # Aux-loss-free balancing (DeepSeek-V3): a persistent per-expert bias is
    # added to the scores *for selection only*; gate weights use raw scores.
    use_selection_bias: bool = False
    routed_scaling_factor: float = 1.0
    norm_topk_prob: bool = True
    aux_loss_weight: float = 0.0
    z_loss_weight: float = 0.0
    dtype: jnp.dtype = jnp.float32


@dataclasses.dataclass
class RouterOutput:
    topk_idx: jax.Array        # [T, K] int32 — global expert ids
    topk_weights: jax.Array    # [T, K] float32 — combine weights
    aux_loss: jax.Array        # scalar
    z_loss: jax.Array          # scalar
    # per-expert assignment fraction, for aux-free bias update / monitoring
    expert_load: jax.Array     # [E] float32


def _group_limited_mask(scores: jax.Array, cfg: RouterConfig) -> jax.Array:
    """DeepSeek-V3 group-limited routing: keep only the topk_groups groups
    with the highest (sum of top-2 in-group scores); mask the rest to -inf.
    scores: [T, E] -> bool mask [T, E] of eligible experts."""
    T, E = scores.shape
    g = cfg.n_groups
    per = E // g
    grouped = scores.reshape(T, g, per)
    # group score = sum of top-2 scores within the group (V3 definition)
    top2 = jax.lax.top_k(grouped, min(2, per))[0].sum(axis=-1)  # [T, g]
    _, gidx = jax.lax.top_k(top2, cfg.topk_groups)              # [T, topk_groups]
    gmask = jnp.zeros((T, g), dtype=bool).at[jnp.arange(T)[:, None], gidx].set(True)
    return jnp.repeat(gmask, per, axis=-1)                      # [T, E]


def route(
    logits: jax.Array,
    cfg: RouterConfig,
    selection_bias: jax.Array | None = None,
) -> RouterOutput:
    """Compute top-k routing from raw router logits [T, E]."""
    T, E = logits.shape
    logits = logits.astype(jnp.float32)

    if cfg.gating == "softmax":
        scores = jax.nn.softmax(logits, axis=-1)
    else:  # sigmoid (DeepSeek-V3)
        scores = jax.nn.sigmoid(logits)

    select_scores = scores
    if cfg.use_selection_bias and selection_bias is not None:
        select_scores = scores + selection_bias[None, :]

    if cfg.n_groups > 1:
        eligible = _group_limited_mask(select_scores, cfg)
        select_scores = jnp.where(eligible, select_scores, -jnp.inf)

    _, topk_idx = jax.lax.top_k(select_scores, cfg.top_k)       # [T, K]
    topk_idx = topk_idx.astype(jnp.int32)
    # Gate weights always come from the *unbiased* scores (aux-free rule).
    topk_w = jnp.take_along_axis(scores, topk_idx, axis=-1)     # [T, K]
    if cfg.norm_topk_prob:
        topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-20)
    topk_w = topk_w * cfg.routed_scaling_factor

    # Load-balancing aux loss (Switch/GShard): E * sum_e f_e * p_e
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32).sum(1)   # [T, E]
    f = onehot.mean(0)                                # fraction routed to e
    p = (jax.nn.softmax(logits, -1)).mean(0)          # mean router prob
    aux = E * jnp.sum(f * p) * cfg.aux_loss_weight
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.z_loss_weight

    return RouterOutput(
        topk_idx=topk_idx,
        topk_weights=topk_w.astype(jnp.float32),
        aux_loss=aux,
        z_loss=z,
        expert_load=f,
    )


def update_selection_bias(
    bias: jax.Array, expert_load: jax.Array, update_rate: float = 1e-3
) -> jax.Array:
    """Aux-loss-free balancing bias update (DeepSeek-V3): increase the bias of
    underloaded experts, decrease it for overloaded ones."""
    mean_load = jnp.mean(expert_load)
    return bias + update_rate * jnp.sign(mean_load - expert_load)
