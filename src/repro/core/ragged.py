"""Exact-sized LL transfers via ``jax.lax.ragged_all_to_all`` — the closest
TPU analogue of the paper's RDMA slot writes (only real tokens cross the
wire, receive regions are shared rather than per-pair).

With this path the LL buffer accounting matches Eq. 3 *exactly*:
dispatch ``N*B*P`` worst case but only actual bytes move; combine ``B*K*P``
shared slots. Entries destined to the same peer are made contiguous by the
same running-count maps the dense path uses (a stable sort by destination),
then each (src, dst) pair transfers exactly ``counts[src,dst]`` rows at
offsets both sides derive from the shared metadata.

**Gated**: XLA:CPU cannot compile ``ragged-all-to-all`` (verified on this
container: ThunkEmitter unimplemented), so this module is trace-tested only
here and selected via ``EpGroupConfig`` on TPU deployments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.group import EpGroup, EpHandle
from repro.core import slots as S


def ragged_supported() -> bool:
    return (hasattr(jax.lax, "ragged_all_to_all")
            and jax.default_backend() == "tpu")


def ll_dispatch_ragged(group: EpGroup, handle: EpHandle, x: jax.Array):
    """Per-shard LL dispatch with exact-sized transfers.

    Returns (recv [N*C_d, H] shared buffer, recv_row_of_entry metadata) —
    unpack to the 3D layout reuses the dense path's maps."""
    if group.placement is not None:
        # this trace-only path still derives destinations contiguously; an
        # EpPlacement group must not silently route with stale arithmetic
        raise NotImplementedError(
            "ragged LL dispatch does not support explicit expert placements "
            "yet — route placement resolution through plan.dest_of when "
            "enabling it (docs/DESIGN.md §8)")
    N, L = group.ep_size, group.local_experts
    C = group.ll_disp_cap
    axis = group.cfg.ep_axis[0] if len(group.cfg.ep_axis) == 1 else group.cfg.ep_axis
    T, Kk = handle.topk_idx.shape
    dst = handle.topk_idx // L
    sends = jnp.zeros((T, N), bool).at[
        jnp.arange(T)[:, None], dst].set(True, mode="drop")
    pos = jnp.cumsum(sends.astype(jnp.int32), axis=0) - 1
    send_counts = sends.astype(jnp.int32).sum(0)               # [N]
    # pack send rows contiguous by destination: row = dst_block*C + pos
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, N)).reshape(-1)
    d_idx = jnp.broadcast_to(jnp.arange(N)[None, :], (T, N)).reshape(-1)
    gmap = S.build_gather_map(d_idx, pos.reshape(-1), t_idx, sends.reshape(-1),
                              N, C, sentinel=T)
    operand = S.gather_rows(x.astype(group.cfg.payload_dtype),
                            gmap).reshape(N * C, -1)
    output = jnp.zeros_like(operand)
    # offsets: sender reads block d at d*C; receiver writes block src at src*C
    input_offsets = jnp.arange(N, dtype=jnp.int32) * C
    send_sizes = send_counts
    me = jax.lax.axis_index(axis if isinstance(axis, str) else axis[0])
    output_offsets = jnp.full((N,), me * C, jnp.int32)  # my block on each peer
    # recv sizes: what each peer sends me == column me of the global counts
    recv_sizes = jax.lax.all_to_all(send_counts[:, None], axis,
                                    split_axis=0, concat_axis=1,
                                    tiled=False).reshape(N)
    recv = jax.lax.ragged_all_to_all(
        operand, output, input_offsets, send_sizes, output_offsets, recv_sizes,
        axis_name=axis)
    return recv, recv_sizes


def ll_dispatch_ragged_jaxpr(group: EpGroup, T: int, H: int):
    """Trace-only helper (tests): builds the jaxpr under an abstract mesh."""
    def f(x, topk):
        from repro.core.ll import ll_create_handle
        h = ll_create_handle(group, topk, jnp.ones(topk.shape, jnp.float32))
        return ll_dispatch_ragged(group, h, x)
    return f
