"""repro.core — NCCL EP's contribution rendered in JAX: unified expert-parallel
dispatch/combine with LL (decode) and HT (training/prefill) algorithm modes,
two-tier group/handle resources, and a Megatron-style AllToAll baseline."""
from repro.core.api import (  # noqa: F401
    EpGroup, EpGroupConfig, EpHandle, EpPending, ep_create_group,
    ep_create_handle, ep_handle_refresh, ep_dispatch, ep_combine, ep_complete,
    ep_handle_get_num_recv_tokens, ep_handle_destroy, ep_dispatch_tensors,
    ep_combine_tensors, registered_modes,
)
from repro.core.backend import (  # noqa: F401
    BaseBackend, EpBackend, get_backend, register_backend,
)
from repro.core.placement import (  # noqa: F401
    EpPlacement, HeatTracker, identity_placement, redundant_placement,
    rebalance, heat_from_topk, fold_slot_counts, rank_loads, imbalance,
    expand_expert_params, collapse_expert_params,
    placement_to_jsonable, placement_from_jsonable,
)
from repro.core.plan import EpPlan, build_plan, routing_hash  # noqa: F401
from repro.core.routing import RouterConfig, RouterOutput, route  # noqa: F401
from repro.core.tensor import EpTensor, EpTensorTag, ep_tensor_create  # noqa: F401
