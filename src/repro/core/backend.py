"""EpBackend: the mode-agnostic staged-EP backend protocol.

The unified API's original rendering dispatched on ``group.mode`` through
if/elif chains in ``core/api.py``, and the staged surface
(``send_only=True`` + ``ep_complete``) existed only for LL — HT and the
baseline accepted the flag and silently ran eager, and ``ep_complete`` was an
``isinstance`` chain over LL's private pending types. This module replaces
all of that with one protocol:

* ``EpBackend`` — the five-phase contract every mode implements:
  ``create_handle``, ``dispatch_send``, ``dispatch_complete``,
  ``combine_send``, ``combine_complete``. The eager ``dispatch``/``combine``
  entry points are derived (send then complete), so **staged is the primitive
  and eager is the composition** — a mode cannot implement the eager path
  without the staged one, which is exactly the no-silent-ignore contract
  tests/test_backends.py pins: every registered backend either executes
  ``send_only=True`` staged or raises ``NotImplementedError``; none may
  accept the flag and run eager.

* ``EpPending`` — the one mode-tagged pending pytree shared by every mode.
  ``mode`` and ``op`` are static (aux-data) fields, so ``ep_complete`` can
  route through the registry by tag with zero ``isinstance`` special-casing,
  and a pending created by one mode handed to another mode's group fails
  loudly instead of silently unpacking garbage.

* the registry — backends self-register at import keyed by their mode name;
  ``get_backend(group.mode)`` is the only mode dispatch left in the API
  layer. Future modes (the ROADMAP's standing contract) plug in by
  registering a backend and shipping their phase maps in ``EpPlan``.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax

from repro.core.group import EpGroup, EpHandle


@dataclasses.dataclass
class EpPending:
    """In-flight staged EP operation (the JAX rendering of the paper's
    posted-but-not-consumed transfer).

    ``recv`` holds the received-but-unconsumed payload blocks — for chunked
    hierarchical HT, the flat concatenation of every chunk's stage-2 buffer —
    and ``recv_scales`` the ride-along fp8 scales when the dispatch payload
    is quantized. ``mode``/``op`` are static pytree metadata: they survive
    jit tracing as Python strings, which is what lets ``ep_complete`` route
    by tag instead of by Python type."""

    mode: str                          # owning backend ("ll" | "ht" | ...)
    op: str                            # "dispatch" | "combine"
    recv: jax.Array                    # received payload rows
    recv_scales: jax.Array | None = None   # fp8 scales riding along


jax.tree_util.register_dataclass(
    EpPending, data_fields=["recv", "recv_scales"], meta_fields=["mode", "op"])


@runtime_checkable
class EpBackend(Protocol):
    """Protocol every mode backend satisfies (see BaseBackend for the
    derived eager surface)."""

    mode: str

    def create_handle(self, group: EpGroup, topk_idx, topk_weights,
                      num_tokens=None) -> EpHandle: ...
    def dispatch_send(self, group: EpGroup, handle: EpHandle,
                      tokens) -> EpPending: ...
    def dispatch_complete(self, group: EpGroup, handle: EpHandle,
                          pending: EpPending): ...
    def combine_send(self, group: EpGroup, handle: EpHandle,
                     expert_out) -> EpPending: ...
    def combine_complete(self, group: EpGroup, handle: EpHandle,
                         pending: EpPending): ...


class BaseBackend:
    """Shared driver half of the protocol: eager = send ∘ complete.

    Subclasses implement the four phase halves (plus ``create_handle``); the
    staged/eager selection and the ``ep_complete`` tag routing live here so
    every mode honors ``send_only`` by construction."""

    mode: str = "?"

    # -- phase halves (mode-specific; subclasses override) ------------------
    def create_handle(self, group, topk_idx, topk_weights, num_tokens=None):
        raise NotImplementedError

    def dispatch_send(self, group, handle, tokens) -> EpPending:
        raise NotImplementedError

    def dispatch_complete(self, group, handle, pending: EpPending):
        raise NotImplementedError

    def combine_send(self, group, handle, expert_out) -> EpPending:
        raise NotImplementedError

    def combine_complete(self, group, handle, pending: EpPending):
        raise NotImplementedError

    # -- derived eager + staged surface ------------------------------------
    def dispatch(self, group, handle, tokens, *, send_only: bool = False):
        pending = self.dispatch_send(group, handle, tokens)
        if send_only:
            return pending
        return self.dispatch_complete(group, handle, pending)

    def combine(self, group, handle, expert_out, *, send_only: bool = False):
        pending = self.combine_send(group, handle, expert_out)
        if send_only:
            return pending
        return self.combine_complete(group, handle, pending)

    def complete(self, group, handle, pending: EpPending):
        if not isinstance(pending, EpPending):
            raise TypeError(f"not a pending EP operation: {type(pending)}")
        if pending.mode != self.mode:
            raise ValueError(
                f"pending op belongs to mode {pending.mode!r}, but the group "
                f"resolved mode {self.mode!r} — handles and pendings are not "
                "transferable across modes")
        if pending.op == "dispatch":
            return self.dispatch_complete(group, handle, pending)
        if pending.op == "combine":
            return self.combine_complete(group, handle, pending)
        raise ValueError(f"unknown pending op: {pending.op!r}")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, BaseBackend] = {}


def register_backend(backend: BaseBackend) -> BaseBackend:
    """Register a backend instance under its ``mode`` key. Idempotent per
    mode name (last registration wins — lets tests stub modes)."""
    _REGISTRY[backend.mode] = backend
    return backend


def get_backend(mode: str) -> BaseBackend:
    """Resolve a mode name to its registered backend. The ONLY mode dispatch
    in the API layer — no if/elif chains, no isinstance on pending types."""
    try:
        return _REGISTRY[mode]
    except KeyError:
        raise KeyError(
            f"no EP backend registered for mode {mode!r}; "
            f"known: {sorted(_REGISTRY)}") from None


def registered_modes() -> tuple[str, ...]:
    """Registered backend mode names (for the contract tests)."""
    return tuple(sorted(_REGISTRY))
