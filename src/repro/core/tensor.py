"""EpTensor: N-dimensional tensor descriptor with semantic tags.

JAX analogue of the paper's ``ncclNDTensor_t`` (§III-E). In NCCL EP the
descriptor carries (shape, strides, dtype, tag, pointer) so the C library can
validate roles and apply mode-specific transforms. In JAX, arrays already
carry shape/dtype; what survives the port is the *semantic tag* — it lets the
unified dispatch/combine entry points validate that the right tensors were
passed and route them to the mode-specific implementation, exactly like the
C API does.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any

import jax
import jax.numpy as jnp


class EpTensorTag(enum.Enum):
    """Semantic roles, mirroring Table IV of the paper."""

    TOKENS = "tokens"                       # token data (input or output)
    TOPK_IDX = "topk_idx"                   # top-k expert indices
    TOPK_WEIGHTS = "topk_weights"           # top-k router weights
    SCALES = "scales"                       # FP8/INT8 quantization scales
    RECV_EXPERT_COUNTER = "recv_expert_counter"  # per-expert token counts
    TOKENS_PER_EXPERTS = "tokens_per_experts"    # per-expert token counts (dispatch out)
    NONE = "none"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EpTensor:
    """A tagged array. ``data`` is the only leaf; the tag is static metadata."""

    data: jax.Array
    tag: EpTensorTag = dataclasses.field(metadata=dict(static=True), default=EpTensorTag.NONE)

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype


def ep_tensor_create(data: jax.Array, tag: EpTensorTag) -> EpTensor:
    """``ncclEpTensorCreate`` analogue."""
    return EpTensor(data=data, tag=tag)


_ALLOWED_DTYPES = {
    EpTensorTag.TOKENS: (jnp.float32, jnp.bfloat16, jnp.float16, jnp.float8_e4m3fn, jnp.int8),
    EpTensorTag.TOPK_IDX: (jnp.int32,),
    EpTensorTag.TOPK_WEIGHTS: (jnp.float32, jnp.bfloat16),
    EpTensorTag.SCALES: (jnp.float32,),
    EpTensorTag.TOKENS_PER_EXPERTS: (jnp.int32,),
    EpTensorTag.RECV_EXPERT_COUNTER: (jnp.int32,),
}


def validate(t: EpTensor, *, tag: EpTensorTag, ndim: int | None = None) -> jax.Array:
    """Validate a tagged tensor's role/dtype/rank; return the raw array.

    Mirrors the validation the C API performs on ``ncclNDTensor_t`` inputs.
    Raises ``ValueError`` at trace time (i.e. the JAX analogue of the C API
    returning ``ncclInvalidArgument``).
    """
    if isinstance(t, EpTensor):
        if t.tag != tag:
            raise ValueError(f"EpTensor tagged {t.tag} where {tag} expected")
        data = t.data
    else:  # raw arrays accepted for ergonomic Python use, like the ctypes wrapper
        data = t
    allowed = _ALLOWED_DTYPES.get(tag)
    if allowed is not None and data.dtype not in [jnp.dtype(d) for d in allowed]:
        raise ValueError(f"{tag}: dtype {data.dtype} not in allowed {allowed}")
    if ndim is not None and data.ndim != ndim:
        raise ValueError(f"{tag}: expected rank {ndim}, got shape {data.shape}")
    return data


def as_array(t: EpTensor | jax.Array) -> jax.Array:
    return t.data if isinstance(t, EpTensor) else t
