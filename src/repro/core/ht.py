"""High-Throughput (HT) mode — paper §V, adapted from Hybrid-EP.

Targets training and inference prefill (4096+ tokens/rank). Two paths:

* **flat** (single EP axis — the single-pod production mesh): one entry-level
  all-to-all, output grouped by local expert with per-expert counts — the
  deterministic 2D-concatenated layout of Fig. 4, rendered with static
  per-expert capacity padding (TPU adaptation; counts are exact).

* **hierarchical** (EP spans ("pod", inner)): Hybrid-EP's two-tier scheme.
  Stage 1 aggregates tokens *within the fast domain*: an all-to-all over the
  inner axis keyed by the destination chip's inner coordinate (the "rail"),
  deduplicated per (token, rail) — a token headed to several experts on
  same-rail chips crosses the intra-pod fabric once. Stage 2 is the
  rail-aligned slow hop: an all-to-all over the ``pod`` axis between
  same-inner-coordinate chips — the exact analogue of Hybrid-EP's same-rail
  NIC RDMA. Combine runs the mirror path with **hierarchical reduction**
  (§V-A): expert responses are weighted at the source and partially reduced
  at the rail chip before the final intra-pod hop, shrinking fast-domain
  bytes by the per-token multiplicity.

Metadata (the paper's handle-creation exchange, §III-C2) is the all-gathered
``topk_idx``; every rank derives the full slot-map chain locally, so payload
messages carry zero header bytes (see slots.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.group import EpGroup, EpHandle
from repro.core import slots as S
from repro.kernels import ops as K


# --------------------------------------------------------------------------
# handle
# --------------------------------------------------------------------------

def ht_create_handle(group: EpGroup, topk_idx, topk_weights, num_tokens=None) -> EpHandle:
    """Metadata exchange at handle creation (paper §III-C2): gather routing
    across the full EP axis; exact receive counts enable the
    ``ep_handle_get_num_recv_tokens`` query for precise buffer consumption."""
    N, L = group.ep_size, group.local_experts
    T, Kk = topk_idx.shape
    me = _my_rank(group)
    if num_tokens is not None:
        pad = jnp.arange(T)[:, None] >= num_tokens
        topk_idx = jnp.where(pad, group.cfg.num_experts, topk_idx)
    axes = group.cfg.ep_axis
    g = topk_idx
    for ax in reversed(axes):
        g = jax.lax.all_gather(g, ax, axis=0, tiled=False)
    topk_g = g.reshape(N, T, Kk)
    mine = (topk_g // L) == me
    e_l = (topk_g - me * L).clip(0, L - 1)
    counts = jnp.zeros((L,), jnp.int32).at[e_l.reshape(-1)].add(
        mine.reshape(-1).astype(jnp.int32))
    nt = jnp.asarray(T, jnp.int32) if num_tokens is None else num_tokens
    return EpHandle(
        topk_idx=topk_idx, topk_weights=topk_weights, topk_global=topk_g,
        tokens_per_expert=counts, num_recv_tokens=counts.sum(), num_tokens=nt,
    )


def _my_rank(group: EpGroup) -> jax.Array:
    axes = group.cfg.ep_axis
    r = jax.lax.axis_index(axes[0])
    for name in axes[1:]:
        r = r * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return r


def _hierarchical(group: EpGroup) -> bool:
    return group.cfg.ht_hierarchical and len(group.cfg.ep_axis) > 1 and group.outer_size > 1


# --------------------------------------------------------------------------
# flat path (single EP axis)
# --------------------------------------------------------------------------

def _flat_axis(group):
    a = group.cfg.ep_axis
    return a if len(a) > 1 else a[0]


def _flat_maps(group: EpGroup, handle: EpHandle):
    """Shared sender/receiver geometry for the flat path."""
    N, L, C = group.ep_size, group.local_experts, group.ht_pair_cap
    topk = handle.topk_idx
    T, Kk = topk.shape
    dst = (topk // L).reshape(-1)                          # [T*K]
    valid = jnp.broadcast_to((jnp.arange(T) < handle.num_tokens)[:, None],
                             (T, Kk)).reshape(-1)
    c_pos, send_counts = S.positions_by_dest(dst, N, valid)
    return dst, valid, c_pos, send_counts


def ht_dispatch_flat(group: EpGroup, handle: EpHandle, x: jax.Array):
    N, L, C, A = group.ep_size, group.local_experts, group.ht_pair_cap, group.ht_expert_cap
    T, Kk = handle.topk_idx.shape
    dst, valid, c_pos, _ = _flat_maps(group, handle)
    t_of = jnp.broadcast_to(jnp.arange(T)[:, None], (T, Kk)).reshape(-1)
    gmap = S.build_gather_map(dst, c_pos, t_of, valid, N, C, sentinel=T)
    xq, scales = _quant(group, x)
    send = S.gather_rows(xq, gmap)                         # [N, C, H]
    recv = _a2a(send, _flat_axis(group))
    recv_s = _a2a(S.gather_rows(scales, gmap), _flat_axis(group)) if scales is not None else None

    # ---- receiver: entries of every src rank routed to me, in deterministic
    # (expert, src, token, k) order -> [L, A, H]
    me = _my_rank(group)
    topk_g = handle.topk_global
    mine = (topk_g // L) == me                             # [N, T, K]
    e_l = (topk_g - me * L).clip(0, L - 1)
    # sender's slot for each entry: running count per src restricted to dst==me
    flat_mine = mine.reshape(N, T * Kk)
    pos_r = jnp.cumsum(flat_mine.astype(jnp.int32), axis=1) - 1   # [N, T*K]
    slot_ok = flat_mine & (pos_r < C)
    rows = jnp.arange(N)[:, None] * C + pos_r              # recv flat row
    ent_valid = slot_ok.reshape(-1)
    a_pos, counts = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    gmap2 = S.build_gather_map(e_l.reshape(-1), a_pos, rows.reshape(-1), ent_valid,
                               L, A, sentinel=N * C)
    out = S.gather_rows(S.flat_rows(recv), gmap2)
    if recv_s is not None:
        sc = S.gather_rows(S.flat_rows(recv_s), gmap2, fill=0)
        out = K.dequantize_fp8(out, sc)
    return out, counts


def ht_combine_flat(group: EpGroup, handle: EpHandle, y3d: jax.Array):
    """Mirror a2a: expert side repacks [L, A, H] into the same [N, C, H]
    blocks (same slots as dispatch), then the source applies the weighted
    reduction — per-token at the receiver, matching LL semantics."""
    N, L, C, A = group.ep_size, group.local_experts, group.ht_pair_cap, group.ht_expert_cap
    me = _my_rank(group)
    topk_g = handle.topk_global
    Nn, T, Kk = topk_g.shape
    mine = (topk_g // L) == me
    e_l = (topk_g - me * L).clip(0, L - 1)
    flat_mine = mine.reshape(N, T * Kk)
    pos_r = jnp.cumsum(flat_mine.astype(jnp.int32), axis=1) - 1
    slot_ok = flat_mine & (pos_r < C)
    ent_valid = slot_ok.reshape(-1)
    a_pos, _ = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    y_row = e_l.reshape(-1) * A + a_pos
    r_of = jnp.broadcast_to(jnp.arange(N)[:, None, None], (N, T, Kk)).reshape(-1)
    # send slot within me->r block == the dispatch slot pos_r (mirror layout)
    gmap = S.build_gather_map(r_of, pos_r.reshape(-1), y_row,
                              ent_valid & (a_pos < A), N, C, sentinel=L * A)
    send = S.gather_rows(S.flat_rows(y3d.astype(group.cfg.payload_dtype)), gmap)
    recv = _a2a(send, _flat_axis(group))                   # [N, C, H]

    # source side: my entry (t,k) sits in block dst at my own dispatch slot
    dst, valid, c_pos, _ = _flat_maps(group, handle)
    T2, Kk2 = handle.topk_idx.shape
    row = jnp.where(valid & (c_pos < C), dst * C + c_pos, N * C)
    y_tk = S.gather_rows(S.flat_rows(recv), row.reshape(T2, Kk2))
    return K.combine_reduce(y_tk, handle.topk_weights)


# --------------------------------------------------------------------------
# hierarchical path (two-stage, pod-aware)
# --------------------------------------------------------------------------

def _hier_geometry(group: EpGroup, handle: EpHandle):
    """Full slot-map chain, computed identically on every chip from the
    replicated routing. Returns a dict of the intermediate maps."""
    L, Ni, No = group.local_experts, group.inner_size, group.outer_size
    C1, C2 = group.ht_stage1_cap, group.ht_stage2_cap
    topk_g = handle.topk_global          # [N, T, K], N = No*Ni (outer-major)
    N, T, Kk = topk_g.shape
    g = topk_g.reshape(No, Ni, T, Kk)
    r_dst = g // L
    o_dst, i_dst = r_dst // Ni, r_dst % Ni                  # [No, Ni, T, K]

    # stage 1 (per source chip): dedup over destination inner coordinate.
    # Invalid entries (sentinel expert) have r_dst == N -> i_dst computed from
    # it could alias a real coordinate, so mask by dst validity explicitly.
    ent_ok = r_dst < (No * Ni)
    i_dst_s = jnp.where(ent_ok, i_dst, Ni)                  # sentinel -> dropped
    sends1 = jnp.zeros((No, Ni, T, Ni), bool).at[
        jnp.arange(No)[:, None, None, None],
        jnp.arange(Ni)[None, :, None, None],
        jnp.arange(T)[None, None, :, None],
        i_dst_s].set(True, mode="drop")
    pos1 = jnp.cumsum(sends1.astype(jnp.int32), axis=2) - 1  # over tokens
    ok1 = sends1 & (pos1 < C1)
    # mask destination coords of invalid entries everywhere downstream
    o_dst = jnp.where(ent_ok, o_dst, No)
    i_dst = jnp.where(ent_ok, i_dst, Ni)
    return dict(g=g, o_dst=o_dst, i_dst=i_dst, sends1=sends1, pos1=pos1, ok1=ok1)


def ht_dispatch_hier(group: EpGroup, handle: EpHandle, x: jax.Array):
    ax_o, ax_i = group.cfg.ep_axis[0], group.cfg.ep_axis[-1]
    L, Ni, No = group.local_experts, group.inner_size, group.outer_size
    C1, C2, A = group.ht_stage1_cap, group.ht_stage2_cap, group.ht_expert_cap
    me_o, me_i = jax.lax.axis_index(ax_o), jax.lax.axis_index(ax_i)
    T, Kk = handle.topk_idx.shape
    geo = _hier_geometry(group, handle)

    # ---- stage 1 send (local views of the global maps)
    s1 = geo["sends1"][me_o, me_i]                          # [T, Ni]
    p1 = geo["pos1"][me_o, me_i]
    t_of = jnp.broadcast_to(jnp.arange(T)[:, None], (T, Ni)).reshape(-1)
    i_of = jnp.broadcast_to(jnp.arange(Ni)[None, :], (T, Ni)).reshape(-1)
    gmap1 = S.build_gather_map(i_of, p1.reshape(-1), t_of, s1.reshape(-1),
                               Ni, C1, sentinel=T)
    xq, scales = _quant(group, x)
    recv1 = _a2a(S.gather_rows(xq, gmap1), ax_i)            # [Ni, C1, H] at rail
    recv1_s = _a2a(S.gather_rows(scales, gmap1), ax_i) if scales is not None else None

    # ---- stage 2: rail (me_o, me_i) fans held tokens over destination pods.
    # Held slot (r_i, c) <-> token (me_o, r_i, t): needs pod o' iff any k with
    # i_dst == me_i and o_dst == o'.
    need = (geo["i_dst"][me_o] == me_i)                     # [Ni, T, K]
    fan = jnp.zeros((Ni, T, No), bool).at[
        jnp.arange(Ni)[:, None, None], jnp.arange(T)[None, :, None],
        jnp.where(need, geo["o_dst"][me_o], No)].set(True, mode="drop")
    ok1_me = geo["ok1"][me_o, :, :, me_i]                   # [Ni, T] held?
    fan = fan & ok1_me[..., None]
    # slot-2 positions: flat order (r_i-major, token) == recv1 slot order
    pos2, _ = S.positions_by_dest(
        jnp.broadcast_to(jnp.arange(No)[None, None, :], (Ni, T, No)).reshape(-1),
        No, fan.reshape(-1))
    pos2 = pos2.reshape(Ni, T, No)
    # recv1 flat row of token (r_i, t)
    row1 = jnp.arange(Ni)[:, None] * C1 + geo["pos1"][me_o, :, :, me_i]  # [Ni, T]
    gmap2 = S.build_gather_map(
        jnp.broadcast_to(jnp.arange(No)[None, None, :], (Ni, T, No)).reshape(-1),
        pos2.reshape(-1),
        jnp.broadcast_to(row1[..., None], (Ni, T, No)).reshape(-1),
        fan.reshape(-1), No, C2, sentinel=Ni * C1)
    recv2 = _a2a(S.gather_rows(S.flat_rows(recv1), gmap2), ax_o)   # [No, C2, H]
    recv2_s = (_a2a(S.gather_rows(S.flat_rows(recv1_s), gmap2, fill=0), ax_o)
               if recv1_s is not None else None)

    # ---- unpack at destination chip (me_o, me_i): reconstruct, for every
    # source pod o_s, the (r_i, t) -> c2 chain that pod's rail used.
    out, counts, _ = _hier_unpack(group, handle, geo, recv2, recv2_s, me_o, me_i)
    return out, counts


def _hier_recv_chain(group, geo, me_o, me_i):
    """For every (o_s, r_i, t): the stage-2 slot c2 (at source pod o_s's rail
    with inner coord me_i, sending to pod me_o) and validity."""
    L, Ni, No = group.local_experts, group.inner_size, group.outer_size
    C1, C2 = group.ht_stage1_cap, group.ht_stage2_cap
    No_, Ni_, T, Kk = geo["g"].shape
    # held at rail (o_s, me_i): ok1[o_s, r_i, t, me_i]
    held = geo["ok1"][:, :, :, me_i]                        # [No, Ni, T]
    # needs my pod: any k with i_dst==me_i and o_dst==me_o
    needs_me = ((geo["i_dst"] == me_i) & (geo["o_dst"] == me_o)).any(-1)  # [No, Ni, T]
    fanned = held & needs_me
    # c2 = running count in (r_i, t) order per source pod (matches the rail's
    # flat (r_i*C1+pos1) order because pos1 is monotone in t)
    c2 = jnp.cumsum(fanned.reshape(No, Ni * T).astype(jnp.int32), axis=1) - 1
    c2 = c2.reshape(No, Ni, T)
    ok2 = fanned & (c2 < C2)
    return c2, ok2


def _hier_unpack(group, handle, geo, recv2, recv2_s, me_o, me_i):
    L, Ni, No = group.local_experts, group.inner_size, group.outer_size
    C2, A = group.ht_stage2_cap, group.ht_expert_cap
    No_, Ni_, T, Kk = geo["g"].shape
    me = me_o * Ni + me_i
    c2, ok2 = _hier_recv_chain(group, geo, me_o, me_i)
    # entries on me: (o_s, r_i, t, k) with dst rank == me
    mine = (geo["g"] // L) == me                            # [No, Ni, T, K]
    e_l = (geo["g"] - me * L).clip(0, L - 1)
    ent_valid = (mine & ok2[..., None]).reshape(-1)
    a_pos, counts = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    rows = (jnp.arange(No)[:, None, None] * C2 + c2)[..., None]  # [No, Ni, T, 1]
    rows = jnp.broadcast_to(rows, (No, Ni, T, Kk)).reshape(-1)
    gmap = S.build_gather_map(e_l.reshape(-1), a_pos, rows, ent_valid,
                              L, A, sentinel=No * C2)
    out = S.gather_rows(S.flat_rows(recv2), gmap)
    if recv2_s is not None:
        sc = S.gather_rows(S.flat_rows(recv2_s), gmap, fill=0)
        out = K.dequantize_fp8(out, sc)
    return out, counts, (a_pos, ent_valid, gmap)


def ht_combine_hier(group: EpGroup, handle: EpHandle, y3d: jax.Array):
    """Reverse path with hierarchical reduction: weight at the expert chip,
    partial-sum per token at the stage-2 slot, reduce across pods at the rail,
    final sum across rails at the source chip."""
    ax_o, ax_i = group.cfg.ep_axis[0], group.cfg.ep_axis[-1]
    L, Ni, No = group.local_experts, group.inner_size, group.outer_size
    C1, C2, A = group.ht_stage1_cap, group.ht_stage2_cap, group.ht_expert_cap
    me_o, me_i = jax.lax.axis_index(ax_o), jax.lax.axis_index(ax_i)
    me = me_o * Ni + me_i
    geo = _hier_geometry(group, handle)
    No_, Ni_, T, Kk = geo["g"].shape
    H = y3d.shape[-1]
    dt = group.cfg.payload_dtype

    # weights of every entry, globally (gathered topk_weights ride the handle's
    # metadata path: gather once here — small [N, T, K] f32)
    w_g = handle.topk_weights
    for ax in reversed(group.cfg.ep_axis):
        w_g = jax.lax.all_gather(w_g, ax, axis=0, tiled=False)
    w_g = w_g.reshape(No, Ni, T, Kk)

    # ---- expert side: weighted scatter-add into [No, C2, H]. All H-wide
    # work happens in the y3d SLOT domain (<= L*A rows): materializing
    # per-global-entry rows (No*Ni*T*K of them) costed ~870 GB/layer on the
    # deepseek train cell — slot-domain rewrite is ~200x less traffic
    # (EXPERIMENTS.md §Perf D2). Entry->slot maps stay in the int domain.
    c2, ok2 = _hier_recv_chain(group, geo, me_o, me_i)
    mine = (geo["g"] // L) == me
    e_l = (geo["g"] - me * L).clip(0, L - 1)
    ent_valid = (mine & ok2[..., None]).reshape(-1)
    a_pos, _ = S.positions_by_dest(e_l.reshape(-1), L, ent_valid)
    slot_of_entry = jnp.where(ent_valid & (a_pos < A),
                              e_l.reshape(-1) * A + a_pos, L * A)
    idx2 = (jnp.arange(No)[:, None, None] * C2 + c2)[..., None]
    idx2 = jnp.broadcast_to(idx2, (No, Ni, T, Kk)).reshape(-1)
    idx2 = jnp.where(ent_valid, idx2, No * C2)
    # per-slot destination + weight (each y3d slot holds <= 1 entry)
    slot_tgt = jnp.full((L * A + 1,), No * C2, jnp.int32).at[
        slot_of_entry].set(idx2.astype(jnp.int32), mode="drop")[:L * A]
    w_slot = jnp.zeros((L * A + 1,), jnp.float32).at[
        slot_of_entry].set(w_g.reshape(-1), mode="drop")[:L * A]
    weighted = S.flat_rows(y3d).astype(jnp.float32) * w_slot[:, None]
    buf2 = jnp.zeros((No * C2 + 1, H), jnp.float32).at[
        slot_tgt].add(weighted, mode="drop")
    back2 = _a2a(buf2[:-1].reshape(No, C2, H).astype(dt), ax_o)   # -> rails

    # ---- rail: accumulate partials from every pod into its held-slot buffer
    # (second reduction level), using the same c2 chain per destination pod.
    held = geo["ok1"][me_o, :, :, me_i]                     # [Ni, T] my rail
    flat1_rows = jnp.arange(Ni)[:, None] * C1 + geo["pos1"][me_o, :, :, me_i]
    buf_rail = jnp.zeros((Ni * C1 + 1, H), jnp.float32)
    for o_p in range(No):   # No is tiny (pods); unrolled scatter-adds
        needs_p = ((geo["i_dst"][me_o] == me_i) &
                   (geo["o_dst"][me_o] == o_p)).any(-1)     # [Ni, T]
        fanned = held & needs_p
        c2p = jnp.cumsum(fanned.reshape(-1).astype(jnp.int32)) - 1
        okp = fanned.reshape(-1) & (c2p < C2)
        dst_rows = jnp.where(okp & (geo["pos1"][me_o, :, :, me_i].reshape(-1) < C1),
                             flat1_rows.reshape(-1), Ni * C1)
        src_rows = jnp.where(okp, o_p * C2 + c2p, No * C2)
        vals = S.gather_rows(S.flat_rows(back2.astype(jnp.float32)), src_rows)
        buf_rail = buf_rail.at[dst_rows].add(jnp.where(okp[:, None], vals, 0))
    back1 = _a2a(buf_rail[:-1].reshape(Ni, C1, H).astype(dt), ax_i)  # -> sources

    # ---- source chip: sum contributions across rails
    s1 = geo["sends1"][me_o, me_i]                          # [T, Ni]
    p1 = geo["pos1"][me_o, me_i]
    rows = jnp.where(s1 & (p1 < C1), jnp.arange(Ni)[None, :] * C1 + p1, Ni * C1)
    parts = S.gather_rows(S.flat_rows(back1), rows)         # [T, Ni, H]
    return jnp.sum(parts.astype(jnp.float32), axis=1).astype(
        jnp.bfloat16 if dt == jnp.bfloat16 else jnp.float32)


# --------------------------------------------------------------------------
# unified HT entry points
# --------------------------------------------------------------------------

def ht_dispatch(group: EpGroup, handle: EpHandle, x: jax.Array, *, send_only=False):
    if _hierarchical(group):
        return ht_dispatch_hier(group, handle, x)
    return ht_dispatch_flat(group, handle, x)


def ht_combine(group: EpGroup, handle: EpHandle, y3d: jax.Array, *, send_only=False):
    if _hierarchical(group):
        return ht_combine_hier(group, handle, y3d)
    return ht_combine_flat(group, handle, y3d)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _a2a(x, axis):
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def _quant(group: EpGroup, x):
    if not group.cfg.quantize_dispatch:
        return x.astype(group.cfg.payload_dtype), None
    return K.quantize_fp8(x, block=group.cfg.quant_block)
