"""High-Throughput (HT) mode — paper §V, adapted from Hybrid-EP.

Targets training and inference prefill (4096+ tokens/rank). Two paths:

* **flat** (single EP axis — the single-pod production mesh): one entry-level
  all-to-all, output grouped by local expert with per-expert counts — the
  deterministic 2D-concatenated layout of Fig. 4, rendered with static
  per-expert capacity padding (TPU adaptation; counts are exact).

* **hierarchical** (EP spans ("pod", inner)): Hybrid-EP's two-tier scheme.
  Stage 1 aggregates tokens *within the fast domain*: an all-to-all over the
  inner axis keyed by the destination chip's inner coordinate (the "rail"),
  deduplicated per (token, rail) — a token headed to several experts on
  same-rail chips crosses the intra-pod fabric once. Stage 2 is the
  rail-aligned slow hop: an all-to-all over the ``pod`` axis between
  same-inner-coordinate chips — the exact analogue of Hybrid-EP's same-rail
  NIC RDMA. Combine runs the mirror path with **hierarchical reduction**
  (§V-A): expert responses are weighted at the source and partially reduced
  at the rail chip before the final intra-pod hop, shrinking fast-domain
  bytes by the per-token multiplicity.

Metadata (the paper's handle-creation exchange, §III-C2) is the all-gathered
``topk_idx``; every rank derives the full slot-map chain locally — exactly
once, in the ``EpPlan`` engine (core/plan.py) at handle creation — so payload
messages carry zero header bytes (see slots.py) and every dispatch/combine
phase below is a single gather/scatter pass over precomputed int32 maps (the
one-pass-per-phase invariant). Send paths run the fused ``dispatch_pack``
kernel; every dispatch-recv unpack (flat recv, both hierarchical stages)
runs its mirror ``recv_unpack`` through the shared ``core.recv.unpack_recv``
helper — gather + in-kernel fp8 dequantization, never a gather followed by a
separate dequant pass; flat combine-recv runs the fused
``combine_gather_reduce`` kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.group import EpGroup, EpHandle
from repro.core import slots as S
from repro.core import plan as P
from repro.core.recv import unpack_recv
from repro.kernels import ops as K


# --------------------------------------------------------------------------
# handle
# --------------------------------------------------------------------------

def ht_create_handle(group: EpGroup, topk_idx, topk_weights, num_tokens=None) -> EpHandle:
    """Metadata exchange at handle creation (paper §III-C2): gather routing
    across the full EP axis; exact receive counts enable the
    ``ep_handle_get_num_recv_tokens`` query for precise buffer consumption.
    The full slot-map plan (flat, hierarchical, or baseline — whichever the
    group resolved) is derived here, once."""
    topk_idx, nt = P.mask_padding(group, topk_idx, num_tokens)
    topk_g = P.gather_routing(group, topk_idx)
    counts = P.recv_counts(group, topk_g)
    plan = P.build_plan(group, topk_idx, topk_g, nt, topk_weights)
    return EpHandle(
        topk_idx=topk_idx, topk_weights=topk_weights, topk_global=topk_g,
        tokens_per_expert=counts, num_recv_tokens=counts.sum(), num_tokens=nt,
        plan=plan, routing_hash=P.routing_hash(topk_g),
    )


def _hierarchical(group: EpGroup) -> bool:
    return group.cfg.ht_hierarchical and len(group.cfg.ep_axis) > 1 and group.outer_size > 1


# --------------------------------------------------------------------------
# flat path (single EP axis)
# --------------------------------------------------------------------------

def _flat_axis(group):
    a = group.cfg.ep_axis
    return a if len(a) > 1 else a[0]


def ht_dispatch_flat(group: EpGroup, handle: EpHandle, x: jax.Array):
    plan = P.ensure_plan(group, handle)
    send, scales = _pack(group, x, plan.disp_send_gmap)      # [N, C, ...]
    recv = _a2a(send, _flat_axis(group))
    recv_s = _a2a(scales, _flat_axis(group)) if scales is not None else None
    # receiver: one fused unpack pass into the deterministic [L, A, H] layout
    out = unpack_recv(recv, plan.disp_recv_gmap, recv_s)
    return out, plan.disp_counts


def ht_combine_flat(group: EpGroup, handle: EpHandle, y3d: jax.Array):
    """Mirror a2a: expert side repacks [L, A, H] into the same [N, C, H]
    blocks (same slots as dispatch), then the source applies the weighted
    reduction — fused gather+reduce at the receiver, matching LL semantics."""
    plan = P.ensure_plan(group, handle)
    send, _ = K.dispatch_pack(S.flat_rows(y3d), plan.comb_send_gmap,
                              out_dtype=group.cfg.payload_dtype)
    recv = _a2a(send, _flat_axis(group))                     # [N, C, H]
    return K.combine_gather_reduce(S.flat_rows(recv), plan.comb_recv_rows,
                                   handle.topk_weights)


# --------------------------------------------------------------------------
# hierarchical path (two-stage, pod-aware)
# --------------------------------------------------------------------------

def ht_dispatch_hier(group: EpGroup, handle: EpHandle, x: jax.Array):
    ax_o, ax_i = group.cfg.ep_axis[0], group.cfg.ep_axis[-1]
    plan = P.ensure_plan(group, handle)

    # ---- stage 1: fused pack + intra-pod a2a -> rail chips hold [Ni, C1, H]
    send1, scales1 = _pack(group, x, plan.h_gmap1)
    recv1 = _a2a(send1, ax_i)
    recv1_s = _a2a(scales1, ax_i) if scales1 is not None else None

    # ---- stage 2: rail fans held rows over destination pods — a copy-mode
    # unpack (payload stays quantized across the slow hop; scales ride along)
    send2 = unpack_recv(recv1, plan.h_gmap2)
    recv2 = _a2a(send2, ax_o)                                # [No, C2, H]
    recv2_s = None
    if recv1_s is not None:
        recv2_s = _a2a(unpack_recv(recv1_s, plan.h_gmap2), ax_o)

    # ---- unpack at destination chip: one fused pass (gather + dequant)
    out = unpack_recv(recv2, plan.disp_recv_gmap, recv2_s)
    return out, plan.disp_counts


def ht_combine_hier(group: EpGroup, handle: EpHandle, y3d: jax.Array):
    """Reverse path with hierarchical reduction: weight at the expert chip,
    partial-sum per token at the stage-2 slot, reduce across pods at the rail,
    final sum across rails at the source chip. All maps precomputed; all
    H-wide work stays in the slot domain (<= L*A rows): materializing
    per-global-entry rows (No*Ni*T*K of them) costed ~870 GB/layer on the
    deepseek train cell — slot-domain rewrite is ~200x less traffic
    (EXPERIMENTS.md §Perf D2)."""
    ax_o, ax_i = group.cfg.ep_axis[0], group.cfg.ep_axis[-1]
    Ni, No = group.inner_size, group.outer_size
    C1, C2 = group.ht_stage1_cap, group.ht_stage2_cap
    plan = P.ensure_plan(group, handle)
    H = y3d.shape[-1]
    dt = group.cfg.payload_dtype

    # ---- expert side: weighted scatter-add into [No, C2, H]
    weighted = S.flat_rows(y3d).astype(jnp.float32) * plan.h_w_slot[:, None]
    buf2 = jnp.zeros((No * C2 + 1, H), jnp.float32).at[
        plan.h_slot_tgt].add(weighted, mode="drop")
    back2 = _a2a(buf2[:-1].reshape(No, C2, H).astype(dt), ax_o)   # -> rails

    # ---- rail: one scatter-add accumulates partials from every pod into the
    # held-slot buffer (second reduction level); sentinel rows no-op via pads.
    vals = S.gather_rows(S.flat_rows(back2).astype(jnp.float32),
                         plan.h_rail_src_rows.reshape(-1))
    buf_rail = jnp.zeros((Ni * C1 + 1, H), jnp.float32).at[
        plan.h_rail_dst_rows.reshape(-1)].add(vals)
    back1 = _a2a(buf_rail[:-1].reshape(Ni, C1, H).astype(dt), ax_i)  # -> sources

    # ---- source chip: sum contributions across rails
    parts = S.gather_rows(S.flat_rows(back1), plan.h_src_rows)   # [T, Ni, H]
    return jnp.sum(parts.astype(jnp.float32), axis=1).astype(
        jnp.bfloat16 if dt == jnp.bfloat16 else jnp.float32)


# --------------------------------------------------------------------------
# unified HT entry points
# --------------------------------------------------------------------------

def ht_dispatch(group: EpGroup, handle: EpHandle, x: jax.Array, *, send_only=False):
    if _hierarchical(group):
        return ht_dispatch_hier(group, handle, x)
    return ht_dispatch_flat(group, handle, x)


def ht_combine(group: EpGroup, handle: EpHandle, y3d: jax.Array, *, send_only=False):
    if _hierarchical(group):
        return ht_combine_hier(group, handle, y3d)
    return ht_combine_flat(group, handle, y3d)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _a2a(x, axis):
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def _pack(group: EpGroup, x, gmap):
    """Fused send-path pass: slot gather + optional fp8 quantization."""
    if group.cfg.quantize_dispatch:
        return K.dispatch_pack(x, gmap, quant_block=group.cfg.quant_block)
    return K.dispatch_pack(x, gmap, out_dtype=group.cfg.payload_dtype)
