"""High-Throughput (HT) mode — paper §V, adapted from Hybrid-EP.

Targets training and inference prefill (4096+ tokens/rank). Two paths:

* **flat** (single EP axis — the single-pod production mesh): one entry-level
  all-to-all, output grouped by local expert with per-expert counts — the
  deterministic 2D-concatenated layout of Fig. 4, rendered with static
  per-expert capacity padding (TPU adaptation; counts are exact).

* **hierarchical** (EP spans ("pod", inner)): Hybrid-EP's two-tier scheme.
  Stage 1 aggregates tokens *within the fast domain*: an all-to-all over the
  inner axis keyed by the destination chip's inner coordinate (the "rail"),
  deduplicated per (token, rail) — a token headed to several experts on
  same-rail chips crosses the intra-pod fabric once. Stage 2 is the
  rail-aligned slow hop: an all-to-all over the ``pod`` axis between
  same-inner-coordinate chips — the exact analogue of Hybrid-EP's same-rail
  NIC RDMA. Combine runs the mirror path with **hierarchical reduction**
  (§V-A): expert responses are weighted at the source and partially reduced
  at the rail chip before the final intra-pod hop, shrinking fast-domain
  bytes by the per-token multiplicity.

  With ``ht_num_chunks > 1`` the hierarchical path is **pipelined**: the
  token dim splits into static chunks and the two stages stream — chunk
  *i*'s stage-1 intra-pod a2a is issued while chunk *i-1*'s stage-2
  inter-pod a2a is still in flight (combine runs the mirror skew), so XLA's
  async collective scheduler can overlap the fast and slow fabrics the way
  HybridEP overlaps NVLink with RDMA. All chunk slot-map slices ship in the
  ``EpPlan``; at zero-drop capacities the chunked stream is bitwise-
  identical to the nc=1 monolithic path (tests/test_ht_chunked.py).

Both paths honor the full staged surface: ``send_only=True`` returns a
mode-tagged ``EpPending`` whose payload is every received-but-unconsumed
buffer (for the chunked pipeline, the concatenation of per-chunk stage
outputs), and ``ep_complete`` finishes with the single destination-side
pass — which is what lets runtime drivers overlap HT collectives with the
grouped-GEMM expert pass (runtime/prefill.py).

Metadata (the paper's handle-creation exchange, §III-C2) is the all-gathered
``topk_idx``; every rank derives the full slot-map chain locally — exactly
once, in the ``EpPlan`` engine (core/plan.py) at handle creation — so payload
messages carry zero header bytes (see slots.py) and every dispatch/combine
phase below is a single gather/scatter pass over precomputed int32 maps (the
one-pass-per-phase invariant; chunked phases are one pass *per chunk slice*,
each over its own precomputed map). Send paths run the fused
``dispatch_pack`` kernel; every dispatch-recv unpack (flat recv, both
hierarchical stages) runs its mirror ``recv_unpack`` through the shared
``core.recv.unpack_recv`` helper — gather + in-kernel fp8 dequantization,
never a gather followed by a separate dequant pass; flat combine-recv runs
the fused ``combine_gather_reduce`` kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import BaseBackend, EpPending, register_backend
from repro.core.group import EpGroup, EpHandle
from repro.core import slots as S
from repro.core import plan as P
from repro.core.recv import unpack_recv
from repro.kernels import ops as K


# --------------------------------------------------------------------------
# handle
# --------------------------------------------------------------------------

def ht_create_handle(group: EpGroup, topk_idx, topk_weights, num_tokens=None) -> EpHandle:
    """Metadata exchange at handle creation (paper §III-C2): gather routing
    across the full EP axis; exact receive counts enable the
    ``ep_handle_get_num_recv_tokens`` query for precise buffer consumption.
    The full slot-map plan (flat, hierarchical, or baseline — whichever the
    group resolved) is derived here, once."""
    topk_idx, nt = P.mask_padding(group, topk_idx, num_tokens)
    topk_g = P.gather_routing(group, topk_idx)
    counts = P.recv_counts(group, topk_g)
    plan = P.build_plan(group, topk_idx, topk_g, nt, topk_weights)
    return EpHandle(
        topk_idx=topk_idx, topk_weights=topk_weights, topk_global=topk_g,
        tokens_per_expert=counts, num_recv_tokens=counts.sum(), num_tokens=nt,
        plan=plan, routing_hash=P.routing_hash(topk_g, group.placement_salt),
    )


def _hierarchical(group: EpGroup) -> bool:
    return group.cfg.ht_hierarchical and len(group.cfg.ep_axis) > 1 and group.outer_size > 1


def _flat_axis(group):
    a = group.cfg.ep_axis
    return a if len(a) > 1 else a[0]


# --------------------------------------------------------------------------
# flat path (single EP axis)
# --------------------------------------------------------------------------

def _flat_dispatch_send(group: EpGroup, handle: EpHandle, x: jax.Array) -> EpPending:
    plan = P.ensure_plan(group, handle)
    send, scales = _pack(group, x, plan.disp_send_gmap)      # [N, C, ...]
    recv = _a2a(send, _flat_axis(group))
    recv_s = _a2a(scales, _flat_axis(group)) if scales is not None else None
    return EpPending(mode="ht", op="dispatch", recv=recv, recv_scales=recv_s)


def _flat_combine_send(group: EpGroup, handle: EpHandle, y3d: jax.Array) -> EpPending:
    """Mirror a2a: expert side repacks [L, A, H] into the same [N, C, H]
    blocks (same slots as dispatch); the source applies the weighted
    reduction at complete time — fused gather+reduce, matching LL."""
    plan = P.ensure_plan(group, handle)
    send, _ = K.dispatch_pack(S.flat_rows(y3d), plan.comb_send_gmap,
                              out_dtype=group.cfg.payload_dtype)
    return EpPending(mode="ht", op="combine",
                     recv=_a2a(send, _flat_axis(group)))     # [N, C, H]


def _flat_combine_complete(group: EpGroup, handle: EpHandle, pending: EpPending):
    plan = P.ensure_plan(group, handle)
    return K.combine_gather_reduce(S.flat_rows(pending.recv),
                                   plan.comb_recv_rows, handle.topk_weights)


# --------------------------------------------------------------------------
# hierarchical path (two-stage, pod-aware, chunk-pipelined)
# --------------------------------------------------------------------------

def _hier_dispatch_send(group: EpGroup, handle: EpHandle, x: jax.Array) -> EpPending:
    """Chunk-skewed two-stage stream. Iteration *i* of the lax-collective
    schedule issues chunk *i*'s stage-1 intra-pod a2a AND chunk *i-1*'s
    stage-2 inter-pod a2a — neither depends on the other, so XLA's async
    scheduler may run the fast-fabric and slow-fabric hops concurrently
    (HybridEP's NVLink/RDMA overlap). Per chunk, each stage is one fused
    pass over its precomputed map slice."""
    ax_o, ax_i = group.cfg.ep_axis[0], group.cfg.ep_axis[-1]
    plan = P.ensure_plan(group, handle)
    nc = plan.h_gmap1.shape[0]

    recv1, recv1_s = [None] * nc, [None] * nc
    recv2, recv2_s = [None] * nc, [None] * nc
    for i in range(nc + 1):
        if i < nc:
            # ---- stage 1, chunk i: fused pack + intra-pod a2a -> rail
            # chips hold [Ni, C1, H] of this chunk's tokens
            send1, scales1 = _pack(group, x, plan.h_gmap1[i])
            recv1[i] = _a2a(send1, ax_i)
            if scales1 is not None:
                recv1_s[i] = _a2a(scales1, ax_i)
        if i > 0:
            # ---- stage 2, chunk i-1 (overlaps chunk i's stage 1): rail
            # fans held rows over destination pods — a copy-mode unpack
            # (payload stays quantized across the slow hop; scales ride)
            j = i - 1
            send2 = unpack_recv(recv1[j], plan.h_gmap2[j])
            recv2[j] = _a2a(send2, ax_o)                     # [No, C2, H]
            if recv1_s[j] is not None:
                recv2_s[j] = _a2a(unpack_recv(recv1_s[j], plan.h_gmap2[j]),
                                  ax_o)
    recv = jnp.concatenate([S.flat_rows(r) for r in recv2], axis=0)
    recv_s = (jnp.concatenate([S.flat_rows(r) for r in recv2_s], axis=0)
              if recv2_s[0] is not None else None)
    return EpPending(mode="ht", op="dispatch", recv=recv, recv_scales=recv_s)


def ht_dispatch_complete(group: EpGroup, handle: EpHandle, pending: EpPending):
    """Shared dispatch finish (flat and hierarchical): one fused pass
    (gather + dequant) through the plan's expert-region map over the
    received blocks — for the chunked pipeline, their concatenation."""
    plan = P.ensure_plan(group, handle)
    out = unpack_recv(pending.recv, plan.disp_recv_gmap, pending.recv_scales)
    return out, plan.disp_counts


def _hier_combine_send(group: EpGroup, handle: EpHandle, y3d: jax.Array) -> EpPending:
    """Reverse path with hierarchical reduction, mirror-skewed: chunk *i*'s
    inter-pod a2a is issued while chunk *i-1*'s rail reduction + intra-pod
    a2a drains. Weight at the expert chip, partial-sum per token at the
    stage-2 slot, reduce across pods at the rail; the final cross-rail sum
    at the source chip is the complete step. All maps precomputed; all
    H-wide work stays in the slot domain (<= L*A rows): materializing
    per-global-entry rows (No*Ni*T*K of them) costed ~870 GB/layer on the
    deepseek train cell — slot-domain rewrite is ~200x less traffic
    (docs/EXPERIMENTS.md §Perf D2)."""
    ax_o, ax_i = group.cfg.ep_axis[0], group.cfg.ep_axis[-1]
    Ni, No = group.inner_size, group.outer_size
    C1, C2 = group.ht_stage1_cap, group.ht_stage2_cap
    plan = P.ensure_plan(group, handle)
    H = y3d.shape[-1]
    dt = group.cfg.payload_dtype
    nc = plan.h_gmap1.shape[0]

    # ---- expert side: weighted rows once, then ONE scatter-add into the
    # chunk-concatenated [nc*No*C2, H] stage-2 buffer (each y3d slot lands
    # in its source token's chunk slice) — the H-wide slot-domain work stays
    # <= L*A rows regardless of nc; the stream below just slices per chunk
    weighted = S.flat_rows(y3d).astype(jnp.float32) * plan.h_w_slot[:, None]
    buf2 = jnp.zeros((nc * No * C2 + 1, H), jnp.float32).at[
        plan.h_slot_tgt].add(weighted, mode="drop")
    back2, back1 = [None] * nc, [None] * nc
    for i in range(nc + 1):
        if i < nc:
            # ---- chunk i: its slice of the weighted buffer -> pods
            back2[i] = _a2a(buf2[i * No * C2:(i + 1) * No * C2]
                            .reshape(No, C2, H).astype(dt), ax_o)
        if i > 0:
            # ---- chunk i-1 (overlaps chunk i's inter-pod hop): rail
            # scatter-add accumulates partials from every pod into the
            # held-slot buffer (second reduction level), then -> sources
            j = i - 1
            vals = S.gather_rows(S.flat_rows(back2[j]).astype(jnp.float32),
                                 plan.h_rail_src_rows[j].reshape(-1))
            buf_rail = jnp.zeros((Ni * C1 + 1, H), jnp.float32).at[
                plan.h_rail_dst_rows[j].reshape(-1)].add(vals)
            back1[j] = _a2a(buf_rail[:-1].reshape(Ni, C1, H).astype(dt), ax_i)
    return EpPending(mode="ht", op="combine",
                     recv=jnp.concatenate([S.flat_rows(b) for b in back1],
                                          axis=0))


def _hier_combine_complete(group: EpGroup, handle: EpHandle, pending: EpPending):
    """Source chip: sum contributions across rails — one gather over the
    chunk-concatenated stage-1 buffers in token order."""
    plan = P.ensure_plan(group, handle)
    dt = group.cfg.payload_dtype
    parts = S.gather_rows(pending.recv, plan.h_src_rows)     # [T, Ni, H]
    return jnp.sum(parts.astype(jnp.float32), axis=1).astype(
        jnp.bfloat16 if dt == jnp.bfloat16 else jnp.float32)


# --------------------------------------------------------------------------
# unified HT entry points (staged halves + derived eager surface)
# --------------------------------------------------------------------------

def ht_dispatch_send(group: EpGroup, handle: EpHandle, x: jax.Array) -> EpPending:
    if _hierarchical(group):
        return _hier_dispatch_send(group, handle, x)
    return _flat_dispatch_send(group, handle, x)


def ht_combine_send(group: EpGroup, handle: EpHandle, y3d: jax.Array) -> EpPending:
    if _hierarchical(group):
        return _hier_combine_send(group, handle, y3d)
    return _flat_combine_send(group, handle, y3d)


def ht_combine_complete(group: EpGroup, handle: EpHandle, pending: EpPending):
    if _hierarchical(group):
        return _hier_combine_complete(group, handle, pending)
    return _flat_combine_complete(group, handle, pending)


def ht_dispatch(group: EpGroup, handle: EpHandle, x: jax.Array, *, send_only=False):
    pending = ht_dispatch_send(group, handle, x)
    if send_only:
        return pending
    return ht_dispatch_complete(group, handle, pending)


def ht_combine(group: EpGroup, handle: EpHandle, y3d: jax.Array, *, send_only=False):
    pending = ht_combine_send(group, handle, y3d)
    if send_only:
        return pending
    return ht_combine_complete(group, handle, pending)


# --------------------------------------------------------------------------
# backend registration
# --------------------------------------------------------------------------

class HtBackend(BaseBackend):
    """HT mode behind the EpBackend protocol (flat + chunked hierarchical)."""

    mode = "ht"

    def create_handle(self, group, topk_idx, topk_weights, num_tokens=None):
        return ht_create_handle(group, topk_idx, topk_weights, num_tokens)

    def dispatch_send(self, group, handle, tokens):
        return ht_dispatch_send(group, handle, tokens)

    def dispatch_complete(self, group, handle, pending):
        return ht_dispatch_complete(group, handle, pending)

    def combine_send(self, group, handle, expert_out):
        return ht_combine_send(group, handle, expert_out)

    def combine_complete(self, group, handle, pending):
        return ht_combine_complete(group, handle, pending)


register_backend(HtBackend())


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _a2a(x, axis):
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def _pack(group: EpGroup, x, gmap):
    """Fused send-path pass: slot gather + optional fp8 quantization."""
    if group.cfg.quantize_dispatch:
        return K.dispatch_pack(x, gmap, quant_block=group.cfg.quant_block)
    return K.dispatch_pack(x, gmap, out_dtype=group.cfg.payload_dtype)
