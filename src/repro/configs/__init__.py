"""Per-architecture configs. Each module exposes:
  full_config(shape: str | None) -> ArchConfig   — the exact published config,
      with shape-dependent deployment knobs (EP axis/mode, microbatching);
  smoke_config() -> ArchConfig                    — a reduced same-family config
      for CPU smoke tests (small depth/width/experts, tiny vocab).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "minicpm3_4b", "internlm2_20b", "gemma3_27b", "chatglm3_6b",
    "deepseek_v3_671b", "dbrx_132b", "phi3_vision_4_2b", "zamba2_7b",
    "seamless_m4t_large_v2", "mamba2_780m",
]

# canonical ids (as assigned) -> module names
ARCH_IDS = {
    "minicpm3-4b": "minicpm3_4b",
    "internlm2-20b": "internlm2_20b",
    "gemma3-27b": "gemma3_27b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "dbrx-132b": "dbrx_132b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "zamba2-7b": "zamba2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-780m": "mamba2_780m",
}

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "train"),       # per assignment: lowers train_step
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic archs (see docs/DESIGN.md §5)
LONG_OK = {"mamba2-780m", "zamba2-7b", "gemma3-27b"}


def get_config(arch_id: str, shape: str | None = None):
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[arch_id]}")
    return mod.full_config(shape)


def get_smoke(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[arch_id]}")
    return mod.smoke_config()


def cells():
    """All (arch, shape) dry-run cells, with skips resolved."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            skip = (s == "long_500k" and a not in LONG_OK)
            out.append((a, s, skip))
    return out
