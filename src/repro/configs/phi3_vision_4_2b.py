"""Phi-3-vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct]: 32L
d_model=3072 32H (MHA) d_ff=8192 vocab=32064 — phi3-mini backbone + CLIP
frontend. The modality frontend is a STUB: input_specs() provides precomputed
patch embeddings [B, 576, d_model] injected at the sequence front."""
from repro.models.config import ArchConfig, AttnSpec


def full_config(shape=None):
    micro = {"train_4k": 4, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="phi-3-vision-4.2b", family="vlm", num_layers=32, d_model=3072,
        d_ff=8192, vocab=32064,
        attn=AttnSpec(n_heads=32, n_kv=32, head_dim=96, rope_base=10000.0),
        img_tokens=576, microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="phi3v-smoke", family="vlm", num_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=4, head_dim=16),
        img_tokens=8, remat=False,
    )
