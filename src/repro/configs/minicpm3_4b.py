"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: 62L d_model=2560 40H d_ff=6400
vocab=73448 — MLA attention (q_lora 768, kv_lora 256, nope 64, rope 32, v 64).
Dense (no MoE) -> EP inapplicable; exercises MLA + absorbed decode."""
from repro.models.config import ArchConfig, AttnSpec, MLASpec


def full_config(shape=None):
    micro = {"train_4k": 8, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="minicpm3-4b", family="lm", num_layers=62, d_model=2560,
        d_ff=6400, vocab=73448,
        attn=AttnSpec(n_heads=40, n_kv=40, head_dim=64, kind="mla",
                      rope_base=10000.0),
        mla=MLASpec(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                    qk_rope_dim=32, v_head_dim=64),
        tie_embeddings=True, microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="minicpm3-smoke", family="lm", num_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=4, head_dim=16, kind="mla"),
        mla=MLASpec(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16),
        tie_embeddings=True, remat=False,
    )
