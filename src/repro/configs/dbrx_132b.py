"""DBRX-132B [hf:databricks/dbrx-base]: 40L d_model=6144 48H (GQA kv=8)
d_ff=10752/expert, vocab=100352, MoE 16 experts top-4 (fine-grained).

E=16 bounds EP at 16 ranks: EP over ("data",) with expert-TP over model
(Megatron "ETP") for every shape. HT for train/prefill, LL for decode."""
from repro.models.config import ArchConfig, AttnSpec, MoESpec


def full_config(shape=None):
    kind = "decode" if shape in ("decode_32k", "long_500k") else "train"
    moe = MoESpec(
        num_experts=16, top_k=4, d_ff_expert=10752,
        ep_mode=("ll" if kind == "decode" else "ht"), ep_axis=("data",),
        capacity_factor=(None if kind == "decode" else 1.25),
        expert_capacity_factor=(2.0 if kind == "decode" else 1.25),
        quantize_dispatch=(kind != "decode"),  # fp8 train dispatch (§Perf B1)
    )
    micro = {"train_4k": 8, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="dbrx-132b", family="lm", num_layers=40, d_model=6144,
        d_ff=10752, vocab=100352,
        attn=AttnSpec(n_heads=48, n_kv=8, head_dim=128, rope_base=5e5),
        moe=moe, microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="dbrx-smoke", family="lm", num_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=2, head_dim=16),
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=64,
                    ep_axis=("data",), capacity_factor=None),
        remat=False,
    )
