"""DeepSeek-V3-671B [arXiv:2412.19437]: 61L d_model=7168 128H MLA,
d_ff=18432 dense / 2048 per expert, vocab=129280, MoE: 1 shared + 256 routed
top-8, sigmoid gating, group-limited (8 groups, top-4), aux-loss-free bias,
first 3 layers dense, MTP. **The paper's primary workload family** — this is
the arch the NCCL EP evaluation models (256 experts, hidden 7168, top-8).

EP deployment per shape (mirrors §VI/VII):
  train/prefill: HT mode, wide EP over ("data","model") = 256 ranks, L=1,
                 hierarchical two-stage a2a (outer=data, inner=model);
  decode:        LL mode, EP over ("data",) = 16 ranks, L=16,
                 expert-TP over model, fp8 dispatch payloads.
"""
import dataclasses

from repro.models.config import ArchConfig, AttnSpec, MLASpec, MoESpec


def full_config(shape=None):
    kind = "decode" if shape in ("decode_32k", "long_500k") else "train"
    if kind == "train":
        # Flat (single-stage) a2a beats the hierarchical two-stage on the
        # single-pod mesh: both EP axes are same-fabric ICI, so the 2x bytes
        # of the extra hop are never paid back (measured: memory 499->163s,
        # collective 183->88s — docs/EXPERIMENTS.md §Perf D3). Hierarchy remains
        # the right choice only when EP spans the genuinely slower pod axis.
        moe = MoESpec(
            num_experts=256, top_k=8, d_ff_expert=2048, shared_experts=1,
            first_k_dense=3, gating="sigmoid", n_groups=8, topk_groups=4,
            use_selection_bias=True, routed_scaling=2.5,
            ep_mode="ht", ep_axis=("data", "model"), ht_hierarchical=False,
            capacity_factor=1.25, expert_capacity_factor=1.25,
            quantize_dispatch=True,   # fp8 dispatch: -39% collective (§Perf D4)
        )
    else:
        moe = MoESpec(
            num_experts=256, top_k=8, d_ff_expert=2048, shared_experts=1,
            first_k_dense=3, gating="sigmoid", n_groups=8, topk_groups=4,
            use_selection_bias=True, routed_scaling=2.5,
            ep_mode="ll", ep_axis=("data",), ll_layout="nccl_ep",
            capacity_factor=None, expert_capacity_factor=2.0,
            quantize_dispatch=True,
        )
    micro = {"train_4k": 8, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="deepseek-v3-671b", family="lm", num_layers=61, d_model=7168,
        d_ff=18432, vocab=129280,
        attn=AttnSpec(n_heads=128, n_kv=128, head_dim=128, kind="mla"),
        mla=MLASpec(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                    qk_rope_dim=64, v_head_dim=128),
        moe=moe, mtp=(kind == "train"), microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="deepseek-v3-smoke", family="lm", num_layers=3, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=4, head_dim=16, kind="mla"),
        mla=MLASpec(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16),
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=32, shared_experts=1,
                    first_k_dense=1, gating="sigmoid", n_groups=2,
                    topk_groups=1, use_selection_bias=True,
                    ep_mode="auto", ep_axis=("data",), capacity_factor=None),
        mtp=True, remat=False,
    )
