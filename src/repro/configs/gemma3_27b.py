"""Gemma3-27B [hf:google/gemma-3-*-pt family]: 62L d_model=5376 32H (GQA
kv=16) d_ff=21504 vocab=262144 — 5:1 local:global attention, local window
1024, 128k context. Sub-quadratic in 5/6 layers -> runs long_500k."""
from repro.models.config import ArchConfig, AttnSpec


def full_config(shape=None):
    micro = {"train_4k": 8, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="gemma3-27b", family="gemma3", num_layers=62, d_model=5376,
        d_ff=21504, vocab=262144,
        attn=AttnSpec(n_heads=32, n_kv=16, head_dim=128, rope_base=1e6,
                      qk_norm=True),
        local_global=(5, 1), local_window=1024,
        tie_embeddings=True, microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="gemma3-smoke", family="gemma3", num_layers=8, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=2, head_dim=16, qk_norm=True),
        local_global=(2, 1), local_window=8, tie_embeddings=True, remat=False,
    )
