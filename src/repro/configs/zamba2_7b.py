"""Zamba2-7B [arXiv:2411.15242]: 81L d_model=3584 32H d_ff=14336,
vocab=32000, ssm_state=64 — Mamba2 backbone + ONE shared attention+FFN block
applied every 6 mamba blocks. Hybrid -> runs long_500k."""
from repro.models.config import ArchConfig, AttnSpec, SSMSpec


def full_config(shape=None):
    micro = {"train_4k": 4, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
        d_ff=14336, vocab=32000,
        attn=AttnSpec(n_heads=32, n_kv=32, head_dim=112),
        ssm=SSMSpec(d_state=64, headdim=64, expand=2, conv_width=4, chunk=128),
        shared_attn_period=6, microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="zamba2-smoke", family="hybrid", num_layers=5, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=4, head_dim=16),
        ssm=SSMSpec(d_state=16, headdim=16, expand=2, conv_width=4, chunk=8),
        shared_attn_period=2, remat=False,
    )
