"""ChatGLM3-6B [arXiv:2406.12793]: 28L d_model=4096 32H (GQA kv=2)
d_ff=13696 vocab=65024 — 2d RoPE (rotary on half the head dims), GQA kv=2."""
from repro.models.config import ArchConfig, AttnSpec


def full_config(shape=None):
    micro = {"train_4k": 4, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="chatglm3-6b", family="lm", num_layers=28, d_model=4096,
        d_ff=13696, vocab=65024,
        attn=AttnSpec(n_heads=32, n_kv=2, head_dim=128,
                      rope_fraction=0.5),          # 2d RoPE
        microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="chatglm3-smoke", family="lm", num_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=2, head_dim=16, rope_fraction=0.5),
        remat=False,
    )
