"""SeamlessM4T-large-v2 [arXiv:2308.11596]: enc-dec, 24L encoder + 24L
decoder, d_model=1024 16H d_ff=8192 vocab=256206. Audio frontend is a STUB:
input_specs() provides precomputed frame embeddings [B, src_len, d_model]."""
from repro.models.config import ArchConfig, AttnSpec


def full_config(shape=None):
    micro = {"train_4k": 2, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="seamless-m4t-large-v2", family="encdec", num_layers=48,
        enc_layers=24, dec_layers=24, cross_attn=True,
        d_model=1024, d_ff=8192, vocab=256206, src_len=4096,
        attn=AttnSpec(n_heads=16, n_kv=16, head_dim=64),
        act="gelu", tie_embeddings=True, microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="seamless-smoke", family="encdec", num_layers=4,
        enc_layers=2, dec_layers=2, cross_attn=True,
        d_model=64, d_ff=128, vocab=256, src_len=16,
        attn=AttnSpec(n_heads=4, n_kv=4, head_dim=16),
        act="gelu", tie_embeddings=True, remat=False,
    )
