"""InternLM2-20B [arXiv:2403.17297]: 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92544. Dense GQA decoder."""
from repro.models.config import ArchConfig, AttnSpec


def full_config(shape=None):
    micro = {"train_4k": 8, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="internlm2-20b", family="lm", num_layers=48, d_model=6144,
        d_ff=16384, vocab=92544,
        attn=AttnSpec(n_heads=48, n_kv=8, head_dim=128, rope_base=1e6),
        microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="internlm2-smoke", family="lm", num_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnSpec(n_heads=4, n_kv=2, head_dim=16), remat=False,
    )
