"""Mamba2-780m [arXiv:2405.21060]: 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). Pure SSM -> runs long_500k."""
from repro.models.config import ArchConfig, SSMSpec


def full_config(shape=None):
    micro = {"train_4k": 2, "prefill_32k": 1}.get(shape, 1)
    return ArchConfig(
        name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
        d_ff=0, vocab=50280,
        ssm=SSMSpec(d_state=128, headdim=64, expand=2, conv_width=4, chunk=256),
        tie_embeddings=True, microbatch=micro,
    )


def smoke_config():
    return ArchConfig(
        name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
        d_ff=0, vocab=256,
        ssm=SSMSpec(d_state=16, headdim=16, expand=2, conv_width=4, chunk=8),
        tie_embeddings=True, remat=False,
    )
