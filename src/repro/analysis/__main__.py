"""``python -m repro.analysis`` — run the full static-analysis suite.

Exit 0 when the tree is clean, 1 on any finding. Layers:

- AST contract linter (``contracts.RULES``) over the configured targets
- slot-map / write-set verification matrix (``plan_verify.PLAN_CASES``),
  which needs 8 host devices — XLA_FLAGS is set below, before jax loads

``--mutation-smoke`` instead seeds a known violation into a real module's
source and asserts the linter still flags it (CI's guard against the
analyzer rotting into a no-op): exit 0 when caught, 1 when missed.
"""
import os

# must precede any (transitive) jax import: the plan verifier shard_maps
# over an 8-device host platform, exactly like tests/conftest.py
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import ast
import sys


def _mutation_smoke() -> int:
    """Seed slot arithmetic into a real phase body and a host sync into a
    real step body; the linter must flag both."""
    from repro.analysis.contracts import check_source, repo_root

    failures = []

    def seed(rel, fn_name, line, rule):
        src = (repo_root() / rel).read_text()
        tree = ast.parse(src)
        fn = next(n for n in ast.walk(tree)
                  if isinstance(n, ast.FunctionDef) and n.name == fn_name)
        first = fn.body[0]
        lines = src.splitlines()
        lines.insert(first.lineno - 1, " " * first.col_offset + line)
        mutated = "\n".join(lines)
        found = check_source(rule, mutated, path=f"<mutated {rel}>")
        tag = f"{rule} @ {rel}:{fn_name}"
        if any(f.rule == rule for f in found):
            print(f"mutation-smoke: {tag}: caught ({len(found)} finding(s))")
        else:
            failures.append(tag)
            print(f"mutation-smoke: {tag}: MISSED")

    seed("src/repro/core/ll.py", "ll_dispatch_send",
         "_pos = S.positions_by_dest(handle.topk_idx, 8, None)",
         "phase-one-pass")
    seed("src/repro/runtime/steps.py", "make_serve_step",
         "_host = float(jnp.zeros(()))",
         "step-no-host-sync")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--only", choices=["contracts", "plans"],
                    help="run a single layer")
    ap.add_argument("--mutation-smoke", action="store_true",
                    help="verify the linter catches seeded violations")
    args = ap.parse_args(argv)

    if args.mutation_smoke:
        return _mutation_smoke()

    rc = 0
    if args.only in (None, "contracts"):
        from repro.analysis.contracts import run_all_contracts
        findings = run_all_contracts()
        print(f"contracts: {len(findings)} finding(s)")
        for f in findings:
            print(f"  {f}")
        rc |= bool(findings)
    if args.only in (None, "plans"):
        from repro.analysis.plan_verify import run_plan_checks
        print("plan verification matrix:")
        violations = run_plan_checks(log=print)
        print(f"plans: {len(violations)} violation(s)")
        for v in violations:
            print(f"  {v}")
        rc |= bool(violations)
    return rc


if __name__ == "__main__":
    sys.exit(main())
