"""Static-analysis subsystem: the standing contracts as first-class checks.

Three layers (ISSUE 10 / docs/DESIGN.md §12):

- ``contracts``   — AST invariant linter: the named rules that used to live
  as ``inspect.getsource`` string greps scattered across tests (one-pass-
  per-phase, placement-never-in-phase-bodies, registry-only API layer,
  staged-primitive backends, recv one-pass) plus the step-path host-sync
  rule. Tests and CI call the same rule objects.
- ``trace_audit`` — runtime auditors: retrace/compiled-cache-bound counter,
  ``adopt_expert_params`` donation auditor, and the device->host transfer
  guard for serve steps.
- ``plan_verify`` — slot-map/write-set verifier over modes x geometries x
  chunking x placements: in-capacity, write-disjoint, EMPTY-safe, and
  round-trip bijective where the plan claims zero-drop.

CLI: ``python -m repro.analysis`` (see ``__main__``).
"""
from repro.analysis.contracts import (Finding, RULES, run_all_contracts,
                                      run_rule, check_source)
from repro.analysis.trace_audit import (RetraceAuditor, DonationAuditor,
                                        transfer_guard, guard_serve_steps)

__all__ = [
    "Finding", "RULES", "run_all_contracts", "run_rule", "check_source",
    "RetraceAuditor", "DonationAuditor", "transfer_guard",
    "guard_serve_steps",
]
