"""AST invariant linter: the repo's standing contracts as named rules.

Each rule is a real AST check over the module(s) it governs — the single
source of truth that tests/test_plan.py, tests/test_placement.py,
tests/test_backends.py, and the CI ``analysis`` job all call (they used to
each carry their own ``inspect.getsource`` string grep; docs/DESIGN.md §12
has the catalog).

Rule model: a ``Rule`` names the files it governs (repo-relative), an
optional function scope (only those function bodies are scanned; ``None`` =
whole module), and a ``scan(tree, ctx)`` that yields findings. Running a
rule against arbitrary source (``check_source``) scans ALL functions — that
is what the known-bad fixture tests use, and it keeps fixtures honest: a
fixture violates the rule by containing the construct, not by matching a
magic function name.

Suppressions are loud, never silent: a finding on line *n* is suppressed
only by a ``# contract: allow(<rule>): <justification>`` comment on line
*n* or *n-1*, and an empty justification is itself a finding. There are no
out-of-file allowlists.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Callable, Iterable

# --------------------------------------------------------------------------
# findings + suppression
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str            # repo-relative (or "<fixture>" for check_source)
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


_ALLOW_RE = re.compile(r"#\s*contract:\s*allow\(([\w\-., ]+)\)\s*:?\s*(.*)")


def _suppressions(src: str) -> dict[int, tuple[set[str], str]]:
    """line -> (rule names allowed, justification). A comment on line n
    covers findings on lines n and n+1."""
    out: dict[int, tuple[set[str], str]] = {}
    for i, text in enumerate(src.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        just = m.group(2).strip()
        out[i] = (rules, just)
        out[i + 1] = (rules, just)
    return out


def _apply_suppressions(findings: list[Finding], src: str) -> list[Finding]:
    sup = _suppressions(src)
    out = []
    for f in findings:
        hit = sup.get(f.line)
        if hit is None or f.rule not in hit[0]:
            out.append(f)
        elif not hit[1]:
            out.append(dataclasses.replace(
                f, message=(f.message + " (suppression present but has no "
                            "justification — write the why after the colon)")))
    return out


# --------------------------------------------------------------------------
# AST helpers
# --------------------------------------------------------------------------

_FN_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _functions(tree: ast.Module, names: set[str] | None
               ) -> Iterable[ast.FunctionDef]:
    """Module-level and class-level function defs, filtered by name.
    ``names=None`` selects every function (the fixture/check_source mode)."""
    for node in ast.walk(tree):
        if isinstance(node, _FN_NODES):
            if names is None or node.name in names:
                yield node


def _is_name_or_attr(node: ast.AST, name: str) -> bool:
    return ((isinstance(node, ast.Name) and node.id == name)
            or (isinstance(node, ast.Attribute) and node.attr == name))


def _mentions(node: ast.AST, names: set[str]) -> ast.AST | None:
    """First sub-node that is a Name/Attribute matching any of ``names``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in names:
            return sub
        if isinstance(sub, ast.Attribute) and sub.attr in names:
            return sub
    return None


# --------------------------------------------------------------------------
# rule engine
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RuleCtx:
    path: str
    src: str
    fn_names: set[str] | None    # None = scan all functions


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    # repo-relative file -> function-name scope (None = whole module)
    targets: dict[str, frozenset[str] | None]
    scan: Callable[[ast.Module, RuleCtx], list[Finding]]


def _f(rule: str, ctx: RuleCtx, node: ast.AST, msg: str) -> Finding:
    return Finding(rule, ctx.path, getattr(node, "lineno", 0), msg)


# ---- rule: api-registry-only ---------------------------------------------

_API_FILE = "src/repro/core/api.py"
_MODE_ALIASES = {"_ll", "_ht", "_bl"}


def _scan_api_registry_only(tree: ast.Module, ctx: RuleCtx) -> list[Finding]:
    out: list[Finding] = []
    mode_lines: set[int] = set()
    for fn in _functions(tree, ctx.fn_names):
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and _is_name_or_attr(node.func, "isinstance")):
                out.append(_f("api-registry-only", ctx, node,
                              f"{fn.name}: isinstance dispatch — route "
                              "pending types through the backend registry"))
            if isinstance(node, (ast.Compare, ast.If, ast.IfExp, ast.Match)):
                if isinstance(node, (ast.If, ast.IfExp)):
                    probe: ast.AST = node.test
                elif isinstance(node, ast.Match):
                    probe = node.subject
                else:
                    probe = node
                hit = _mentions(probe, {"mode"})
                if hit is not None and hit.lineno not in mode_lines:
                    mode_lines.add(hit.lineno)
                    out.append(_f("api-registry-only", ctx, hit,
                                  f"{fn.name}: branches on `mode` — the API "
                                  "layer must route through "
                                  "get_backend(group.mode) only"))
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in _MODE_ALIASES):
                out.append(_f("api-registry-only", ctx, node,
                              f"{fn.name}: direct mode-module call "
                              f"`{node.value.id}.{node.attr}` — use the "
                              "registry"))
    return out


# ---- rule: phase-one-pass ------------------------------------------------

_PHASE_FNS = frozenset({
    # ll.py
    "_ncclep_dispatch_send", "_ncclep_dispatch_recv",
    "_ncclep_combine_send", "_ncclep_combine_recv",
    "_deepep_dispatch_send", "_deepep_dispatch_recv",
    "_deepep_combine_send", "_deepep_combine_recv",
    # ht.py
    "_flat_dispatch_send", "_flat_combine_send", "_flat_combine_complete",
    "_hier_dispatch_send", "_hier_combine_send", "_hier_combine_complete",
    "ht_dispatch_complete",
    # baseline.py
    "baseline_dispatch_send", "baseline_dispatch_complete",
    "baseline_combine_send", "baseline_combine_complete",
})

_SLOT_ARITH = {"positions_by_dest", "cumsum", "argsort", "build_gather_map"}

_MODE_FILES = ("src/repro/core/ll.py", "src/repro/core/ht.py",
               "src/repro/core/baseline.py")


def _scan_phase_one_pass(tree: ast.Module, ctx: RuleCtx) -> list[Finding]:
    out: list[Finding] = []
    for fn in _functions(tree, ctx.fn_names):
        for node in ast.walk(fn):
            if isinstance(node, (ast.Name, ast.Attribute)):
                name = node.id if isinstance(node, ast.Name) else node.attr
                if name in _SLOT_ARITH:
                    out.append(_f("phase-one-pass", ctx, node,
                                  f"{fn.name}: slot arithmetic `{name}` in a "
                                  "phase body — maps are computed once in "
                                  "plan.build_plan"))
    return out


# ---- rule: phase-no-placement --------------------------------------------

_PLACEMENT_NAMES = {"assign", "dest_of", "slot_expert"}


def _scan_phase_no_placement(tree: ast.Module, ctx: RuleCtx) -> list[Finding]:
    out: list[Finding] = []
    scope = (_functions(tree, ctx.fn_names) if ctx.fn_names is not None
             else [tree])
    for top in scope:
        for node in ast.walk(top):
            if isinstance(node, (ast.Name, ast.Attribute)):
                name = node.id if isinstance(node, ast.Name) else node.attr
                if name in _PLACEMENT_NAMES:
                    out.append(_f(
                        "phase-no-placement", ctx, node,
                        f"placement resolution `{name}` in a mode module — "
                        "plan construction (core/plan.py dest_of) is the one "
                        "resolution site (docs/DESIGN.md §8)"))
    return out


# ---- rule: recv-one-pass -------------------------------------------------

_RECV_PHASE_FNS = frozenset({
    "_ncclep_dispatch_recv", "_deepep_dispatch_recv",
    "_flat_dispatch_send", "_hier_dispatch_send", "ht_dispatch_complete",
})
_RECV_FILE = "src/repro/core/recv.py"


def _scan_recv_one_pass(tree: ast.Module, ctx: RuleCtx) -> list[Finding]:
    out: list[Finding] = []
    if ctx.path == _RECV_FILE:
        # the helper itself must be the fused kernel wrapper: it must call
        # recv_unpack and must not two-pass via gather_rows
        has_unpack = _mentions(tree, {"recv_unpack"}) is not None
        if not has_unpack:
            out.append(Finding("recv-one-pass", ctx.path, 1,
                               "core/recv.py no longer routes through the "
                               "fused recv_unpack kernel"))
        hit = _mentions(tree, {"gather_rows"})
        if hit is not None:
            out.append(_f("recv-one-pass", ctx, hit,
                          "two-pass gather in core/recv.py — unpack must be "
                          "the fused recv_unpack kernel"))
        return out
    # mode modules: no separate dequant anywhere; no gather in recv phases
    for node in ast.walk(tree):
        if (isinstance(node, (ast.Name, ast.Attribute))
                and _is_name_or_attr(node, "dequantize_fp8")):
            out.append(_f("recv-one-pass", ctx, node,
                          "dequantize_fp8 outside kernels/core.recv — recv "
                          "unpack must be one fused pass"))
    for fn in _functions(tree, ctx.fn_names):
        for node in ast.walk(fn):
            if (isinstance(node, (ast.Name, ast.Attribute))
                    and _is_name_or_attr(node, "gather_rows")):
                out.append(_f("recv-one-pass", ctx, node,
                              f"{fn.name}: gather_rows in a dispatch-recv "
                              "phase — use core.recv.unpack_recv (fused "
                              "gather + dequant)"))
    return out


# ---- rule: backend-staged-primitive --------------------------------------

_EAGER_SURFACE = {"dispatch", "combine", "complete"}


def _scan_backend_staged(tree: ast.Module, ctx: RuleCtx) -> list[Finding]:
    """Backends define ONLY the staged halves; BaseBackend derives the eager
    surface from them. An override of dispatch/combine/complete is how a
    backend could accept send_only and silently run eager — forbidden."""
    out: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        is_backend = any(_is_name_or_attr(b, "BaseBackend")
                         for b in node.bases)
        if not is_backend:
            continue
        for item in node.body:
            if isinstance(item, _FN_NODES) and item.name in _EAGER_SURFACE:
                out.append(_f(
                    "backend-staged-primitive", ctx, item,
                    f"{node.name}.{item.name}: overrides the derived eager "
                    "surface — backends implement staged halves only "
                    "(dispatch_send/dispatch_complete/combine_send/"
                    "combine_complete); the no-silent-ignore contract lives "
                    "in BaseBackend"))
    return out


# ---- rule: step-no-host-sync ---------------------------------------------

# Step-path registry: functions (including everything they define inside —
# the factories' returned closures) that are traced into jit on the serve/
# train step path. Host synchronization belongs at step BOUNDARIES
# (runtime/server.py drains/rebalance/recovery), never inside these.
_STEP_PATH: dict[str, frozenset[str]] = {
    "src/repro/runtime/steps.py": frozenset({
        "make_train_step", "make_serve_step", "make_paged_serve_step"}),
    "src/repro/runtime/decode.py": frozenset({
        "naive_decode_step", "_staged_pair", "pipelined_decode_step",
        "decode_loop"}),
    "src/repro/runtime/prefill.py": frozenset({
        "sequential_prefill", "prefill_moe"}),
}

_NP_ALIASES = {"np", "numpy", "onp"}
_SYNC_ATTRS = {"device_get", "block_until_ready"}


def _scan_step_no_host_sync(tree: ast.Module, ctx: RuleCtx) -> list[Finding]:
    out: list[Finding] = []
    for fn in _functions(tree, ctx.fn_names):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "item" \
                    and not node.args and not node.keywords:
                out.append(_f("step-no-host-sync", ctx, node,
                              f"{fn.name}: .item() forces a device->host "
                              "sync inside a step-path function"))
            elif isinstance(f, (ast.Name, ast.Attribute)) and (
                    (f.id if isinstance(f, ast.Name) else f.attr)
                    in _SYNC_ATTRS):
                name = f.id if isinstance(f, ast.Name) else f.attr
                out.append(_f("step-no-host-sync", ctx, node,
                              f"{fn.name}: {name}() inside a step-path "
                              "function — host sync belongs at step "
                              "boundaries"))
            elif (isinstance(f, ast.Attribute) and f.attr == "asarray"
                  and isinstance(f.value, ast.Name)
                  and f.value.id in _NP_ALIASES):
                out.append(_f("step-no-host-sync", ctx, node,
                              f"{fn.name}: {f.value.id}.asarray() on a "
                              "traced value reads the device buffer back — "
                              "keep numpy at step boundaries"))
            elif (isinstance(f, ast.Name) and f.id in {"float", "int"}
                  and node.args
                  and not isinstance(node.args[0], ast.Constant)):
                out.append(_f("step-no-host-sync", ctx, node,
                              f"{fn.name}: {f.id}(...) on a non-literal "
                              "concretizes (and in eager mode silently "
                              "syncs) a traced array"))
    return out


# --------------------------------------------------------------------------
# registry + runners
# --------------------------------------------------------------------------

RULES: dict[str, Rule] = {r.name: r for r in [
    Rule("api-registry-only",
         "core/api.py routes exclusively through the backend registry: no "
         "per-mode branching, no isinstance pending dispatch, no direct "
         "mode-module calls",
         {_API_FILE: None},
         _scan_api_registry_only),
    Rule("phase-one-pass",
         "no slot arithmetic (positions_by_dest/cumsum/argsort/"
         "build_gather_map) inside dispatch/combine phase bodies — maps are "
         "built once in plan.build_plan",
         {p: _PHASE_FNS for p in _MODE_FILES},
         _scan_phase_one_pass),
    Rule("phase-no-placement",
         "placement/replica resolution (assign/dest_of/slot_expert) never "
         "appears in a mode module — plan construction is the one site",
         {p: None for p in _MODE_FILES},
         _scan_phase_no_placement),
    Rule("recv-one-pass",
         "recv unpack is one fused pass: no gather_rows in dispatch-recv "
         "phases, no dequantize_fp8 outside kernels/core.recv, and "
         "core/recv.py stays a recv_unpack kernel wrapper",
         {**{p: _RECV_PHASE_FNS for p in _MODE_FILES}, _RECV_FILE: None},
         _scan_recv_one_pass),
    Rule("backend-staged-primitive",
         "EpBackend subclasses implement staged halves only — overriding "
         "the derived dispatch/combine/complete could silently drop "
         "send_only",
         {p: None for p in _MODE_FILES},
         _scan_backend_staged),
    Rule("step-no-host-sync",
         "no host-sync calls (.item(), device_get, block_until_ready, "
         "np.asarray, float/int on arrays) inside step-path functions in "
         "runtime/",
         {p: fns for p, fns in _STEP_PATH.items()},
         _scan_step_no_host_sync),
]}


def repo_root() -> pathlib.Path:
    # src/repro/analysis/contracts.py -> repo root is three levels above src
    return pathlib.Path(__file__).resolve().parents[3]


def run_rule(name: str, root: pathlib.Path | None = None) -> list[Finding]:
    """Run one named rule over its configured targets in the repo tree."""
    rule = RULES[name]
    root = root or repo_root()
    out: list[Finding] = []
    for rel, fns in rule.targets.items():
        path = root / rel
        src = path.read_text()
        tree = ast.parse(src, filename=rel)
        ctx = RuleCtx(path=rel, src=src,
                      fn_names=set(fns) if fns is not None else None)
        out.extend(_apply_suppressions(rule.scan(tree, ctx), src))
    return out


def run_all_contracts(root: pathlib.Path | None = None) -> list[Finding]:
    out: list[Finding] = []
    for name in RULES:
        out.extend(run_rule(name, root))
    return out


def check_source(rule_name: str, source: str,
                 path: str = "<fixture>") -> list[Finding]:
    """Run one rule against arbitrary source, scanning ALL functions (no
    name scope) — the fixture/mutation-smoke entry point. Suppression
    comments in the source are honored, same as the tree run."""
    rule = RULES[rule_name]
    tree = ast.parse(source, filename=path)
    ctx = RuleCtx(path=path, src=source, fn_names=None)
    return _apply_suppressions(rule.scan(tree, ctx), source)
