"""Runtime auditors for the serving determinism contracts.

Three tools, usable from tests and benches (docs/DESIGN.md §12):

- ``RetraceAuditor``: counts serve-step traces and compile-cache activity on
  a ``DecodeServer`` and asserts the ``{current, previous}`` compiled-cache
  bound — the property that makes a long-lived rebalancing server's memory
  O(1) in the number of placement swaps, and that a shape/dtype drift would
  silently break (every extra trace is a latency spike AND a pinned buffer
  set).
- ``DonationAuditor``: patches ``adopt_expert_params`` at every import site
  and verifies that each adoption boundary which CAN donate (device leaves,
  layout actually changing, slot count preserved) really deleted the old
  expert buffers — the adopt-once peak-memory contract.
- ``transfer_guard`` / ``guard_serve_steps``: make an unexpected
  device->host sync inside ``serve_step`` a hard error.  Host->device stays
  allowed: continuous batching feeds host-built numpy inputs (tokens /
  page_tbl / kv_lens / active) every step by design; it is the *readback*
  direction that must only happen at step boundaries
  (``jax.block_until_ready`` + explicit ``np.asarray`` after the step).
"""
from __future__ import annotations

import contextlib
import functools

import jax

_ADOPT_SITES = ("repro.checkpoint.store", "repro.checkpoint",
                "repro.runtime.server")


class RetraceAuditor:
    """Attach to a (running) DecodeServer; every compile and every trace of
    the serve step from then on is counted, and the compiled-step cache is
    bound-checked on every ``_compiled_step`` call.

    Attach AFTER construction: the initial compile is the baseline, and the
    counters then measure exactly the swap/recovery traffic — on a healthy
    server ``compiles == traces == placements adopted since attach``.
    """

    def __init__(self, server, max_cache: int = 2):
        self.server = server
        self.max_cache = max_cache
        self.traces = 0          # serve-step function bodies executed (trace time)
        self.compiles = 0        # new entries admitted to the step cache
        self.cache_calls = 0     # _compiled_step invocations (incl. hits)
        self.max_cache_seen = len(server._step_cache)
        self._placements_at_attach = len(server.placements)

        orig_factory = server._step_factory
        orig_compiled = server._compiled_step

        def counting_factory():
            fn = orig_factory()

            @functools.wraps(fn)
            def traced(*args, **kwargs):
                # executes once per jit trace (the step is always jitted)
                self.traces += 1
                return fn(*args, **kwargs)
            return traced

        def checking_compiled():
            self.cache_calls += 1
            before = set(map(id, server._step_cache.values()))
            step = orig_compiled()
            if any(id(v) not in before for v in server._step_cache.values()):
                self.compiles += 1
            self.max_cache_seen = max(self.max_cache_seen,
                                      len(server._step_cache))
            if len(server._step_cache) > self.max_cache:
                raise AssertionError(
                    f"compiled-step cache grew to "
                    f"{len(server._step_cache)} entries — the "
                    f"{{current, previous}} bound is {self.max_cache}")
            return step

        server._step_factory = counting_factory
        server._compiled_step = checking_compiled

    @property
    def placements_adopted(self) -> int:
        """Placements adopted since this auditor attached."""
        return len(self.server.placements) - self._placements_at_attach

    def assert_cache_bounded(self):
        if self.max_cache_seen > self.max_cache:
            raise AssertionError(
                f"compiled-step cache peaked at {self.max_cache_seen} "
                f"(bound {self.max_cache})")

    def assert_retrace_economy(self):
        """Exactly one compile and one trace per adopted placement — no
        hidden retraces (shape/dtype drift, cache-key churn) and no
        compile that failed to trace."""
        self.assert_cache_bounded()
        want = self.placements_adopted
        if not (self.compiles == self.traces == want):
            raise AssertionError(
                f"retrace economy violated: {self.compiles} compiles / "
                f"{self.traces} traces for {want} placement adoptions "
                "(expected exactly one of each per adoption)")


class DonationAuditor:
    """Context manager verifying every ``adopt_expert_params`` call inside
    the block donates what it can: expert device leaves whose layout
    actually changes with the slot count preserved must come out deleted
    (``jax.Array.is_deleted``), or the adoption held two full weight sets.

    ``checked`` counts rebind-eligible leaves observed; ``donated`` the ones
    verified deleted. Violations raise on exit (or immediately via
    ``assert_clean``). Patches every import site of ``adopt_expert_params``
    and restores them on exit.
    """

    def __init__(self):
        self.checked = 0
        self.donated = 0
        self.calls = 0
        self.violations: list[str] = []
        self._saved: list[tuple[object, object]] = []

    # -- donation eligibility mirrors checkpoint.store._donating_rebind --

    @staticmethod
    def _rows(src_pl, dst_pl):
        any_pl = src_pl or dst_pl
        in_rows = (src_pl.num_slots if src_pl
                   else any_pl.num_experts if any_pl else None)
        out_rows = (dst_pl.num_slots if dst_pl
                    else any_pl.num_experts if any_pl else None)
        return in_rows, out_rows

    def _wrap(self, orig):
        from repro.checkpoint.store import _same_layout
        from repro.parallel.sharding import ParamSpec

        @functools.wraps(orig)
        def audited(params, specs, src_placement=None, dst_placement=None,
                    *, donate=True):
            self.calls += 1
            in_rows, out_rows = self._rows(src_placement, dst_placement)
            eligible = (donate
                        and not _same_layout(src_placement, dst_placement)
                        and in_rows is not None and in_rows == out_rows)
            watched: list[jax.Array] = []
            if eligible:
                def collect(spec, leaf):
                    if (isinstance(spec, ParamSpec)
                            and "expert" in (spec.axes or ())
                            and isinstance(leaf, jax.Array)):
                        watched.append(leaf)
                    return leaf
                jax.tree.map(collect, specs, params,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
            out = orig(params, specs, src_placement, dst_placement,
                       donate=donate)
            for leaf in watched:
                self.checked += 1
                if leaf.is_deleted():
                    self.donated += 1
                else:
                    self.violations.append(
                        f"adopt_expert_params(src={src_placement!r:.40s}, "
                        f"dst={dst_placement!r:.40s}): expert leaf shape "
                        f"{tuple(leaf.shape)} was rebind-eligible for "
                        "donation but the old buffer survived — the "
                        "adoption held two weight sets")
            return out
        return audited

    def __enter__(self):
        import importlib
        for name in _ADOPT_SITES:
            mod = importlib.import_module(name)
            orig = getattr(mod, "adopt_expert_params", None)
            if orig is None:
                continue
            self._saved.append((mod, orig))
            setattr(mod, "adopt_expert_params", self._wrap(orig))
        return self

    def __exit__(self, exc_type, exc, tb):
        for mod, orig in self._saved:
            setattr(mod, "adopt_expert_params", orig)
        self._saved.clear()
        if exc_type is None:
            self.assert_clean()
        return False

    def assert_clean(self):
        if self.violations:
            raise AssertionError("undonated adoption rebind(s):\n"
                                 + "\n".join(self.violations))


@contextlib.contextmanager
def transfer_guard(level: str = "disallow"):
    """Any device->host transfer inside the block is a hard error (the JAX
    transfer guard, scoped to the d2h direction only — see module
    docstring for why h2d stays allowed). Arms on accelerators; on the CPU
    host platform d2h is zero-copy and the guard never fires, so the linter's
    static ``step-no-host-sync`` rule is the CPU-side line of defense."""
    with jax.transfer_guard_device_to_host(level):
        yield


@contextlib.contextmanager
def guard_serve_steps(server, level: str = "disallow"):
    """Run a DecodeServer with every ``serve_step`` invocation under the
    device->host transfer guard: a stray ``.item()`` / ``np.asarray`` /
    implicit readback inside the step becomes a hard error, while the
    boundary-scoped host work the server does between steps (heat drain,
    scheduler observe, token readback after ``block_until_ready``) stays
    legal. Wraps the current compiled step AND the compile path, so steps
    re-jitted at placement swaps / recoveries inside the block are guarded
    too."""
    def wrap(fn):
        if getattr(fn, "_d2h_guarded", False):
            return fn

        @functools.wraps(fn)
        def guarded(*args, **kwargs):
            with jax.transfer_guard_device_to_host(level):
                return fn(*args, **kwargs)
        guarded._d2h_guarded = True
        return guarded

    prev_compiled = server._compiled_step
    prev_step = server.step

    def guarded_compiled():
        return wrap(prev_compiled())

    server._compiled_step = guarded_compiled
    server.step = wrap(prev_step)
    try:
        yield server
    finally:
        server._compiled_step = prev_compiled
        # leave a functional (unguarded) step bound: recompute from the
        # cache rather than restoring prev_step, which may be stale after
        # an in-block placement swap
        server.step = prev_compiled()
