"""Slot-map / write-set verifier — layer 3 of the analysis suite.

Every EP transfer in this repo is a gather/scatter through maps the plan
builder (``core/plan.py``) precomputes at handle creation. The Pallas/XLA
chain *assumes* properties of those maps it cannot itself express or check:

- **in-capacity**: every map value lies in ``[0, sentinel]`` for its buffer
  (an out-of-range index silently clamps on device — data corruption, not
  an error);
- **write-disjoint**: scatter targets (``h_entry_slot``, ``h_slot_tgt``,
  per-pod rail rows, combine recv rows) are unique per destination buffer —
  duplicate ``.at[].set`` targets have *unspecified order* in XLA, i.e.
  run-to-run nondeterminism, and duplicate ``.at[].add`` targets double-
  count;
- **EMPTY-safe**: a degraded placement's dead ranks receive exactly nothing
  (send blocks all-sentinel, counts zero, expert region empty);
- **round-trip**: pushing token ids through the full dispatch + combine
  map chain reproduces every token exactly where the plan claims zero-drop,
  and where a capacity factor is configured a dropped entry only ever
  yields the zero row — never another token's data.

This module extracts the per-rank plans (jit + shard_map over the 8-device
host platform, exactly how production builds them) and checks all of the
above in numpy, over every mode x layout x geometry x chunking x placement
(contiguous / redundant / degraded EMPTY-row tables / padding / dropping).

Run via ``python -m repro.analysis`` (CI) or call :func:`run_plan_checks`.
``extract_plans`` / ``check_plans`` are exposed separately so tests can
corrupt a map between the two and assert the verifier catches it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# NOTE: importing this module imports jax. The CLI (``__main__``) sets
# XLA_FLAGS=--xla_force_host_platform_device_count=8 BEFORE this import
# (conftest.py does the same under pytest); a bare interpreter that imported
# jax first will fail the device-count check below with a hint.
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ep_create_handle
from repro.core import placement as PL
from repro.core.group import EpGroupConfig, ep_create_group
from repro.core.plan import dest_of

N_RANKS = 8
E, T, K, H = 16, 8, 2, 16


@dataclasses.dataclass(frozen=True)
class PlanCase:
    """One point of the verification matrix."""
    name: str
    kind: str                        # "flat" | "transpose" | "hier"
    cfg_kw: dict
    num_tokens: int | None = None    # < T exercises the padding sentinel
    zero_drop: bool = True           # False: capacity factor drops allowed
    seed: int = 0


def _redundant():
    return PL.redundant_placement(E, N_RANKS, 8)


def _degraded():
    # rank 3 dead: table keeps 8 rows, row 3 all EMPTY, 16 + 5 = 21
    # replicas packed 3-per-rank onto the 7 survivors
    return PL.rebalance(np.ones(E), N_RANKS, num_redundant=5,
                        alive_ranks=tuple(r for r in range(N_RANKS)
                                          if r != 3))


def _cases() -> list[PlanCase]:
    hier = dict(mode="ht", ep_axis=("pod", "data"), ht_hierarchical=True)
    return [
        PlanCase("ll-nccl/contig", "flat", dict(mode="ll")),
        PlanCase("ll-nccl/redundant", "flat",
                 dict(mode="ll", placement=_redundant())),
        PlanCase("ll-nccl/degraded", "flat",
                 dict(mode="ll", placement=_degraded())),
        PlanCase("ll-nccl/padding", "flat", dict(mode="ll"), num_tokens=5),
        PlanCase("ll-nccl/dropping", "flat",
                 dict(mode="ll", capacity_factor=1.0, slot_align=1),
                 zero_drop=False),
        PlanCase("ll-deepep/contig", "transpose",
                 dict(mode="ll", ll_layout="deepep")),
        PlanCase("ll-deepep/redundant", "transpose",
                 dict(mode="ll", ll_layout="deepep", placement=_redundant())),
        PlanCase("ht-flat/contig", "flat", dict(mode="ht")),
        PlanCase("ht-flat/degraded", "flat",
                 dict(mode="ht", placement=_degraded())),
        PlanCase("ht-hier/nc1", "hier", dict(**hier)),
        PlanCase("ht-hier/nc2", "hier", dict(ht_num_chunks=2, **hier)),
        PlanCase("ht-hier/nc2-redundant", "hier",
                 dict(ht_num_chunks=2, placement=_redundant(), **hier)),
        PlanCase("ht-hier/nc2-degraded", "hier",
                 dict(ht_num_chunks=2, placement=_degraded(), **hier)),
        PlanCase("baseline/contig", "transpose", dict(mode="baseline")),
        PlanCase("baseline/redundant", "transpose",
                 dict(mode="baseline", placement=_redundant())),
    ]


PLAN_CASES: dict[str, PlanCase] = {c.name: c for c in _cases()}


# --------------------------------------------------------------------------
# extraction: build the handle exactly like production and ship the maps out
# --------------------------------------------------------------------------

def _build(case: PlanCase):
    if len(jax.devices()) < N_RANKS:
        raise RuntimeError(
            f"plan verification needs {N_RANKS} devices; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
            "importing jax (python -m repro.analysis does this for you)")
    cfg = EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                        top_k=K, payload_dtype=jnp.float32, **case.cfg_kw)
    is_hier = len(cfg.ep_axis) > 1
    group = ep_create_group(cfg, ep_size=N_RANKS,
                            inner_size=4 if is_hier else None)
    if is_hier:
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = jax.make_mesh((N_RANKS,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(case.seed)
    topk = np.stack([np.stack([rng.choice(E, K, replace=False)
                               for _ in range(T)])
                     for _ in range(N_RANKS)]).astype(np.int32)
    w = rng.rand(N_RANKS, T, K).astype(np.float32)
    return group, mesh, topk, w


def extract_plans(case: PlanCase):
    """Build the case's handle under jit + shard_map (the production path)
    and return ``(group, topk [N,T,K], plans)`` with every non-None plan
    field stacked across ranks as a numpy array ``[N, ...]``."""
    group, mesh, topk, w = _build(case)

    def step(tk, wt):
        h = ep_create_handle(group, tk[0], wt[0], case.num_tokens)
        return {f.name: getattr(h.plan, f.name)[None]
                for f in dataclasses.fields(h.plan)
                if getattr(h.plan, f.name) is not None}

    lead = (P(tuple(mesh.axis_names)) if len(mesh.axis_names) > 1
            else P(mesh.axis_names[0]))
    fn = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(lead, lead),
                               out_specs=lead))
    plans = fn(jnp.asarray(topk), jnp.asarray(w))
    return group, topk, {k: np.asarray(v) for k, v in plans.items()}


def _oracle(case: PlanCase, group, topk):
    """Host-side routing ground truth: per global entry (r, t, k) the
    physical (dest_rank, dest_slot) and validity — ``dest_of`` evaluated
    eagerly with the same padding masking handle creation applies."""
    nt = T if case.num_tokens is None else case.num_tokens
    tk = topk.copy()
    tk[:, nt:, :] = E
    src = jnp.arange(N_RANKS, dtype=jnp.int32)[:, None, None]
    dst, slot = dest_of(group, jnp.asarray(tk), src)
    dst, slot = np.asarray(dst), np.asarray(slot)
    valid = (np.arange(T)[None, :, None] < nt) & (dst < N_RANKS)
    return dst, slot, valid


# --------------------------------------------------------------------------
# numpy map-chain simulators
# --------------------------------------------------------------------------

def _gather(buf, idx, fill=0):
    """Mirror of kernels' sentinel gather: ``idx == len(buf)`` -> fill."""
    flat = np.concatenate([np.asarray(buf), [fill]])
    return flat[np.minimum(idx, len(buf))]


def _dead_ranks(group):
    pl = group.placement
    return () if pl is None else pl.dead_ranks()


class _Checker:
    def __init__(self, case):
        self.case = case
        self.violations: list[str] = []

    def expect(self, cond, msg):
        if not cond:
            self.violations.append(f"{self.case.name}: {msg}")

    def in_range(self, name, arr, sentinel):
        self.expect(arr.min(initial=0) >= 0 and arr.max(initial=0) <= sentinel,
                    f"{name} out of range [0, {sentinel}]: "
                    f"min={arr.min()} max={arr.max()}")

    def unique(self, name, vals, sentinel):
        live = vals[vals != sentinel]
        self.expect(len(np.unique(live)) == len(live),
                    f"{name}: duplicate scatter/consume targets "
                    "(write-set not disjoint)")


def _expected_counts(group, dst, slot, valid):
    """[N, L] oracle receive counts per physical slot."""
    L = group.local_experts
    cnt = np.zeros((N_RANKS, L), np.int64)
    d, s = dst[valid], slot[valid]
    np.add.at(cnt, (d, s), 1)
    return cnt


def _check_flat(ck, case, group, plans, ids, dst, slot, valid):
    """ll/nccl_ep and ht/flat: 4-map chain through mirrored [N, C] blocks."""
    sg = plans["disp_send_gmap"]           # [N, N, Cd] -> token, sentinel T
    rg = plans["disp_recv_gmap"]           # [N, L, A]  -> recv row
    cg = plans["comb_send_gmap"]           # [N, N, Cc] -> y3d row
    rows = plans["comb_recv_rows"]         # [N, T, K]  -> comb recv row
    counts = plans["disp_counts"]          # [N, L]
    Cd, Cc = sg.shape[-1], cg.shape[-1]
    L, A = rg.shape[1], rg.shape[2]

    ck.in_range("disp_send_gmap", sg, T)
    ck.in_range("disp_recv_gmap", rg, N_RANKS * Cd)
    ck.in_range("comb_send_gmap", cg, L * A)
    ck.in_range("comb_recv_rows", rows, N_RANKS * Cc)
    for r in range(N_RANKS):
        ck.unique(f"comb_recv_rows[rank {r}]", rows[r].reshape(-1),
                  N_RANKS * Cc)
        if group.mode == "ll":             # rank-dedup layout: one slot per
            for d in range(N_RANKS):       # (token, dest rank) pair
                ck.unique(f"disp_send_gmap[rank {r} -> {d}]", sg[r, d], T)

    # EMPTY safety: dead ranks send/receive/host nothing
    for d in _dead_ranks(group):
        ck.expect((sg[:, d, :] == T).all(),
                  f"dispatch send block to dead rank {d} not all-sentinel")
        ck.expect((counts[d] == 0).all(), f"dead rank {d} has recv counts")
        ck.expect((rg[d] == N_RANKS * Cd).all(),
                  f"dead rank {d} expert region not empty")
        ck.expect((cg[d] == L * A).all(),
                  f"dead rank {d} combine send block not empty")
        landed = rows[rows != N_RANKS * Cc]
        ck.expect((landed // Cc != d).all(),
                  f"combine recv rows land in dead rank {d}'s block")

    # round-trip: ids through dispatch a2a -> expert region -> combine a2a
    sv = np.stack([_gather(ids[r], sg[r]) for r in range(N_RANKS)])
    recv = sv.transpose(1, 0, 2).reshape(N_RANKS, N_RANKS * Cd)
    y = np.stack([_gather(recv[d], rg[d].reshape(-1))
                  for d in range(N_RANKS)])                 # [N, L*A]
    cb = np.stack([_gather(y[d], cg[d]) for d in range(N_RANKS)])
    crecv = cb.transpose(1, 0, 2).reshape(N_RANKS, N_RANKS * Cc)
    fin = np.stack([_gather(crecv[r], rows[r]) for r in range(N_RANKS)])

    exp = np.where(valid, ids[:, :, None], 0)
    if case.zero_drop:
        ck.expect((fin == exp).all(),
                  "round-trip mismatch at zero-drop capacities: "
                  f"{int((fin != exp).sum())} entries wrong")
        ck.expect((counts == _expected_counts(group, dst, slot, valid)).all(),
                  "disp_counts disagree with the routing oracle")
        per_slot = (rg != N_RANKS * Cd).sum(axis=2)         # [N, L]
        ck.expect((per_slot == counts).all(),
                  "expert-region occupancy disagrees with disp_counts")
    else:
        ok = (fin == exp) | (fin == 0)
        ck.expect(ok.all(),
                  "capacity drop corrupted data: an entry returned another "
                  f"token's payload ({int((~ok).sum())} entries)")


def _check_transpose(ck, case, group, plans, ids, dst, slot, valid):
    """ll/deepep and baseline: positional slots; recv/combine are pure
    transposes, so the whole chain is send map + combine recv rows."""
    sg = plans["disp_send_gmap"]           # [N, N, S] -> token, sentinel T
    rows = plans["comb_recv_rows"]         # [N, T, K]
    counts = plans["disp_counts"]
    S_ = sg.shape[-1]

    ck.in_range("disp_send_gmap", sg, T)
    ck.in_range("comb_recv_rows", rows, N_RANKS * S_)
    for r in range(N_RANKS):
        ck.unique(f"comb_recv_rows[rank {r}]", rows[r].reshape(-1),
                  N_RANKS * S_)

    for d in _dead_ranks(group):
        ck.expect((sg[:, d, :] == T).all(),
                  f"dispatch send block to dead rank {d} not all-sentinel")
        ck.expect((counts[d] == 0).all(), f"dead rank {d} has recv counts")
        landed = rows[rows != N_RANKS * S_]
        ck.expect((landed // S_ != d).all(),
                  f"combine recv rows land in dead rank {d}'s block")

    sv = np.stack([_gather(ids[r], sg[r]) for r in range(N_RANKS)])
    # combine mirror: expert rank d returns its recv block to each source,
    # so source r reads back exactly its own send matrix, flattened
    back = sv.reshape(N_RANKS, N_RANKS * S_)
    fin = np.stack([_gather(back[r], rows[r]) for r in range(N_RANKS)])
    exp = np.where(valid, ids[:, :, None], 0)
    ck.expect((fin == exp).all(),
              f"round-trip mismatch: {int((fin != exp).sum())} entries wrong")
    ck.expect((counts == _expected_counts(group, dst, slot, valid)).all(),
              "disp_counts disagree with the routing oracle")


def _check_hier(ck, case, group, plans, ids, dst, slot, valid):
    """ht/hier: two-stage chunked chain, forward (dispatch) by id transport
    and reverse (combine) by value-sum through the scatter-add maps."""
    Ni, No = group.inner_size, group.outer_size
    C1, C2 = group.ht_stage1_cap, group.ht_stage2_cap
    L, A = group.local_experts, group.ht_expert_cap
    g1 = plans["h_gmap1"]                  # [N, nc, Ni, C1] -> token
    g2 = plans["h_gmap2"]                  # [N, nc, No, C2] -> recv1 row
    rg = plans["disp_recv_gmap"]           # [N, L, A] -> concat row
    st = plans["h_slot_tgt"]               # [N, L*A] -> stage-2 concat row
    es = plans["h_entry_slot"]             # [N, N*T*K] -> y3d slot
    rd = plans["h_rail_dst_rows"]          # [N, nc, No, Ni*Tc]
    rs = plans["h_rail_src_rows"]          # [N, nc, No, Ni*Tc]
    sr = plans["h_src_rows"]               # [N, T, Ni] -> concat1 row
    counts = plans["disp_counts"]
    nc = g1.shape[1]

    ck.in_range("h_gmap1", g1, T)
    ck.in_range("h_gmap2", g2, Ni * C1)
    ck.in_range("disp_recv_gmap", rg, nc * No * C2)
    ck.in_range("h_slot_tgt", st, nc * No * C2)
    ck.in_range("h_entry_slot", es, L * A)
    ck.in_range("h_rail_dst_rows", rd, Ni * C1)
    ck.in_range("h_rail_src_rows", rs, No * C2)
    ck.in_range("h_src_rows", sr, nc * Ni * C1)
    for r in range(N_RANKS):
        # scatter write-sets: .at[].set targets must be unique
        ck.unique(f"h_entry_slot[rank {r}]", es[r], L * A)
        # h_slot_tgt is a scatter-ADD (the per-token partial sum at the
        # stage-2 slot), so duplicates are legal — but only among slots of
        # ONE token; two tokens adding into one row would corrupt both
        placed = es[r] < L * A
        tok = np.nonzero(placed)[0] // K        # entry order is (r_src,t,k)
        tgt = st[r][es[r][placed]]
        order = np.argsort(tgt, kind="stable")
        tgt_s, tok_s = tgt[order], tok[order]
        same_row = tgt_s[1:] == tgt_s[:-1]
        ck.expect((~same_row | (tok_s[1:] == tok_s[:-1])).all(),
                  f"h_slot_tgt[rank {r}]: a stage-2 row accumulates "
                  "contributions from more than one token")
        for c in range(nc):
            for o in range(No):
                # within one pod block the rail accumulates distinct slots
                ck.unique(f"h_rail_dst_rows[rank {r}, chunk {c}, pod {o}]",
                          rd[r, c, o], Ni * C1)
                ck.unique(f"h_rail_src_rows[rank {r}, chunk {c}, pod {o}]",
                          rs[r, c, o], No * C2)

    for d in _dead_ranks(group):
        ck.expect((counts[d] == 0).all(), f"dead rank {d} has recv counts")
        ck.expect((rg[d] == nc * No * C2).all(),
                  f"dead rank {d} expert region not empty")
        ck.expect((es[d] == L * A).all(),
                  f"dead rank {d} owns combine entry slots")

    # ---- dispatch: ids through stage-1 (intra-pod) + stage-2 (inter-pod)
    concat = np.zeros((N_RANKS, nc * No * C2), ids.dtype)
    for c in range(nc):
        s1 = np.stack([_gather(ids[r], g1[r, c]) for r in range(N_RANKS)])
        recv1 = s1.reshape(No, Ni, Ni, C1).transpose(0, 2, 1, 3)
        flat1 = recv1.reshape(N_RANKS, Ni * C1)
        s2 = np.stack([_gather(flat1[r], g2[r, c]) for r in range(N_RANKS)])
        recv2 = s2.reshape(No, Ni, No, C2).transpose(2, 1, 0, 3)
        concat[:, c * No * C2:(c + 1) * No * C2] = recv2.reshape(
            N_RANKS, No * C2)
    y = np.stack([_gather(concat[r], rg[r].reshape(-1))
                  for r in range(N_RANKS)])                 # [N, L*A]

    # every valid entry's payload sits where h_entry_slot says it does
    ent_dst = dst.reshape(-1)              # entry order (r_src, t, k) ==
    ent_ids = np.broadcast_to(ids[:, :, None],
                              (N_RANKS, T, K)).reshape(-1)  # plan's (o,i,t,k)
    ent_valid = valid.reshape(-1)
    for d in range(N_RANKS):
        mine = ent_valid & (ent_dst == d)
        sl = es[d]
        if case.zero_drop:
            ck.expect((sl[mine] < L * A).all(),
                      f"rank {d}: valid entries without a y3d slot "
                      "at zero-drop capacities")
            ck.expect((sl[~mine] == L * A).all(),
                      f"rank {d}: entry slots assigned to foreign entries")
        placed = mine & (sl < L * A)
        ck.expect((y[d][sl[placed]] == ent_ids[placed]).all(),
                  f"rank {d}: dispatched payload does not match "
                  "h_entry_slot's claim")
    if case.zero_drop:
        ck.expect((counts == _expected_counts(group, dst, slot, valid)).all(),
                  "disp_counts disagree with the routing oracle")
        per_slot = (rg != nc * No * C2).sum(axis=2)
        ck.expect((per_slot == counts).all(),
                  "expert-region occupancy disagrees with disp_counts")

    # ---- combine: unique per-entry values, summed back through the
    # slot-domain scatter + rail reduction + source gather
    rng = np.random.RandomState(7)
    vals = rng.rand(N_RANKS * T * K) + 1.0                  # float64, > 0
    vslot = np.zeros((N_RANKS, L * A + 1))
    for d in range(N_RANKS):
        live = es[d] < L * A
        vslot[d][es[d][live]] = vals[live]                  # unique (checked)
    buf2 = np.zeros((N_RANKS, nc * No * C2 + 1))
    for r in range(N_RANKS):
        np.add.at(buf2[r], st[r], vslot[r][:L * A])
    buf2 = buf2[:, :nc * No * C2]
    out = np.zeros((N_RANKS, nc * Ni * C1))
    for c in range(nc):
        chunk = buf2[:, c * No * C2:(c + 1) * No * C2]
        back2 = chunk.reshape(No, Ni, No, C2).transpose(2, 1, 0, 3)
        back2f = back2.reshape(N_RANKS, No * C2)
        rail = np.zeros((N_RANKS, Ni * C1 + 1))
        for r in range(N_RANKS):
            v = _gather(back2f[r], rs[r, c].reshape(-1), fill=0.0)
            np.add.at(rail[r], rd[r, c].reshape(-1), v)
        back1 = rail[:, :Ni * C1].reshape(No, Ni, Ni, C1).transpose(0, 2, 1, 3)
        out[:, c * Ni * C1:(c + 1) * Ni * C1] = back1.reshape(
            N_RANKS, Ni * C1)
    fin = np.stack([
        _gather(out[r], sr[r].reshape(-1), fill=0.0).reshape(T, Ni).sum(1)
        for r in range(N_RANKS)])                           # [N, T]
    exp = (np.where(valid, vals.reshape(N_RANKS, T, K), 0.0)).sum(-1)
    ck.expect(np.allclose(fin, exp, rtol=1e-9, atol=1e-9),
              "combine value-sum mismatch: the reverse chain does not "
              f"reduce every entry exactly once (max err "
              f"{np.abs(fin - exp).max():.3e})")


_CHECKERS = {"flat": _check_flat, "transpose": _check_transpose,
             "hier": _check_hier}


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def check_plans(case: PlanCase, group, topk, plans) -> list[str]:
    """Check extracted ``plans`` for ``case``; returns violation strings
    (empty == clean). Split from :func:`extract_plans` so tests can corrupt
    a map in between and assert detection."""
    ck = _Checker(case)
    dst, slot, valid = _oracle(case, group, topk)
    ids = (np.arange(N_RANKS * T, dtype=np.int64) + 1).reshape(N_RANKS, T)
    _CHECKERS[case.kind](ck, case, group, plans, ids, dst, slot, valid)
    return ck.violations


def verify_case(case: PlanCase) -> list[str]:
    group, topk, plans = extract_plans(case)
    return check_plans(case, group, topk, plans)


def run_plan_checks(names=None, log=None) -> list[str]:
    """Run the whole matrix (or the named subset); returns all violations."""
    out: list[str] = []
    for name, case in PLAN_CASES.items():
        if names is not None and name not in names:
            continue
        v = verify_case(case)
        if log is not None:
            log(f"  {name:24s} {'FAIL (' + str(len(v)) + ')' if v else 'ok'}")
        out.extend(v)
    return out
