"""AdamW with global-norm clipping, cosine schedule, and fully sharded
optimizer state (each moment inherits its parameter's sharding — ZeRO-3 by
construction under GSPMD). `state_dtype` trades moment precision for HBM:
f32 default; bf16 for the 671B-class configs (see docs/DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    state_dtype: Any = jnp.float32


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init_specs(param_specs, cfg: AdamWConfig):
    """Moment ParamSpecs mirror parameter shapes & logical axes."""
    def one(s: ParamSpec):
        return ParamSpec(s.shape, cfg.state_dtype, s.axes, init="zeros")
    is_leaf = lambda x: isinstance(x, ParamSpec)
    return dict(
        m=jax.tree.map(one, param_specs, is_leaf=is_leaf),
        v=jax.tree.map(one, param_specs, is_leaf=is_leaf),
        step=ParamSpec((), jnp.int32, (), init="zeros"),
    )


def adamw_init(params, cfg: AdamWConfig):
    z = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return dict(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                step=jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    # global-norm clip in f32
    gsq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh, vh = m2 / bc1, v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    flat, td = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(td, [t[0] for t in flat])
    new_m = jax.tree.unflatten(td, [t[1] for t in flat])
    new_v = jax.tree.unflatten(td, [t[2] for t in flat])
    return new_p, dict(m=new_m, v=new_v, step=step), dict(grad_norm=gnorm, lr=lr)
