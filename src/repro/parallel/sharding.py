"""Logical-axis sharding: every parameter/activation declares *logical* axes;
a rules table maps them onto mesh axes (the MaxText/T5X pattern). This keeps
model code mesh-agnostic — the same definitions lower on the single-pod
(16, 16) and multi-pod (2, 16, 16) production meshes and on tiny test meshes.

Rules (defaults; overridable per arch/shape config):
  batch      -> ("pod", "data")   data parallelism (pods are extra DP)
  vocab      -> "model"           TP embedding / logits
  heads      -> "model"           TP attention (q heads; kv replicated when
                                  n_kv doesn't divide the model axis)
  ffn        -> "model"           TP MLP
  expert     -> EP axis (the EpGroupConfig.ep_axis, usually "model")
  kv_seq     -> "model" (decode)  sequence-sharded KV caches; XLA inserts the
                                  softmax all-reduces (split-KV decode)
  kv_seq_long-> ("data","model")  524k contexts: KV over the whole pod
  stack      -> None              scan-over-layers leading axis, never sharded
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape + dtype + logical axes + initializer for one parameter."""

    shape: tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: tuple[str | None, ...] = ()
    init: str = "normal"       # normal | zeros | ones | embed | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.axes) in (0, len(self.shape)), (self.shape, self.axes)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Mapping[str, tuple[str, ...] | str | None]

    def mesh_axes(self, logical: str | None, mesh: Mesh):
        if logical is None:
            return None
        target = self.rules.get(logical, None)
        if target is None:
            return None
        axes = (target,) if isinstance(target, str) else tuple(target)
        # drop axes not present in the mesh (e.g. "pod" on single-pod)
        axes = tuple(a for a in axes if a in mesh.shape)
        return axes if axes else None


DEFAULT_RULES = ShardingRules(rules={
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "expert": "model",
    "kv_seq": "model",
    "kv_seq_long": ("data", "model"),
    "mamba_heads": "model",
    "embed": None, "seq": None, "stack": None, "qk": None, "v": None,
    "lora": None, "state": None, "conv": None, "img": None,
})


def _divisible(dim: int, mesh: Mesh, axes: tuple[str, ...] | None) -> bool:
    if not axes:
        return True
    n = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % n == 0


def logical_to_pspec(spec: ParamSpec, mesh: Mesh, rules: ShardingRules) -> P:
    """Logical axes -> PartitionSpec. A dimension is silently replicated when
    it doesn't divide its mesh extent (e.g. 2 kv heads over a 16-way model
    axis) or when its mesh axis was already claimed by an earlier dimension
    (first-come-wins, the T5X rule — e.g. decode caches map both kv_seq and
    kv_heads to "model"; kv_seq wins)."""
    if not spec.axes:
        return P()
    parts = []
    used: set[str] = set()
    for dim, ax in zip(spec.shape, spec.axes):
        m = rules.mesh_axes(ax, mesh)
        if m:
            m = tuple(a for a in m if a not in used)
        if m and _divisible(dim, mesh, m):
            parts.append(tuple(m) if len(m) > 1 else m[0])
            used.update(m)
        else:
            parts.append(None)
    return P(*parts)


def spec_to_named_sharding(spec: ParamSpec, mesh: Mesh,
                           rules: ShardingRules = DEFAULT_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(spec, mesh, rules))


def abstract_from_specs(specs, mesh: Mesh | None = None,
                        rules: ShardingRules = DEFAULT_RULES):
    """Pytree of ParamSpec -> pytree of ShapeDtypeStruct (dry-run inputs)."""
    def one(s: ParamSpec):
        sh = spec_to_named_sharding(s, mesh, rules) if mesh is not None else None
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_from_specs(key: jax.Array, specs, mesh: Mesh | None = None,
                    rules: ShardingRules = DEFAULT_RULES):
    """Materialize parameters (tests/examples; production uses checkpoint)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))

    def one(k, s: ParamSpec):
        if s.init == "zeros":
            v = jnp.zeros(s.shape, s.dtype)
        elif s.init == "ones":
            v = jnp.ones(s.shape, s.dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / np.sqrt(max(fan_in, 1))
            v = (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)
        if mesh is not None:
            v = jax.device_put(v, spec_to_named_sharding(s, mesh, rules))
        return v

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in zip(keys, leaves)])


def arch_rules(cfg) -> ShardingRules:
    """Arch-aware rules: the expert dimension shards over the config's EP
    axis and the expert FFN dim over whatever model capacity EP leaves free.
    (Using DEFAULT_RULES for a MoE arch replicates expert FFNs — measured
    82 GB/chip on deepseek-v3; §Perf D5.)"""
    rules = dict(DEFAULT_RULES.rules)
    if getattr(cfg, "moe", None) is not None:
        rules["expert"] = cfg.moe.ep_axis
        rules["expert_ffn"] = ("model",) if "model" not in cfg.moe.ep_axis else None
    return ShardingRules(rules=rules)


def constrain(x: jax.Array, mesh: Mesh | None, *axes: str | None,
              rules: ShardingRules = DEFAULT_RULES):
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if mesh is None or mesh.empty:
        return x
    spec = ParamSpec(shape=tuple(x.shape), axes=tuple(axes))
    return jax.lax.with_sharding_constraint(
        x, spec_to_named_sharding(spec, mesh, rules))
