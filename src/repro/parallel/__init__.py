from repro.parallel.sharding import (  # noqa: F401
    ParamSpec, ShardingRules, DEFAULT_RULES, spec_to_named_sharding,
    logical_to_pspec, init_from_specs, abstract_from_specs, constrain,
)
