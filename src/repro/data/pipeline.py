"""Deterministic, resumable, sharded synthetic token pipeline.

Production shape without production data: batches are generated from a
counter-based PRNG (threefry over (seed, step)) so that (a) any step's batch
is reproducible from (seed, step) alone — the pipeline state in a checkpoint
is just an integer, (b) restart/elastic-reshard resumes mid-epoch exactly,
(c) every host can generate only its addressable shard (no data redistribution
on restore). The synthetic distribution is a Zipf-ish unigram mix so losses
move like real text rather than uniform noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    microbatch: int = 1
    seed: int = 0


class DataPipeline:
    def __init__(self, cfg: DataConfig, mesh=None, rules=None):
        self.cfg = cfg
        self.mesh = mesh
        self.step = 0
        # Zipf-ish unigram distribution, fixed by seed
        rng = np.random.RandomState(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1)
        p = 1.0 / ranks ** 1.1
        self._probs = jnp.asarray(p / p.sum(), jnp.float32)

    def state(self) -> dict:
        return dict(step=self.step, seed=self.cfg.seed)

    def restore(self, state: dict):
        assert state["seed"] == self.cfg.seed, "seed mismatch on resume"
        self.step = int(state["step"])

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step) — the resumability property."""
        c = self.cfg
        g = max(c.microbatch, 1)
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        shape = (g, c.global_batch // g, c.seq_len + 1)
        toks = jax.random.choice(key, c.vocab, shape=shape, p=self._probs)
        toks = toks.astype(jnp.int32)
        batch = dict(tokens=toks[..., :-1], targets=toks[..., 1:])
        if self.mesh is not None:
            from repro.parallel.sharding import constrain
            batch = {k: constrain(v, self.mesh, None, "batch", None)
                     for k, v in batch.items()}
        return batch

    def __next__(self):
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def __iter__(self):
        return self
