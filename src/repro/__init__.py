"""repro: JAX/TPU expert-parallel training & inference framework reproducing
"NCCL EP: Towards a Unified Expert Parallel Communication API for NCCL"."""
from repro import compat as _compat

_compat.install()

__version__ = "0.1.0"
