"""Pallas TPU kernel: fused K-way weighted combine reduction.

Paper §IV-C(c): combine/recv splits warps into reduction groups; a TMA warp
stages K expert responses into shared memory and the rest perform the weighted
reduction as a pipeline. The TPU rendering: the grid walks (token-block,
hidden-block) tiles; each invocation holds a [bt, K, bh] VMEM tile of
responses plus the [bt, K] weights and reduces over K on the VPU in fp32.
Pipelining HBM->VMEM staging against compute is what `pallas_call`'s grid
machinery does natively (the TMA-warp analogue).

VMEM budget per invocation: bt*K*bh*2B (bf16 responses) + bt*bh*4B (f32 acc)
≈ 8*8*512*2 + 8*512*4 = 80 KiB at the default tiling — comfortably inside
the ~16 MiB VMEM of a TPU core, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(y_ref, w_ref, o_ref):
    # y_ref: [bt, K, bh]; w_ref: [bt, K]; o_ref: [bt, bh]
    y = y_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(y * w[:, :, None], axis=1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "bh", "interpret"))
def combine_reduce(y: jax.Array, w: jax.Array, *, bt: int = 8, bh: int = 512,
                   interpret: bool = False) -> jax.Array:
    """y: [T, K, H], w: [T, K] -> [T, H] = sum_k w[t,k] * y[t,k,:].

    Tiling: hidden in lane-aligned bh-wide blocks (bh % 128 == 0), tokens in
    bt-tall blocks (sublane-aligned). K is kept whole inside the tile — K <= 16
    for every assigned architecture, so the tile stays small."""
    T, K, H = y.shape
    bt = min(bt, T)
    bh = min(bh, H)
    assert T % bt == 0 and H % bh == 0, (T, K, H, bt, bh)
    out_dt = y.dtype if y.dtype in (jnp.bfloat16, jnp.float32, jnp.float16) else jnp.bfloat16
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((T, H), out_dt),
        grid=(T // bt, H // bh),
        in_specs=[
            pl.BlockSpec((bt, K, bh), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bt, K), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bh), lambda i, j: (i, j)),
        interpret=interpret,
    )(y, w)
