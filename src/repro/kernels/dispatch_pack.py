"""Pallas TPU kernel: fused dispatch slot-pack + FP8 quantization.

Paper §IV-C(a) "Send Tokens": payload messages are packed into the send
region and (optionally) quantized to FP8 in-kernel, by dedicated warps, before
the RDMA write. The TPU rendering: a scalar-prefetched gather — the slot->token
map (computed by slots.py, the counter analogue) is prefetched into SMEM and
drives the BlockSpec index_map, so each grid step DMAs exactly the token row
its slot needs from HBM into VMEM, quantizes on the VPU, and writes the packed
send-buffer tile. Empty slots (sentinel) are zero-filled — they map to a
guaranteed-zero pad row, keeping the index_map branch-free.

This is the data-movement hot spot of LL dispatch: the fused version touches
each token row exactly (#destination ranks) times with no intermediate
materialization of the [T, H] quantized copy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_quant(gmap_ref, x_ref, q_ref, s_ref, *, block):
    # x_ref: [1, H] the gathered token row; outputs: q [1, H] fp8, s [1, H/block]
    x = x_ref[...].astype(jnp.float32)
    H = x.shape[-1]
    g = x.reshape(H // block, block)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)
    q_ref[...] = (g / scale).reshape(1, H).astype(q_ref.dtype)
    s_ref[...] = scale.reshape(1, -1).astype(jnp.float32)


def _kernel_copy(gmap_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("quant_block", "out_dtype", "interpret"))
def dispatch_pack(x: jax.Array, gmap: jax.Array, *, quant_block: int | None = None,
                  out_dtype=None, interpret: bool = False):
    """x: [T, H]; gmap: [N, C] int32 (sentinel == T -> empty slot).

    Returns packed [N, C, H] (+ scales [N, C, H//quant_block] if quantizing).
    ``out_dtype`` (copy mode) casts the packed payload; None keeps x.dtype.
    """
    T, H = x.shape
    if out_dtype is None:
        out_dtype = x.dtype
    N, C = gmap.shape
    # pad row T is zeros => sentinel slots come out zero
    xp = jnp.concatenate([x, jnp.zeros((1, H), x.dtype)], axis=0)
    flat_map = gmap.reshape(-1)

    grid = (N * C,)
    in_specs = [pl.BlockSpec((1, H), lambda i, m_ref: (m_ref[i], 0))]

    if quant_block is None:
        out = pl.pallas_call(
            _kernel_copy,
            out_shape=jax.ShapeDtypeStruct((N * C, H), out_dtype),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
                out_specs=pl.BlockSpec((1, H), lambda i, m_ref: (i, 0)),
            ),
            interpret=interpret,
        )(flat_map, xp)
        return out.reshape(N, C, H), None

    kern = functools.partial(_kernel_quant, block=quant_block)
    q, s = pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((N * C, H), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((N * C, H // quant_block), jnp.float32),
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=(
                pl.BlockSpec((1, H), lambda i, m_ref: (i, 0)),
                pl.BlockSpec((1, H // quant_block), lambda i, m_ref: (i, 0)),
            ),
        ),
        interpret=interpret,
    )(flat_map, xp)
    return q.reshape(N, C, H), s.reshape(N, C, H // quant_block)
