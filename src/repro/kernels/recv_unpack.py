"""Pallas TPU kernel: fused recv-side slot unpack + FP8 dequantization.

Paper §IV-C(b) "Recv Tokens", the mirror of ``dispatch_pack``: received
payload rows sit at precomputed (pair, slot) coordinates of the receive
buffer; the destination's unpack walks the expert-region map and lands each
row in the 3D expert-major layout, dequantizing FP8 payloads in the same
pass. The TPU rendering: a scalar-prefetched gather — the plan's
``disp_recv_gmap`` (expert slot -> flat receive row) is prefetched into SMEM
and drives the BlockSpec index_map, so each grid step DMAs exactly the
receive-buffer row (and, when quantized, its scale row) that the output slot
needs from HBM into VMEM, dequantizes on the VPU, and writes the unpacked
tile. Empty slots (sentinel == R) map to guaranteed-zero pad rows (zero
payload, zero scales), keeping the index_map branch-free.

This closes the recv half of the one-pass-per-phase invariant: the seed's
unpack was an XLA gather followed by a separate ``dequantize_fp8`` pass,
materializing the full gathered fp8 copy in HBM in between. The fused
version touches each received row exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_copy(gmap_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(o_ref.dtype)


def _kernel_dequant(gmap_ref, q_ref, s_ref, o_ref, *, block):
    # q_ref: [1, H] gathered fp8 row; s_ref: [1, H/block] its scales
    q = q_ref[...].astype(jnp.float32)
    H = q.shape[-1]
    g = q.reshape(H // block, block)
    o_ref[...] = (g * s_ref[0][:, None]).reshape(1, H).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def recv_unpack(recv: jax.Array, gmap: jax.Array, scales: jax.Array | None = None,
                *, out_dtype=None, interpret: bool = False):
    """recv: [R, H] flat received rows; gmap: int32 (any shape, sentinel == R).

    Returns the unpacked rows with shape ``gmap.shape + (H,)``. With
    ``scales`` ([R, H/block] f32) the gathered fp8 payload is dequantized in
    the same pass (``out_dtype`` defaults to bf16); without, rows are gathered
    and cast to ``out_dtype`` (None keeps recv.dtype). Sentinel slots are
    exactly zero either way.
    """
    R, H = recv.shape
    M = gmap.size
    flat_map = gmap.reshape(-1)
    grid = (M,)

    if scales is None:
        if out_dtype is None:
            out_dtype = recv.dtype
        # pad row R is zeros => sentinel slots come out zero
        xp = jnp.concatenate([recv, jnp.zeros((1, H), recv.dtype)], axis=0)
        out = pl.pallas_call(
            _kernel_copy,
            out_shape=jax.ShapeDtypeStruct((M, H), out_dtype),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=grid,
                in_specs=[pl.BlockSpec((1, H), lambda i, m_ref: (m_ref[i], 0))],
                out_specs=pl.BlockSpec((1, H), lambda i, m_ref: (i, 0)),
            ),
            interpret=interpret,
        )(flat_map, xp)
        return out.reshape(gmap.shape + (H,))

    if out_dtype is None:
        out_dtype = jnp.bfloat16
    block = H // scales.shape[-1]
    # zero pad rows for payload AND scales: a sentinel slot dequantizes to
    # exactly 0 * 0 = 0, matching the two-pass reference (gathers fill=0)
    qp = jnp.concatenate([recv, jnp.zeros((1, H), recv.dtype)], axis=0)
    sp = jnp.concatenate([scales, jnp.zeros((1, H // block), scales.dtype)],
                         axis=0)
    kern = functools.partial(_kernel_dequant, block=block)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, H), out_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=[
                pl.BlockSpec((1, H), lambda i, m_ref: (m_ref[i], 0)),
                pl.BlockSpec((1, H // block), lambda i, m_ref: (m_ref[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, H), lambda i, m_ref: (i, 0)),
        ),
        interpret=interpret,
    )(flat_map, qp, sp)
    return out.reshape(gmap.shape + (H,))
