"""Pallas TPU kernel pair: split-KV paged decode attention (flash-decoding).

The decode-side analogue of ``flash_attention.py`` for the paged KV pool
(``models/kv_pages.py``): one query token per request, keys/values scattered
across fixed-size pages addressed by a per-request page table. Shaped like
aiter's ``mla_decode_fwd`` (SNIPPETS.md Snippet 1):

  stage 1 — grid (B, num_kv_splits, pages_per_split), pages innermost. The
    flattened page table is scalar-prefetched into SMEM and drives the K/V
    BlockSpec index_map, so each grid step DMAs exactly one page from HBM
    into VMEM (the recv_unpack gather idiom). Online softmax over the
    split's pages accumulates in VMEM scratch (the flash_attention m/l/acc
    idiom); the split's locally-normalized output and its log-sum-exp are
    written at the last page.
  stage 2 — grid (B,): LSE-weighted reduction across splits.

Determinism contract (what makes page recycling safe): masked positions
contribute an EXACT zero — ``p = where(pos < kv_len, exp(s - m), 0)``, never
exp underflow — so garbage in recycled or pad pages cannot perturb a live
request, and an empty split/request yields o == 0, lse == NEG_INF exactly.
Page tables pad unused entries with the pool's zero pad page (index P), so
the index_map stays branch-free.

Absorbed-MLA decode shares one pool between K and V (``share_kv=True``): the
page payload is [ckv | k_rope] with Hkv == 1, queries attend over the full
row, and values are its first ``dv = r_kv`` columns — each page is read from
HBM exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _stage1_kernel(tbl_ref, lens_ref, q_ref, k_ref, *rest,
                   page, pps, Hkv, G, dv, scale, share_kv):
    if share_kv:
        v_ref = None
        o_ref, lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref = rest
    j = pl.program_id(2)
    b = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = lens_ref[b]
    base = (s * pps + j) * page

    # page-level skip: entirely past the request's live tokens (covers idle
    # slots with kv_len == 0 — their whole walk is skipped and the store
    # emits the exact empty values)
    @pl.when(base < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(Hkv, G, -1)   # [Hkv, G, dk]
        k = k_ref[0].astype(jnp.float32)                        # [page, Hkv, dk]
        v = k[..., :dv] if share_kv else v_ref[0].astype(jnp.float32)
        sc = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale         # [Hkv, G, page]
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (Hkv, G, page), 2)
        valid = pos < kv_len
        sc = jnp.where(valid, sc, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        # exact zero for masked positions — recycled-page garbage and pad
        # pages contribute nothing, not just "something tiny"
        p = jnp.where(valid, jnp.exp(sc - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        ctx = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)                 # [Hkv, G, dv]
        acc_ref[...] = acc_ref[...] * corr[..., None] + ctx
        m_ref[...] = m_new

    @pl.when(j == pps - 1)
    def _store():
        l = l_ref[...]
        live = l > 0
        safe = jnp.where(live, l, 1.0)
        o = jnp.where(live[..., None], acc_ref[...] / safe[..., None], 0.0)
        lse = jnp.where(live, m_ref[...] + jnp.log(safe), NEG_INF)
        o_ref[0, 0] = o.reshape(Hkv * G, dv)
        lse_ref[0, 0] = lse.reshape(Hkv * G)


def _stage2_kernel(o_ref, lse_ref, out_ref):
    o = o_ref[0]                                                # [S, Hq, dv]
    lse = lse_ref[0]                                            # [S, Hq]
    mx = lse.max(axis=0)                                        # [Hq]
    w = jnp.where(lse > NEG_INF / 2, jnp.exp(lse - mx[None, :]), 0.0)
    denom = w.sum(axis=0)                                       # [Hq]
    out = (w[..., None] * o).sum(axis=0)                        # [Hq, dv]
    safe = jnp.where(denom > 0, denom, 1.0)
    out_ref[0] = jnp.where((denom > 0)[:, None], out / safe[:, None], 0.0)


@functools.partial(jax.jit, static_argnames=("scale", "num_kv_splits", "dv",
                                             "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array | None,
                           kv_indices: jax.Array, kv_lens: jax.Array, *,
                           scale: float, num_kv_splits: int = 1,
                           dv: int | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: [B, Hq, dk]; k_pages: [P+1, page, Hkv, dk] (last row = zero pad
    page); v_pages: same layout trailing dv, or None for the absorbed-MLA
    shared pool (then ``dv`` selects the leading value columns of K);
    kv_indices: [B, max_pages] int32 page table padded with P; kv_lens: [B]
    int32 live tokens per request. Returns [B, Hq, dv] f32."""
    B, max_pages = kv_indices.shape
    page, Hkv, dk = k_pages.shape[1:]
    Hq = q.shape[1]
    G = Hq // Hkv
    S = num_kv_splits
    assert max_pages % S == 0, (max_pages, S)
    pps = max_pages // S
    share_kv = v_pages is None
    if share_kv:
        assert dv is not None and Hkv == 1
    else:
        dv = v_pages.shape[-1]

    flat_tbl = kv_indices.reshape(-1).astype(jnp.int32)
    lens = kv_lens.astype(jnp.int32)

    kern = functools.partial(_stage1_kernel, page=page, pps=pps, Hkv=Hkv,
                             G=G, dv=dv, scale=scale, share_kv=share_kv)
    k_spec = pl.BlockSpec(
        (1, page, Hkv, dk),
        lambda b, s, j, tbl, lens: (tbl[b * max_pages + s * pps + j], 0, 0, 0))
    in_specs = [pl.BlockSpec((1, Hq, dk), lambda b, s, j, tbl, lens: (b, 0, 0)),
                k_spec]
    operands = [q, k_pages]
    if not share_kv:
        in_specs.append(pl.BlockSpec(
            (1, page, Hkv, dv),
            lambda b, s, j, tbl, lens: (tbl[b * max_pages + s * pps + j],
                                        0, 0, 0)))
        operands.append(v_pages)

    o_parts, lse = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((B, S, Hq, dv), jnp.float32),
                   jax.ShapeDtypeStruct((B, S, Hq), jnp.float32)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(B, S, pps),
            in_specs=in_specs,
            out_specs=(
                pl.BlockSpec((1, 1, Hq, dv),
                             lambda b, s, j, tbl, lens: (b, s, 0, 0)),
                pl.BlockSpec((1, 1, Hq),
                             lambda b, s, j, tbl, lens: (b, s, 0)),
            ),
            scratch_shapes=[
                pltpu.VMEM((Hkv, G), jnp.float32),
                pltpu.VMEM((Hkv, G), jnp.float32),
                pltpu.VMEM((Hkv, G, dv), jnp.float32),
            ],
        ),
        interpret=interpret,
    )(flat_tbl, lens, *operands)

    return pl.pallas_call(
        _stage2_kernel,
        out_shape=jax.ShapeDtypeStruct((B, Hq, dv), jnp.float32),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S, Hq, dv), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, S, Hq), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, dv), lambda b: (b, 0, 0)),
        interpret=interpret,
    )(o_parts, lse)
