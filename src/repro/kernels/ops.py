"""Jit'd public wrappers for the kernels package.

Backend selection: on TPU the Pallas kernels run compiled; elsewhere the
pure-jnp oracles from ref.py are used (bitwise-identical semantics — the test
suite asserts so under interpret mode). `REPRO_FORCE_PALLAS=interpret` forces
interpret-mode Pallas everywhere (slow; used by kernel tests and debugging).

Every EP hot-path op is fused single-pass on TPU: dispatch_pack (slot gather
+ fp8 quant), recv_unpack (slot gather + fp8 dequant, its recv-side mirror),
combine_gather_reduce (slot gather + K-way weighted reduce), combine_reduce,
quantize/dequantize_fp8, grouped_gemm, flash attention.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import combine_reduce as _cr
from repro.kernels import combine_gather_reduce as _cgr
from repro.kernels import dispatch_pack as _dp
from repro.kernels import fp8 as _fp8
from repro.kernels import grouped_gemm as _gg
from repro.kernels import recv_unpack as _ru


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "interpret":
        return True, True
    if force == "off":
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    return on_tpu, False


def combine_reduce(y: jax.Array, w: jax.Array) -> jax.Array:
    use, interp = _use_pallas()
    T, K, H = y.shape
    if use and T % 8 == 0 and H % 128 == 0:
        return _cr.combine_reduce(y, w, interpret=interp)
    return _ref.combine_reduce(y, w)


def combine_gather_reduce(recv: jax.Array, rows: jax.Array, w: jax.Array) -> jax.Array:
    """Fused gather-through-slot-rows + weighted top-k reduction.

    recv: [R, H] flat received rows; rows: [T, K] int32 (sentinel == R);
    w: [T, K] -> [T, H]. One pass; no [T, K, H] materialization on TPU."""
    use, interp = _use_pallas()
    H = recv.shape[-1]
    if use and H % 128 == 0:
        return _cgr.combine_gather_reduce(recv, rows, w, interpret=interp)
    return _ref.combine_gather_reduce(recv, rows, w)


def quantize_fp8(x: jax.Array, block: int = 128):
    use, interp = _use_pallas()
    H = x.shape[-1]
    M = math.prod(x.shape[:-1])
    if use and H % block == 0 and block % 128 == 0 and M > 0 and M % 8 == 0:
        q, s = _fp8.quantize_fp8(x.reshape(M, H), block, interpret=interp)
        return q.reshape(x.shape), s.reshape(x.shape[:-1] + (H // block,))
    return _ref.quantize_fp8(x, block)


def dequantize_fp8(q: jax.Array, scales: jax.Array, out_dtype=jnp.bfloat16):
    use, interp = _use_pallas()
    H = q.shape[-1]
    M = math.prod(q.shape[:-1])
    block = H // scales.shape[-1] if scales.shape[-1] else 0
    if (use and block and H % block == 0 and block % 128 == 0
            and M > 0 and M % 8 == 0):
        out = _fp8.dequantize_fp8(q.reshape(M, H), scales.reshape(M, H // block),
                                  out_dtype, interpret=interp)
        return out.reshape(q.shape)
    return _ref.dequantize_fp8(q, scales, out_dtype)


def dispatch_pack(x: jax.Array, gmap: jax.Array, quant_block: int | None = None,
                  out_dtype=None):
    """Fused slot-pack (+ optional fp8 quantization) over a [N, C] slot map.

    ``out_dtype`` (copy mode only) casts the packed payload; None keeps
    x.dtype. Quantizing always yields (f8e4m3 payload, f32 scales)."""
    use, interp = _use_pallas()
    if use and x.shape[-1] % 128 == 0:
        return _dp.dispatch_pack(x, gmap, quant_block=quant_block,
                                 out_dtype=out_dtype, interpret=interp)
    return _ref.dispatch_pack(x, gmap, quant_block, out_dtype)


def recv_unpack(recv: jax.Array, gmap: jax.Array, scales: jax.Array | None = None,
                out_dtype=None):
    """Fused recv-side slot unpack (+ optional fp8 dequantization) — the
    mirror of dispatch_pack. recv: [R, H] flat received rows; gmap: int32
    slot map of any shape (sentinel == R); scales: [R, H/block] f32 when the
    payload is quantized. One pass; no intermediate gathered-fp8 copy."""
    use, interp = _use_pallas()
    H = recv.shape[-1]
    if scales is not None:
        block = H // scales.shape[-1] if scales.shape[-1] else 0
        ok = bool(block) and H % block == 0 and block % 128 == 0
    else:
        ok = H % 128 == 0
    if use and ok:
        return _ru.recv_unpack(recv, gmap, scales, out_dtype=out_dtype,
                               interpret=interp)
    return _ref.recv_unpack(recv, gmap, scales, out_dtype)


def flash_attention_bshd(q, k, v, *, scale, window=None, causal=True):
    """[B,S,H,d]-layout wrapper over the flash-attention kernel (TPU) with
    the chunked-XLA formulation as the portable fallback (same math)."""
    use, interp = _use_pallas()
    hd = q.shape[-1]
    if use and q.shape[1] % 128 == 0 and k.shape[1] % 128 == 0:
        from repro.kernels import flash_attention as _fa
        out = _fa.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), scale=scale, window=window,
            causal=causal, interpret=interp)
        return out.transpose(0, 2, 1, 3)
    from repro.models.attention import _sdpa_chunked
    return _sdpa_chunked(q, k, v, None, scale, window)


def paged_decode_attention(q, k_pages, v_pages, kv_indices, kv_lens, *,
                           scale, num_kv_splits=1, dv=None):
    """Split-KV paged decode attention over a page-table-indexed KV pool.

    q: [B, Hq, dk]; k_pages: [P+1, page, Hkv, dk] (last row = zero pad
    page); v_pages: same layout with trailing dv, or None for the
    absorbed-MLA shared pool (values = leading ``dv`` key columns);
    kv_indices: [B, max_pages] int32 padded with P; kv_lens: [B] int32.
    Returns [B, Hq, dv] f32. Two-stage flash-decoding on TPU; jnp oracle
    elsewhere (identical masking semantics — exact zeros off the live
    prefix, so both backends are safe over recycled pages)."""
    use, interp = _use_pallas()
    page = k_pages.shape[1]
    dk = k_pages.shape[-1]
    dvv = dv if v_pages is None else v_pages.shape[-1]
    if use and dk % 128 == 0 and dvv % 128 == 0 and page % 8 == 0:
        from repro.kernels import decode_attention as _da
        return _da.paged_decode_attention(
            q, k_pages, v_pages, kv_indices, kv_lens, scale=scale,
            num_kv_splits=num_kv_splits, dv=dv, interpret=interp)
    return _ref.paged_decode_attention(
        q, k_pages, v_pages, kv_indices, kv_lens, scale=scale,
        num_kv_splits=num_kv_splits, dv=dv)


def grouped_gemm(x: jax.Array, w: jax.Array, counts: jax.Array) -> jax.Array:
    use, interp = _use_pallas()
    L, A, H = x.shape
    F = w.shape[-1]
    if use and A % 128 == 0 and F % 128 == 0 and H % 128 == 0:
        return _gg.grouped_gemm(x, w, counts, interpret=interp)
    return _ref.grouped_gemm(x, w, counts)
