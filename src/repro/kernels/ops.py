"""Jit'd public wrappers for the kernels package.

Backend selection: on TPU the Pallas kernels run compiled; elsewhere the
pure-jnp oracles from ref.py are used (bitwise-identical semantics — the test
suite asserts so under interpret mode). `REPRO_FORCE_PALLAS=interpret` forces
interpret-mode Pallas everywhere (slow; used by kernel tests and debugging).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import combine_reduce as _cr
from repro.kernels import dispatch_pack as _dp
from repro.kernels import grouped_gemm as _gg


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "interpret":
        return True, True
    if force == "off":
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    return on_tpu, False


def combine_reduce(y: jax.Array, w: jax.Array) -> jax.Array:
    use, interp = _use_pallas()
    T, K, H = y.shape
    if use and T % 8 == 0 and H % 128 == 0:
        return _cr.combine_reduce(y, w, interpret=interp)
    return _ref.combine_reduce(y, w)


def quantize_fp8(x: jax.Array, block: int = 128):
    return _ref.quantize_fp8(x, block)


def dequantize_fp8(q: jax.Array, scales: jax.Array, out_dtype=jnp.bfloat16):
    return _ref.dequantize_fp8(q, scales, out_dtype)


def dispatch_pack(x: jax.Array, gmap: jax.Array, quant_block: int | None = None):
    use, interp = _use_pallas()
    if use and x.shape[-1] % 128 == 0:
        return _dp.dispatch_pack(x, gmap, quant_block=quant_block, interpret=interp)
    return _ref.dispatch_pack(x, gmap, quant_block)


def flash_attention_bshd(q, k, v, *, scale, window=None, causal=True):
    """[B,S,H,d]-layout wrapper over the flash-attention kernel (TPU) with
    the chunked-XLA formulation as the portable fallback (same math)."""
    use, interp = _use_pallas()
    hd = q.shape[-1]
    if use and q.shape[1] % 128 == 0 and k.shape[1] % 128 == 0:
        from repro.kernels import flash_attention as _fa
        out = _fa.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), scale=scale, window=window,
            causal=causal, interpret=interp)
        return out.transpose(0, 2, 1, 3)
    from repro.models.attention import _sdpa_chunked
    return _sdpa_chunked(q, k, v, None, scale, window)


def grouped_gemm(x: jax.Array, w: jax.Array, counts: jax.Array) -> jax.Array:
    use, interp = _use_pallas()
    L, A, H = x.shape
    F = w.shape[-1]
    if use and A % 128 == 0 and F % 128 == 0 and H % 128 == 0:
        return _gg.grouped_gemm(x, w, counts, interpret=interp)
    return _ref.grouped_gemm(x, w, counts)
