"""Pallas TPU kernel: fused slot-gather + K-way weighted combine reduction.

Paper §IV-C(c) combine/recv: responses for token t sit at precomputed slots
of the receive buffer; a TMA warp stages the K rows and reduction warps apply
the gate-weighted sum. The TPU rendering: the slot rows (the EpPlan's
``comb_recv_rows`` — the counter arithmetic's output) are scalar-prefetched
into SMEM and drive the input BlockSpec index_map, so each grid step DMAs
exactly the receive-buffer row the (t, k) entry needs, multiplies by the gate
weight on the VPU, and accumulates into a VMEM fp32 scratch tile; the k
innermost grid dimension revisits the same output tile, which pallas keeps
resident. Sentinel rows (== R) hit a guaranteed-zero pad row, keeping the
index_map branch-free — a dropped entry contributes exactly zero.

This replaces the seed's two-pass gather-then-reduce, which materialized the
full [T, K, H] response tensor in HBM between the passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, y_ref, w_ref, o_ref, acc_ref, *, K):
    # y_ref: [1, bh] the gathered recv row for entry (t, k); w_ref: [1, K]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += (y_ref[...].astype(jnp.float32)
                     * w_ref[0, k].astype(jnp.float32))

    @pl.when(k == K - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def combine_gather_reduce(recv: jax.Array, rows: jax.Array, w: jax.Array, *,
                          bh: int = 512, interpret: bool = False) -> jax.Array:
    """recv: [R, H] flat received rows; rows: [T, K] int32 slot rows with
    sentinel == R meaning "no contribution"; w: [T, K] gate weights.
    Returns [T, H] = sum_k w[t,k] * recv[rows[t,k]] in fp32 accumulation.

    Grid (T, H/bh, K): hidden in lane-aligned bh-wide blocks, K innermost so
    the output tile stays VMEM-resident across the reduction."""
    R, H = recv.shape
    T, K = rows.shape
    bh = min(bh, H)
    while H % bh != 0:        # largest lane-aligned tile dividing H
        bh -= 128
    assert bh > 0 and H % bh == 0, (H, bh)
    # pad row R is zeros => sentinel entries contribute zero
    recv_p = jnp.concatenate([recv, jnp.zeros((1, H), recv.dtype)], axis=0)
    out_dt = (recv.dtype if recv.dtype in (jnp.bfloat16, jnp.float32, jnp.float16)
              else jnp.bfloat16)
    kern = functools.partial(_kernel, K=K)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((T, H), out_dt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(T, H // bh, K),
            in_specs=[
                pl.BlockSpec((1, bh), lambda t, j, k, rows_ref: (rows_ref[t * K + k], j)),
                pl.BlockSpec((1, K), lambda t, j, k, rows_ref: (t, 0)),
            ],
            out_specs=pl.BlockSpec((1, bh), lambda t, j, k, rows_ref: (t, j)),
            scratch_shapes=[pltpu.VMEM((1, bh), jnp.float32)],
        ),
        interpret=interpret,
    )(rows.reshape(-1), recv_p, w)
