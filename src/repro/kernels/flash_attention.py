"""Pallas TPU kernel: causal (optionally sliding-window) flash attention.

The fused online-softmax pipeline whose HBM traffic is exactly Q+K+V+O — the
[Sq, Sk] score matrix lives only as VMEM tiles. This is the TPU
implementation of record for the attention sublayer; the pure-XLA chunked
formulation in models/attention.py computes the same function (and is what
the CPU-hosted dry-run lowers), but XLA's fusion-blind cost model charges it
full score-matrix traffic — the roofline's kernel-corrected memory term uses
THIS kernel's Q/K/V/O byte count for the attention region (docs/EXPERIMENTS.md
§Roofline notes).

Tiling: grid (B, Hq, Sq/bq, Sk/bk), KV innermost; m/l/acc accumulators in
VMEM scratch persist across the KV walk; GQA is handled in the index_map
(kv head = q head // G — no KV repetition in HBM). Fully-masked KV tiles are
skipped via pl.when (the causal compute saving). MXU-aligned: bq, bk are
128-multiples; hd padded by the caller if needed.

VMEM/invocation ≈ bq*hd + bk*hd (in) + bq*bk (scores) + bq*(hd+2) (scratch)
at f32 ≈ 128*128*4*2 + 128*512*4 + ... ≈ 0.5 MiB — far under budget, so the
pipeline can double-buffer the K/V streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq, bk, nk, scale, window, causal):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * bq
    k_start = j * bk
    # tile-level skips: entirely-in-the-future (causal) or entirely outside
    # the sliding window — the flash compute saving.
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window is not None:
        live &= (q_start - (k_start + bk - 1)) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)           # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "causal",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, window: int | None = None,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Sq, d]; k/v: [B, Hkv, Sk, d] -> [B, Hq, Sq, d]."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nk = Sk // bk
    kern = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                             window=window, causal=causal)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, d), q.dtype),
        grid=(B, Hq, Sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def hbm_bytes(B, Hq, Hkv, Sq, Sk, d, dtype_bytes=2) -> int:
    """The kernel's definitional HBM traffic: Q + K + V + O, each once."""
    return dtype_bytes * (B * Hq * Sq * d * 2 + B * Hkv * Sk * d * 2)
