"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each Pallas kernel's test sweeps shapes and
dtypes and asserts allclose against the function of the same name here. They
are also the production path on non-TPU backends (interpret-mode Pallas is
orders of magnitude slower on CPU; XLA fuses these fine there).

``positions_by_dest`` is the one exception to the "Pallas oracle" rule: it is
the O(M·D) one-hot-cumsum oracle for the sort-based O(M log M) production
implementation in ``repro.core.slots`` (bitwise-identical by contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def positions_by_dest(dest: jax.Array, num_dest: int, valid: jax.Array):
    """One-hot-cumsum slot-position oracle (the seed implementation).

    O(M·D) — kept as the semantics of record for
    ``repro.core.slots.positions_by_dest``; tests assert the sort-based
    production version matches this bit for bit on every entry, including
    invalid and out-of-range destinations."""
    oh = jax.nn.one_hot(dest, num_dest, dtype=jnp.int32) * valid[:, None].astype(jnp.int32)
    incl = jnp.cumsum(oh, axis=0)
    pos = jnp.take_along_axis(incl - oh, dest[:, None].clip(0, num_dest - 1), axis=1)[:, 0]
    counts = incl[-1] if dest.shape[0] > 0 else jnp.zeros((num_dest,), jnp.int32)
    return pos.astype(jnp.int32), counts.astype(jnp.int32)


def combine_reduce(y: jax.Array, w: jax.Array) -> jax.Array:
    """Weighted K-way reduction — paper §IV-C(c) combine/recv.

    y: [T, K, H] expert responses (any float dtype), w: [T, K] gate weights.
    Returns [T, H] in w-independent f32 accumulation, cast to y.dtype's
    "compute" dtype (bf16 stays bf16, matching the paper's BF16 combine)."""
    acc = jnp.einsum("tkh,tk->th", y.astype(jnp.float32), w.astype(jnp.float32))
    out_dt = y.dtype if y.dtype in (jnp.bfloat16, jnp.float32, jnp.float16) else jnp.bfloat16
    return acc.astype(out_dt)


def combine_gather_reduce(recv: jax.Array, rows: jax.Array, w: jax.Array) -> jax.Array:
    """Fused gather + weighted K-way reduction — combine/recv without the
    [T, K, H] materialization.

    recv: [R, H] flat received rows; rows: [T, K] int32 with sentinel == R
    meaning "no contribution"; w: [T, K] gate weights. Returns [T, H] =
    sum_k w[t,k] * recv[rows[t,k]] (sentinel rows contribute zero)."""
    pad = jnp.zeros((1, recv.shape[-1]), recv.dtype)
    y = jnp.concatenate([recv, pad], axis=0)[rows]          # [T, K, H]
    return combine_reduce(y, w)


def quantize_fp8(x: jax.Array, block: int = 128):
    """Block-wise FP8(e4m3) quantization — the paper's in-kernel dispatch
    quantization (§IV-B: token data fp8 + 4-byte scales per 128 elements).

    x: [..., H] with H % block == 0 -> (q [..., H] f8e4m3, scales [..., H/block] f32)."""
    H = x.shape[-1]
    assert H % block == 0, (H, block)
    g = x.reshape(x.shape[:-1] + (H // block, block)).astype(jnp.float32)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)
    q = (g / scale).astype(jnp.float8_e4m3fn)
    return q.reshape(x.shape), scale[..., 0].astype(jnp.float32)


def dequantize_fp8(q: jax.Array, scales: jax.Array, out_dtype=jnp.bfloat16):
    """Inverse of quantize_fp8. q: [..., H], scales: [..., H/block]."""
    H = q.shape[-1]
    block = H // scales.shape[-1]
    g = q.reshape(q.shape[:-1] + (H // block, block)).astype(jnp.float32)
    out = g * scales[..., None]
    return out.reshape(q.shape).astype(out_dtype)


def dispatch_pack(x: jax.Array, gmap: jax.Array, quant_block: int | None = None,
                  out_dtype=None):
    """Fused slot-pack (+ optional quantization) — paper §IV-C(a) Send Tokens.

    x: [T, H] tokens; gmap: [N, C] int32 slot->token map with sentinel == T
    meaning empty. Returns packed [N, C, H] (and scales [N, C, H/qb] if
    quantizing). Empty slots are zero. ``out_dtype`` (copy mode only) casts
    the packed payload; None keeps x.dtype."""
    T, H = x.shape
    if quant_block is not None:
        xq, sc = quantize_fp8(x, quant_block)
        xp = jnp.concatenate([xq, jnp.zeros((1, H), xq.dtype)], 0)
        # empty slots: zero payload, unit scale (== quantizing a zero row)
        sp = jnp.concatenate([sc, jnp.ones((1, sc.shape[-1]), sc.dtype)], 0)
        return xp[gmap], sp[gmap]
    xp = jnp.concatenate([x, jnp.zeros((1, H), x.dtype)], 0)
    out = xp[gmap]
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return out, None


def recv_unpack(recv: jax.Array, gmap: jax.Array, scales: jax.Array | None = None,
                out_dtype=None):
    """Fused recv-side unpack — paper §IV-C(b) Recv Tokens (dispatch_pack's
    mirror).

    recv: [R, H] flat received rows; gmap: int32 of any shape with sentinel
    == R meaning "empty slot"; scales: [R, H/block] f32 when the payload is
    fp8-quantized. Returns ``gmap.shape + (H,)``: the gathered rows,
    dequantized when scales are given (out_dtype defaults to bf16 then; in
    copy mode None keeps recv.dtype). Sentinel slots are exactly zero."""
    R, H = recv.shape
    pad = jnp.zeros((1, H), recv.dtype)
    rows = jnp.concatenate([recv, pad], axis=0)[gmap]
    if scales is None:
        return rows if out_dtype is None else rows.astype(out_dtype)
    spad = jnp.zeros((1, scales.shape[-1]), scales.dtype)
    sc = jnp.concatenate([scales, spad], axis=0)[gmap]
    return dequantize_fp8(rows, sc, out_dtype or jnp.bfloat16)


NEG_INF = -1e30


def paged_decode_stage1(q, k_pages, v_pages, kv_indices, kv_lens, *,
                        scale, num_kv_splits, dv=None):
    """Stage 1 of split-KV paged decode attention: per-(request, split)
    partial outputs + log-sum-exp (the aiter ``mla_stage1`` shape).

    q: [B, Hq, dk] one decode query per request. k_pages: [P+1, page, Hkv,
    dk] paged key pool whose LAST row is the zero pad page. v_pages: same
    layout with trailing dv — or None for the absorbed-MLA shared pool,
    where values are the first ``dv`` key columns (Hkv == 1, one pool read).
    kv_indices: [B, max_pages] int32 per-request page table, padded with the
    pad-page index P. kv_lens: [B] int32 valid tokens per request (0 for an
    idle slot). max_pages must divide by num_kv_splits.

    Returns (o [B, S, Hq, dv] f32 split-local softmax outputs, lse [B, S,
    Hq] f32). Empty splits yield o == 0 and lse == NEG_INF exactly; masked
    positions contribute an exact 0 (explicit ``where``, not exp underflow),
    so recycled-page garbage can never leak into a live request."""
    B, max_pages = kv_indices.shape
    page, Hkv, dk = k_pages.shape[1:]
    Hq = q.shape[1]
    G = Hq // Hkv
    S = num_kv_splits
    assert max_pages % S == 0, (max_pages, S)
    if v_pages is None:
        assert dv is not None and Hkv == 1
        v_pages = k_pages[..., :dv]
    dv = v_pages.shape[-1]
    k = k_pages[kv_indices].reshape(B, max_pages * page, Hkv, dk)
    v = v_pages[kv_indices].reshape(B, max_pages * page, Hkv, dv)
    qg = q.reshape(B, Hkv, G, dk).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32)) * scale
    pos = jnp.arange(max_pages * page)
    valid = pos[None, :] < kv_lens[:, None]                 # [B, Stot]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    # split the KV axis: [B, Hkv, G, S, pps*page]
    sc = s.reshape(B, Hkv, G, S, -1)
    vc = v.reshape(B, S, -1, Hkv, dv).astype(jnp.float32)
    mc = valid.reshape(B, 1, 1, S, -1)
    m = sc.max(-1)                                          # [B, Hkv, G, S]
    p = jnp.where(mc, jnp.exp(sc - m[..., None]), 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bhgsk,bskhv->bhgsv", p, vc)
    o = jnp.where((l > 0)[..., None], acc / jnp.where(l > 0, l, 1.0)[..., None], 0.0)
    lse = jnp.where(l > 0, m + jnp.log(jnp.where(l > 0, l, 1.0)), NEG_INF)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, dv)
    lse = lse.transpose(0, 3, 1, 2).reshape(B, S, Hq)
    return o, lse


def paged_decode_stage2(o_parts, lse):
    """Stage 2: LSE-weighted reduction across KV splits (the aiter
    ``_fwd_kernel_stage2`` shape). o_parts: [B, S, Hq, dv] f32, lse: [B, S,
    Hq] f32 -> [B, Hq, dv] f32. Splits with lse == NEG_INF (empty) get
    exactly zero weight; a fully-empty request returns exactly zero."""
    mx = lse.max(axis=1)                                    # [B, Hq]
    live = lse > NEG_INF / 2
    w = jnp.where(live, jnp.exp(lse - mx[:, None]), 0.0)    # [B, S, Hq]
    denom = w.sum(axis=1)                                   # [B, Hq]
    out = jnp.einsum("bsh,bshv->bhv", w, o_parts)
    safe = jnp.where(denom > 0, denom, 1.0)
    return jnp.where((denom > 0)[..., None], out / safe[..., None], 0.0)


def paged_decode_attention(q, k_pages, v_pages, kv_indices, kv_lens, *,
                           scale, num_kv_splits=1, dv=None):
    """Two-stage split-KV paged decode attention over a page-table-indexed
    KV pool — the jnp semantics of record for
    ``kernels/decode_attention.py``. Returns [B, Hq, dv] f32."""
    o, lse = paged_decode_stage1(q, k_pages, v_pages, kv_indices, kv_lens,
                                 scale=scale, num_kv_splits=num_kv_splits,
                                 dv=dv)
    return paged_decode_stage2(o, lse)


def grouped_gemm(x: jax.Array, w: jax.Array, counts: jax.Array) -> jax.Array:
    """Expert-major grouped GEMM over the LL 3D layout (§III-E, Fig. 3).

    x: [L, A, H], w: [L, H, F], counts: [L] valid rows per expert.
    Rows >= counts[l] produce zeros (padding is never computed into output)."""
    L, A, H = x.shape
    out = jnp.einsum("lah,lhf->laf", x.astype(jnp.float32), w.astype(jnp.float32))
    mask = jnp.arange(A)[None, :] < counts[:, None]
    return jnp.where(mask[..., None], out, 0.0).astype(x.dtype if x.dtype != jnp.float8_e4m3fn else jnp.bfloat16)
