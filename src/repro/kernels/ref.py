"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each Pallas kernel's test sweeps shapes and
dtypes and asserts allclose against the function of the same name here. They
are also the production path on non-TPU backends (interpret-mode Pallas is
orders of magnitude slower on CPU; XLA fuses these fine there).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def combine_reduce(y: jax.Array, w: jax.Array) -> jax.Array:
    """Weighted K-way reduction — paper §IV-C(c) combine/recv.

    y: [T, K, H] expert responses (any float dtype), w: [T, K] gate weights.
    Returns [T, H] in w-independent f32 accumulation, cast to y.dtype's
    "compute" dtype (bf16 stays bf16, matching the paper's BF16 combine)."""
    acc = jnp.einsum("tkh,tk->th", y.astype(jnp.float32), w.astype(jnp.float32))
    out_dt = y.dtype if y.dtype in (jnp.bfloat16, jnp.float32, jnp.float16) else jnp.bfloat16
    return acc.astype(out_dt)


def quantize_fp8(x: jax.Array, block: int = 128):
    """Block-wise FP8(e4m3) quantization — the paper's in-kernel dispatch
    quantization (§IV-B: token data fp8 + 4-byte scales per 128 elements).

    x: [..., H] with H % block == 0 -> (q [..., H] f8e4m3, scales [..., H/block] f32)."""
    H = x.shape[-1]
    assert H % block == 0, (H, block)
    g = x.reshape(x.shape[:-1] + (H // block, block)).astype(jnp.float32)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)
    q = (g / scale).astype(jnp.float8_e4m3fn)
    return q.reshape(x.shape), scale[..., 0].astype(jnp.float32)


def dequantize_fp8(q: jax.Array, scales: jax.Array, out_dtype=jnp.bfloat16):
    """Inverse of quantize_fp8. q: [..., H], scales: [..., H/block]."""
    H = q.shape[-1]
    block = H // scales.shape[-1]
    g = q.reshape(q.shape[:-1] + (H // block, block)).astype(jnp.float32)
    out = g * scales[..., None]
    return out.reshape(q.shape).astype(out_dtype)


def dispatch_pack(x: jax.Array, gmap: jax.Array, quant_block: int | None = None):
    """Fused slot-pack (+ optional quantization) — paper §IV-C(a) Send Tokens.

    x: [T, H] tokens; gmap: [N, C] int32 slot->token map with sentinel == T
    meaning empty. Returns packed [N, C, H] (and scales [N, C, H/qb] if
    quantizing). Empty slots are zero."""
    T, H = x.shape
    if quant_block is not None:
        xq, sc = quantize_fp8(x, quant_block)
        xp = jnp.concatenate([xq, jnp.zeros((1, H), xq.dtype)], 0)
        # empty slots: zero payload, unit scale (== quantizing a zero row)
        sp = jnp.concatenate([sc, jnp.ones((1, sc.shape[-1]), sc.dtype)], 0)
        return xp[gmap], sp[gmap]
    xp = jnp.concatenate([x, jnp.zeros((1, H), x.dtype)], 0)
    return xp[gmap], None


def grouped_gemm(x: jax.Array, w: jax.Array, counts: jax.Array) -> jax.Array:
    """Expert-major grouped GEMM over the LL 3D layout (§III-E, Fig. 3).

    x: [L, A, H], w: [L, H, F], counts: [L] valid rows per expert.
    Rows >= counts[l] produce zeros (padding is never computed into output)."""
    L, A, H = x.shape
    out = jnp.einsum("lah,lhf->laf", x.astype(jnp.float32), w.astype(jnp.float32))
    mask = jnp.arange(A)[None, :] < counts[:, None]
    return jnp.where(mask[..., None], out, 0.0).astype(x.dtype if x.dtype != jnp.float8_e4m3fn else jnp.bfloat16)
