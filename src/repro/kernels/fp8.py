"""Pallas TPU kernels: block-wise FP8(e4m3) quantize / dequantize.

Paper §IV-B: dispatch payloads travel as fp8 token data plus one 4-byte scale
per 128 elements, computed in-kernel. Standalone quantize/dequantize passes
are still needed off the fused-pack path (dequantization of received rows,
re-quantization of expert outputs), and previously always fell back to the
pure-jnp oracle; these kernels close that gap. The grid walks (row-block,
hidden-block) tiles with the hidden block a multiple of the quant block, so
each invocation computes whole scale groups on the VPU: amax over each
``block``-wide group, scale = amax/448 (e4m3 max normal), payload = value /
scale. Zero groups get unit scale, matching the oracle bit for bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, block):
    x = x_ref[...].astype(jnp.float32)                  # [bm, bh]
    bm, bh = x.shape
    g = x.reshape(bm, bh // block, block)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)
    q_ref[...] = (g / scale).reshape(bm, bh).astype(q_ref.dtype)
    s_ref[...] = scale[..., 0].astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref, *, block):
    q = q_ref[...].astype(jnp.float32)                  # [bm, bh]
    bm, bh = q.shape
    g = q.reshape(bm, bh // block, block)
    o_ref[...] = (g * s_ref[...][..., None]).reshape(bm, bh).astype(o_ref.dtype)


def _pick_bh(H: int, block: int, bh: int | None) -> int:
    """Largest whole-scale-group tile <= the requested bh that divides H
    (callers guarantee H % block == 0, so bh == block always works)."""
    bh = min(bh or max(block, 512), H)
    bh = (bh // block) * block
    while H % bh != 0:
        bh -= block
    return bh


@functools.partial(jax.jit, static_argnames=("block", "bm", "bh", "interpret"))
def quantize_fp8(x: jax.Array, block: int = 128, *, bm: int = 8,
                 bh: int | None = None, interpret: bool = False):
    """x: [M, H] with H % block == 0 and M % bm == 0 ->
    (q [M, H] f8e4m3, scales [M, H/block] f32)."""
    M, H = x.shape
    bh = _pick_bh(H, block, bh)
    bm = min(bm, M)
    assert M % bm == 0 and H % bh == 0 and bh % block == 0, (M, H, bm, bh, block)
    kern = functools.partial(_quant_kernel, block=block)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((M, H), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((M, H // block), jnp.float32),
        ),
        grid=(M // bm, H // bh),
        in_specs=[pl.BlockSpec((bm, bh), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((bm, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bh // block), lambda i, j: (i, j)),
        ),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("out_dtype", "bm", "bh", "interpret"))
def dequantize_fp8(q: jax.Array, scales: jax.Array, out_dtype=jnp.bfloat16, *,
                   bm: int = 8, bh: int | None = None, interpret: bool = False):
    """Inverse of quantize_fp8. q: [M, H], scales: [M, H/block] -> [M, H]."""
    M, H = q.shape
    block = H // scales.shape[-1]
    bh = _pick_bh(H, block, bh)
    bm = min(bm, M)
    assert M % bm == 0 and H % bh == 0 and bh % block == 0, (M, H, bm, bh, block)
    kern = functools.partial(_dequant_kernel, block=block)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, H), out_dtype),
        grid=(M // bm, H // bh),
        in_specs=[
            pl.BlockSpec((bm, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bh // block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bh), lambda i, j: (i, j)),
        interpret=interpret,
    )(q, scales)
