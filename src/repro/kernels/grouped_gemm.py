"""Pallas TPU kernel: expert-major grouped GEMM over the LL/HT 3D layout.

Consumes the dispatch output [L, A, H] (tokens grouped by local expert,
padded to capacity A) against per-expert weights [L, H, F]. Per-expert valid
row counts are scalar-prefetched; tiles that lie entirely beyond an expert's
count are *skipped* (output zeroed, no MXU work) — the static-shape analogue
of DeepEP's grouped GEMM consuming only m(e,r) valid rows.

Tiling: (expert, A/bm, F/bn, H/bk) grid, MXU-aligned 128x128 output tiles with
a bk-deep reduction loop accumulating in fp32 VMEM scratch. The weight tile
[bk, bn] is revisited across the A dimension (standard output-stationary
schedule); XLA's grid pipeliner double-buffers the HBM->VMEM streams.

VMEM/invocation ≈ bm*bk + bk*bn (bf16) + bm*bn (f32) = 128*512*2*2 + 128*128*4
≈ 320 KiB — well within budget, sized so the MXU sees 128-multiples always.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(counts_ref, x_ref, w_ref, o_ref, acc_ref, *, bm, bk, nk):
    l = pl.program_id(0)
    i = pl.program_id(1)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip MXU work for tiles fully beyond this expert's valid rows.
    live = (i * bm) < counts_ref[l]

    @pl.when(live)
    def _compute():
        acc_ref[0] += jnp.dot(
            x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        # zero rows beyond the count inside a partially-live tile
        row = i * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref[0].shape, 0)
        o_ref[0] = jnp.where(row < counts_ref[l], acc_ref[0], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_gemm(x: jax.Array, w: jax.Array, counts: jax.Array, *,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = False) -> jax.Array:
    """x: [L, A, H] @ w: [L, H, F] -> [L, A, F], rows >= counts[l] zeroed."""
    L, A, H = x.shape
    _, _, F = w.shape
    bm, bn, bk = min(bm, A), min(bn, F), min(bk, H)
    assert A % bm == 0 and F % bn == 0 and H % bk == 0, (x.shape, w.shape, bm, bn, bk)
    nk = H // bk
    out_dt = x.dtype if x.dtype in (jnp.bfloat16, jnp.float32) else jnp.bfloat16
    kern = functools.partial(_kernel, bm=bm, bk=bk, nk=nk)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((L, A, F), out_dt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L, A // bm, F // bn, nk),
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda l, i, j, k, c: (l, i, k)),
                pl.BlockSpec((1, bk, bn), lambda l, i, j, k, c: (l, k, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, k, c: (l, i, j)),
            scratch_shapes=[pltpu.VMEM((1, bm, bn), jnp.float32)],
        ),
        interpret=interpret,
    )(counts, x, w)
