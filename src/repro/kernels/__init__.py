"""Pallas TPU kernels for the EP hot spots the paper fuses in-kernel:
dispatch_pack (slot pack + fp8 quant), combine_reduce (K-way weighted
reduction), grouped_gemm (expert-major GEMM). ops.py = jit'd wrappers with
backend selection; ref.py = pure-jnp oracles of record."""
