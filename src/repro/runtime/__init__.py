from repro.runtime.steps import (  # noqa: F401
    make_train_step, make_serve_step, train_batch_specs, serve_state_specs,
)
from repro.runtime.decode import (  # noqa: F401
    naive_decode_step, pipelined_decode_step, decode_loop,
)
