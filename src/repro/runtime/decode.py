"""Double-buffered EP decode pipeline — the paper's §IV overlap made a
driver, not a latent capability.

The paper's LL mode hides all-to-all latency behind expert compute by double
buffering: while micro-batch *i*'s expert GEMM runs, micro-batch *i+1*'s
dispatch is already in flight (DeepEP and UCCL-EP build their decode paths
around the same overlap). The JAX rendering uses the staged
``send_only=True`` / ``ep_complete`` surface: issuing the second
micro-batch's dispatch-send *before* completing the first removes the serial
dependency between the two micro-batches' collectives and compute, so XLA's
async collective scheduler can overlap B's all-to-all with A's unpack +
expert GEMM, and A's combine all-to-all with B's expert GEMM.

The driver is **mode-agnostic**: the staged surface is part of the
``EpBackend`` contract (core/backend.py), so the same schedule runs over LL,
HT (flat or chunked hierarchical), and the baseline — LL remains the decode
preset, and runtime/prefill.py applies the same idea to the 4096+-token
prefill regime.

Steady state is also *plan-free*: handles are refreshed via
``ep_handle_refresh`` (routing-hash fast path) instead of rebuilt, so an
unchanged routing (speculative-decode replay) pays one checksum compare
instead of the full slot-map chain.

All functions here are EP-level and must run inside the sharded region (they
call the collective EP API), mirroring how a serving engine embeds them in
its MoE layer. ``DecodeServer`` (runtime/server.py) applies the same
double-buffering idea one level up: ``pipeline_depth`` keeps two decode
steps in flight at the host so device work never waits on host dispatch.
benchmarks/bench_decode_pipeline.py measures the steady-state per-step win
against the naive (rebuild-plan, unstaged) loop.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax

from repro.core.api import (ep_create_handle, ep_handle_refresh, ep_dispatch,
                            ep_combine, ep_complete)
from repro.core.group import EpGroup, EpGroupConfig, EpHandle
from repro.core import placement as PL

# router_fn: tokens [T, H] -> (topk_idx [T, K], topk_weights [T, K])
RouterFn = Callable[[jax.Array], tuple[jax.Array, jax.Array]]
# expert_fn: (y3d [L, A, H], counts [L]) -> [L, A, H]
ExpertFn = Callable[[jax.Array, jax.Array], jax.Array]


def naive_decode_step(group: EpGroup, router_fn: RouterFn, expert_fn: ExpertFn,
                      x: jax.Array) -> jax.Array:
    """The unpipelined per-step baseline: rebuild the handle (full plan
    construction) and run dispatch/expert/combine fully serialized. This is
    what every decode step cost before the staged surface + plan reuse; the
    benchmark measures the pipeline against it."""
    topk_idx, topk_weights = router_fn(x)
    h = ep_create_handle(group, topk_idx, topk_weights)
    y3d, counts = ep_dispatch(group, h, x)
    return ep_combine(group, h, expert_fn(y3d, counts))


def _staged_pair(group: EpGroup, expert_fn: ExpertFn,
                 ha: EpHandle, hb: EpHandle, xa: jax.Array, xb: jax.Array):
    """The double-buffer schedule over one micro-batch pair: both
    dispatch-sends are issued back-to-back (B's all-to-all overlaps A's
    unpack + expert GEMM), and A's combine-send is issued before B's expert
    work completes (A's all-to-all overlaps B's GEMM)."""
    pa = ep_dispatch(group, ha, xa, send_only=True)
    pb = ep_dispatch(group, hb, xb, send_only=True)    # B a2a in flight
    y3a, ca = ep_complete(group, ha, pa)
    qa = ep_combine(group, ha, expert_fn(y3a, ca), send_only=True)
    y3b, cb = ep_complete(group, hb, pb)               # overlaps A combine a2a
    qb = ep_combine(group, hb, expert_fn(y3b, cb), send_only=True)
    return ep_complete(group, ha, qa), ep_complete(group, hb, qb)


def pipelined_decode_step(group: EpGroup, router_fn: RouterFn,
                          expert_fn: ExpertFn,
                          handles: Sequence[EpHandle],
                          xa: jax.Array, xb: jax.Array):
    """One steady-state step over a micro-batch pair (the two buffers).

    Handles are refreshed, not rebuilt: the routing-hash fast path reuses
    the cached slot maps whenever the (global) routing replays. Returns
    ``((out_a, out_b), (handle_a, handle_b))`` — feed the handles back in
    for the next step. Mode-agnostic: the staged surface is part of the
    EpBackend contract, so the same schedule drives LL decode, HT
    micro-batched prefill, and the baseline."""
    ta, wa = router_fn(xa)
    tb, wb = router_fn(xb)
    ha = ep_handle_refresh(group, handles[0], wa, ta)
    hb = ep_handle_refresh(group, handles[1], wb, tb)
    return _staged_pair(group, expert_fn, ha, hb, xa, xb), (ha, hb)


def decode_loop(group: EpGroup, router_fn: RouterFn, expert_fn: ExpertFn,
                xs: Sequence[tuple[jax.Array, jax.Array]]):
    """Drive a sequence of micro-batch pairs through the pipeline.

    ``xs``: iterable of (xa, xb) pairs, one per decode step. Step 0 creates
    the two handles and feeds them straight into the staged schedule (the
    only full plan construction in the window); every later step refreshes
    them. Returns the list of (out_a, out_b) pairs. Python-level loop —
    unrolls under jit, matching how a serving engine would trace a fixed
    decode window. Mode-agnostic (see ``pipelined_decode_step``)."""
    outs = []
    handles = None
    for xa, xb in xs:
        if handles is None:
            ta, wa = router_fn(xa)
            tb, wb = router_fn(xb)
            handles = (ep_create_handle(group, ta, wa),
                       ep_create_handle(group, tb, wb))
            outs.append(_staged_pair(group, expert_fn, handles[0], handles[1],
                                     xa, xb))
            continue
        (oa, ob), handles = pipelined_decode_step(
            group, router_fn, expert_fn, handles, xa, xb)
        outs.append((oa, ob))
    return outs


# --------------------------------------------------------------------------
# EPLB: heat-driven placement rebalancing between decode windows
# --------------------------------------------------------------------------

def rebalancing_decode_loop(base_cfg: EpGroupConfig, make_window, xs,
                            *, rebalance_every: int, ep_size: int,
                            num_redundant: int = 0, inner_size: int | None = None,
                            decay: float = 0.0,
                            rebalance_fn=PL.rebalance, params=None,
                            expert_keys: tuple = PL.EXPERT_PARAM_KEYS,
                            donate_params: bool = True, fault_injector=None,
                            min_replicas: int = 1, fault_domains=None,
                            max_slots_per_rank: int | None = None):
    """Host-level EPLB decode driver: placements swap BETWEEN steps, at
    window boundaries, through the same mode-agnostic staged surface the
    pipeline runs on.

    ``make_window(group) -> fn(pairs) -> (outs, heat)``: the caller wraps the
    EP-level window (typically ``decode_loop`` plus a routed-token histogram,
    see tests/test_refresh.py) in its own jit/shard_map for the group's mesh
    — mesh specifics stay caller-owned, exactly like ``decode_loop`` itself.
    Every ``rebalance_every`` step-pairs the folded heat drives the greedy
    rebalancer (``core/placement.py``) and the next window runs on a group
    built for the new placement. A placement swap is a new *static* group
    (new traced maps), so window functions are cached per placement and any
    handle carried across the boundary is force-rebuilt by the placement-
    salted routing hash. Decode outputs are placement-invariant; parity with
    the naive per-step loop under the same placement schedule is pinned by
    tests/test_refresh.py.

    Returns ``(outs, placements)`` — the per-step outputs and the placement
    used for each window (None = the contiguous default). A window whose
    rebalance reproduces the current table reuses the placement object, so
    the compiled window function is cache-hit, not re-traced.

    Adopt-once physical weights: pass ``params`` (expert-stacked leaves
    under ``expert_keys``, laid out for ``base_cfg.placement``) and
    ``make_window`` is called as ``make_window(group, params)`` with the
    expert leaves rebound ONCE per adopted placement (old physical -> new
    physical) — no per-step expansion inside the window (docs/DESIGN.md
    §8). The driver takes ownership of ``params`` by default (old buffers
    donated at each boundary); ``donate_params=False`` preserves the
    caller's tree.

    Elastic EP: ``fault_injector`` (a ``runtime/fault.py FaultInjector``,
    step indices = WINDOW indices here) forces an immediate shrink to a
    degraded placement on an injected kill and a full-width re-expand on
    rejoin — the ``run_rebalancing`` fault path; see docs/DESIGN.md §9 for
    the zero-data-loss rules. ``min_replicas``/``fault_domains``/
    ``max_slots_per_rank`` turn on the fault-domain floor: every adopted
    placement keeps >= ``min_replicas`` replicas of every expert on
    distinct ranks/domains and passes the shrink-feasibility precheck, so
    any single correlated kill recovers via the zero-data-loss path."""
    if rebalance_every < 1:
        raise ValueError(f"rebalance_every={rebalance_every} must be >= 1")
    windows = [xs[s:s + rebalance_every]
               for s in range(0, len(xs), rebalance_every)]
    win_outs, placements = PL.run_rebalancing(
        base_cfg, make_window, windows, advance_every=1, ep_size=ep_size,
        num_redundant=num_redundant, inner_size=inner_size, decay=decay,
        rebalance_fn=rebalance_fn, params=params, expert_keys=expert_keys,
        donate_params=donate_params, fault_injector=fault_injector,
        min_replicas=min_replicas, fault_domains=fault_domains,
        max_slots_per_rank=max_slots_per_rank)
    return [o for w in win_outs for o in w], placements
