"""Decode serving loop: continuous batched greedy decoding against a KV/state
cache — the vLLM-style harness the paper's LL mode targets (§VI-C). Tracks
the serving metrics of Table VII: output tok/s, TTFT, ITL/TPOT.

``pipeline_depth > 1`` turns on the host-level rendering of the paper's
double-buffered decode (runtime/decode.py holds the EP-level one): up to
``depth`` decode steps stay in flight before the host blocks on the oldest,
so step *i+1*'s dispatch work overlaps step *i*'s device execution instead
of serializing on a per-step ``block_until_ready``. Greedy next-token
sampling feeds device-to-device, so no readback sits on the critical path."""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model
from repro.models.config import ArchConfig
from repro.parallel.sharding import init_from_specs
from repro.runtime.steps import make_serve_step, serve_state_specs


@dataclasses.dataclass
class ServeMetrics:
    ttft_s: float
    itl_mean_s: float
    itl_p99_s: float
    output_tok_s: float
    total_tokens: int

    def as_dict(self):
        return dataclasses.asdict(self)


class DecodeServer:
    def __init__(self, cfg: ArchConfig, batch: int, max_len: int, mesh=None,
                 params=None, seed=0, pipeline_depth: int = 1):
        self.cfg, self.mesh, self.batch = cfg, mesh, batch
        self.pipeline_depth = max(int(pipeline_depth), 1)
        self.model = get_model(cfg)
        if params is None:
            params = init_from_specs(jax.random.PRNGKey(seed),
                                     self.model.params_spec(cfg), mesh)
        self.params = params
        st_spec, _ = serve_state_specs(cfg, batch, max_len)
        self.state = jax.tree.map(
            jnp.zeros_like, init_from_specs(jax.random.PRNGKey(1), st_spec, mesh))
        self.step = jax.jit(make_serve_step(cfg, mesh), donate_argnums=(1,))

    def prefill(self, prompts: jax.Array):
        """Token-by-token prefill through the decode path (keeps this harness
        family-agnostic; a production server runs a fused prefill)."""
        t0 = time.perf_counter()
        tok = None
        for i in range(prompts.shape[1]):
            tok, self.state = self.step(self.params, self.state,
                                        {"tokens": prompts[:, i:i + 1]})
        jax.block_until_ready(tok)
        return tok, time.perf_counter() - t0

    def decode(self, first_tok: jax.Array, steps: int):
        if self.pipeline_depth > 1:
            return self._decode_pipelined(first_tok, steps)
        tok = first_tok
        itls = []
        outs = [np.asarray(tok)]
        for _ in range(steps):
            t0 = time.perf_counter()
            tok, self.state = self.step(self.params, self.state,
                                        {"tokens": tok})
            jax.block_until_ready(tok)
            itls.append(time.perf_counter() - t0)
            outs.append(np.asarray(tok))
        return np.concatenate(outs, axis=1), np.asarray(itls)

    def _decode_pipelined(self, first_tok: jax.Array, steps: int):
        """Double-buffered decode: keep up to ``pipeline_depth`` steps in
        flight, blocking only on the oldest. ITL is completion-to-completion
        between drain points — steady state only: the fill interval (start
        to first completion, which amortizes ``depth`` issues) is excluded,
        so ``len(itls) == steps - 1`` (single-step windows fall back to the
        fill interval). serve() therefore charges tok/s against its own
        wall clock, never ``itls.sum()``."""
        tok = first_tok
        pending: collections.deque[jax.Array] = collections.deque()
        done: list[jax.Array] = []          # D2H conversion deferred: keeps
        marks = []                          # the timed loop free of readbacks,
        t0 = time.perf_counter()            # matching the unpipelined path
        for _ in range(steps):
            tok, self.state = self.step(self.params, self.state,
                                        {"tokens": tok})
            pending.append(tok)
            if len(pending) >= self.pipeline_depth:
                d = pending.popleft()
                jax.block_until_ready(d)
                marks.append(time.perf_counter())
                done.append(d)
        while pending:
            d = pending.popleft()
            jax.block_until_ready(d)
            marks.append(time.perf_counter())
            done.append(d)
        if len(marks) > 1:
            itls = np.diff(np.asarray(marks))
        else:                               # degenerate 1-step window
            itls = np.asarray([m - t0 for m in marks])
        outs = [np.asarray(first_tok)] + [np.asarray(d) for d in done]
        return np.concatenate(outs, axis=1), itls

    def serve(self, prompts: jax.Array, gen_steps: int) -> ServeMetrics:
        first, ttft = self.prefill(prompts)
        t0 = time.perf_counter()
        toks, itls = self.decode(first, gen_steps)
        # tok/s over the decode wall clock, not itls.sum(): the pipelined
        # path's itls are steady-state-only (fill excluded), so summing them
        # would inflate its tok/s relative to the depth-1 baseline
        decode_wall = time.perf_counter() - t0
        total = toks.shape[0] * toks.shape[1]
        return ServeMetrics(
            ttft_s=ttft, itl_mean_s=float(itls.mean()),
            itl_p99_s=float(np.percentile(itls, 99)),
            output_tok_s=total / (ttft + decode_wall),
            total_tokens=total)
