"""Decode serving loop: continuous batched greedy decoding against a KV/state
cache — the vLLM-style harness the paper's LL mode targets (§VI-C). Tracks
the serving metrics of Table VII: output tok/s, TTFT, ITL/TPOT."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model
from repro.models.config import ArchConfig
from repro.parallel.sharding import init_from_specs
from repro.runtime.steps import make_serve_step, serve_state_specs


@dataclasses.dataclass
class ServeMetrics:
    ttft_s: float
    itl_mean_s: float
    itl_p99_s: float
    output_tok_s: float
    total_tokens: int

    def as_dict(self):
        return dataclasses.asdict(self)


class DecodeServer:
    def __init__(self, cfg: ArchConfig, batch: int, max_len: int, mesh=None,
                 params=None, seed=0):
        self.cfg, self.mesh, self.batch = cfg, mesh, batch
        self.model = get_model(cfg)
        if params is None:
            params = init_from_specs(jax.random.PRNGKey(seed),
                                     self.model.params_spec(cfg), mesh)
        self.params = params
        st_spec, _ = serve_state_specs(cfg, batch, max_len)
        self.state = jax.tree.map(
            jnp.zeros_like, init_from_specs(jax.random.PRNGKey(1), st_spec, mesh))
        self.step = jax.jit(make_serve_step(cfg, mesh), donate_argnums=(1,))

    def prefill(self, prompts: jax.Array):
        """Token-by-token prefill through the decode path (keeps this harness
        family-agnostic; a production server runs a fused prefill)."""
        t0 = time.perf_counter()
        tok = None
        for i in range(prompts.shape[1]):
            tok, self.state = self.step(self.params, self.state,
                                        {"tokens": prompts[:, i:i + 1]})
        jax.block_until_ready(tok)
        return tok, time.perf_counter() - t0

    def decode(self, first_tok: jax.Array, steps: int):
        tok = first_tok
        itls = []
        outs = [np.asarray(tok)]
        for _ in range(steps):
            t0 = time.perf_counter()
            tok, self.state = self.step(self.params, self.state,
                                        {"tokens": tok})
            jax.block_until_ready(tok)
            itls.append(time.perf_counter() - t0)
            outs.append(np.asarray(tok))
        return np.concatenate(outs, axis=1), np.asarray(itls)

    def serve(self, prompts: jax.Array, gen_steps: int) -> ServeMetrics:
        first, ttft = self.prefill(prompts)
        toks, itls = self.decode(first, gen_steps)
        total = toks.shape[0] * toks.shape[1]
        return ServeMetrics(
            ttft_s=ttft, itl_mean_s=float(itls.mean()),
            itl_p99_s=float(np.percentile(itls, 99)),
            output_tok_s=total / (ttft + float(itls.sum())),
            total_tokens=total)
