"""Decode serving loop: continuous batched greedy decoding against a KV/state
cache — the vLLM-style harness the paper's LL mode targets (§VI-C). Tracks
the serving metrics of Table VII: output tok/s, TTFT, ITL/TPOT.

``pipeline_depth > 1`` turns on the host-level rendering of the paper's
double-buffered decode (runtime/decode.py holds the EP-level one): up to
``depth`` decode steps stay in flight before the host blocks on the oldest,
so step *i+1*'s dispatch work overlaps step *i*'s device execution instead
of serializing on a per-step ``block_until_ready``. Greedy next-token
sampling feeds device-to-device, so no readback sits on the critical path.

EPLB serving hook: with ``MoESpec.track_expert_heat`` the decode state
carries per-logical-expert routed-token counters ("expert_heat"); ``serve``
folds them into ``ServeMetrics`` (load imbalance alongside latency), and
``rebalance_every > 0`` swaps the expert placement between decode steps —
the heat drives the greedy rebalancer (core/placement.py), the serve step is
re-jitted for the new (static) placement, and the token stream is unchanged
because placement only moves *where* experts compute.

Adopt-once physical weights (``MoESpec.params_physical``): the server keeps
expert weights in the ACTIVE placement's physical slot order and rebinds
them host-side exactly once per adoption boundary
(``checkpoint.adopt_expert_params``, old buffers donated so peak memory
stays ~one set of expert weights) — the per-step in-graph logical->physical
gather is skipped, so placed steady-state decode matches the
placement=None per-step cost. Token parity with the per-step-expansion mode
is pinned by tests/test_runtime.py. Compiled serve steps are cached per
placement and BOUNDED to {current, previous}: a server that swaps hundreds
of times must not accumulate compiled executables.

Elastic fault tolerance (docs/DESIGN.md §9): a ``FaultDetector`` (fed by a
deterministic ``FaultInjector`` in tests/benches, by the transport layer in
production) is polled at every decode-step boundary. On a detected rank
death the server drains the pipeline, builds a DEGRADED placement that packs
every expert onto the survivors (the dead rank's row is all EMPTY — zero
slots, zero traffic), re-adopts weights by collapsing through the masked old
placement (reads only surviving replicas — zero data loss whenever the dead
rank's experts had replicas elsewhere), re-jits the step, and keeps serving
on N-1 ranks. When no live replica exists the recovery warns
``DegradedRecovery`` loudly and falls back to checkpoint restore
(``ckpt_dir``) or raises — never silent corruption. A rejoin re-expands to a
full-width placement at the next boundary; the placement-salted routing hash
force-rebuilds handles exactly once per transition, after which the fast
path resumes. The greedy token stream is placement-invariant, so surviving-
rank decode tokens are bitwise-identical to an uninterrupted run
(tests/test_elastic.py). With ``min_replicas >= 2`` (the fault-domain
replica floor) the checkpoint fallback becomes unreachable for any single
correlated failure — every adopted placement keeps that many replicas of
every expert on distinct ranks and distinct fault domains (pods), and is
shrink-feasibility-prechecked at adoption, so even a whole pod dying at
one boundary recovers through the masked rebind with zero restores
(``ServeMetrics.checkpoint_restores``, asserted in bench_fault).

Preemption (``runtime/fault.py PreemptionGuard``): SIGTERM/SIGINT is polled
at the same boundaries — the server drains in-flight steps, writes a
placement-tagged checkpoint (``ckpt_dir``), and returns cleanly with
``preempted=True`` instead of dying mid-collective. A ``StragglerWatchdog``
watches the ITL stream and its flag count lands in ``ServeMetrics``."""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (adopt_expert_params, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.core import placement as PL
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.parallel.sharding import init_from_specs
from repro.runtime.fault import (DegradedRecovery, FaultDetector,
                                 PreemptionGuard, StragglerWatchdog)
from repro.runtime.steps import (make_paged_serve_step, make_serve_step,
                                 paged_serve_state_specs, serve_state_specs)
from repro.runtime.telemetry import NULL_SERIES, NULL_TRACER, json_safe


@dataclasses.dataclass
class ServeMetrics:
    ttft_s: float
    itl_mean_s: float
    itl_p99_s: float
    output_tok_s: float
    total_tokens: int
    # --- continuous-batching percentiles (ContinuousDecodeServer only;
    # per-REQUEST distributions under real admission, not batch means) ---
    ttft_p50_s: float | None = None
    ttft_p95_s: float | None = None
    ttft_p99_s: float | None = None
    itl_p50_s: float | None = None
    itl_p95_s: float | None = None
    requests_completed: int | None = None
    serve_steps: int | None = None
    # paged-KV accounting: allocator high-water vs the dense B x S_max
    # reservation the fixed-batch engine would have pinned (both in pages)
    pages_peak: int | None = None
    pages_dense_equiv: int | None = None
    per_request: list | None = None        # per-request ttft/itl records
    # --- EPLB load counters (None when the config doesn't track heat) ---
    expert_heat: list | None = None        # per-logical-expert routed tokens
    heat_max_mean: float | None = None     # max/mean per-expert load ratio
    rank_heat_max_mean: float | None = None  # max/mean per-EP-rank load
    # --- elastic fault tolerance (runtime/fault.py; docs/DESIGN.md §9) ---
    degraded_steps: int = 0                # decode steps served with <N alive
    recovery_count: int = 0                # shrink + expand transitions taken
    recovery_latency_s: float | None = None  # total wall time inside recovery
    recovery_events: list | None = None    # per-transition records (dicts)
    checkpoint_restores: int = 0           # recoveries that needed a restore
    #                                        (0 under a satisfied replica
    #                                        floor — the bench asserts it)
    alive_ranks: list | None = None        # EP ranks alive at end of serve
    stragglers_flagged: int = 0            # watchdog outlier ITL steps
    preempted: bool = False                # SIGTERM drain-and-checkpoint exit
    # --- telemetry (runtime/telemetry.py; None when tracing is off) ---
    timeline: dict | None = None           # Tracer.summary(): per-span count
    #                                        + total seconds aggregates
    series: list | None = None             # TimeSeries rows (per-window and,
    #                                        continuous engine, per-step)

    def as_dict(self):
        # json_safe: the telemetry rows (and any caller-added fields) may
        # carry numpy scalars — as_dict feeds json.dumps in benches/CI
        return json_safe(dataclasses.asdict(self))


class DecodeServer:
    def __init__(self, cfg: ArchConfig, batch: int, max_len: int, mesh=None,
                 params=None, seed=0, pipeline_depth: int = 1,
                 rebalance_every: int = 0, num_redundant_experts: int = 0,
                 fault_injector=None, fault_detector: FaultDetector | None = None,
                 miss_threshold: int = 2, ckpt_dir: str | None = None,
                 min_replicas: int = 1, fault_domains=None,
                 max_slots_per_rank: int | None = None,
                 tracer=None, series=None, heat_decay: float = 0.0):
        self.cfg, self.mesh, self.batch = cfg, mesh, batch
        self.pipeline_depth = max(int(pipeline_depth), 1)
        # telemetry (runtime/telemetry.py): host-side, boundary-scoped only —
        # spans/rows wrap code that ALREADY runs at step boundaries, so
        # tracing on vs off is bitwise-identical on the token stream (pinned
        # by tests/test_telemetry.py). None -> shared no-op singletons.
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.series = NULL_SERIES if series is None else series
        self._win_itls: list[float] = []    # ITLs since the last window row
        # heat decay for the rebalancer's tracker: >0 fades old windows so
        # the placement tracks DRIFTING load instead of the all-time sum
        self.heat_decay = float(heat_decay)
        # EPLB: swap expert placements every `rebalance_every` decode steps,
        # driven by the tracked heat (requires MoESpec.track_expert_heat)
        self.rebalance_every = int(rebalance_every)
        self.num_redundant_experts = int(num_redundant_experts)
        # fault-domain replica floor (docs/DESIGN.md §9): every adopted
        # placement keeps >= min_replicas replicas of every expert on
        # distinct ranks (and distinct fault domains when the topology
        # permits), so ANY single correlated failure — up to a whole pod —
        # recovers through the zero-data-loss masked rebind, never a
        # checkpoint restore. fault_domains=None derives pod boundaries
        # from the EP mesh geometry (core/plan.py rank_pod arithmetic).
        self.min_replicas = int(min_replicas)
        if self.min_replicas < 1:
            raise ValueError(f"min_replicas={min_replicas} must be >= 1")
        self.max_slots_per_rank = max_slots_per_rank
        self.fault_domains = fault_domains
        if self.rebalance_every and not (cfg.moe and cfg.moe.track_expert_heat):
            raise ValueError("rebalance_every requires an MoE config with "
                             "track_expert_heat=True (the heat drives the "
                             "rebalancer)")
        self.placements: list = []          # placements adopted, in order
        self._sched = None
        self._heat_drained = None           # float64 totals of drained counters
        self._rank_loads = None             # [N] float64 per-rank load, summed
        #                                     under the placement ACTIVE when
        #                                     each window's heat accrued
        # --- elastic fault tolerance (docs/DESIGN.md §9) ---
        # the injector is the deterministic test/bench fault source; the
        # detector is the serving-boundary heartbeat monitor (production
        # feeds it from the transport layer and passes it in directly)
        self.ckpt_dir = ckpt_dir
        self._injector = fault_injector
        self._detector = fault_detector
        self.recoveries: list[dict] = []    # shrink/expand transition records
        self._degraded_steps = 0
        self._recovery_wall_s = 0.0
        self._ckpt_restores = 0
        self.preempted = False
        self.guard = PreemptionGuard()      # SIGTERM/SIGINT -> drain + ckpt
        self.watchdog = StragglerWatchdog(
            tracer=self.tracer if self.tracer.enabled else None)
        n = self._ep_size()
        if (fault_injector is not None or fault_detector is not None):
            if not (cfg.moe and n > 1):
                raise ValueError("fault tolerance requires an MoE config on "
                                 "an EP mesh (ep extent > 1) — rank death is "
                                 "an EP-placement event")
            if self._detector is None:
                self._detector = FaultDetector(n,
                                               miss_threshold=miss_threshold)
            elif self._detector.num_ranks != n:
                raise ValueError(
                    f"fault_detector watches {self._detector.num_ranks} "
                    f"ranks but the EP extent is {n}")
        if self.rebalance_every or self._detector is not None:
            if self.rebalance_every and n <= 1:
                pass                        # rebalance hook inert off-mesh
            elif n > 1:
                if (cfg.moe.num_experts + self.num_redundant_experts) % n:
                    raise ValueError(
                        f"num_experts={cfg.moe.num_experts} + "
                        f"num_redundant_experts={self.num_redundant_experts} "
                        f"must divide by the EP extent {n}")
                if cfg.moe.placement is None and cfg.moe.num_experts % n:
                    raise ValueError(
                        f"num_experts={cfg.moe.num_experts} must divide by "
                        f"the EP extent {n} for the contiguous initial "
                        "placement — pass an explicit MoESpec.placement")
                if self.fault_domains is None and self.min_replicas > 1:
                    self.fault_domains = self._derived_domains(n)
                if self.min_replicas > 1:
                    E = cfg.moe.num_experts
                    if self.num_redundant_experts < E * (self.min_replicas - 1):
                        raise ValueError(
                            f"min_replicas={self.min_replicas} floor needs "
                            f"num_redundant_experts >= E*(min_replicas-1) = "
                            f"{E * (self.min_replicas - 1)}, got "
                            f"{self.num_redundant_experts}")
                    if cfg.moe.placement is not None:
                        # gate at adoption: the INITIAL placement must already
                        # satisfy the floor and survive any single correlated
                        # failure — infeasibility surfaces here, not during a
                        # recovery
                        PL.validate_floor(cfg.moe.placement,
                                          self.min_replicas,
                                          self.fault_domains,
                                          where="initial placement")
                        PL.assert_shrink_feasible(
                            E, cfg.moe.placement.num_redundant, n,
                            domains=self.fault_domains,
                            min_replicas=self.min_replicas,
                            max_slots_per_rank=self.max_slots_per_rank,
                            placement=cfg.moe.placement)
                self._sched = PL.RebalanceScheduler(
                    cfg.moe.num_experts, n,
                    num_redundant=self.num_redundant_experts,
                    decay=self.heat_decay,
                    initial=cfg.moe.placement,
                    min_replicas=self.min_replicas,
                    domains=self.fault_domains,
                    max_slots_per_rank=self.max_slots_per_rank)
        self.model = get_model(cfg)
        self.params_physical = bool(cfg.moe and cfg.moe.params_physical)
        # Caller-supplied ``params`` must already match the config's weight
        # layout: logical [E, ...] normally, cfg.moe.placement's physical
        # slot order under params_physical (convert with
        # checkpoint.adopt_expert_params, or restore_checkpoint(placement=
        # cfg.moe.placement), which validates against the recorded
        # fingerprint). Raw arrays carry no layout metadata, so a
        # wrongly-ordered tree with the RIGHT row count (e.g. logical
        # weights under a pure-permutation placement) cannot be detected
        # here — the checkpoint path is the validated way in. Under
        # params_physical the server also takes OWNERSHIP of the tree:
        # adoption boundaries donate the old expert buffers (slot count
        # permitting), so the caller's original arrays may be deleted.
        if params is None:
            # random init ALWAYS goes through the logical [E, ...] spec —
            # per-slot init under a redundant placement would give replicas
            # of one expert different weights, breaking the replica
            # invariant. Physical mode then adopts the initial placement
            # once (logical -> physical expansion, host-level).
            init_cfg = self._logical_cfg()
            params = init_from_specs(jax.random.PRNGKey(seed),
                                     self.model.params_spec(init_cfg), mesh)
            if self.params_physical and cfg.moe.placement is not None:
                params = adopt_expert_params(
                    params, self.model.params_spec(init_cfg),
                    None, cfg.moe.placement)
        self.params = params
        self.state = self._init_state(batch, max_len)
        # compiled serve steps, keyed by placement, bounded to
        # {current, previous} — see _compiled_step
        self._step_cache: collections.OrderedDict = collections.OrderedDict()
        self.step = self._compiled_step()

    # ---- engine hooks (ContinuousDecodeServer overrides both) ----

    def _init_state(self, batch: int, max_len: int):
        """Zeroed decode state for this engine's layout (dense KV caches)."""
        st_spec, _ = serve_state_specs(self.cfg, batch, max_len)
        return jax.tree.map(
            jnp.zeros_like,
            init_from_specs(jax.random.PRNGKey(1), st_spec, self.mesh))

    def _step_factory(self):
        """Uncompiled serve step for this engine's layout. _compiled_step
        jits THIS — so placement re-jits, fault recoveries, and the bounded
        step cache work identically for the dense and paged engines."""
        return make_serve_step(self.cfg, self.mesh)

    def _logical_cfg(self) -> ArchConfig:
        """This server's config with the expert-weight layout forced logical
        (spec metadata for init and for locating expert axes at adoption)."""
        if not self.params_physical:
            return self.cfg
        return dataclasses.replace(
            self.cfg, moe=dataclasses.replace(self.cfg.moe,
                                              params_physical=False))

    def _compiled_step(self):
        """Compiled serve step for the CURRENT placement. Cached per
        placement and bounded to two entries (current + previous): each
        compiled executable pins device buffers, so an unbounded per-swap
        cache is a leak on a long-lived rebalancing server. Today a
        placement key never recurs (the scheduler version-bumps every
        changed table and _maybe_rebalance early-returns on an unchanged
        one), so the previous entry is a one-window grace retention, not a
        reuse path — the cache-hit branch is defensive; what matters is
        the bound."""
        key = self.cfg.moe.placement if self.cfg.moe else None
        if key in self._step_cache:
            self._step_cache.move_to_end(key)
        else:
            self._step_cache[key] = jax.jit(
                self._step_factory(), donate_argnums=(1,))
            while len(self._step_cache) > 2:
                self._step_cache.popitem(last=False)
        return self._step_cache[key]

    # ---- EPLB hook: heat-driven placement swaps between steps ----

    def _device_heat(self):
        if isinstance(self.state, dict) and "expert_heat" in self.state:
            return np.asarray(jax.device_get(self.state["expert_heat"]),
                              np.float64)
        return None

    def _tracked_heat(self):
        """[E] float64 per-expert routed-token totals: the live on-device
        counter plus everything drained at rebalance boundaries (draining
        keeps the f32 device counter at per-window magnitude, so a
        long-lived server never hits f32 integer saturation)."""
        dev = self._device_heat()
        if dev is None:
            return None
        return dev if self._heat_drained is None else self._heat_drained + dev

    def _ep_size(self) -> int:
        m = self.cfg.moe
        if not m or self.mesh is None:
            return 0
        import math
        sizes = [self.mesh.shape[a] for a in m.ep_axis
                 if a in self.mesh.shape]
        return math.prod(sizes) if sizes else 0

    def _derived_domains(self, n: int):
        """Fault domains from the EP mesh geometry — same derivation as
        ``EpGroup.fault_domains()``: a hierarchical EP axis makes the pod
        (``rank // inner_size``, `core/plan.py rank_pod`) the correlated-
        failure unit; a flat axis leaves every rank its own domain."""
        m = self.cfg.moe
        sizes = [self.mesh.shape[a] for a in m.ep_axis
                 if a in self.mesh.shape]
        inner = sizes[-1] if sizes else n
        if len(sizes) > 1 and n // inner > 1:
            return PL.domains_from_geometry(n, inner)
        return PL.trivial_domains(n)

    def _record_window(self, step_idx: int, kind: str, dev, rl):
        """One time-series row for a heat window that just ended (rebalance
        or recovery boundary). Strictly host-side: ``dev``/``rl`` are the
        host arrays the boundary ALREADY drained — recording never adds a
        device sync. Drains the per-window ITL buffer either way."""
        imb = None if rl is None else PL.imbalance(rl)
        if self.tracer.enabled and imb is not None:
            self.tracer.counter("rank_imbalance", float(imb))
        itls = self._win_itls
        self._win_itls = []
        if not self.series.enabled:
            return
        self.series.record(
            kind=kind, step=step_idx,
            window_tokens=None if dev is None else float(dev.sum()),
            heat_max_mean=None if dev is None else PL.imbalance(dev),
            imbalance=imb,
            rank_loads=None if rl is None else [float(x) for x in rl],
            itl_mean_s=float(np.mean(itls)) if itls else None,
            alive=(len(self._detector.alive)
                   if self._detector is not None else None),
            stragglers_flagged=self.watchdog.flagged,
            watchdog_rebased=self.watchdog.rebased,
            placements_adopted=len(self.placements))

    def _maybe_rebalance(self, step_idx: int):
        """Every ``rebalance_every`` steps: drain the device heat counter
        into the host-side float64 totals, fold it into the shared
        ``RebalanceScheduler``, and — only when the table actually changed —
        adopt the new placement and re-jit the serve step. The placement
        only moves *where* experts compute — weights are rebound in-graph
        per step (logical mode, models/moe.py) or once right here at the
        adoption boundary (``params_physical``) — so the greedy token
        stream is unchanged either way (pinned by tests)."""
        if (self._sched is None or not self.rebalance_every
                or (step_idx + 1) % self.rebalance_every):
            return
        dev = self._device_heat()
        if dev is None:
            return
        with self.tracer.span("rebalance", step=step_idx):
            self._sched.observe(dev)
            self._heat_drained = (dev if self._heat_drained is None
                                  else self._heat_drained + dev)
            # attribute this window's per-rank load to the placement it
            # actually ran under, BEFORE any swap — rank_heat_max_mean then
            # reports the imbalance experienced, not what the final
            # placement would have had
            rl = PL.rank_loads(dev, self.cfg.moe.placement,
                               self._sched.num_ranks)
            self._rank_loads = (rl if self._rank_loads is None
                                else self._rank_loads + rl)
            self._record_window(step_idx, "rebalance", dev, rl)
            self.state["expert_heat"] = jnp.zeros_like(
                self.state["expert_heat"])
            pl = self._sched.advance()
            old = self.cfg.moe.placement
            if pl is old:
                return              # unchanged table: keep the compiled step
            self.cfg = dataclasses.replace(
                self.cfg, moe=dataclasses.replace(self.cfg.moe, placement=pl))
            self.placements.append(pl)
            self.tracer.instant("placement_swap", step=step_idx,
                                version=len(self.placements))
            if self.params_physical:
                # adopt-once: rebind the physical expert weights from the
                # old placement's slot order to the new one, HOST-LEVEL and
                # exactly once per adoption (old buffers donated — peak
                # memory ~one set of expert weights). The re-jitted step
                # then runs with zero per-step expansion cost.
                with self.tracer.span("adopt", step=step_idx):
                    self.params = adopt_expert_params(
                        self.params,
                        self.model.params_spec(self._logical_cfg()),
                        old, pl)
            self.step = self._compiled_step()

    # ---- elastic fault tolerance: detect -> shrink/expand -> re-adopt ----

    def _poll_faults(self, step_idx: int):
        """Advance the injected fault schedule (tests/benches) and poll the
        detector at a step boundary. Returns the FaultReport when something
        newly died or rejoined, else None. Detection only — the caller
        drains any in-flight pipeline before handing the report to
        ``_recover`` (recovery re-jits the step; in-flight tokens must land
        under the placement that issued them).

        Coalescing: the detector is re-polled until a quiet poll, and every
        report from this boundary merges into ONE (``FaultReport.merge`` —
        dedup, died+rejoined cancels). However many ranks die at a boundary
        — a whole pod at once, or stragglers declared across back-to-back
        polls while the wall clock advances a ``timeout_s`` detector — the
        caller sees a single report and takes a single degraded-placement
        transition: one fingerprint bump, one handle rebuild, one weight
        adoption, not one per dead rank."""
        if self._detector is None:
            return None
        with self.tracer.span("fault_poll"):
            if self._injector is not None:
                self._injector.advance(step_idx)
                for r in range(self._detector.num_ranks):
                    if self._injector.is_alive(r):
                        self._detector.heartbeat(r, step_idx)
            merged = self._detector.poll(step_idx)
            while merged:
                more = self._detector.poll(step_idx)
                if not more:
                    break
                merged = merged.merge(more)
        if not merged:
            return None
        self.tracer.instant("fault_detected", step=step_idx,
                            died=list(merged.died),
                            rejoined=list(merged.rejoined))
        return merged

    def _recover(self, step_idx: int, report):
        """One shrink or expand transition (docs/DESIGN.md §9). Drains the
        heat window, narrows/widens the scheduler to the detector's alive
        set, builds the new placement, and re-adopts the physical expert
        weights by collapsing through the MASKED old placement — reads only
        surviving replicas, so the shrink is zero-data-loss whenever the
        dead ranks' experts had replicas elsewhere. When an expert lost its
        last replica this warns ``DegradedRecovery`` and restores the whole
        tree from ``ckpt_dir`` (rebound to the new placement) or raises —
        never silent corruption. Logical (non-physical) weight mode keeps
        the full [E, ...] tree host/device-side, so no data can be lost and
        only the placement swap happens. The placement-salted routing hash
        force-rebuilds handles exactly once per transition."""
        t0 = time.perf_counter()
        kind = "shrink" if report.died else "expand"
        # per-transition phase durations (satellite of the opaque
        # recovery_latency_s total): repack = scheduler narrow/widen +
        # placement build; adopt = masked weight rebind; restore = the
        # checkpoint fallback. Each also lands as a nested tracer span.
        phases: dict[str, float] = {}
        with self.tracer.span(f"recover:{kind}", step=step_idx,
                              died=list(report.died),
                              rejoined=list(report.rejoined)):
            dev = self._device_heat()
            if dev is not None:
                self._sched.observe(dev)
                self._heat_drained = (dev if self._heat_drained is None
                                      else self._heat_drained + dev)
                rl = PL.rank_loads(dev, self.cfg.moe.placement,
                                   self._sched.num_ranks)
                self._rank_loads = (rl if self._rank_loads is None
                                    else self._rank_loads + rl)
                self._record_window(step_idx, f"recover:{kind}", dev, rl)
                self.state["expert_heat"] = jnp.zeros_like(
                    self.state["expert_heat"])
            tp = time.perf_counter()
            with self.tracer.span("recover:repack"):
                self._sched.set_alive(self._detector.alive)
                old = self.cfg.moe.placement
                pl = self._sched.advance()
            phases["repack_s"] = time.perf_counter() - tp
            event = dict(step=step_idx, kind=kind,
                         died=list(report.died),
                         rejoined=list(report.rejoined),
                         alive=list(self._detector.alive),
                         lost_experts=[], restored_from=None,
                         placement_changed=pl is not old, phases=phases)
            if pl is not old:
                if self.params_physical:
                    src_live = (old if old is not None else
                                PL.identity_placement(
                                    self.cfg.moe.num_experts,
                                    self._sched.num_ranks))
                    lost = (PL.lost_experts(src_live, self._sched.alive)
                            if report.died else ())
                    if lost:
                        # the dead ranks held every replica of these experts:
                        # their physical slot rows are unavailable on a real
                        # pod, so zero-data-loss recovery is impossible
                        event["lost_experts"] = list(lost)
                        ck = (latest_step(self.ckpt_dir)
                              if self.ckpt_dir is not None else None)
                        warnings.warn(DegradedRecovery(
                            f"rank death {list(report.died)} lost every "
                            f"replica of experts {list(lost)[:8]} — "
                            "zero-data-loss shrink impossible; "
                            + (f"restoring from checkpoint step {ck}"
                               if ck is not None else
                               f"no checkpoint available (ckpt_dir="
                               f"{self.ckpt_dir!r})")))
                        if ck is None:
                            # record the failed transition before bailing so
                            # post-mortems see what died and what was lost
                            event["latency_s"] = time.perf_counter() - t0
                            self.recoveries.append(event)
                            raise RuntimeError(
                                f"experts {list(lost)[:8]} unrecoverable "
                                "from surviving ranks and no checkpoint to "
                                f"restore from (ckpt_dir={self.ckpt_dir!r}) "
                                "— pass ckpt_dir= with a saved checkpoint "
                                "or add redundant replicas "
                                "(num_redundant_experts)")
                        new_cfg = dataclasses.replace(
                            self.cfg, moe=dataclasses.replace(self.cfg.moe,
                                                              placement=pl))
                        tp = time.perf_counter()
                        with self.tracer.span("checkpoint", restore=True,
                                              ckpt_step=ck):
                            self.params, _ = restore_checkpoint(
                                self.ckpt_dir, ck,
                                self.model.params_spec(new_cfg),
                                mesh=self.mesh, placement=pl)
                        phases["restore_s"] = time.perf_counter() - tp
                        event["restored_from"] = ck
                        self._ckpt_restores += 1
                    else:
                        src = (PL.mask_placement(src_live, self._sched.alive)
                               if report.died else old)
                        tp = time.perf_counter()
                        with self.tracer.span("recover:adopt"):
                            self.params = adopt_expert_params(
                                self.params,
                                self.model.params_spec(self._logical_cfg()),
                                src, pl)
                        phases["adopt_s"] = time.perf_counter() - tp
                self.cfg = dataclasses.replace(
                    self.cfg, moe=dataclasses.replace(self.cfg.moe,
                                                      placement=pl))
                self.placements.append(pl)
                self.tracer.instant("placement_swap", step=step_idx,
                                    version=len(self.placements))
                self.step = self._compiled_step()
        dt = time.perf_counter() - t0
        event["latency_s"] = dt
        self._recovery_wall_s += dt
        self.recoveries.append(event)

    def _preempt(self, step_idx: int):
        """SIGTERM/SIGINT drain path: with the pipeline already drained by
        the caller, write a placement-tagged checkpoint (``ckpt_dir``) and
        mark the server preempted — ``decode`` then exits cleanly at this
        step boundary and ``serve`` reports metrics for the tokens that DID
        complete, with ``preempted=True``."""
        self.preempted = True
        if self.ckpt_dir is None:
            return
        pl = self.cfg.moe.placement if self.cfg.moe else None
        with self.tracer.span("checkpoint", step=step_idx, preempt=True):
            save_checkpoint(
                self.ckpt_dir, step_idx + 1, self.params,
                placement=pl if self.params_physical else None,
                extra=dict(preempted=True,
                           alive_ranks=(list(self._detector.alive)
                                        if self._detector is not None
                                        else None)))

    def close(self):
        """Uninstall the preemption signal handlers (restores whatever was
        registered before this server). Call when retiring a server inside
        a longer-lived process; tests do."""
        self.guard.restore()

    def prefill(self, prompts: jax.Array):
        """Token-by-token prefill through the decode path (keeps this harness
        family-agnostic; a production server runs a fused prefill)."""
        t0 = time.perf_counter()
        tok = None
        with self.tracer.span("prefill", tokens=int(prompts.shape[1])):
            for i in range(prompts.shape[1]):
                tok, self.state = self.step(self.params, self.state,
                                            {"tokens": prompts[:, i:i + 1]})
            jax.block_until_ready(tok)
        return tok, time.perf_counter() - t0

    def decode(self, first_tok: jax.Array, steps: int):
        if self.pipeline_depth > 1:
            return self._decode_pipelined(first_tok, steps)
        tok = first_tok
        itls = []
        outs = [np.asarray(tok)]
        record_itls = self.series.enabled
        for i in range(steps):
            t0 = time.perf_counter()
            with self.tracer.span("serve_step"):
                tok, self.state = self.step(self.params, self.state,
                                            {"tokens": tok})
                jax.block_until_ready(tok)
            itls.append(time.perf_counter() - t0)
            if record_itls:
                self._win_itls.append(itls[-1])
            outs.append(np.asarray(tok))
            report = self._poll_faults(i)
            if report is not None:
                # recovery drains the heat window and advances the
                # placement itself — a coinciding periodic boundary would
                # just dedup to the same table
                self._recover(i, report)
            else:
                self._maybe_rebalance(i)
            if self._detector is not None and self._detector.dead:
                self._degraded_steps += 1
            if self.guard.should_stop:
                self._preempt(i)
                break
        return np.concatenate(outs, axis=1), np.asarray(itls)

    def _decode_pipelined(self, first_tok: jax.Array, steps: int):
        """Double-buffered decode: keep up to ``pipeline_depth`` steps in
        flight, blocking only on the oldest. ITL is completion-to-completion
        between drain points — steady state only: the fill interval (start
        to first completion, which amortizes ``depth`` issues) is excluded,
        so ``len(itls) == steps - 1`` (single-step windows fall back to the
        fill interval). serve() therefore charges tok/s against its own
        wall clock, never ``itls.sum()``."""
        tok = first_tok
        pending: collections.deque[jax.Array] = collections.deque()
        done: list[jax.Array] = []          # D2H conversion deferred: keeps
        marks = []                          # the timed loop free of readbacks,
        t0 = time.perf_counter()            # matching the unpipelined path
        for i in range(steps):
            tok, self.state = self.step(self.params, self.state,
                                        {"tokens": tok})
            pending.append(tok)
            if len(pending) >= self.pipeline_depth:
                d = pending.popleft()
                jax.block_until_ready(d)
                marks.append(time.perf_counter())
                done.append(d)
            boundary = (self._sched is not None and self.rebalance_every
                        and (i + 1) % self.rebalance_every == 0)
            report = self._poll_faults(i)
            if boundary or report is not None or self.guard.should_stop:
                # placement swap / recovery / preemption boundary: drain the
                # in-flight window first (a swap re-jits the step; in-flight
                # tokens must land under the placement that issued them).
                # The drain and any post-swap recompile are charged to the
                # ITL stream on purpose — swaps and recoveries cost real
                # latency, and the serving metrics should show it.
                with self.tracer.span("drain", pending=len(pending)):
                    while pending:
                        d = pending.popleft()
                        jax.block_until_ready(d)
                        marks.append(time.perf_counter())
                        done.append(d)
                if report is not None:
                    self._recover(i, report)
                elif boundary:
                    self._maybe_rebalance(i)
                if self.guard.should_stop:
                    self._preempt(i)
                    break
            if self._detector is not None and self._detector.dead:
                self._degraded_steps += 1
        while pending:
            d = pending.popleft()
            jax.block_until_ready(d)
            marks.append(time.perf_counter())
            done.append(d)
        if len(marks) > 1:
            itls = np.diff(np.asarray(marks))
        else:                               # degenerate 1-step window
            itls = np.asarray([m - t0 for m in marks])
        outs = [np.asarray(first_tok)] + [np.asarray(d) for d in done]
        return np.concatenate(outs, axis=1), itls

    def serve(self, prompts: jax.Array, gen_steps: int) -> ServeMetrics:
        first, ttft = self.prefill(prompts)
        t0 = time.perf_counter()
        toks, itls = self.decode(first, gen_steps)
        # tok/s over the decode wall clock, not itls.sum(): the pipelined
        # path's itls are steady-state-only (fill excluded), so summing them
        # would inflate its tok/s relative to the depth-1 baseline
        decode_wall = time.perf_counter() - t0
        total = toks.shape[0] * toks.shape[1]
        for t in itls:      # straggler signal over the ITL stream
            self.watchdog.observe(float(t))
        # EPLB: fold the tracked per-expert heat into the metrics so serving
        # benchmarks report load imbalance alongside latency
        heat = self._tracked_heat()
        heat_mm = rank_mm = None
        if heat is not None:
            heat_mm = PL.imbalance(heat)
            n = self._ep_size()
            phys = (self.cfg.moe.placement.num_slots
                    if self.cfg.moe.placement is not None
                    else self.cfg.moe.num_experts)
            if n > 1 and phys % n == 0:
                # per-window attribution: drained windows were charged to
                # their active placement in _maybe_rebalance; only the
                # residual device counter ran under the current placement
                rl = PL.rank_loads(self._device_heat(),
                                   self.cfg.moe.placement, n)
                if self._rank_loads is not None:
                    rl = self._rank_loads + rl
                rank_mm = PL.imbalance(rl)
        return ServeMetrics(
            ttft_s=ttft, itl_mean_s=float(itls.mean()),
            itl_p99_s=float(np.percentile(itls, 99)),
            output_tok_s=total / (ttft + decode_wall),
            total_tokens=total,
            expert_heat=None if heat is None else heat.tolist(),
            heat_max_mean=heat_mm, rank_heat_max_mean=rank_mm,
            degraded_steps=self._degraded_steps,
            recovery_count=len(self.recoveries),
            recovery_latency_s=self._recovery_wall_s or None,
            recovery_events=list(self.recoveries) or None,
            checkpoint_restores=self._ckpt_restores,
            alive_ranks=(list(self._detector.alive)
                         if self._detector is not None else None),
            stragglers_flagged=self.watchdog.flagged,
            preempted=self.preempted,
            timeline=self.tracer.summary() or None,
            series=list(self.series.rows) or None)


class ContinuousDecodeServer(DecodeServer):
    """Continuous-batching serving engine over the paged KV pool.

    Same fault/rebalance/preemption machinery as DecodeServer — the engine
    hooks swap the decode state for per-layer page pools
    (models/kv_pages.py) and the step for the paged split-KV decode
    (runtime/steps.make_paged_serve_step) — plus ``serve_requests``: a
    request-level loop where admission, slot recycling, and page alloc/free
    all happen at the same step boundaries placement swaps and fault
    recoveries already use. ``batch`` is the fixed max concurrency (slot
    count); the page table / kv_lens / active mask are host-built per-step
    inputs with fixed shapes, so join/leave never retraces the step.

    Per-request token streams are bitwise identical to running each request
    alone through this same engine (and across placement swaps / rank-kill
    transitions): rows are batch-independent end to end given zero-drop MoE
    capacity — a capacity_factor would let co-residents compete for expert
    slots and break that, so it is rejected here.

    Pipelining stays depth-1: continuous batching feeds each request's
    PREVIOUS output token back in, so the host readback the fixed-batch
    pipelined path avoids is inherent here.
    """

    def __init__(self, cfg: ArchConfig, batch: int, max_len: int, mesh=None,
                 *, page_size: int = 8, num_pages: int | None = None,
                 **kwargs):
        from repro.models import kv_pages as KVP
        from repro.models.registry import get_model as _gm
        if _gm(cfg).paged_decode_step is None:
            raise NotImplementedError(
                f"family {cfg.family!r} has no paged decode path")
        a = cfg.attn
        if a is None or a.window is not None:
            raise NotImplementedError(
                "continuous batching requires non-windowed attention "
                "(sliding-window paged decode is not implemented)")
        if a.kv_chunk % page_size:
            raise ValueError(
                f"kv_chunk={a.kv_chunk} must be a multiple of "
                f"page_size={page_size} — chunked prefill attention and the "
                "paged decode kernel must agree on tiling")
        if cfg.moe and cfg.moe.capacity_factor is not None:
            raise ValueError(
                "continuous batching requires zero-drop MoE routing "
                "(capacity_factor=None): capacity competition couples "
                "co-resident requests and breaks solo-parity")
        if int(kwargs.get("pipeline_depth", 1)) > 1:
            raise ValueError("continuous batching is depth-1: the next step "
                             "consumes this step's tokens host-side")
        self.page_size = int(page_size)
        # page-table width: enough pages for max_len, rounded up so the
        # configured split count divides it (padding entries are pad pages)
        mp = KVP.pages_for_tokens(max_len, self.page_size)
        s = max(int(a.decode_kv_splits), 1)
        self.max_pages = -(-mp // s) * s
        # default pool = the dense-equivalent reservation (batch x max_len):
        # never exhausts; pass a smaller pool to realize the memory win
        self.num_pages = (int(num_pages) if num_pages is not None
                          else batch * self.max_pages)
        self.max_len = max_len
        self.reqsched = None
        super().__init__(cfg, batch, max_len, mesh, **kwargs)

    def _init_state(self, batch: int, max_len: int):
        st_spec, _ = paged_serve_state_specs(
            self.cfg, batch, self.num_pages, self.page_size, self.max_pages)
        return jax.tree.map(
            jnp.zeros_like,
            init_from_specs(jax.random.PRNGKey(1), st_spec, self.mesh))

    def _step_factory(self):
        return make_paged_serve_step(self.cfg, self.mesh)

    def serve_requests(self, requests, max_steps: int | None = None
                       ) -> ServeMetrics:
        """Run the continuous-batching loop until every request completes
        (or ``max_steps``). Placement swaps, fault recoveries, and
        preemption run at the same boundaries as admission/retirement —
        page tables are host state, so a transition can never corrupt them
        (pinned by tests/test_elastic.py)."""
        from repro.models.kv_pages import PageAllocator, pages_for_tokens
        from repro.runtime.scheduler import ContinuousScheduler
        allocator = PageAllocator(self.num_pages, self.page_size)
        sched = ContinuousScheduler(requests, self.batch, self.max_pages,
                                    allocator,
                                    tracer=(self.tracer if self.tracer.enabled
                                            else None))
        self.reqsched = sched
        record = self.series.enabled
        t0 = time.perf_counter()
        step_idx = 0
        marks = []
        while not sched.done:
            if max_steps is not None and step_idx >= max_steps:
                break
            with self.tracer.span("admission"):
                feed = sched.advance(step_idx)
            with self.tracer.span("serve_step"):
                tok, self.state = self.step(self.params, self.state, feed)
                jax.block_until_ready(tok)
            now = time.perf_counter()
            sched.observe(np.asarray(tok), now)
            if record:
                # pure host state — engine occupancy at this boundary
                itl = now - (marks[-1] if marks else t0)
                self._win_itls.append(itl)
                self.series.record(
                    kind="step", step=step_idx, itl_s=itl,
                    queue_depth=len(sched.queue), active=sched.live_count,
                    pages_live=allocator.live_count,
                    pages_peak=allocator.peak_live)
            marks.append(now)
            report = self._poll_faults(step_idx)
            if report is not None:
                self._recover(step_idx, report)
            else:
                self._maybe_rebalance(step_idx)
            if self._detector is not None and self._detector.dead:
                self._degraded_steps += 1
            if self.guard.should_stop:
                self._preempt(step_idx)
                break
            step_idx += 1
        wall = time.perf_counter() - t0
        step_itls = np.diff(np.asarray(marks)) if len(marks) > 1 else np.asarray([0.0])
        for t in step_itls:
            self.watchdog.observe(float(t))
        recs = [sched.request_metrics(rid) for rid in sorted(sched.finished)]
        ttfts = np.asarray([r["ttft_s"] for r in recs]) if recs else np.asarray([0.0])
        itls = np.concatenate([np.asarray(r["itl_s"]) for r in recs
                               if r["itl_s"]] or [np.zeros(1)])
        total = int(sum(r["tokens"] for r in recs))
        heat = self._tracked_heat()
        heat_mm = rank_mm = None
        if heat is not None:
            heat_mm = PL.imbalance(heat)
            n = self._ep_size()
            phys = (self.cfg.moe.placement.num_slots
                    if self.cfg.moe.placement is not None
                    else self.cfg.moe.num_experts)
            if n > 1 and phys % n == 0:
                rl = PL.rank_loads(self._device_heat(),
                                   self.cfg.moe.placement, n)
                if self._rank_loads is not None:
                    rl = self._rank_loads + rl
                rank_mm = PL.imbalance(rl)
        return ServeMetrics(
            ttft_s=float(ttfts.mean()),
            itl_mean_s=float(itls.mean()),
            itl_p99_s=float(np.percentile(itls, 99)),
            output_tok_s=total / wall if wall > 0 else 0.0,
            total_tokens=total,
            ttft_p50_s=float(np.percentile(ttfts, 50)),
            ttft_p95_s=float(np.percentile(ttfts, 95)),
            ttft_p99_s=float(np.percentile(ttfts, 99)),
            itl_p50_s=float(np.percentile(itls, 50)),
            itl_p95_s=float(np.percentile(itls, 95)),
            requests_completed=len(recs),
            serve_steps=step_idx,
            pages_peak=allocator.peak_live,
            # dense baseline = un-rounded B x ceil(S_max/page): what a dense
            # [B, S_max] cache would pin regardless of live occupancy
            pages_dense_equiv=self.batch * pages_for_tokens(self.max_len,
                                                            self.page_size),
            per_request=recs,
            expert_heat=None if heat is None else heat.tolist(),
            heat_max_mean=heat_mm, rank_heat_max_mean=rank_mm,
            degraded_steps=self._degraded_steps,
            recovery_count=len(self.recoveries),
            recovery_latency_s=self._recovery_wall_s or None,
            recovery_events=list(self.recoveries) or None,
            checkpoint_restores=self._ckpt_restores,
            alive_ranks=(list(self._detector.alive)
                         if self._detector is not None else None),
            stragglers_flagged=self.watchdog.flagged,
            preempted=self.preempted,
            timeline=self.tracer.summary() or None,
            series=list(self.series.rows) or None)
