"""Host-side serving telemetry: step-timeline tracer + windowed time series.

The serving loop (PRs 4-8) makes load-bearing runtime decisions — heat-driven
placement swaps, fault shrink/expand, admission/retirement, page allocation —
that were previously only visible as end-of-run ``ServeMetrics`` scalars.
This module makes them observable without perturbing the thing observed:

* ``Tracer`` records named spans and instant events at EXISTING host-side
  step boundaries (``serve_step``, ``prefill``, ``rebalance``, ``adopt``,
  ``fault_poll``, ``recover:shrink`` / ``recover:expand``, ``admission``,
  ``checkpoint``) and exports Chrome-trace / Perfetto JSON.
* ``TimeSeries`` records per-window rows (ITL, queue depth, active slots,
  pages live/peak, per-rank heat + imbalance ratio, alive ranks,
  straggler/rebase counters) and exports JSONL.

Hard contracts (pinned by tests/test_telemetry.py):

* **Host-side only, boundary-scoped.** Telemetry never adds a device sync:
  spans wrap host code that already runs at step boundaries, and heat series
  rows reuse the ``device_get`` the rebalancer/recovery path already
  performs. Decode token streams are bitwise identical tracing on vs off.
* **Disabled == no-op.** ``NULL_TRACER`` / ``NULL_SERIES`` are shared
  singletons whose methods allocate nothing per step (``span`` returns one
  shared no-op context manager; ``record`` returns immediately).
* **Deterministic tests.** The clock is injectable (monotonic callable
  returning seconds); tests drive a fake clock and assert exact durations.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Callable, Iterable


def json_safe(obj):
    """Recursively coerce numpy scalars/arrays (and other non-JSON leaves)
    into plain Python so ``json.dumps`` succeeds on metrics payloads."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    # numpy scalars expose .item(); arrays expose .tolist()
    if hasattr(obj, "item") and not hasattr(obj, "__len__"):
        return json_safe(obj.item())
    if hasattr(obj, "tolist"):
        return json_safe(obj.tolist())
    return str(obj)


class _NullSpan:
    """Shared no-op context manager handed out by a disabled tracer."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Context manager recording one complete ("X") Chrome-trace event."""
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc):
        tr = self._tracer
        tr._events.append(("X", self._name, self._t0,
                           tr.clock() - self._t0, self._args))
        return False


class Tracer:
    """Named spans + instant events with an injectable monotonic clock.

    Events are stored as host tuples ``(ph, name, t_s, dur_s, args)`` and
    exported as Chrome-trace JSON (``ts``/``dur`` in microseconds relative
    to the tracer's construction time), loadable in Perfetto / chrome://tracing.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 pid: int = 0, tid: int = 0):
        self.clock = clock
        self.pid = pid
        self.tid = tid
        self._t0 = clock()
        self._events: list[tuple] = []   # (ph, name, t_s, dur_s, args)

    # -- recording ---------------------------------------------------------
    def span(self, name: str, **args) -> _Span:
        """Context manager timing a named host-side region."""
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        self._events.append(("i", name, self.clock(), 0.0, args))

    def counter(self, name: str, value: float) -> None:
        self._events.append(("C", name, self.clock(), 0.0, {"value": value}))

    # -- export ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> list[tuple]:
        return list(self._events)

    def summary(self) -> dict:
        """Per-name aggregate (count + total seconds for spans) folded into
        ``ServeMetrics.timeline``. JSON-safe by construction."""
        out: dict[str, dict] = {}
        for ph, name, _t, dur, _a in self._events:
            row = out.setdefault(name, {"count": 0, "total_s": 0.0, "ph": ph})
            row["count"] += 1
            if ph == "X":
                row["total_s"] = round(row["total_s"] + float(dur), 9)
        return out

    def to_chrome_trace(self) -> dict:
        ev = []
        for ph, name, t, dur, args in self._events:
            e = {"name": name, "ph": ph, "pid": self.pid, "tid": self.tid,
                 "ts": round((t - self._t0) * 1e6, 3)}
            if ph == "X":
                e["dur"] = round(dur * 1e6, 3)
            if ph == "i":
                e["s"] = "t"                      # thread-scoped instant
            if args:
                e["args"] = json_safe(args)
            ev.append(e)
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome_trace()))
        return path


class NullTracer:
    """Disabled tracer: every method is a no-op with no per-call allocation
    (``span`` returns one shared context-manager object)."""

    enabled = False

    def span(self, name, **args):
        return _NULL_SPAN

    def instant(self, name, **args):
        return None

    def counter(self, name, value):
        return None

    def __len__(self):
        return 0

    def events(self):
        return []

    def summary(self):
        return {}

    def to_chrome_trace(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = NullTracer()


class TimeSeries:
    """Append-only recorder of per-window metric rows (plain dicts)."""

    enabled = True

    def __init__(self):
        self.rows: list[dict] = []

    def record(self, **fields) -> None:
        self.rows.append(json_safe(fields))

    def __len__(self) -> int:
        return len(self.rows)

    def to_jsonl(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as f:
            for row in self.rows:
                f.write(json.dumps(row) + "\n")
        return path


class NullTimeSeries:
    """Disabled series: ``record`` returns immediately, ``rows`` stays ()."""

    enabled = False
    rows: tuple = ()

    def record(self, **fields):
        return None

    def __len__(self):
        return 0


NULL_SERIES = NullTimeSeries()


def validate_chrome_trace(obj: dict) -> list[dict]:
    """Assert ``obj`` is well-formed Chrome-trace JSON; return its events.

    Checks the event-format invariants CI relies on: a ``traceEvents`` list;
    every event has ``name``/``ph``/``pid``/``tid``/``ts`` with ``ph`` in
    {X, i, C}; ``ts >= 0`` and ``dur >= 0``; and complete ("X") spans
    properly NEST per (pid, tid) — a span either contains or is disjoint
    from every other span on its track (no partial overlap).
    """
    assert isinstance(obj, dict), f"trace root must be a dict, got {type(obj)}"
    events = obj.get("traceEvents")
    assert isinstance(events, list), "trace must carry a traceEvents list"
    tracks: dict[tuple, list[tuple]] = {}
    for i, e in enumerate(events):
        assert isinstance(e, dict), f"event {i} is not an object: {e!r}"
        for key in ("name", "ph", "pid", "tid", "ts"):
            assert key in e, f"event {i} missing {key!r}: {e!r}"
        assert e["ph"] in ("X", "i", "C"), f"event {i} bad ph: {e['ph']!r}"
        assert e["ts"] >= 0, f"event {i} negative ts: {e['ts']}"
        if e["ph"] == "X":
            assert "dur" in e, f"span event {i} missing dur: {e!r}"
            assert e["dur"] >= 0, f"event {i} negative dur: {e['dur']}"
            tracks.setdefault((e["pid"], e["tid"]), []).append(
                (float(e["ts"]), float(e["ts"]) + float(e["dur"]), e["name"]))
    eps = 1e-6        # µs rounding slack from the 3-decimal export
    for track, spans in tracks.items():
        # sort by start asc, end desc: a containing span sorts before its
        # children, so a containment stack detects partial overlap.
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple] = []
        for t0, t1, name in spans:
            while stack and t0 >= stack[-1][1] - eps:
                stack.pop()
            if stack:
                assert t1 <= stack[-1][1] + eps, (
                    f"track {track}: span {name!r} [{t0}, {t1}] partially "
                    f"overlaps {stack[-1][2]!r} [{stack[-1][0]}, "
                    f"{stack[-1][1]}] — spans must nest")
            stack.append((t0, t1, name))
    return events


def load_chrome_trace(path) -> dict:
    return json.loads(pathlib.Path(path).read_text())


def span_names(events: Iterable[dict]) -> list[str]:
    """Names of complete ("X") events, in file order."""
    return [e["name"] for e in events if e.get("ph") == "X"]


__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER",
    "TimeSeries", "NullTimeSeries", "NULL_SERIES",
    "json_safe", "validate_chrome_trace", "load_chrome_trace", "span_names",
]
