"""Training loop: jitted train_step + checkpointing + fault tolerance +
straggler watchdog. Drives any registered architecture on any mesh."""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.data import DataConfig, DataPipeline
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, adamw_init, adamw_init_specs
from repro.parallel.sharding import init_from_specs, abstract_from_specs
from repro.runtime.fault import PreemptionGuard, StragglerWatchdog, StepTimer
from repro.runtime.steps import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig, mesh=None,
                 opt_cfg: AdamWConfig | None = None):
        if cfg.moe and cfg.moe.params_physical:
            # adopt-once physical weights are a SERVING layout: under
            # training, gradients would flow to physical slots independently
            # and replicas of one expert would diverge, breaking the
            # replica-consistency invariant every placed transfer relies on.
            # Training keeps logical [E, ...] storage + the in-graph per-step
            # expansion (placements may swap mid-epoch; checkpoints stay
            # placement-independent — docs/DESIGN.md §8).
            raise ValueError(
                "MoESpec.params_physical=True is a serving-only layout; "
                "train with params_physical=False (logical expert weights)")
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.opt_cfg = opt_cfg or AdamWConfig(
            total_steps=tcfg.steps, warmup_steps=max(tcfg.steps // 20, 1))
        self.model = get_model(cfg)
        self.data = DataPipeline(DataConfig(
            vocab=cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, microbatch=max(cfg.microbatch, 1),
            seed=tcfg.seed), mesh)
        self.step_fn = jax.jit(make_train_step(cfg, mesh, self.opt_cfg),
                               donate_argnums=(0, 1))
        self.guard = PreemptionGuard()
        self.watchdog = StragglerWatchdog()
        self.metrics_log: list[dict] = []

    # ---- state management -------------------------------------------------
    def init_state(self):
        from repro.parallel.sharding import arch_rules
        pspec = self.model.params_spec(self.cfg)
        params = init_from_specs(jax.random.PRNGKey(self.tcfg.seed), pspec,
                                 self.mesh, arch_rules(self.cfg))
        opt = adamw_init(params, self.opt_cfg)
        return params, opt

    def maybe_restore(self):
        if not self.tcfg.ckpt_dir:
            return None
        step = latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return None
        pspec = self.model.params_spec(self.cfg)
        ospec = adamw_init_specs(pspec, self.opt_cfg)
        (params, opt, dstate), idx = restore_checkpoint(
            self.tcfg.ckpt_dir, step, (pspec, ospec,
                                       dict(step=np.zeros((), np.int64),
                                            seed=np.zeros((), np.int64))),
            mesh=self.mesh)
        self.data.restore({k: int(v) for k, v in dstate.items()})
        return params, opt

    def save(self, params, opt):
        if not self.tcfg.ckpt_dir:
            return
        ds = self.data.state()
        save_checkpoint(self.tcfg.ckpt_dir, self.data.step,
                        (params, opt, {k: np.int64(v) for k, v in ds.items()}))

    # ---- main loop ---------------------------------------------------------
    def run(self):
        restored = self.maybe_restore()
        if restored is not None:
            params, opt = restored
            print(f"[trainer] resumed at data step {self.data.step}")
        else:
            params, opt = self.init_state()
        preempted = False
        while self.data.step < self.tcfg.steps:
            batch = next(self.data)
            t = StepTimer()
            with t:
                params, opt, m = self.step_fn(params, opt, batch)
                jax.block_until_ready(m["loss"])
            if self.watchdog.observe(t.times[-1]):
                print(f"[watchdog] straggler step {self.data.step}: "
                      f"{t.times[-1]:.2f}s vs ema {self.watchdog.ema:.2f}s")
            if self.data.step % self.tcfg.log_every == 0:
                rec = dict(step=self.data.step, loss=float(m["loss"]),
                           gnorm=float(m["grad_norm"]), t=t.times[-1],
                           stragglers_flagged=self.watchdog.flagged,
                           watchdog_rebased=self.watchdog.rebased)
                self.metrics_log.append(rec)
                print(f"[train] step={rec['step']} loss={rec['loss']:.4f} "
                      f"gnorm={rec['gnorm']:.3f} {rec['t']*1e3:.0f}ms"
                      + (f" stragglers={rec['stragglers_flagged']}"
                         if rec['stragglers_flagged'] else ""))
            if (self.tcfg.ckpt_dir and
                    self.data.step % self.tcfg.ckpt_every == 0):
                self.save(params, opt)
            if self.guard.should_stop:
                print("[trainer] preemption signal — checkpoint + exit")
                self.save(params, opt)
                preempted = True
                break
        if not preempted and self.tcfg.ckpt_dir:
            self.save(params, opt)
        return params, opt
