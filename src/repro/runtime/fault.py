"""Fault tolerance: preemption-safe checkpointing, restart, stragglers,
rank-death detection, and elastic recovery.

At 1000+-node scale the failure model is: (a) planned preemption (SIGTERM
with grace), (b) hard node loss (step dies; orchestrator restarts the job on
a reconfigured slice), (c) stragglers (synchronous collectives make the step
time the max over nodes), and (d) EP rank death mid-serve (UBEP, PAPERS.md:
a production EP library must shrink around a dead rank instead of restarting
the world). The corresponding mechanisms here:

  * SIGTERM/SIGINT handler sets a flag checked once per step; the loop then
    writes a synchronous checkpoint (data-pipeline state included) and exits
    cleanly — restart resumes bit-exact from (params, opt, data.step). Both
    ``Trainer`` and ``DecodeServer.serve`` poll the guard at step boundaries
    (the server's checkpoint is placement-tagged, docs/DESIGN.md §9).
  * restart: `latest_step()` + elastic `restore_checkpoint` re-shards onto
    the new mesh — node replacement and scale changes are the same code path.
  * stragglers: a step-time watchdog keeps an EMA and flags outliers
    (> factor x EMA). A transient outlier never updates the EMA; a
    *persistent* slowdown (``rebase_after`` consecutive outliers — a new
    steady state, e.g. thermal throttling) re-bases the EMA so the flag
    clears instead of firing forever. Under synchronous SPMD the mitigation
    is detect -> checkpoint -> evict -> elastic restart; the watchdog emits
    the signal an orchestrator would consume (surfaced through
    ``ServeMetrics.stragglers_flagged`` and the Trainer metrics log).
  * rank death: ``FaultDetector`` watches per-rank heartbeats at serving-step
    boundaries and declares a rank dead after ``miss_threshold`` consecutive
    silent boundaries (or a wall-clock ``timeout_s``); a dead rank that
    heartbeats again is reported as rejoined. ``FaultInjector`` is the
    deterministic test/bench fault source: a step-keyed kill/rejoin schedule
    that suppresses the victims' heartbeats so detection takes the exact
    path a production transport error would. Recovery — degraded placement
    on survivors, weight re-adoption, later re-expand — is the driver's job
    (`runtime/server.py DecodeServer`, `core/placement.py run_rebalancing`);
    docs/DESIGN.md §9 records the contract.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import NamedTuple


class DegradedRecovery(UserWarning):
    """A rank death could NOT be absorbed with zero data loss: some experts
    had every replica on dead ranks, so their weights are unrecoverable from
    survivors. The driver falls back to checkpoint restore when one is
    available and raises otherwise — this warning is the loud marker that
    the recovery was degraded, never silent corruption (docs/DESIGN.md §9)."""


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers; `should_stop` is polled per step."""

    def __init__(self):
        self._stop = False
        self._orig = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:      # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def restore(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)
        self._orig = {}


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time monitor; ``observe`` returns True when the step is an
    outlier (> factor x EMA). Transient outliers never update the EMA (one
    slow collective must not poison the baseline) — but a slowdown that
    *persists* for ``rebase_after`` consecutive steps is a new steady state
    (thermal throttling, a degraded link), so the EMA re-bases to the mean
    of that outlier run and the flag clears instead of firing forever.
    ``flagged``/``rebased`` are the counters drivers surface
    (``ServeMetrics.stragglers_flagged``, Trainer metrics log); with a
    ``tracer`` attached, each flag/rebase additionally lands on the step
    timeline as an instant event (``straggler`` / ``watchdog_rebase``) so
    the trace shows *when* the outlier run happened, not just the total."""
    factor: float = 2.5
    decay: float = 0.9
    rebase_after: int = 5
    ema: float | None = None
    flagged: int = 0
    rebased: int = 0
    consecutive: int = 0
    _outlier_sum: float = 0.0
    tracer: object | None = None

    def observe(self, step_time: float) -> bool:
        if self.ema is None:
            self.ema = step_time
            return False
        outlier = step_time > self.factor * self.ema
        if outlier:
            self.flagged += 1
            self.consecutive += 1
            self._outlier_sum += step_time
            if self.tracer is not None:
                self.tracer.instant("straggler", step_time_s=step_time,
                                    ema_s=self.ema, consecutive=self.consecutive)
            if self.consecutive >= self.rebase_after:
                # persistent new steady state: re-base on the outlier run
                self.ema = self._outlier_sum / self.consecutive
                self.rebased += 1
                self.consecutive = 0
                self._outlier_sum = 0.0
                if self.tracer is not None:
                    self.tracer.instant("watchdog_rebase", new_ema_s=self.ema,
                                        rebased=self.rebased)
        else:
            self.consecutive = 0
            self._outlier_sum = 0.0
            self.ema = self.decay * self.ema + (1 - self.decay) * step_time
        return outlier


class StepTimer:
    def __init__(self):
        self.t0 = None
        self.times = []

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.times.append(time.perf_counter() - self.t0)


# --------------------------------------------------------------------------
# rank-death detection (elastic EP)
# --------------------------------------------------------------------------

class FaultReport(NamedTuple):
    """What one detector poll found: ranks newly declared dead and dead
    ranks that came back. Empty tuples = healthy boundary."""
    died: tuple[int, ...] = ()
    rejoined: tuple[int, ...] = ()

    def __bool__(self):
        return bool(self.died or self.rejoined)

    def merge(self, other: "FaultReport") -> "FaultReport":
        """Coalesce a later report into this one: the combined report the
        driver treats as ONE fault event, so back-to-back detections within
        a single step boundary trigger one degraded-placement transition —
        one fingerprint bump, one handle rebuild, one weight adoption —
        instead of one per dead rank. A rank that died in one report and
        rejoined in the other cancels out (net no-op for the boundary);
        duplicates dedupe; order is normalized (sorted) since the merged
        report describes a set of simultaneous events, not a sequence."""
        died = (set(self.died) | set(other.died))
        rejoined = (set(self.rejoined) | set(other.rejoined))
        both = died & rejoined
        return FaultReport(tuple(sorted(died - both)),
                           tuple(sorted(rejoined - both)))


class FaultDetector:
    """Heartbeat/step-timeout rank-death detector, polled at serving-step
    boundaries.

    Each live rank calls ``heartbeat(rank, step)`` once per step (in this
    single-host harness the driver forwards heartbeats for every rank the
    ``FaultInjector`` says is alive; on a real pod the transport layer
    would). ``poll(step)`` then declares dead any rank silent for
    ``miss_threshold`` consecutive boundaries — strictly step-count based,
    so detection is deterministic for tests — optionally OR'd with a
    wall-clock ``timeout_s`` (the production knob: a rank pinned in a hung
    collective misses wall time before it misses steps). A dead rank whose
    heartbeat resumes is reported ``rejoined`` at the next poll. The
    detector only *reports*; placement shrink/expand is the caller's move.
    """

    def __init__(self, num_ranks: int, *, miss_threshold: int = 2,
                 timeout_s: float | None = None):
        if num_ranks < 1:
            raise ValueError(f"num_ranks={num_ranks} must be >= 1")
        if miss_threshold < 1:
            raise ValueError(f"miss_threshold={miss_threshold} must be >= 1")
        self.num_ranks = num_ranks
        self.miss_threshold = miss_threshold
        self.timeout_s = timeout_s
        self._last_step = {r: -1 for r in range(num_ranks)}
        self._last_time = {r: None for r in range(num_ranks)}
        self._dead: set[int] = set()

    def heartbeat(self, rank: int, step: int, now: float | None = None):
        if not 0 <= rank < self.num_ranks:
            raise ValueError(f"rank {rank} out of range [0, {self.num_ranks})")
        self._last_step[rank] = step
        self._last_time[rank] = time.perf_counter() if now is None else now

    def poll(self, step: int, now: float | None = None) -> FaultReport:
        """Evaluate liveness at a step boundary. A rank is dead when it has
        been silent for >= miss_threshold boundaries (a rank that NEVER
        heartbeat counts from step 0) or, with ``timeout_s``, when its last
        heartbeat is older than the timeout."""
        died, rejoined = [], []
        for r in range(self.num_ranks):
            missed = step - self._last_step[r]
            timed_out = missed >= self.miss_threshold
            if (not timed_out and self.timeout_s is not None
                    and self._last_time[r] is not None):
                t = time.perf_counter() if now is None else now
                timed_out = (t - self._last_time[r]) > self.timeout_s
            if r in self._dead:
                if not timed_out:
                    self._dead.discard(r)
                    rejoined.append(r)
            elif timed_out:
                self._dead.add(r)
                died.append(r)
        return FaultReport(tuple(died), tuple(rejoined))

    @property
    def dead(self) -> tuple[int, ...]:
        return tuple(sorted(self._dead))

    @property
    def alive(self) -> tuple[int, ...]:
        return tuple(r for r in range(self.num_ranks) if r not in self._dead)


class FaultInjector:
    """Deterministic kill/rejoin schedule for tests and benches.

    ``kill``/``rejoin`` map a step index to the rank (or ranks) that die /
    come back AT that step boundary: ``advance(step)`` applies the events
    scheduled for ``step`` and returns them as a ``FaultReport`` (here
    "died" means *injected*, not yet detected — detection is the
    ``FaultDetector``'s job, fed by the injector suppressing the victims'
    heartbeats). Pure function of the schedule and the step sequence, so
    two runs over the same schedule produce identical event logs
    (``self.log``) — the determinism tests/benches rely on.

    Correlated (whole-domain) failures: ``kill_domains``/``rejoin_domains``
    schedule entire fault domains — ``{step: domain_id_or_ids}`` against the
    ``domains`` topology (`core/placement.FaultDomains`) — and expand to
    every rank in the domain dying/rejoining AT THE SAME step boundary (a
    pod losing power is one event, not a sequence). Expanded events merge
    with any per-rank schedule for the same step.
    """

    def __init__(self, num_ranks: int, *, kill=None, rejoin=None,
                 domains=None, kill_domains=None, rejoin_domains=None):
        self.num_ranks = num_ranks
        self.domains = domains
        if (kill_domains or rejoin_domains) and domains is None:
            raise ValueError(
                "kill_domains/rejoin_domains need the domains= topology "
                "(core/placement.FaultDomains) to expand to ranks")
        if domains is not None and domains.num_ranks != num_ranks:
            raise ValueError(f"domains cover {domains.num_ranks} ranks, "
                             f"injector spans num_ranks={num_ranks}")

        def norm(d):
            out = {}
            for step, ranks in (d or {}).items():
                rs = (ranks,) if isinstance(ranks, int) else tuple(ranks)
                for r in rs:
                    if not 0 <= r < num_ranks:
                        raise ValueError(
                            f"rank {r} out of range [0, {num_ranks})")
                out[int(step)] = rs
            return out

        def expand(dom_sched, rank_sched):
            for step, ds in (dom_sched or {}).items():
                ds = (ds,) if isinstance(ds, int) else tuple(ds)
                ranks = []
                for d in ds:
                    rs = domains.ranks_in(d)
                    if not rs:
                        raise ValueError(
                            f"domain {d} has no ranks in "
                            f"{domains.describe()}")
                    ranks.extend(rs)
                step = int(step)
                rank_sched[step] = tuple(dict.fromkeys(
                    rank_sched.get(step, ()) + tuple(ranks)))
            return rank_sched

        self.kill = expand(kill_domains, norm(kill))
        self.rejoin = expand(rejoin_domains, norm(rejoin))
        self._dead: set[int] = set()
        self.log: list[tuple[int, FaultReport]] = []

    def advance(self, step: int) -> FaultReport:
        killed = tuple(r for r in self.kill.get(step, ())
                       if r not in self._dead)
        rejoined = tuple(r for r in self.rejoin.get(step, ())
                         if r in self._dead)
        self._dead |= set(killed)
        self._dead -= set(rejoined)
        report = FaultReport(killed, rejoined)
        if report:
            self.log.append((step, report))
        return report

    def is_alive(self, rank: int) -> bool:
        return rank not in self._dead

    @property
    def dead_ranks(self) -> tuple[int, ...]:
        return tuple(sorted(self._dead))
