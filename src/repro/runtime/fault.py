"""Fault tolerance: preemption-safe checkpointing, restart, stragglers.

At 1000+-node scale the failure model is: (a) planned preemption (SIGTERM
with grace), (b) hard node loss (step dies; orchestrator restarts the job on
a reconfigured slice), (c) stragglers (synchronous collectives make the step
time the max over nodes). The corresponding mechanisms here:

  * SIGTERM/SIGINT handler sets a flag checked once per step; the loop then
    writes a synchronous checkpoint (data-pipeline state included) and exits
    cleanly — restart resumes bit-exact from (params, opt, data.step).
  * restart: `latest_step()` + elastic `restore_checkpoint` re-shards onto
    the new mesh — node replacement and scale changes are the same code path.
  * stragglers: a step-time watchdog keeps an EMA and flags outliers
    (> factor x EMA). Under synchronous SPMD the mitigation is detect ->
    checkpoint -> evict -> elastic restart; the watchdog emits the signal an
    orchestrator would consume.
"""
from __future__ import annotations

import dataclasses
import signal
import time


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers; `should_stop` is polled per step."""

    def __init__(self):
        self._stop = False
        self._orig = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:      # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def restore(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time monitor; returns True when the step is an outlier."""
    factor: float = 2.5
    decay: float = 0.9
    ema: float | None = None
    flagged: int = 0

    def observe(self, step_time: float) -> bool:
        if self.ema is None:
            self.ema = step_time
            return False
        outlier = step_time > self.factor * self.ema
        if outlier:
            self.flagged += 1
        else:
            self.ema = self.decay * self.ema + (1 - self.decay) * step_time
        return outlier


class StepTimer:
    def __init__(self):
        self.t0 = None
        self.times = []

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.times.append(time.perf_counter() - self.t0)
