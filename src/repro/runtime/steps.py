"""Step factories: the jitted units the launcher, dry-run, and roofline all
share.

``train_step``: microbatched (gradient-accumulation scan) value_and_grad +
AdamW update. ``serve_step``: one decode token against the KV/state cache,
returning greedy next tokens (the paper's LL decode loop unit).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, adamw_update
from repro.parallel.sharding import ParamSpec, abstract_from_specs


# --------------------------------------------------------------------------
# batch/state spec builders (ShapeDtypeStruct factories for the dry-run)
# --------------------------------------------------------------------------

def train_batch_specs(cfg: ArchConfig, global_batch: int, seq: int):
    """Returns pytree of ParamSpec for one *global* batch, shaped
    [microbatch, B/microbatch, ...] when gradient accumulation is on."""
    g = max(cfg.microbatch, 1)
    assert global_batch % g == 0, (global_batch, g)
    b = global_batch // g

    def tok(shape):
        return ParamSpec(shape, jnp.int32, (None, "batch") + (None,) * (len(shape) - 2))

    batch = dict(tokens=tok((g, b, seq)), targets=tok((g, b, seq)))
    if cfg.family == "vlm":
        batch["img_embeds"] = ParamSpec((g, b, cfg.img_tokens, cfg.d_model),
                                        cfg.dtype, (None, "batch", None, None))
    if cfg.family == "encdec":
        batch["src_embeds"] = ParamSpec((g, b, cfg.src_len, cfg.d_model),
                                        cfg.dtype, (None, "batch", None, None))
    return batch


def serve_state_specs(cfg: ArchConfig, batch: int, kv_len: int, *, long=False):
    m = get_model(cfg)
    state = m.decode_state_spec(cfg, batch, kv_len, long=long)
    tokens = ParamSpec((batch, 1), jnp.int32, ("batch", None))
    return state, dict(tokens=tokens)


def paged_serve_state_specs(cfg: ArchConfig, batch: int, num_pages: int,
                            page_size: int, max_pages: int):
    """Specs for the continuous-batching paged decode step: state = per-layer
    page pools; batch inputs = tokens + host-built page table / kv_lens /
    active mask (fixed shapes — join/leave/recycle never retraces)."""
    m = get_model(cfg)
    if m.paged_decode_state_spec is None:
        raise NotImplementedError(
            f"family {cfg.family!r} has no paged decode path")
    state = m.paged_decode_state_spec(cfg, num_pages, page_size)
    batch_specs = dict(
        tokens=ParamSpec((batch, 1), jnp.int32, ("batch", None)),
        page_tbl=ParamSpec((batch, max_pages), jnp.int32, ("batch", None)),
        kv_lens=ParamSpec((batch,), jnp.int32, ("batch",)),
        active=ParamSpec((batch,), jnp.int32, ("batch",)),
    )
    return state, batch_specs


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig()
    model = get_model(cfg)

    def loss_fn(params, micro):
        loss, _ = model.forward(params, micro, cfg, mesh)
        return loss

    def train_step(params, opt_state, batch):
        g = jax.tree.leaves(batch)[0].shape[0]

        def acc_body(carry, micro):
            loss_sum, grad_sum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, micro)
            grad_sum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), grad_sum, grads)
            return (loss_sum + loss, grad_sum), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            acc_body, (jnp.float32(0), zero_grads), batch)
        grads = jax.tree.map(lambda x: x / g, grads)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(loss=loss_sum / g, **om)
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, mesh):
    model = get_model(cfg)

    def serve_step(params, state, batch):
        logits, state = model.decode_step(params, state, batch, cfg, mesh)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)
        return next_tok[:, None], state

    return serve_step


def make_paged_serve_step(cfg: ArchConfig, mesh):
    """Greedy serve step over the paged decode path — same (params, state,
    batch) -> (tokens, state) signature as make_serve_step, so the server's
    compiled-step cache, placement re-jits, and fault recovery treat both
    identically."""
    model = get_model(cfg)
    if model.paged_decode_step is None:
        raise NotImplementedError(
            f"family {cfg.family!r} has no paged decode path")

    def serve_step(params, state, batch):
        logits, state = model.paged_decode_step(params, state, batch, cfg, mesh)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)
        return next_tok[:, None], state

    return serve_step
