"""Chunked HT prefill pipeline — §V's throughput overlap made a driver.

The hierarchical HT path earns its throughput by *streaming*: the
chunk-pipelined dispatch (core/ht.py, ``ht_num_chunks``) overlaps the
intra-pod hop with the inter-pod hop inside one EP call, and this driver
adds the layer above — overlapping the HT dispatch collectives of one
micro-batch with the grouped-GEMM expert pass of the previous one, for the
4096+-tokens-per-rank prefill regime the paper targets with HT mode
(decode's double buffer lives in runtime/decode.py; this is its prefill
mirror over P-way micro-batching instead of a 2-buffer window).

Built entirely on the mode-agnostic staged surface (``send_only=True`` +
``ep_complete`` — the EpBackend contract): the schedule issues micro-batch
*i+1*'s dispatch-send before completing micro-batch *i*, so XLA's async
collective scheduler can run *i+1*'s all-to-alls against *i*'s expert GEMM,
and drains every combine at the end. Because the surface is mode-agnostic
the same driver runs on LL or the baseline for apples-to-apples benchmarks
(benchmarks/bench_modes.py measures it), but the operating point it is
shaped for is HT prefill.

All functions are EP-level and must run inside the sharded region, like the
EP API itself. Size the group's ``max_tokens_per_rank`` to the micro-batch
(= T / num_microbatches) — each micro-batch carries its own handle, which is
what keeps the per-stage buffer footprint at 1/P of the monolithic call.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.api import (ep_create_handle, ep_dispatch, ep_combine,
                            ep_complete)
from repro.core.group import EpGroup, EpGroupConfig
from repro.core import placement as PL

# router_fn: tokens [T, H] -> (topk_idx [T, K], topk_weights [T, K])
RouterFn = Callable[[jax.Array], tuple[jax.Array, jax.Array]]
# expert_fn: (y3d [L, A, H], counts [L]) -> [L, A, H]
ExpertFn = Callable[[jax.Array, jax.Array], jax.Array]


def sequential_prefill(group: EpGroup, router_fn: RouterFn,
                       expert_fn: ExpertFn, x: jax.Array,
                       num_microbatches: int = 2) -> jax.Array:
    """The unpipelined reference: each micro-batch runs handle ->
    dispatch -> expert -> combine fully serialized. Bitwise-identical to
    ``prefill_moe`` (same handles, same staged computation, different
    schedule) — the benchmark baseline and the parity oracle."""
    T = x.shape[0]
    mb = num_microbatches
    assert T % mb == 0, (T, mb)
    Tm = T // mb
    outs = []
    for i in range(mb):
        xi = x[i * Tm:(i + 1) * Tm]
        ti, wi = router_fn(xi)
        h = ep_create_handle(group, ti, wi)
        y3d, counts = ep_dispatch(group, h, xi)
        outs.append(ep_combine(group, h, expert_fn(y3d, counts)))
    return jnp.concatenate(outs, axis=0)


def prefill_moe(group: EpGroup, router_fn: RouterFn, expert_fn: ExpertFn,
                x: jax.Array, num_microbatches: int = 2) -> jax.Array:
    """One prefill MoE layer over ``x`` [T, H], pipelined ``mb`` ways.

    Skewed schedule: micro-batch *i+1*'s dispatch-send is issued before
    micro-batch *i* is completed (its a2a overlaps *i*'s unpack + expert
    GEMM), every combine is issued staged, and all combines drain at the
    end — so no collective ever sits on the critical path between two
    expert GEMMs. Returns the [T, H] combined tokens in input order."""
    T = x.shape[0]
    mb = num_microbatches
    assert T % mb == 0, (T, mb)
    Tm = T // mb
    xs = [x[i * Tm:(i + 1) * Tm] for i in range(mb)]

    handles = []
    for xi in xs:
        ti, wi = router_fn(xi)
        handles.append(ep_create_handle(group, ti, wi))

    pend = [None] * mb
    pend[0] = ep_dispatch(group, handles[0], xs[0], send_only=True)
    comb = [None] * mb
    for i in range(mb):
        if i + 1 < mb:      # next micro-batch's a2a in flight over this GEMM
            pend[i + 1] = ep_dispatch(group, handles[i + 1], xs[i + 1],
                                      send_only=True)
        y3d, counts = ep_complete(group, handles[i], pend[i])
        comb[i] = ep_combine(group, handles[i], expert_fn(y3d, counts),
                             send_only=True)
    return jnp.concatenate(
        [ep_complete(group, handles[i], comb[i]) for i in range(mb)], axis=0)


# --------------------------------------------------------------------------
# EPLB: heat-driven placement rebalancing between prefill batches
# --------------------------------------------------------------------------

def rebalancing_prefill(base_cfg: EpGroupConfig, make_layer, batches,
                        *, rebalance_every: int, ep_size: int,
                        num_redundant: int = 0, inner_size: int | None = None,
                        decay: float = 0.0, rebalance_fn=PL.rebalance,
                        params=None,
                        expert_keys: tuple = PL.EXPERT_PARAM_KEYS,
                        donate_params: bool = True,
                        min_replicas: int = 1, fault_domains=None,
                        max_slots_per_rank: int | None = None):
    """Prefill mirror of ``runtime/decode.py::rebalancing_decode_loop``:
    placements swap between *batches* (a prefill batch is the natural
    scheduling boundary — within one batch the micro-batched staged pipeline
    runs on a single placement).

    ``make_layer(group) -> fn(x) -> (out, heat)``: the caller wraps one
    staged prefill layer (typically ``prefill_moe`` plus a routed-token
    histogram) in its own jit/shard_map for the group's mesh. Every
    ``rebalance_every`` batches the folded heat drives the shared
    ``RebalanceScheduler`` (same dedup semantics as the decode driver: an
    unchanged table reuses the placement object and its compiled layer).
    Returns ``(outs, placements)`` (one placement per batch; None =
    contiguous). With ``params``, ``make_layer(group, params)`` receives
    expert leaves rebound once per adopted placement (adopt-once physical
    mode; the driver owns ``params`` unless ``donate_params=False`` — see
    ``rebalancing_decode_loop``). ``min_replicas``/``fault_domains``/
    ``max_slots_per_rank`` enable the fault-domain placement floor
    (docs/DESIGN.md §9), same semantics as the decode driver."""
    return PL.run_rebalancing(
        base_cfg, make_layer, list(batches), advance_every=rebalance_every,
        ep_size=ep_size, num_redundant=num_redundant, inner_size=inner_size,
        decay=decay, rebalance_fn=rebalance_fn, params=params,
        expert_keys=expert_keys, donate_params=donate_params,
        min_replicas=min_replicas, fault_domains=fault_domains,
        max_slots_per_rank=max_slots_per_rank)
