"""Continuous-batching request scheduler: admission, slot recycling, paged-KV
bookkeeping — all strictly at decode-step boundaries.

The device step function stays jit-stable: a fixed ``max_concurrency`` slot
count, with per-step host-built inputs (tokens, page_tbl, kv_lens, active)
whose SHAPES never change. Requests join and leave only between steps — the
same boundaries where the server already takes placement swaps, fault
recoveries, and preemption (runtime/server.py), so the whole step-boundary
contract of PRs 2–7 composes unchanged.

Prefill is token-by-token through the decode step (the repo's family-
agnostic serving harness idiom, ``DecodeServer.prefill``): a newly admitted
request feeds its prompt one token per step; the step that consumes the LAST
prompt token emits the first generated token (TTFT). Every per-request
token stream is bitwise identical to running that request alone through the
same engine: rows are batch-independent end to end (paged attention masks
with exact zeros, zero-drop MoE routes per token), so co-residents — and
idle slots computing masked garbage — cannot perturb a request's stream.

Admission is reservation-based: a request is admitted only if the page pool
can cover its WORST-CASE footprint (prompt + max_new_tokens - 1 tokens) on
top of every live request's outstanding reservation. Pages still alloc
lazily page-by-page as tokens land, but admission guarantees lazy alloc can
never hit ``PagePoolExhausted`` mid-flight — a request, once admitted,
always runs to completion (no preempt-and-requeue path to corrupt parity).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.models.kv_pages import PageAllocator, pages_for_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [L] int32 prompt tokens
    max_new_tokens: int
    arrival_step: int = 0               # step index at which it becomes visible

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")

    @property
    def total_tokens(self) -> int:
        """KV tokens written over the request's life: L prompt positions plus
        the fed-back generated tokens (the final generated token is never
        fed, so it writes nothing)."""
        return self.prompt.size + self.max_new_tokens - 1


@dataclasses.dataclass
class _Slot:
    req: Request
    pages: list                         # page ids owned, in table order
    fed: int = 0                        # tokens fed so far (== kv position)
    generated: list = dataclasses.field(default_factory=list)
    admit_t: float = 0.0
    first_tok_t: float | None = None
    tok_times: list = dataclasses.field(default_factory=list)


class ContinuousScheduler:
    """Host-side continuous-batching state machine.

    Per step: ``advance(step)`` admits arrivals / allocs boundary pages and
    returns the step's batch inputs; after the device step, ``observe(tok,
    now)`` records outputs, completes requests, and frees their pages. Both
    run at the step boundary — never mid-step."""

    def __init__(self, requests, max_concurrency: int, max_pages: int,
                 allocator: PageAllocator, tracer=None):
        self.B = int(max_concurrency)
        self.max_pages = int(max_pages)
        self.alloc = allocator
        self.tracer = tracer
        page = allocator.page_size
        for r in requests:
            need = pages_for_tokens(r.total_tokens, page)
            if need > self.max_pages:
                raise ValueError(
                    f"request {r.rid}: needs {need} pages "
                    f"({r.total_tokens} tokens at page_size={page}) but the "
                    f"page table holds max_pages={self.max_pages}")
            if need > allocator.num_pages:
                raise ValueError(
                    f"request {r.rid}: needs {need} pages but the pool has "
                    f"only {allocator.num_pages}")
        self.queue: list[Request] = sorted(requests,
                                           key=lambda r: (r.arrival_step, r.rid))
        self.slots: list[_Slot | None] = [None] * self.B
        self.finished: dict[int, _Slot] = {}
        self._reserved = 0              # pages promised to live requests
        # persistent host-side batch inputs (rebuilt in place each step)
        self._tbl = np.full((self.B, self.max_pages), allocator.pad_page,
                            np.int32)
        self._lens = np.zeros((self.B,), np.int32)
        self._active = np.zeros((self.B,), np.int32)
        self._tokens = np.zeros((self.B, 1), np.int32)

    # ---- queries ----

    @property
    def done(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    @property
    def live_count(self) -> int:
        return sum(s is not None for s in self.slots)

    def _outstanding(self, s: _Slot) -> int:
        """Pages this request may still alloc (reservation accounting)."""
        return (pages_for_tokens(s.req.total_tokens, self.alloc.page_size)
                - len(s.pages))

    # ---- the step-boundary state machine ----

    def advance(self, step: int, now: float | None = None):
        """Admit arrivals into free slots (FIFO, reservation-gated), alloc
        page-boundary pages for every live request, and build this step's
        batch inputs. Returns dict(tokens, page_tbl, kv_lens, active) of
        fixed-shape int32 numpy arrays."""
        now = time.perf_counter() if now is None else now
        # admission: strictly FIFO — a too-big head-of-line request blocks
        # later ones (no reordering; keeps arrival order deterministic)
        for i in range(self.B):
            if self.slots[i] is not None or not self.queue:
                continue
            r = self.queue[0]
            if r.arrival_step > step:
                break                    # arrivals are time-sorted
            need = pages_for_tokens(r.total_tokens, self.alloc.page_size)
            if self.alloc.free_count - self._reserved < need:
                break                    # pool can't guarantee completion yet
            self.queue.pop(0)
            self.slots[i] = _Slot(req=r, pages=[], admit_t=now)
            self._reserved += need
            self._tbl[i, :] = self.alloc.pad_page
            self._lens[i] = 0
            if self.tracer is not None:
                self.tracer.instant("admit", rid=r.rid, step=step, slot=i,
                                    queued=len(self.queue))
        for i, s in enumerate(self.slots):
            if s is None:
                self._active[i] = 0
                self._tokens[i, 0] = 0
                continue
            pos = s.fed
            if pos % self.alloc.page_size == 0:
                # crossing into a fresh page: reservation guarantees success
                (pid,) = self.alloc.alloc(1)
                s.pages.append(pid)
                self._reserved -= 1
                self._tbl[i, len(s.pages) - 1] = pid
            L = s.req.prompt.size
            self._tokens[i, 0] = (s.req.prompt[pos] if pos < L
                                  else s.generated[pos - L])
            self._lens[i] = pos
            self._active[i] = 1
        return dict(tokens=self._tokens.copy(),
                    page_tbl=self._tbl.copy(),
                    kv_lens=self._lens.copy(),
                    active=self._active.copy())

    def observe(self, out_tokens: np.ndarray, now: float | None = None):
        """Record the device step's outputs. Prompt-phase outputs are
        discarded until the step that consumed the last prompt token — its
        output is the first generated token. Completed requests free their
        pages and recycle the slot, effective next ``advance``. Returns the
        list of request ids completed at this boundary."""
        now = time.perf_counter() if now is None else now
        out = np.asarray(out_tokens).reshape(self.B, -1)[:, 0]
        completed = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.fed += 1
            L = s.req.prompt.size
            if s.fed < L:
                continue                 # still consuming the prompt
            tok = int(out[i])
            s.generated.append(tok)
            if s.first_tok_t is None:
                s.first_tok_t = now
            s.tok_times.append(now)
            if len(s.generated) >= s.req.max_new_tokens:
                self.alloc.free(s.pages)
                self._reserved -= self._outstanding(s)
                self.finished[s.req.rid] = s
                completed.append(s.req.rid)
                if self.tracer is not None:
                    self.tracer.instant("complete", rid=s.req.rid,
                                        tokens=len(s.generated))
                self.slots[i] = None
                self._tbl[i, :] = self.alloc.pad_page
                self._lens[i] = 0
                self._active[i] = 0
        return completed

    # ---- results ----

    def tokens_for(self, rid: int) -> np.ndarray:
        return np.asarray(self.finished[rid].generated, np.int32)

    def request_metrics(self, rid: int) -> dict:
        s = self.finished[rid]
        itls = np.diff(np.asarray(s.tok_times)) if len(s.tok_times) > 1 else np.asarray([])
        return dict(rid=rid,
                    ttft_s=(s.first_tok_t - s.admit_t),
                    itl_s=itls.tolist(),
                    tokens=len(s.generated))
