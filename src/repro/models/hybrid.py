"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared transformer block
(attention + FFN, single parameter set) applied every `shared_attn_period`
mamba blocks. Each application has its own KV cache. (The real Zamba2 adds
per-application LoRA deltas on the shared block and concatenates the original
embedding into its input; we apply the shared block on the residual stream —
noted in docs/DESIGN.md §Arch-applicability.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import mamba2 as SSM
from repro.models.config import ArchConfig
from repro.models.layers import (rmsnorm, rmsnorm_spec, ffn_spec, ffn_apply,
                                 embed_spec, embed_lookup, logits_out,
                                 cross_entropy)
from repro.models.transformer import _stack, _scan_stack, _empty_caches
from repro.parallel.sharding import ParamSpec


def _counts(cfg: ArchConfig):
    per = cfg.shared_attn_period
    n_super = cfg.num_layers // per          # super-block = per mambas + attn
    tail = cfg.num_layers - n_super * per
    return per, n_super, tail


def _mamba_layer_spec(cfg):
    return dict(ln=rmsnorm_spec(cfg.d_model, cfg.dtype),
                mamba=SSM.mamba_spec(cfg))


def _shared_block_spec(cfg):
    return dict(ln1=rmsnorm_spec(cfg.d_model, cfg.dtype),
                attn=ATT.attn_spec(cfg),
                ln2=rmsnorm_spec(cfg.d_model, cfg.dtype),
                ffn=ffn_spec(cfg.d_model, cfg.d_ff, cfg.dtype, cfg.act))


def hybrid_spec(cfg: ArchConfig):
    per, n_super, tail = _counts(cfg)
    sp = dict(
        embed=embed_spec(cfg.padded_vocab(), cfg.d_model, cfg.dtype),
        ln_f=rmsnorm_spec(cfg.d_model, cfg.dtype),
        mamba_super=_stack(_stack(_mamba_layer_spec(cfg), per), n_super),
        shared=_shared_block_spec(cfg),       # ONE param set, 13 applications
    )
    if tail:
        sp["tail"] = _stack(_mamba_layer_spec(cfg), tail)
    return sp


def _shared_apply(p, x, cfg, mesh, cache):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, c2 = ATT.attention(p["attn"], h, cfg, mesh, cache=cache, window=None)
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + ffn_apply(p["ffn"], h, cfg.act), c2


def _mamba_apply(p, x, cfg, mesh, cache):
    y, c2 = SSM.mamba_block(p["mamba"], rmsnorm(x, p["ln"], cfg.norm_eps),
                            cfg, mesh, cache=cache)
    return x + y, c2


def hybrid_forward(params, batch, cfg: ArchConfig, mesh):
    per, n_super, tail = _counts(cfg)
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)
    shared = params["shared"]

    def super_body(x, p, c):
        for j in range(per):
            pj = jax.tree.map(lambda a: a[j], p)
            x, _ = _mamba_apply(pj, x, cfg, mesh, None)
        x, _ = _shared_apply(shared, x, cfg, mesh, None)
        return x, c, jnp.float32(0)

    x, _, _ = _scan_stack(super_body, x, params["mamba_super"],
                          _empty_caches(n_super), cfg, remat=cfg.remat)
    if tail:
        def body(x, p, c):
            x, _ = _mamba_apply(p, x, cfg, mesh, None)
            return x, c, jnp.float32(0)
        x, _, _ = _scan_stack(body, x, params["tail"], _empty_caches(tail),
                              cfg, remat=cfg.remat)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_out(x, params["embed"])
    targets = batch.get("targets")
    if targets is None:
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return cross_entropy(logits, targets, batch.get("loss_mask")), {}


def hybrid_decode_state_spec(cfg: ArchConfig, batch: int, max_len: int, *, long=False):
    per, n_super, tail = _counts(cfg)
    st = dict(
        mamba=_stack(_stack(SSM.ssm_cache_spec(cfg, batch), per), n_super),
        attn=_stack(ATT.kv_cache_spec(cfg, batch, max_len, long=long), n_super),
    )
    if tail:
        st["tail"] = _stack(SSM.ssm_cache_spec(cfg, batch), tail)
    return st


def hybrid_decode_step(params, state, batch, cfg: ArchConfig, mesh):
    per, n_super, tail = _counts(cfg)
    x = embed_lookup(params["embed"], batch["tokens"])
    shared = params["shared"]

    def f(x, xs):
        p, cm, ca = xs
        new_cm = []
        for j in range(per):
            pj = jax.tree.map(lambda a: a[j], p)
            cj = jax.tree.map(lambda a: a[j], cm)
            x, cj2 = _mamba_apply(pj, x, cfg, mesh, cj)
            new_cm.append(cj2)
        x, ca2 = _shared_apply(shared, x, cfg, mesh, ca)
        stk = jax.tree.map(lambda *a: jnp.stack(a), *new_cm)
        return x, (stk, ca2)

    def scan_f(carry, xs):
        x = carry
        x, c2 = f(x, xs)
        return x, c2
    x, (new_m, new_a) = jax.lax.scan(
        scan_f, x, (params["mamba_super"], state["mamba"], state["attn"]))
    new_state = dict(state, mamba=new_m, attn=new_a)
    if tail:
        def body(x, p, c):
            x, c2 = _mamba_apply(p, x, cfg, mesh, c)
            return x, c2, jnp.float32(0)
        x, new_state["tail"], _ = _scan_stack(body, x, params["tail"],
                                              state["tail"], cfg, remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return logits_out(x, params["embed"]), new_state
