from repro.models.config import (  # noqa: F401
    ArchConfig, AttnSpec, MLASpec, MoESpec, SSMSpec,
)
from repro.models.registry import get_model  # noqa: F401
