"""Model registry: family -> (params_spec, forward, decode_state_spec,
decode_step). Every architecture config resolves through here."""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.models import transformer as T
from repro.models import hybrid as HY
from repro.models import encdec as ED
from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ModelFns:
    params_spec: Callable
    forward: Callable           # (params, batch, cfg, mesh) -> (loss, aux)
    decode_state_spec: Callable  # (cfg, batch, max_len, long=False) -> spec tree
    decode_step: Callable       # (params, state, batch, cfg, mesh) -> (logits, state)
    # paged continuous-batching decode path (None = family not supported):
    # state holds per-layer page pools; page_tbl/kv_lens/active ride the
    # step's batch inputs (runtime/scheduler.py builds them host-side)
    paged_decode_state_spec: Callable | None = None  # (cfg, num_pages, page_size)
    paged_decode_step: Callable | None = None


_REGISTRY = {
    "lm": ModelFns(T.lm_spec, T.lm_forward, T.lm_decode_state_spec, T.lm_decode_step,
                   T.lm_paged_decode_state_spec, T.lm_paged_decode_step),
    "vlm": ModelFns(T.lm_spec, T.lm_forward, T.lm_decode_state_spec, T.lm_decode_step,
                    T.lm_paged_decode_state_spec, T.lm_paged_decode_step),
    "gemma3": ModelFns(T.gemma3_spec, T.gemma3_forward,
                       T.gemma3_decode_state_spec, T.gemma3_decode_step),
    "ssm": ModelFns(T.ssm_spec, T.ssm_forward, T.ssm_decode_state_spec,
                    T.ssm_decode_step),
    "hybrid": ModelFns(HY.hybrid_spec, HY.hybrid_forward,
                       HY.hybrid_decode_state_spec, HY.hybrid_decode_step),
    "encdec": ModelFns(ED.encdec_spec, ED.encdec_forward,
                       ED.encdec_decode_state_spec, ED.encdec_decode_step),
}


def get_model(cfg: ArchConfig) -> ModelFns:
    return _REGISTRY[cfg.family]
