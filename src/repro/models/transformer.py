"""Decoder-LM assembly: scanned homogeneous layer stacks, per-family forward
and decode-step functions. Covers families: "lm" (GQA or MLA, dense or MoE),
"gemma3" (5:1 local:global super-blocks), "vlm" (lm + patch-embedding stub),
"ssm" (pure Mamba2). Hybrid (zamba2) and encdec (seamless) live in their own
modules but reuse the stack machinery here.

Scan-over-layers keeps the HLO O(1) in depth (the production-framework norm);
the dry-run's roofline corrects per-layer cost by trip count (docs/DESIGN.md §6).

Param-layout threading (docs/DESIGN.md §8): expert-stacked MoE weights ride
the scanned ``moe_stack`` as ``[n_moe, R, ...]`` where R follows the layout
mode — logical E by default, physical slot count (E + redundant replicas)
under ``MoESpec.params_physical``. The stack machinery is shape-agnostic, so
a placement adoption that changes the slot count simply retraces the decode
step with the new stacked shapes; everything *routing*-scoped stays logical
regardless of mode: the router/sel_bias specs, and the ``expert_heat``
decode-state counter, which is [E] per-LOGICAL-expert in both layouts (the
EPLB rebalancer consumes logical heat).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import mla as MLA
from repro.models import mamba2 as SSM
from repro.models import moe as MOE
from repro.models.config import ArchConfig
from repro.models.layers import (rmsnorm, rmsnorm_spec, ffn_spec, ffn_apply,
                                 embed_spec, embed_lookup, logits_out,
                                 cross_entropy)
from repro.parallel.sharding import ParamSpec, constrain


# --------------------------------------------------------------------------
# single decoder layer (dense or MoE FFN; GQA or MLA attention)
# --------------------------------------------------------------------------

def layer_spec(cfg: ArchConfig, *, moe_layer: bool):
    sp = dict(ln1=rmsnorm_spec(cfg.d_model, cfg.dtype),
              ln2=rmsnorm_spec(cfg.d_model, cfg.dtype))
    if cfg.attn and cfg.attn.kind == "mla":
        sp["attn"] = MLA.mla_spec(cfg)
    elif cfg.attn:
        sp["attn"] = ATT.attn_spec(cfg)
    if moe_layer:
        sp["moe"] = MOE.moe_spec(cfg)
    else:
        sp["ffn"] = ffn_spec(cfg.d_model, cfg.d_ff, cfg.dtype, cfg.act)
    return sp


def layer_apply(p, x, cfg: ArchConfig, mesh, *, cache=None, window="cfg",
                positions=None, with_heat=False):
    """-> (x, new_cache, aux). With ``with_heat=True`` aux is the pair
    (aux_loss, expert_heat [E]) — the per-logical-expert routed-token
    histogram the EPLB serving hook accumulates (runtime/server.py)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn and cfg.attn.kind == "mla":
        a, new_cache = MLA.mla_attention(p["attn"], h, cfg, mesh,
                                         cache=cache, positions=positions)
    else:
        a, new_cache = ATT.attention(p["attn"], h, cfg, mesh, cache=cache,
                                     window=window, positions=positions)
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        if with_heat:
            f, aux, heat = MOE.moe_block(p["moe"], h, cfg, mesh,
                                         with_heat=True)
            return x + f, new_cache, (aux, heat)
        f, aux = MOE.moe_block(p["moe"], h, cfg, mesh)
    else:
        f, aux = ffn_apply(p["ffn"], h, cfg.act), jnp.float32(0)
        if with_heat:
            E = cfg.moe.num_experts if cfg.moe else 1
            return x + f, new_cache, (aux, jnp.zeros((E,), jnp.float32))
    return x + f, new_cache, aux


def paged_layer_apply(p, x, cfg: ArchConfig, mesh, pool, page_tbl, kv_lens,
                      active, *, num_kv_splits: int, with_heat=False):
    """layer_apply's paged-decode twin: attention runs against the paged KV
    pool (kernels/decode_attention via ops); the FFN/MoE half is identical.
    -> (x, new_pool, aux) with the same aux contract as layer_apply."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn and cfg.attn.kind == "mla":
        a, new_pool = MLA.paged_mla_attention(
            p["attn"], h, cfg, mesh, pool, page_tbl, kv_lens, active,
            num_kv_splits=num_kv_splits)
    else:
        a, new_pool = ATT.paged_attention(
            p["attn"], h, cfg, mesh, pool, page_tbl, kv_lens, active,
            num_kv_splits=num_kv_splits)
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        if with_heat:
            f, aux, heat = MOE.moe_block(p["moe"], h, cfg, mesh,
                                         with_heat=True)
            return x + f, new_pool, (aux, heat)
        f, aux = MOE.moe_block(p["moe"], h, cfg, mesh)
    else:
        f, aux = ffn_apply(p["ffn"], h, cfg.act), jnp.float32(0)
        if with_heat:
            E = cfg.moe.num_experts if cfg.moe else 1
            return x + f, new_pool, (aux, jnp.zeros((E,), jnp.float32))
    return x + f, new_pool, aux


def _stack(specs, n: int):
    """Stack a layer's ParamSpec tree n times along a leading 'stack' axis."""
    def one(s: ParamSpec):
        return ParamSpec((n,) + s.shape, s.dtype, ("stack",) + (s.axes or (None,) * len(s.shape)),
                         init=s.init, scale=s.scale)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _scan_stack(body, x, stack_params, stack_cache, cfg, *, remat: bool,
                aux0=None):
    """scan over (params, cache) stacks; body(x, p, c) -> (x, c', aux).
    ``aux0`` seeds the aux accumulator (default scalar 0); any pytree of the
    same structure as the body's aux adds leafwise — the decode path uses an
    (aux, expert_heat) pair to surface EPLB heat without changing the
    decode-step signature."""
    if aux0 is None:
        aux0 = jnp.float32(0)

    def f(carry, pc):
        x, aux = carry
        p, c = pc
        x, c2, a = body(x, p, c)
        return (x, jax.tree.map(jnp.add, aux, a)), c2
    if remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_cache = jax.lax.scan(f, (x, aux0),
                                       (stack_params, stack_cache))
    return x, new_cache, aux


# --------------------------------------------------------------------------
# family: "lm" / "vlm"  (uniform stack, optional dense prefix, optional MTP)
# --------------------------------------------------------------------------

def lm_spec(cfg: ArchConfig):
    n_dense = cfg.moe.first_k_dense if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0
    sp = dict(
        embed=embed_spec(cfg.padded_vocab(), cfg.d_model, cfg.dtype),
        ln_f=rmsnorm_spec(cfg.d_model, cfg.dtype),
    )
    if n_dense:
        sp["dense_stack"] = _stack(layer_spec(cfg, moe_layer=False), n_dense)
    if n_moe:
        sp["moe_stack"] = _stack(layer_spec(cfg, moe_layer=True), n_moe)
    if not cfg.tie_embeddings:
        sp["lm_head"] = embed_spec(cfg.padded_vocab(), cfg.d_model, cfg.dtype)
    if cfg.mtp:  # DeepSeek-V3 multi-token prediction: one extra depth-1 layer
        sp["mtp_layer"] = layer_spec(cfg, moe_layer=bool(cfg.moe))
        sp["mtp_proj"] = ParamSpec((2 * cfg.d_model, cfg.d_model), cfg.dtype,
                                   ("embed", "embed"))
        sp["mtp_ln"] = rmsnorm_spec(cfg.d_model, cfg.dtype)
    return sp


def _empty_caches(n):
    return jnp.zeros((n, 0)) if n else None


def lm_forward(params, batch, cfg: ArchConfig, mesh):
    """Training/prefill forward. batch: {tokens [B,S], (img_embeds [B,P,D])}.
    Returns (loss, aux dict) — loss includes CE + router aux + MTP term."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    if cfg.family == "vlm" and "img_embeds" in batch:
        P_img = batch["img_embeds"].shape[1]
        x = jnp.concatenate([batch["img_embeds"].astype(x.dtype),
                             x[:, P_img:]], axis=1)
    x = constrain(x, mesh, "batch", None, None)
    aux = jnp.float32(0)
    n_dense = cfg.moe.first_k_dense if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0

    def body(x, p, c):
        return layer_apply(p, x, cfg, mesh, cache=None)

    if n_dense:
        x, _, a = _scan_stack(body, x, params["dense_stack"],
                              _empty_caches(n_dense), cfg, remat=cfg.remat)
        aux += a
    if n_moe:
        x, _, a = _scan_stack(body, x, params["moe_stack"],
                              _empty_caches(n_moe), cfg, remat=cfg.remat)
        aux += a
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = logits_out(x, head)
    targets = batch.get("targets")
    if targets is None:
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = batch.get("loss_mask")
    loss = cross_entropy(logits, targets, mask)

    if cfg.mtp:
        # depth-1 MTP: predict t+2 from [h_t ; emb(t+1)]
        nxt = embed_lookup(params["embed"], targets)
        h2 = jnp.concatenate([x, nxt], axis=-1) @ params["mtp_proj"]
        h2 = rmsnorm(h2, params["mtp_ln"], cfg.norm_eps)
        h2, _, a2 = layer_apply(params["mtp_layer"], h2, cfg, mesh)
        aux += a2
        mtp_logits = logits_out(h2, head)
        t2 = jnp.concatenate([targets[:, 1:], targets[:, :1]], axis=1)
        loss = loss + 0.3 * cross_entropy(mtp_logits, t2, mask)

    return loss + aux, dict(aux=aux)


def lm_decode_state_spec(cfg: ArchConfig, batch: int, max_len: int, *, long=False):
    n_dense = cfg.moe.first_k_dense if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0
    mk = (MLA.mla_cache_spec if (cfg.attn and cfg.attn.kind == "mla")
          else ATT.kv_cache_spec)
    st = {}
    if n_dense:
        st["dense"] = _stack(mk(cfg, batch, max_len, long=long), n_dense)
    if n_moe:
        st["moe"] = _stack(mk(cfg, batch, max_len, long=long), n_moe)
        if cfg.moe.track_expert_heat:
            # EPLB heat counters ride the decode state: per-LOGICAL-expert
            # routed tokens summed over MoE layers and steps (replicated).
            # Deliberately [E] in both param-layout modes — heat drives the
            # rebalancer, which reasons about logical experts; a placement
            # adoption therefore never invalidates the decode state.
            st["expert_heat"] = ParamSpec((cfg.moe.num_experts,), jnp.float32,
                                          (None,), init="zeros")
    return st


def lm_decode_step(params, state, batch, cfg: ArchConfig, mesh):
    """One decode step. batch: {tokens [B,1]}. -> (logits [B,1,V], state)."""
    x = embed_lookup(params["embed"], batch["tokens"])
    x = constrain(x, mesh, "batch", None, None)
    new_state = dict(state)

    def body(x, p, c):
        return layer_apply(p, x, cfg, mesh, cache=c)

    if "dense" in state:
        x, new_state["dense"], _ = _scan_stack(
            body, x, params["dense_stack"], state["dense"], cfg, remat=False)
    if "moe" in state:
        if "expert_heat" in state:
            def body_heat(x, p, c):
                return layer_apply(p, x, cfg, mesh, cache=c, with_heat=True)
            aux0 = (jnp.float32(0),
                    jnp.zeros((cfg.moe.num_experts,), jnp.float32))
            x, new_state["moe"], (_, heat) = _scan_stack(
                body_heat, x, params["moe_stack"], state["moe"], cfg,
                remat=False, aux0=aux0)
            new_state["expert_heat"] = state["expert_heat"] + heat
        else:
            x, new_state["moe"], _ = _scan_stack(
                body, x, params["moe_stack"], state["moe"], cfg, remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return logits_out(x, head), new_state


def lm_paged_decode_state_spec(cfg: ArchConfig, num_pages: int,
                               page_size: int):
    """Paged twin of lm_decode_state_spec: per-layer page POOLS instead of
    dense [B, S_max] caches. The page table / kv_lens / active mask are NOT
    device state — they are host-built per-step batch inputs (jit-stable
    shapes; runtime/scheduler.py owns them), so join/leave/recycle never
    retraces the step."""
    from repro.models import kv_pages as KVP
    n_dense = cfg.moe.first_k_dense if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0
    mk = (KVP.paged_mla_pool_spec if (cfg.attn and cfg.attn.kind == "mla")
          else KVP.paged_kv_pool_spec)
    st = {}
    if n_dense:
        st["dense"] = _stack(mk(cfg, num_pages, page_size), n_dense)
    if n_moe:
        st["moe"] = _stack(mk(cfg, num_pages, page_size), n_moe)
        if cfg.moe.track_expert_heat:
            # same logical-[E] heat contract as the dense decode state
            st["expert_heat"] = ParamSpec((cfg.moe.num_experts,), jnp.float32,
                                          (None,), init="zeros")
    return st


def _decode_splits(cfg: ArchConfig, max_pages: int) -> int:
    """Largest split count <= AttnSpec.decode_kv_splits dividing the page-
    table width (static shapes only — resolved at trace time)."""
    s = max(min(cfg.attn.decode_kv_splits, max_pages), 1)
    while max_pages % s:
        s -= 1
    return s


def lm_paged_decode_step(params, state, batch, cfg: ArchConfig, mesh):
    """One paged decode step. batch: {tokens [B,1], page_tbl [B,max_pages],
    kv_lens [B], active [B]}. -> (logits [B,1,V], state). Idle rows (active
    == 0, all-pad tables) compute deterministic garbage that lands in the
    pad page and zero attention context — the scheduler discards their
    logits, and live rows provably can't see them (exact masking)."""
    x = embed_lookup(params["embed"], batch["tokens"])
    x = constrain(x, mesh, "batch", None, None)
    tbl = batch["page_tbl"].astype(jnp.int32)
    lens = batch["kv_lens"].astype(jnp.int32)
    act = batch["active"].astype(jnp.int32)
    splits = _decode_splits(cfg, tbl.shape[1])
    new_state = dict(state)

    def body(x, p, c):
        return paged_layer_apply(p, x, cfg, mesh, c, tbl, lens, act,
                                 num_kv_splits=splits)

    if "dense" in state:
        x, new_state["dense"], _ = _scan_stack(
            body, x, params["dense_stack"], state["dense"], cfg, remat=False)
    if "moe" in state:
        if "expert_heat" in state:
            def body_heat(x, p, c):
                return paged_layer_apply(p, x, cfg, mesh, c, tbl, lens, act,
                                         num_kv_splits=splits, with_heat=True)
            aux0 = (jnp.float32(0),
                    jnp.zeros((cfg.moe.num_experts,), jnp.float32))
            x, new_state["moe"], (_, heat) = _scan_stack(
                body_heat, x, params["moe_stack"], state["moe"], cfg,
                remat=False, aux0=aux0)
            new_state["expert_heat"] = state["expert_heat"] + heat
        else:
            x, new_state["moe"], _ = _scan_stack(
                body, x, params["moe_stack"], state["moe"], cfg, remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return logits_out(x, head), new_state


# --------------------------------------------------------------------------
# family: "gemma3"  (super-blocks of 5 local + 1 global)
# --------------------------------------------------------------------------

def _g3_counts(cfg):
    loc, glob = cfg.local_global
    per = loc + glob
    n_super = cfg.num_layers // per
    tail = cfg.num_layers - n_super * per
    return loc, glob, n_super, tail


def gemma3_spec(cfg: ArchConfig):
    loc, glob, n_super, tail = _g3_counts(cfg)
    per = loc + glob
    sb = _stack(layer_spec(cfg, moe_layer=False), per)      # [per, ...]
    sp = dict(
        embed=embed_spec(cfg.padded_vocab(), cfg.d_model, cfg.dtype),
        ln_f=rmsnorm_spec(cfg.d_model, cfg.dtype),
        super=_stack(sb, n_super),                          # [n_super, per, ...]
    )
    if tail:
        sp["tail"] = _stack(layer_spec(cfg, moe_layer=False), tail)
    return sp


def gemma3_forward(params, batch, cfg: ArchConfig, mesh):
    loc, glob, n_super, tail = _g3_counts(cfg)
    per = loc + glob
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)        # gemma embed scale

    def super_body(x, p, c):
        aux = jnp.float32(0)
        for j in range(per):
            pj = jax.tree.map(lambda a: a[j], p)
            w = cfg.local_window if j < loc else None
            x, _, a = layer_apply(pj, x, cfg, mesh, window=w)
            aux += a
        return x, c, aux

    x, _, _ = _scan_stack(super_body, x, params["super"],
                          _empty_caches(n_super), cfg, remat=cfg.remat)
    if tail:
        def body(x, p, c):
            return layer_apply(p, x, cfg, mesh, window=cfg.local_window)
        x, _, _ = _scan_stack(body, x, params["tail"], _empty_caches(tail),
                              cfg, remat=cfg.remat)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_out(x, params["embed"])                 # gemma ties embeds
    targets = batch.get("targets")
    if targets is None:
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return cross_entropy(logits, targets, batch.get("loss_mask")), {}


def gemma3_decode_state_spec(cfg: ArchConfig, batch: int, max_len: int, *, long=False):
    loc, glob, n_super, tail = _g3_counts(cfg)
    wlen = min(cfg.local_window, max_len)
    lc = ATT.kv_cache_spec(cfg, batch, wlen)                # ring, local
    gc = ATT.kv_cache_spec(cfg, batch, max_len, long=long)  # linear, global
    st = dict(
        local=_stack(_stack(lc, loc), n_super),             # [n_super, loc, ...]
        globl=_stack(_stack(gc, glob), n_super),
    )
    if tail:
        st["tail"] = _stack(lc, tail)
    return st


def gemma3_decode_step(params, state, batch, cfg: ArchConfig, mesh):
    loc, glob, n_super, tail = _g3_counts(cfg)
    per = loc + glob
    x = embed_lookup(params["embed"], batch["tokens"])
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    wlen = state["local"]["k"].shape[3] if isinstance(state["local"], dict) \
        else jax.tree.leaves(state["local"])[0].shape[3]

    def super_body(x, pc, cc):
        p, (c_loc, c_glob) = pc, cc
        new_loc, new_glob = [], []
        aux = jnp.float32(0)
        for j in range(per):
            pj = jax.tree.map(lambda a: a[j], p)
            if j < loc:
                cj = jax.tree.map(lambda a: a[j], c_loc)
                x, cj2, _ = _ring_local_decode(pj, x, cfg, mesh, cj, wlen)
                new_loc.append(cj2)
            else:
                cj = jax.tree.map(lambda a: a[j - loc], c_glob)
                x, cj2, _ = layer_apply(pj, x, cfg, mesh, cache=cj, window=None)
                new_glob.append(cj2)
        stk = lambda cs: jax.tree.map(lambda *a: jnp.stack(a), *cs)
        return x, (stk(new_loc), stk(new_glob)), aux

    def f(carry, pc):
        x = carry
        p, c = pc[0], (pc[1], pc[2])
        x, c2, _ = super_body(x, p, c)
        return x, c2
    x, (nl, ng) = jax.lax.scan(f, x, (params["super"], state["local"], state["globl"]))
    new_state = dict(state, local=nl, globl=ng)
    if tail:
        def body(x, p, c):
            return _ring_local_decode(p, x, cfg, mesh, c, wlen)
        x, new_state["tail"], _ = _scan_stack(body, x, params["tail"],
                                              state["tail"], cfg, remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return logits_out(x, params["embed"]), new_state


def _ring_local_decode(p, x, cfg, mesh, cache, wlen):
    """Sliding-window decode with a ring KV cache of length `wlen`: write at
    length % wlen; key positions reconstructed from the ring arithmetic."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    B, S, _ = x.shape
    pos = cache.length                                       # absolute position
    slot = pos % wlen
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
    a = cfg.attn
    pvec = jnp.broadcast_to(pos[None, None], (B, S))
    from repro.models.layers import apply_rope
    q = apply_rope(q, pvec, a.rope_base, a.rope_fraction)
    k = apply_rope(k, pvec, a.rope_base, a.rope_fraction)
    kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    # slot i holds absolute position: the largest p <= pos with p % wlen == i
    idx = jnp.arange(wlen)
    k_pos = pos - ((pos - idx) % wlen)
    mask = (k_pos >= 0) & (k_pos <= pos) & (pos - k_pos < wlen)
    o = ATT._sdpa(q, kc, vc, mask[None, :].repeat(S, 0), a.logit_softcap,
                  a.head_dim ** -0.5)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + ffn_apply(p["ffn"], h2, cfg.act)
    return x, ATT.KVCache(k=kc, v=vc, length=cache.length + S), jnp.float32(0)


# --------------------------------------------------------------------------
# family: "ssm"  (pure Mamba2)
# --------------------------------------------------------------------------

def ssm_spec(cfg: ArchConfig):
    lay = dict(ln=rmsnorm_spec(cfg.d_model, cfg.dtype),
               mamba=SSM.mamba_spec(cfg))
    return dict(
        embed=embed_spec(cfg.padded_vocab(), cfg.d_model, cfg.dtype),
        ln_f=rmsnorm_spec(cfg.d_model, cfg.dtype),
        stack=_stack(lay, cfg.num_layers),
    )


def ssm_forward(params, batch, cfg: ArchConfig, mesh):
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)

    def body(x, p, c):
        y, _ = SSM.mamba_block(p["mamba"], rmsnorm(x, p["ln"], cfg.norm_eps),
                               cfg, mesh)
        return x + y, c, jnp.float32(0)

    x, _, _ = _scan_stack(body, x, params["stack"],
                          _empty_caches(cfg.num_layers), cfg, remat=cfg.remat)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_out(x, params["embed"])
    targets = batch.get("targets")
    if targets is None:
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return cross_entropy(logits, targets, batch.get("loss_mask")), {}


def ssm_decode_state_spec(cfg: ArchConfig, batch: int, max_len: int, *, long=False):
    return dict(stack=_stack(SSM.ssm_cache_spec(cfg, batch), cfg.num_layers))


def ssm_decode_step(params, state, batch, cfg: ArchConfig, mesh):
    x = embed_lookup(params["embed"], batch["tokens"])

    def body(x, p, c):
        y, c2 = SSM.mamba_block(p["mamba"], rmsnorm(x, p["ln"], cfg.norm_eps),
                                cfg, mesh, cache=c)
        return x + y, c2, jnp.float32(0)

    x, new_stack, _ = _scan_stack(body, x, params["stack"], state["stack"],
                                  cfg, remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return logits_out(x, params["embed"]), dict(stack=new_stack)
