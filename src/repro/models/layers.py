"""Shared primitive layers: norms, RoPE, gated FFNs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import ParamSpec


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    x2 = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(x2 + eps)).astype(x.dtype) * w


def rmsnorm_spec(d: int, dtype=jnp.bfloat16) -> ParamSpec:
    return ParamSpec((d,), dtype, ("embed",), init="ones")


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(rot_dim: int, base: float) -> np.ndarray:
    return 1.0 / (base ** (np.arange(0, rot_dim, 2, dtype=np.float64) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, base: float,
               fraction: float = 1.0) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S]. Rotates the first
    ``fraction * D`` components (chatglm3's 2d RoPE == fraction 0.5)."""
    B, S, H, D = x.shape
    rot = int(D * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = jnp.asarray(rope_frequencies(rot, base), jnp.float32)     # [rot/2]
    ang = positions.astype(jnp.float32)[:, :, None] * inv[None, None, :]  # [B,S,rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(B, S, H, rot)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------
# gated FFN
# --------------------------------------------------------------------------

def ffn_spec(d: int, f: int, dtype=jnp.bfloat16, act: str = "swiglu"):
    if act == "gelu":
        return dict(
            w_in=ParamSpec((d, f), dtype, ("embed", "ffn")),
            w_out=ParamSpec((f, d), dtype, ("ffn", "embed")),
        )
    return dict(
        w_gate=ParamSpec((d, f), dtype, ("embed", "ffn")),
        w_up=ParamSpec((d, f), dtype, ("embed", "ffn")),
        w_down=ParamSpec((f, d), dtype, ("ffn", "embed")),
    )


def ffn_apply(p, x: jax.Array, act: str = "swiglu") -> jax.Array:
    if act == "gelu":
        h = jax.nn.gelu(x @ p["w_in"])
        return h @ p["w_out"]
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    h = (jax.nn.gelu(g) if act == "geglu" else jax.nn.silu(g)) * u
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# embeddings / logits
# --------------------------------------------------------------------------

def embed_spec(vocab: int, d: int, dtype=jnp.bfloat16) -> ParamSpec:
    return ParamSpec((vocab, d), dtype, ("vocab", "embed"), init="embed")


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return table[tokens]


def logits_out(x: jax.Array, table: jax.Array) -> jax.Array:
    """Final projection; f32 logits for a stable softmax-CE."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      table.astype(jnp.float32))


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
