"""Architecture configuration schema covering all ten assigned families."""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv: int
    head_dim: int
    kind: Literal["gqa", "mla"] = "gqa"
    rope_base: float = 10000.0
    rope_fraction: float = 1.0        # chatglm3 "2d RoPE" = 0.5 (half rotary)
    window: int | None = None         # sliding-window width (local layers)
    qk_norm: bool = False
    logit_softcap: float | None = None
    # KV tile width for chunked (online-softmax) prefill attention AND the
    # tiling contract with the paged decode path: a paged serving engine
    # requires kv_chunk % page_size == 0 so prefill chunking and decode
    # paging agree on boundaries. Ragged tails (S % kv_chunk != 0) are
    # handled by masked padding, not asserted away.
    kv_chunk: int = 1024
    # KV-split count for the two-stage paged decode attention kernel
    # (flash-decoding parallelism); clamped to the page-table width at call
    # sites so tiny configs stay valid.
    decode_kv_splits: int = 4


@dataclasses.dataclass(frozen=True)
class MLASpec:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_experts: int = 0
    first_k_dense: int = 0            # deepseek-v3: first 3 layers dense
    gating: Literal["softmax", "sigmoid"] = "softmax"
    n_groups: int = 1
    topk_groups: int = 1
    use_selection_bias: bool = False
    routed_scaling: float = 1.0
    norm_topk: bool = True
    aux_loss_weight: float = 1e-3
    # --- EP communication (the paper's knobs) ---
    ep_mode: Literal["ll", "ht", "baseline", "auto"] = "auto"
    ll_layout: Literal["nccl_ep", "deepep"] = "nccl_ep"
    ep_axis: tuple[str, ...] = ("model",)
    capacity_factor: float | None = 1.25
    expert_capacity_factor: float | None = 1.25
    ht_hierarchical: bool = False
    # hierarchical-HT chunk count: >1 streams the two a2a stages (prefill
    # pipelining, core/ht.py); must divide the per-EP-rank token count
    ht_num_chunks: int = 1
    quantize_dispatch: bool = False
    # --- EPLB (core/placement.py) ---
    # Explicit expert placement table (EpPlacement) with optional redundant
    # replicas; None = contiguous striping. In the default logical mode
    # expert weights stay stored in logical [E, ...] order — moe_block
    # rebinds them to physical slot order in-graph when a placement is set.
    placement: "object | None" = None
    # Adopt-once physical parameter mode (serving fast path): expert-stacked
    # weights (w_gate/w_up/w_down) are stored ALREADY in `placement`'s
    # physical [N*S, ...] slot order and moe_block skips the per-step
    # in-graph expansion entirely. The runtime rebinds params host-side at
    # placement-adoption boundaries (checkpoint.adopt_expert_params, buffers
    # donated). Keep False for training, where placements may swap mid-epoch
    # and checkpoints should stay placement-independent; with placement=None
    # the physical layout coincides with the logical one (docs/DESIGN.md §8).
    params_physical: bool = False
    # Fold per-logical-expert routed-token counts into the decode state
    # ("expert_heat") so serving reports load imbalance and the rebalance
    # hook (runtime/server.py) can re-place experts between steps. The
    # on-device counter is f32: the serving hook drains it to host float64
    # at every rebalance boundary, so exact counting holds for any window
    # below ~16M routed tokens per expert.
    track_expert_heat: bool = False


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["lm", "gemma3", "hybrid", "ssm", "encdec", "vlm"]
    num_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: AttnSpec | None = None
    mla: MLASpec | None = None
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    # gemma3: (local, global) pattern + local window
    local_global: tuple[int, int] | None = None
    local_window: int = 1024
    # zamba2: one shared attention block applied every `shared_attn_period`
    shared_attn_period: int | None = None
    # encdec
    enc_layers: int = 0
    dec_layers: int = 0
    cross_attn: bool = False
    src_len: int = 4096               # encoder memory length (frontend stub)
    # vlm
    img_tokens: int = 0               # patch embeddings injected at the front
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    # multi-token prediction (deepseek-v3 MTP): extra depth-1 head
    mtp: bool = False
    # training-time knobs
    remat: bool = True
    microbatch: int = 1               # gradient-accumulation chunks

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def padded_heads(self, multiple: int = 16) -> int:
        n = self.attn.n_heads if self.attn else 0
        return ((n + multiple - 1) // multiple) * multiple
