"""Encoder-decoder backbone (seamless-m4t-large-v2). The audio frontend is a
stub per the assignment: `input_specs()` supplies precomputed frame embeddings
[B, S_src, D]. Encoder: bidirectional self-attention stack. Decoder: causal
self-attention + cross-attention over the encoder memory. Decode state holds
the encoder memory's per-layer cross K/V (computed once at prefill) plus the
decoder self-attention KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models.config import ArchConfig
from repro.models.layers import (rmsnorm, rmsnorm_spec, ffn_spec, ffn_apply,
                                 embed_spec, embed_lookup, logits_out,
                                 cross_entropy)
from repro.models.transformer import _stack, _scan_stack, _empty_caches
from repro.parallel.sharding import ParamSpec


def _enc_layer_spec(cfg):
    return dict(ln1=rmsnorm_spec(cfg.d_model, cfg.dtype),
                attn=ATT.attn_spec(cfg),
                ln2=rmsnorm_spec(cfg.d_model, cfg.dtype),
                ffn=ffn_spec(cfg.d_model, cfg.d_ff, cfg.dtype, cfg.act))


def _dec_layer_spec(cfg):
    sp = _enc_layer_spec(cfg)
    sp["ln_x"] = rmsnorm_spec(cfg.d_model, cfg.dtype)
    sp["xattn"] = ATT.attn_spec(cfg)
    return sp


def encdec_spec(cfg: ArchConfig):
    return dict(
        embed=embed_spec(cfg.padded_vocab(), cfg.d_model, cfg.dtype),
        ln_enc=rmsnorm_spec(cfg.d_model, cfg.dtype),
        ln_dec=rmsnorm_spec(cfg.d_model, cfg.dtype),
        enc=_stack(_enc_layer_spec(cfg), cfg.enc_layers),
        dec=_stack(_dec_layer_spec(cfg), cfg.dec_layers),
    )


def _encode(params, src_embeds, cfg, mesh):
    x = src_embeds

    def body(x, p, c):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, _ = ATT.attention(p["attn"], h, cfg, mesh, window=None,
                             causal=False)     # bidirectional encoder
        x = x + a
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + ffn_apply(p["ffn"], h, cfg.act), c, jnp.float32(0)

    x, _, _ = _scan_stack(body, x, params["enc"], _empty_caches(cfg.enc_layers),
                          cfg, remat=cfg.remat)
    return rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def _dec_layer(p, x, cfg, mesh, memory, cache):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, c2 = ATT.attention(p["attn"], h, cfg, mesh, cache=cache, window=None)
    x = x + a
    h = rmsnorm(x, p["ln_x"], cfg.norm_eps)
    kv_k = jnp.einsum("bsd,dhk->bshk", memory, p["xattn"]["wk"])
    kv_v = jnp.einsum("bsd,dhk->bshk", memory, p["xattn"]["wv"])
    a, _ = ATT.attention(p["xattn"], h, cfg, mesh, kv_override=(kv_k, kv_v))
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + ffn_apply(p["ffn"], h, cfg.act), c2


def encdec_forward(params, batch, cfg: ArchConfig, mesh):
    """batch: {src_embeds [B,S_src,D], tokens [B,S_tgt]}"""
    memory = _encode(params, batch["src_embeds"], cfg, mesh)
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)

    def body(x, p, c):
        x, _ = _dec_layer(p, x, cfg, mesh, memory, None)
        return x, c, jnp.float32(0)

    x, _, _ = _scan_stack(body, x, params["dec"], _empty_caches(cfg.dec_layers),
                          cfg, remat=cfg.remat)
    x = rmsnorm(x, params["ln_dec"], cfg.norm_eps)
    logits = logits_out(x, params["embed"])
    targets = batch.get("targets")
    if targets is None:
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return cross_entropy(logits, targets, batch.get("loss_mask")), {}


def encdec_decode_state_spec(cfg: ArchConfig, batch: int, max_len: int, *, long=False):
    return dict(
        self=_stack(ATT.kv_cache_spec(cfg, batch, max_len, long=long),
                    cfg.dec_layers),
        memory=ParamSpec((batch, cfg.src_len, cfg.d_model), cfg.dtype,
                         ("batch", None, None)),
    )


def encdec_decode_step(params, state, batch, cfg: ArchConfig, mesh):
    x = embed_lookup(params["embed"], batch["tokens"])
    memory = state["memory"]

    def body(x, p, c):
        x, c2 = _dec_layer(p, x, cfg, mesh, memory, c)
        return x, c2, jnp.float32(0)

    x, new_self, _ = _scan_stack(body, x, params["dec"], state["self"],
                                 cfg, remat=False)
    x = rmsnorm(x, params["ln_dec"], cfg.norm_eps)
    return logits_out(x, params["embed"]), dict(state, self=new_self)
