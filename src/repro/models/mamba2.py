"""Mamba2 / SSD (state-space duality, arXiv:2405.21060).

Train/prefill: the chunked SSD algorithm — intra-chunk quadratic attention-like
blocks + inter-chunk linear state recurrence (a port of the paper's
``ssd_minimal_discrete`` to jnp, scan-free via segment-sum matrices).

Decode: the O(1)-per-token state recurrence over (conv_state, ssm_state) — the
attention-free path that makes the ``long_500k`` cell tractable.

Heads are sharded over the model axis ("mamba_heads"); the state tensors ride
the decode cache like a KV cache does.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import rmsnorm
from repro.parallel.sharding import ParamSpec


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.headdim
    return d_inner, nh, s.headdim, s.d_state, s.n_groups


def mamba_spec(cfg: ArchConfig, dtype=None):
    dtype = dtype or cfg.dtype
    d = cfg.d_model
    di, nh, hp, n, g = _dims(cfg)
    W = cfg.ssm.conv_width
    return dict(
        wz=ParamSpec((d, di), dtype, ("embed", "ffn")),
        wx=ParamSpec((d, di), dtype, ("embed", "ffn")),
        wB=ParamSpec((d, g * n), dtype, ("embed", None)),
        wC=ParamSpec((d, g * n), dtype, ("embed", None)),
        wdt=ParamSpec((d, nh), dtype, ("embed", "mamba_heads")),
        conv_x=ParamSpec((W, di), dtype, ("conv", "ffn")),
        conv_B=ParamSpec((W, g * n), dtype, ("conv", None)),
        conv_C=ParamSpec((W, g * n), dtype, ("conv", None)),
        A_log=ParamSpec((nh,), jnp.float32, ("mamba_heads",), init="zeros"),
        D=ParamSpec((nh,), jnp.float32, ("mamba_heads",), init="ones"),
        dt_bias=ParamSpec((nh,), jnp.float32, ("mamba_heads",), init="zeros"),
        norm=ParamSpec((di,), dtype, ("ffn",), init="ones"),
        wo=ParamSpec((di, d), dtype, ("ffn", "embed")),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    conv_x: jax.Array     # [B, W-1, d_inner]
    conv_B: jax.Array     # [B, W-1, g*n]
    conv_C: jax.Array     # [B, W-1, g*n]
    state: jax.Array      # [B, nh, hp, n]
    length: jax.Array


def ssm_cache_spec(cfg: ArchConfig, batch: int):
    di, nh, hp, n, g = _dims(cfg)
    W = cfg.ssm.conv_width
    f32 = jnp.float32
    return SSMCache(
        conv_x=ParamSpec((batch, W - 1, di), cfg.dtype, ("batch", None, "ffn")),
        conv_B=ParamSpec((batch, W - 1, g * n), cfg.dtype, ("batch", None, None)),
        conv_C=ParamSpec((batch, W - 1, g * n), cfg.dtype, ("batch", None, None)),
        state=ParamSpec((batch, nh, hp, n), f32, ("batch", "mamba_heads", None, None)),
        length=ParamSpec((), jnp.int32, (), init="zeros"),
    )


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv, width W. x: [B,S,C], w: [W,C]."""
    W = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = cache
    xp = jnp.concatenate([pad, x], axis=1)                 # [B, S+W-1, C]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_cache = xp[:, -(W - 1):]
    return jax.nn.silu(y), new_cache


def _segsum(x):
    """x: [..., T] -> [..., T, T] lower-triangular segment sums."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, ss, -jnp.inf)


def _ssd_chunked(xh, dtA, B, C, chunk):
    """SSD over chunks. xh: [b,s,h,p]; dtA: [b,s,h]; B,C: [b,s,n] (g=1).

    Returns y: [b,s,h,p] (fp32)."""
    b, s, h, p = xh.shape
    n = B.shape[-1]
    c = s // chunk
    x_ = xh.reshape(b, c, chunk, h, p).astype(jnp.float32)
    A_ = dtA.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)         # [b,h,c,l]
    B_ = B.reshape(b, c, chunk, n).astype(jnp.float32)
    C_ = C.reshape(b, c, chunk, n).astype(jnp.float32)
    A_cum = jnp.cumsum(A_, axis=-1)

    # 1. intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(A_))                                    # [b,h,c,l,l]
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", C_, B_, Lmat, x_)

    # 2. chunk-final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)                # [b,h,c,l]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", B_, decay_states, x_)

    # 3. inter-chunk recurrence: state entering chunk z is
    #    sum_c exp(sum_{c<j<z} A_last_j) * local_c  ==  dc[z, c+1] @ local_c
    A_last = A_cum[..., -1]                                        # [b,h,c]
    pad = jnp.pad(A_last, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(pad))                            # [b,h,c+1,c+1]
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk[:, :, :-1, 1:], states)

    # 4. state -> output
    out_decay = jnp.exp(A_cum)                                     # [b,h,c,l]
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", C_, new_states, out_decay)
    return (Y_diag + Y_off).reshape(b, s, h, p)


def mamba_block(p, x, cfg: ArchConfig, mesh, *, cache: SSMCache | None = None):
    """x: [B, S, D] -> ([B, S, D], new_cache)."""
    di, nh, hp, n, g = _dims(cfg)
    B_, S, D = x.shape
    z = x @ p["wz"]
    xr = x @ p["wx"]
    Bv = x @ p["wB"]
    Cv = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                       # [nh]

    if cache is None:
        xr, _ = _causal_conv(xr, p["conv_x"])
        Bv, _ = _causal_conv(Bv, p["conv_B"])
        Cv, _ = _causal_conv(Cv, p["conv_C"])
        xh = xr.reshape(B_, S, nh, hp).astype(jnp.float32)
        chunk = min(cfg.ssm.chunk, S)
        # pre-discretize: SSD consumes (x*dt, A*dt); skip term is D*x
        y = _ssd_chunked(xh * dt[..., None], dt * A[None, None], Bv, Cv, chunk)
        y = y + p["D"][None, None, :, None] * xh
        new_cache = None
    else:
        xr, cx = _causal_conv(xr, p["conv_x"], cache.conv_x)
        Bv, cb = _causal_conv(Bv, p["conv_B"], cache.conv_B)
        Cv, cc = _causal_conv(Cv, p["conv_C"], cache.conv_C)
        xh = xr.reshape(B_, S, nh, hp).astype(jnp.float32)
        # recurrence (S is 1 at decode; loop for tiny S generality)
        st = cache.state
        ys = []
        for t in range(S):
            dA = jnp.exp(dt[:, t] * A[None])                       # [B, nh]
            upd = jnp.einsum("bn,bhp->bhpn", Bv[:, t].astype(jnp.float32),
                             dt[:, t, :, None] * xh[:, t])
            st = st * dA[..., None, None] + upd
            yt = jnp.einsum("bhpn,bn->bhp", st, Cv[:, t].astype(jnp.float32))
            yt = yt + p["D"][None, :, None] * xh[:, t]
            ys.append(yt)
        y = jnp.stack(ys, axis=1)                                  # [B,S,nh,hp]
        new_cache = SSMCache(conv_x=cx, conv_B=cb, conv_C=cc, state=st,
                             length=cache.length + S)

    y = y.reshape(B_, S, di).astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"], new_cache
