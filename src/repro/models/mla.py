"""Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3).

Train/prefill: queries via low-rank q path; keys/values decompressed from the
shared latent ``c_kv`` plus a single shared RoPE key head.

Decode: the *absorbed* formulation — cache only [c_kv (r_kv) | k_rope] per
token (the whole point of MLA: DeepSeek-V3 caches 512+64 floats/token instead
of 128 heads x 128). W_uk is absorbed into the query and W_uv into the output
projection, so scores are taken directly against the compressed cache.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, rmsnorm
from repro.parallel.sharding import ParamSpec, constrain


def mla_spec(cfg: ArchConfig, dtype=None):
    m, d = cfg.mla, cfg.d_model
    dtype = dtype or cfg.dtype
    h = cfg.padded_heads()
    qk = m.qk_nope_dim + m.qk_rope_dim
    return dict(
        wq_a=ParamSpec((d, m.q_lora_rank), dtype, ("embed", "lora")),
        q_norm=ParamSpec((m.q_lora_rank,), dtype, ("lora",), init="ones"),
        wq_b=ParamSpec((m.q_lora_rank, h, qk), dtype, ("lora", "heads", None)),
        wkv_a=ParamSpec((d, m.kv_lora_rank + m.qk_rope_dim), dtype, ("embed", "lora")),
        kv_norm=ParamSpec((m.kv_lora_rank,), dtype, ("lora",), init="ones"),
        wk_b=ParamSpec((m.kv_lora_rank, h, m.qk_nope_dim), dtype,
                       ("lora", "heads", None)),
        wv_b=ParamSpec((m.kv_lora_rank, h, m.v_head_dim), dtype,
                       ("lora", "heads", None)),
        wo=ParamSpec((h, m.v_head_dim, d), dtype, ("heads", None, "embed")),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    ckv: jax.Array        # [B, S_max, r_kv] compressed latents
    krope: jax.Array      # [B, S_max, rope_dim] shared rope key
    length: jax.Array


def mla_cache_spec(cfg: ArchConfig, batch: int, max_len: int, *, long=False):
    m = cfg.mla
    seq_ax = "kv_seq_long" if long else "kv_seq"
    return MLACache(
        ckv=ParamSpec((batch, max_len, m.kv_lora_rank), cfg.dtype,
                      ("batch", seq_ax, None)),
        krope=ParamSpec((batch, max_len, m.qk_rope_dim), cfg.dtype,
                        ("batch", seq_ax, None)),
        length=ParamSpec((), jnp.int32, (), init="zeros"),
    )


def _dot32(eq, *ops):
    """f32-accumulating einsum. XLA:CPU's DotThunk cannot *execute* some
    bf16xbf16=f32 dots (it compiles them fine), so on CPU we upcast operands;
    on TPU this is the native MXU mixed-precision form."""
    if jax.default_backend() == "cpu":
        return jnp.einsum(eq, *(o.astype(jnp.float32) for o in ops))
    return jnp.einsum(eq, *ops, preferred_element_type=jnp.float32)


def _q_proj(p, x, cfg, positions):
    m = cfg.mla
    B, S, _ = x.shape
    q = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])     # [B,S,H,nope+rope]
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.attn.rope_base, 1.0)
    return q_nope, q_rope


def _mla_chunked(p, q_nope, q_rope, ckv, k_rope, scale, out_dtype, chunk=1024):
    """Online-softmax MLA attention; K/V decompressed one chunk at a time.

    Chunk width from ``AttnSpec.kv_chunk`` at call sites; ragged tails
    (S % chunk != 0) are zero-padded and masked out exactly."""
    B, Sq, H, dn = q_nope.shape
    S = ckv.shape[1]
    pad = (-S) % chunk
    if pad:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    n = (S + pad) // chunk
    ckv_c = ckv.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    kr_c = k_rope.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    q_pos = jnp.arange(Sq)
    dv = p["wv_b"].shape[-1]

    def body(carry, xs):
        m, l, acc = carry
        ci, (ck, kr) = xs
        k_nope = jnp.einsum("bsr,rhk->bshk", ck, p["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", ck, p["wv_b"])
        s = (jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope,
                        preferred_element_type=jnp.float32) +
             jnp.einsum("bqhk,bsk->bhqs", q_rope, kr,
                        preferred_element_type=jnp.float32)) * scale
        k_pos = ci * chunk + jnp.arange(chunk)
        msk = (k_pos[None, :] <= q_pos[:, None]) & (k_pos < S)[None, :]
        s = jnp.where(msk[None, None], s, -1e30)
        m2 = jnp.maximum(m, s.max(-1))
        pb = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + pb.sum(-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bhqs,bshk->bhqk", pb.astype(out_dtype), v,
            preferred_element_type=jnp.float32)
        return (m2, l2, acc2), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dv), jnp.float32)
    # full unroll: exact dry-run cost accounting (see attention.py)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(n), (ckv_c, kr_c)), unroll=True)
    out = acc / jnp.maximum(l, 1e-30)[..., None]       # [B,H,Sq,dv]
    return out.transpose(0, 2, 1, 3)                   # [B,Sq,H,dv]


def paged_mla_attention(p, x, cfg: ArchConfig, mesh, pool, page_tbl, kv_lens,
                        active, *, num_kv_splits: int = 1):
    """One-token absorbed-MLA decode against the paged latent pool.

    pool: {"kv"} [P+1, page, 1, r_kv+rope] holding [ckv | k_rope] — ONE
    shared pool (models/kv_pages.paged_mla_pool_spec): the query is
    [q_absorbed | q_rope] against the full row and values are the leading
    r_kv columns, so each page is read from HBM exactly once
    (share_kv mode of kernels/decode_attention). Returns (y, new_pool)."""
    from repro.kernels import ops as KOPS
    from repro.models.kv_pages import write_token
    m = cfg.mla
    positions = kv_lens[:, None]                           # [B, 1]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    kv = x @ p["wkv_a"]                                    # [B, 1, r_kv+rope]
    ckv = rmsnorm(kv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions,
                        cfg.attn.rope_base, 1.0)[:, :, 0]  # [B, 1, rope]
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    row = jnp.concatenate([ckv, k_rope], axis=-1)[:, 0][:, None]  # [B,1,width]
    kvp = write_token(pool["kv"], row, page_tbl, kv_lens)
    q_abs = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"])  # absorb W_uk
    qcat = jnp.concatenate([q_abs, q_rope], axis=-1)[:, 0]   # [B, H, r+rope]
    eff = kv_lens + active
    ctx = KOPS.paged_decode_attention(qcat, kvp, None, page_tbl, eff,
                                      scale=scale, num_kv_splits=num_kv_splits,
                                      dv=m.kv_lora_rank)     # [B, H, r] f32
    o = jnp.einsum("bhr,rhk->bhk", ctx.astype(x.dtype), p["wv_b"])  # absorb W_uv
    y = jnp.einsum("bqhk,hkd->bqd", o[:, None], p["wo"])
    return y, {"kv": kvp}


def mla_attention(p, x, cfg: ArchConfig, mesh, *, positions=None,
                  cache: MLACache | None = None):
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.padded_heads()
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cache is not None:
            positions = positions + cache.length
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5

    kv = x @ p["wkv_a"]                                # [B,S,r_kv+rope]
    ckv = rmsnorm(kv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions,
                        cfg.attn.rope_base, 1.0)[:, :, 0]   # [B,S,rope]
    q_nope, q_rope = _q_proj(p, x, cfg, positions)

    if cache is None:
        from repro.models.attention import CHUNKED_ATTN_THRESHOLD
        if S >= CHUNKED_ATTN_THRESHOLD:
            # chunked online softmax WITH per-chunk latent decompression:
            # the full per-head K/V ([B,S,H,d]) never materializes — only the
            # compressed ckv ([B,S,r_kv]) is resident, the MLA memory win at
            # prefill (docs/EXPERIMENTS.md §Perf M1).
            o = _mla_chunked(p, q_nope, q_rope, ckv, k_rope, scale, x.dtype,
                             chunk=cfg.attn.kv_chunk)
        else:
            k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_b"])
            v = jnp.einsum("bsr,rhk->bshk", ckv, p["wv_b"])
            sn = jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope,
                            preferred_element_type=jnp.float32)
            sr = jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope,
                            preferred_element_type=jnp.float32)
            s = (sn + sr) * scale
            q_pos = jnp.arange(S)
            mask = q_pos[None, :] <= q_pos[:, None]    # [Sk<=Sq] causal
            s = jnp.where(mask.T[None, None], s, -1e30)
            prob = jax.nn.softmax(s, axis=-1).astype(x.dtype)
            o = jnp.einsum("bhqs,bshk->bqhk", prob, v,
                           preferred_element_type=jnp.float32)
        new_cache = None
    else:
        # absorbed decode: score against the compressed cache directly
        ckv_c = jax.lax.dynamic_update_slice(
            cache.ckv, ckv.astype(cache.ckv.dtype), (0, cache.length, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache.krope, k_rope.astype(cache.krope.dtype), (0, cache.length, 0))
        new_len = cache.length + S
        q_abs = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"])  # absorb W_uk
        s = (_dot32("bqhr,bsr->bhqs", q_abs, ckv_c) +
             _dot32("bqhk,bsk->bhqs", q_rope, kr_c)) * scale
        k_pos = jnp.arange(ckv_c.shape[1])
        mask = (k_pos[None] <= positions[0][:, None]) & (k_pos < new_len)[None]
        s = jnp.where(mask[None, None], s, -1e30)
        prob = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx = _dot32("bhqs,bsr->bqhr", prob, ckv_c)
        o = jnp.einsum("bqhr,rhk->bqhk", ctx.astype(x.dtype), p["wv_b"])  # absorb W_uv
        new_cache = MLACache(ckv=ckv_c, krope=kr_c, length=new_len)

    y = jnp.einsum("bqhk,hkd->bqd", o.astype(x.dtype), p["wo"])
    return y, new_cache
