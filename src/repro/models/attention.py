"""GQA/MHA attention with RoPE, sliding windows, logit softcap, KV caches.

Train/prefill: full causal (optionally windowed) attention, fp32 scores.
Decode: one-token query against a static-capacity KV cache updated with
``dynamic_update_slice``; the cache's sequence axis carries a logical sharding
axis ("kv_seq" / "kv_seq_long"), so on the production mesh the scores/softmax
reduce over a sharded axis and GSPMD inserts the split-KV all-reduces
(flash-decoding's parallelism, expressed declaratively).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, AttnSpec
from repro.models.layers import apply_rope, rmsnorm
from repro.parallel.sharding import ParamSpec, constrain


def attn_spec(cfg: ArchConfig, dtype=None):
    a = cfg.attn
    dtype = dtype or cfg.dtype
    d, hq, hkv, hd = cfg.d_model, cfg.padded_heads(), a.n_kv, a.head_dim
    sp = dict(
        wq=ParamSpec((d, hq, hd), dtype, ("embed", "heads", None)),
        wk=ParamSpec((d, hkv, hd), dtype, ("embed", "kv_heads", None)),
        wv=ParamSpec((d, hkv, hd), dtype, ("embed", "kv_heads", None)),
        wo=ParamSpec((hq, hd, d), dtype, ("heads", None, "embed")),
    )
    if a.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), dtype, (None,), init="ones")
        sp["k_norm"] = ParamSpec((hd,), dtype, (None,), init="ones")
    return sp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array          # [B, S_max, n_kv, hd]
    v: jax.Array          # [B, S_max, n_kv, hd]
    length: jax.Array     # [] int32 — filled prefix


def kv_cache_spec(cfg: ArchConfig, batch: int, max_len: int, *,
                  long: bool = False, n_kv: int | None = None,
                  head_dim: int | None = None):
    a = cfg.attn
    seq_ax = "kv_seq_long" if long else "kv_seq"
    n_kv = n_kv or a.n_kv
    hd = head_dim or a.head_dim
    arr = ParamSpec((batch, max_len, n_kv, hd), cfg.dtype,
                    ("batch", seq_ax, "kv_heads", None))
    return KVCache(k=arr, v=arr,
                   length=ParamSpec((), jnp.int32, (), init="zeros"))


def _scores_mask(q_pos, k_pos, window):
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


# Sequence length at/above which training/prefill attention switches to the
# chunked online-softmax dataflow (flash attention expressed in XLA): the
# [Sq, Sk] score matrix never materializes to HBM — per-chunk tiles live in
# registers/VMEM after fusion. Dropped the prefill memory roofline term ~9x
# on the minicpm3 prefill_32k cell (docs/EXPERIMENTS.md §Perf M1).
CHUNKED_ATTN_THRESHOLD = 2048


def _sdpa_chunked(q, k, v, softcap, scale, window, chunk=1024):
    """Causal grouped attention with online softmax over KV chunks.

    The chunk width comes from ``AttnSpec.kv_chunk`` at model call sites
    (page-size-aligned in the paged serving engine). Ragged tails
    (Sk % chunk != 0) are zero-padded and masked out exactly."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, Sq, Hkv, G, hd)
    q_pos = jnp.arange(Sq)
    n = (Sk + pad) // chunk
    kc = k.reshape(B, n, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        ci, (k_c, v_c) = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_c,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = ci * chunk + jnp.arange(chunk)
        msk = (k_pos[None, :] <= q_pos[:, None]) & (k_pos < Sk)[None, :]
        if window is not None:
            msk &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(msk[None, None, None], s, -1e30)
        m2 = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + p.sum(-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(q.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m2, l2, acc2), None

    m0 = jnp.full((B, Hkv, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    # full unroll: keeps the dry-run cost accounting exact (a while-loop body
    # would be counted once) and matches how flash kernels pipeline chunks.
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(n), (kc, vc)), unroll=True)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, hd).astype(q.dtype)


def _sdpa(q, k, v, mask, softcap, scale):
    """q: [B,Sq,Hq,hd], k/v: [B,Sk,Hkv,hd] — grouped attention.

    Scores accumulate in f32 via preferred_element_type (the MXU-native form)
    WITHOUT materializing f32 copies of K/V — casting the cache would double
    decode HBM traffic (measured: 39.6->21GB bytes-accessed on the
    internlm2 decode_32k cell, see docs/EXPERIMENTS.md §Perf)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)


def paged_attention(p, x, cfg: ArchConfig, mesh, pool, page_tbl, kv_lens,
                    active, *, num_kv_splits: int = 1,
                    attn: AttnSpec | None = None):
    """One-token decode attention against the paged KV pool.

    x: [B, 1, D]; pool: {"k", "v"} [P+1, page, n_kv, hd] (models/kv_pages);
    page_tbl: [B, max_pages] int32 (pad entries = P); kv_lens: [B] int32
    tokens already held; active: [B] int32 0/1. Writes this token's K/V at
    (tbl[b, len//page], len % page), then runs the split-KV paged decode
    kernel over len + active positions (idle rows attend over nothing and
    return exact zeros). Returns (y [B, 1, D], new_pool)."""
    a = attn or cfg.attn
    if a.window is not None:
        raise NotImplementedError("paged decode attention does not support "
                                  "sliding-window layers")
    if a.logit_softcap is not None:
        raise NotImplementedError("paged decode attention does not support "
                                  "logit softcap")
    from repro.kernels import ops as KOPS
    from repro.models.kv_pages import write_token
    positions = kv_lens[:, None]                           # [B, 1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if a.rope_fraction > 0:
        q = apply_rope(q, positions, a.rope_base, a.rope_fraction)
        k = apply_rope(k, positions, a.rope_base, a.rope_fraction)
    q = constrain(q, mesh, "batch", None, "heads", None)
    kp = write_token(pool["k"], k[:, 0], page_tbl, kv_lens)
    vp = write_token(pool["v"], v[:, 0], page_tbl, kv_lens)
    eff = kv_lens + active            # just-written token counts iff active
    out = KOPS.paged_decode_attention(q[:, 0], kp, vp, page_tbl, eff,
                                      scale=a.head_dim ** -0.5,
                                      num_kv_splits=num_kv_splits)
    out = out.astype(x.dtype)[:, None]                     # [B, 1, Hq, hd]
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": kp, "v": vp}


def attention(p, x, cfg: ArchConfig, mesh, *, positions=None,
              cache: KVCache | None = None, window: int | None = "cfg",
              attn: AttnSpec | None = None, kv_override=None,
              causal: bool = True):
    """Returns (out [B,S,D], new_cache)."""
    a = attn or cfg.attn
    if window == "cfg":
        window = a.window
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cache is not None:
            positions = positions + cache.length

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    else:  # cross-attention: kv computed from encoder memory by the caller
        k, v = kv_override
    if a.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if a.rope_fraction > 0 and kv_override is None:
        q = apply_rope(q, positions, a.rope_base, a.rope_fraction)
        k = apply_rope(k, positions, a.rope_base, a.rope_fraction)
    q = constrain(q, mesh, "batch", None, "heads", None)
    scale = a.head_dim ** -0.5

    if cache is None and kv_override is None:
        if causal and S >= CHUNKED_ATTN_THRESHOLD:
            if (a.logit_softcap is None and jax.default_backend() == "tpu"
                    and S % 128 == 0):
                from repro.kernels import ops as KOPS
                out = KOPS.flash_attention_bshd(q, k, v, scale=scale,
                                                window=window)
            else:
                out = _sdpa_chunked(q, k, v, a.logit_softcap, scale, window,
                                    chunk=a.kv_chunk)
        else:
            q_pos = jnp.arange(S)
            mask = (_scores_mask(q_pos, q_pos, window) if causal
                    else jnp.ones((S, S), bool))
            out = _sdpa(q, k, v, mask, a.logit_softcap, scale)
        new_cache = None
    elif kv_override is not None:
        Sk = k.shape[1]
        mask = jnp.ones((S, Sk), bool)     # full cross-attention
        out = _sdpa(q, k, v, mask, a.logit_softcap, scale)
        new_cache = None
    else:
        # decode: append to cache, attend over the filled prefix
        kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, cache.length, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, cache.length, 0, 0))
        new_len = cache.length + S
        k_pos = jnp.arange(kc.shape[1])
        valid = k_pos < new_len
        q_pos = positions[0]               # [S]
        mask = _scores_mask(q_pos, k_pos, window) & valid[None, :]
        out = _sdpa(q, kc, vc, mask, a.logit_softcap, scale)
        new_cache = KVCache(k=kc, v=vc, length=new_len)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache
