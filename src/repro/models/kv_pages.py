"""Paged KV cache: page-table-indexed pools + host-side free-list allocator.

The dense decode caches (``KVCache`` [B, S_max, ...], ``MLACache``) reserve
``batch × max_len`` tokens of HBM up front whether or not a slot is live.
The paged layout replaces them with a shared pool of fixed-size pages:

  pool      [num_pages + 1, page_size, Hkv, d]   (device, per layer)
  page_tbl  [B, max_pages] int32                  (host-built, per step)
  kv_lens   [B] int32                             (host-built, per step)

Row ``num_pages`` is the PAD page: idle slots and unallocated table entries
point at it, keeping every gather branch-free and jit-stable. The pad page's
content is irrelevant by construction — the decode kernel masks positions
``>= kv_lens`` with an exact zero (kernels/decode_attention.py), so neither
pad nor recycled-page garbage can perturb a live request. Memory now scales
with LIVE tokens (pages allocated) instead of ``batch × max_len``
(bench_memory's paged-KV accounting rows assert paged peak <= dense peak).

Allocation is host-side and strictly step-boundary (runtime/scheduler.py):
pages alloc when a request's next token crosses a page boundary, free when
the request completes. The allocator is a LIFO free list — recycling hot
pages quickly is deliberate, it stresses the masking contract that the
paged-KV tests pin.

GQA layers keep separate K and V pools; absorbed-MLA decode uses ONE pool
per layer holding [ckv | k_rope] rows (Hkv == 1) — values are the leading
``kv_lora_rank`` columns, so each page is read from HBM exactly once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.parallel.sharding import ParamSpec


class PagePoolExhausted(RuntimeError):
    """Raised when an alloc cannot be satisfied — always names the pool
    capacity so the failure is actionable (raise num_pages or admit less)."""


class PageAllocator:
    """Host-side LIFO free-list allocator over ``num_pages`` page ids.

    Invariants (pinned by tests/test_paged_kv.py): a page id is never handed
    to two live owners; double-free raises; exhaustion raises
    ``PagePoolExhausted`` naming the capacity."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"need num_pages >= 1 and page_size >= 1, got "
                             f"{num_pages}, {page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.pad_page = self.num_pages          # pool row used for idle slots
        self._free = list(range(num_pages - 1, -1, -1))   # pop() yields 0 first
        self._live: set[int] = set()
        self.peak_live = 0                      # high-water mark (bench_memory)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def live_count(self) -> int:
        return len(self._live)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise PagePoolExhausted(
                f"page pool exhausted: requested {n} page(s) with "
                f"{len(self._free)} free of {self.num_pages} total "
                f"(page_size={self.page_size}); raise num_pages or lower "
                f"admission concurrency")
        ids = [self._free.pop() for _ in range(n)]
        self._live.update(ids)
        self.peak_live = max(self.peak_live, len(self._live))
        return ids

    def free(self, ids) -> None:
        for i in ids:
            if i not in self._live:
                raise ValueError(f"free of page {i} which is not live")
            self._live.remove(i)
            self._free.append(i)


def pages_for_tokens(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` KV entries."""
    return -(-tokens // page_size)


# --------------------------------------------------------------------------
# pool specs (per layer; the transformer stacks them with _stack)
# --------------------------------------------------------------------------

def paged_kv_pool_spec(cfg: ArchConfig, num_pages: int, page_size: int):
    """GQA per-layer pools: {"k", "v"} each [num_pages+1, page, n_kv, hd].
    Row num_pages is the pad page (init zeros, like the whole pool)."""
    a = cfg.attn
    arr = ParamSpec((num_pages + 1, page_size, a.n_kv, a.head_dim), cfg.dtype,
                    (None, None, "kv_heads", None))
    return {"k": arr, "v": arr}


def paged_mla_pool_spec(cfg: ArchConfig, num_pages: int, page_size: int):
    """Absorbed-MLA per-layer pool: {"kv"} [num_pages+1, page, 1, r_kv+rope]
    holding [ckv | k_rope] — one shared pool, values = leading r_kv cols."""
    m = cfg.mla
    width = m.kv_lora_rank + m.qk_rope_dim
    return {"kv": ParamSpec((num_pages + 1, page_size, 1, width), cfg.dtype,
                            (None, None, None, None))}


def write_token(pool: jax.Array, new: jax.Array, page_tbl: jax.Array,
                kv_lens: jax.Array) -> jax.Array:
    """Scatter one decode token's KV row per request into the pool.

    pool: [P+1, page, Hkv, d]; new: [B, Hkv, d] (this step's k/v/latent row);
    page_tbl: [B, max_pages] int32; kv_lens: [B] int32 tokens already held.
    The write lands at (tbl[b, kv_lens[b] // page], kv_lens[b] % page). Idle
    slots carry all-pad tables, so their rows land in the pad page — every
    idle row computes the identical value (same token-0 input), so the
    duplicate scatter is deterministic, and pad content is masked out of
    every live request's attention anyway."""
    B, max_pages = page_tbl.shape
    page = pool.shape[1]
    ord_ = jnp.clip(kv_lens // page, 0, max_pages - 1)
    page_ids = jnp.take_along_axis(page_tbl, ord_[:, None], axis=1)[:, 0]
    offs = kv_lens % page
    return pool.at[page_ids, offs].set(new.astype(pool.dtype))


def dense_equiv_tokens(batch: int, max_len: int) -> int:
    """Token capacity a dense [B, S_max] cache reserves — the baseline the
    paged accounting rows compare against (bench_memory)."""
    return batch * max_len
